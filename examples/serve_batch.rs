//! End-to-end serving driver (DESIGN.md's required e2e validation): start
//! the HTTP server on a real small model, fire concurrent client load at
//! it, and report latency/throughput — wall-clock for the harness and
//! simulated local-PC numbers from the DALI scheduler.
//!
//!     cargo run --release --example serve_batch -- \
//!         [--preset mixtral-sim] [--clients 8] [--requests 16] [--tokens 8]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use dali::coordinator::frameworks::Framework;
use dali::serve::batcher::BatcherCfg;
use dali::serve::http::http_call;
use dali::serve::server::serve_background;
use dali::util::json::Value;
use dali::util::Args;
use dali::workload::corpus::{CorpusGen, TaskProfile};

fn main() -> Result<()> {
    let args = Args::from_env();
    let preset = args.str_or("preset", "mixtral-sim");
    let clients = args.usize_or("clients", 8);
    let total_requests = args.usize_or("requests", 16);
    let max_tokens = args.usize_or("tokens", 8);
    let prompt_len = 8;

    println!("starting server for {preset}...");
    let port = serve_background(
        &preset,
        Framework::Dali,
        BatcherCfg { max_batch: 8, ..Default::default() },
    )?;
    let addr = format!("127.0.0.1:{port}");
    println!("server up at http://{addr}");
    let health = http_call(&addr, "GET", "/health", None)?;
    println!("health: {health}");

    // concurrent clients
    let vocab = 512;
    let counter = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let mut handles = vec![];
    let latencies = Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
    let sims = Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
    for c in 0..clients {
        let addr = addr.clone();
        let counter = counter.clone();
        let latencies = latencies.clone();
        let sims = sims.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut gen = CorpusGen::new(vocab, TaskProfile::c4(), 900 + c as u64);
            loop {
                let i = counter.fetch_add(1, Ordering::SeqCst);
                if i >= total_requests {
                    return Ok(());
                }
                let prompt = gen.sequence(prompt_len);
                let body = Value::obj(vec![
                    (
                        "prompt",
                        Value::arr(prompt.iter().map(|&t| Value::num(t as f64)).collect()),
                    ),
                    ("max_tokens", Value::num(max_tokens as f64)),
                ]);
                let t = Instant::now();
                let resp = http_call(&addr, "POST", "/generate", Some(&body.to_json()))?;
                let wall = t.elapsed().as_secs_f64() * 1e3;
                let v = Value::parse(&resp)?;
                let ntok = v.get("tokens")?.as_arr()?.len();
                assert_eq!(ntok, max_tokens, "short generation");
                latencies.lock().unwrap().push(wall);
                sims.lock().unwrap().push(v.get("sim_tokens_per_s")?.as_f64()?);
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread")?;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let mut lat = latencies.lock().unwrap().clone();
    lat.sort_by(|a, b| a.total_cmp(b));
    let p = |q: f64| lat[((lat.len() - 1) as f64 * q) as usize];
    let sims = sims.lock().unwrap();
    let avg_sim_tps = sims.iter().sum::<f64>() / sims.len() as f64;

    println!("\n=== serve_batch results ===");
    println!("requests          : {total_requests} x {max_tokens} tokens, {clients} concurrent clients");
    println!("harness wall time : {wall_s:.2}s  ({:.1} tokens/s wall)",
        (total_requests * max_tokens) as f64 / wall_s);
    println!("client latency    : p50 {:.0}ms  p90 {:.0}ms  p99 {:.0}ms", p(0.5), p(0.9), p(0.99));
    println!("simulated decode  : {avg_sim_tps:.2} tokens/s on the paper's local PC (DALI)");
    let metrics = http_call(&addr, "GET", "/metrics", None)?;
    println!("server metrics    : {metrics}");
    Ok(())
}
