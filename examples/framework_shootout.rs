//! Framework shootout: decode the same workload under all six systems
//! (llama.cpp, KTransformers, Fiddler, MoE-Lightning, HybriMoE, DALI) and
//! print the comparison table — a one-command miniature of paper Fig. 12.
//!
//!     cargo run --release --example framework_shootout -- \
//!         [--preset deepseek-sim] [--batch 16] [--steps 32]

use anyhow::Result;
use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::replay_decode;
use dali::hw::CostModel;
use dali::util::{Args, Table};
use dali::workload::prep;

fn main() -> Result<()> {
    let args = Args::from_env();
    let preset = args.str_or("preset", "deepseek-sim");
    let batch = args.usize_or("batch", 16);
    let steps = args.usize_or("steps", 32);

    let presets = Presets::load_default()?;
    let model = presets.model(&preset)?;
    let cost = CostModel::new(model, presets.hw("local-pc")?);
    let calib = prep::ensure_calib(&preset)?;
    let trace = prep::ensure_trace(&preset, "c4-sim", 32, 16, 64)?;
    let cfg = FrameworkCfg::paper_default(&model.sim);
    let seq_ids: Vec<usize> = (0..batch).collect();

    let mut frameworks = vec![Framework::Naive, Framework::Fiddler];
    frameworks.extend(Framework::comparison_set());

    let mut table = Table::new(vec![
        "framework", "tokens/s", "vs naive", "cache hit", "PCIe GB", "sched %",
    ]);
    let mut naive_tps = 0.0;
    for fw in frameworks {
        let bundle = fw.bundle(&model.sim, &cost, &calib.freq, &cfg);
        let m = replay_decode(
            &trace, &seq_ids, steps, &cost, bundle, &calib.freq, model.sim.n_shared, 7,
        );
        let tps = m.tokens_per_s();
        if fw == Framework::Naive {
            naive_tps = tps;
        }
        table.row(vec![
            fw.name().to_string(),
            format!("{tps:.2}"),
            format!("{:.2}x", tps / naive_tps.max(1e-9)),
            format!("{:.1}%", 100.0 * m.cache_hit_rate()),
            format!("{:.2}", m.pcie_total_bytes() as f64 / 1e9),
            format!("{:.2}", 100.0 * m.sched_share()),
        ]);
    }
    println!("decode shootout: {preset}, batch {batch}, {steps} steps (simulated local PC)\n");
    table.print();
    Ok(())
}
