//! Quickstart: load a preset, run live MoE inference with DALI's scheduler,
//! and print generated tokens + simulated local-PC performance.
//!
//!     cargo run --release --example quickstart -- [--preset mixtral-sim]
//!
//! Requires `make artifacts`. Demonstrates the full public API surface:
//! presets → engine → calibration → live batch → virtual-time metrics.

use anyhow::Result;
use dali::config::Presets;
use dali::coordinator::engine::InferenceEngine;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::{Phase, StepSimulator};
use dali::hw::CostModel;
use dali::util::{fmt_ns, Args};
use dali::workload::corpus::{CorpusGen, TaskProfile};
use dali::workload::prep;

fn main() -> Result<()> {
    let args = Args::from_env();
    let preset = args.str_or("preset", "mixtral-sim");
    let batch = args.usize_or("batch", 4);
    let steps = args.usize_or("steps", 12);

    // 1. configuration: scaled sim model + paper-scale hardware model
    let presets = Presets::load_default()?;
    let model = presets.model(&preset)?;
    let hw = presets.hw("local-pc")?;
    let cost = CostModel::new(model, hw);
    println!("model   : {} ({} layers, {} experts, top-{})",
        model.display, model.sim.layers, model.sim.n_routed, model.sim.top_k);
    println!("hardware: {} (expert transfer {} over PCIe)",
        hw.display, fmt_ns(cost.trans_time()));

    // 2. offline calibration (residual vectors, Eq. 11) — cached on disk
    let calib = prep::ensure_calib(&preset)?;
    println!("calib   : {} tokens, {} residual vectors", calib.tokens, calib.res_vec.len());

    // 3. live inference with trace recording (real PJRT numerics)
    let engine = InferenceEngine::new(&preset)?;
    let mut gen = CorpusGen::new(model.sim.vocab, TaskProfile::c4(), 1234);
    let prompts = gen.batch(batch, 8);
    let out = engine.run_batch(&prompts, steps, true)?;
    for (i, g) in out.generated.iter().enumerate() {
        println!("seq {i}: prompt {:?} → generated {:?}", prompts[i], g);
    }

    // 4. virtual-time pass: what would this cost on the paper's local PC?
    let trace = out.trace.unwrap();
    let cfg = FrameworkCfg::paper_default(&model.sim);
    let bundle = Framework::Dali.bundle(&model.sim, &cost, &calib.freq, &cfg);
    let mut sim = StepSimulator::new(
        &cost, bundle, &calib.freq,
        model.sim.layers, model.sim.n_routed, model.sim.n_shared, 7,
    );
    let ids: Vec<usize> = (0..batch).collect();
    sim.run_step(&trace.compose_prefill(&ids), 4, Phase::Prefill);
    sim.reset_metrics();
    for s in 0..trace.min_steps() {
        sim.run_step(&trace.compose_decode(&ids, s), 8 + s, Phase::Decode);
    }
    let m = sim.finish();
    println!("--- simulated local-PC decode ---");
    println!("decode speed   : {:.2} tokens/s", m.tokens_per_s());
    println!("virtual time   : {}", fmt_ns(m.total_ns));
    println!("cache hit rate : {:.1}%", 100.0 * m.cache_hit_rate());
    println!("PCIe busy      : {:.1}% of time", 100.0 * m.pcie_time_share());
    Ok(())
}
