//! Trace replay: watch DALI's Workload-Aware cache adapt to a sequence's
//! domain over time (the paper's Fig. 18d behaviour), then compare cache
//! policies on the same trace.
//!
//!     cargo run --release --example trace_replay -- [--preset mixtral-sim]

use anyhow::Result;
use dali::config::Presets;
use dali::coordinator::assignment::GreedyAssigner;
use dali::coordinator::cache::{LruCache, ScoreCache, WorkloadAwareCache};
use dali::coordinator::prefetch::NoPrefetcher;
use dali::coordinator::simrun::{Phase, PolicyBundle, StepSimulator};
use dali::hw::CostModel;
use dali::util::{Args, Table};
use dali::workload::prep;

fn main() -> Result<()> {
    let args = Args::from_env();
    let preset = args.str_or("preset", "mixtral-sim");
    let batch = args.usize_or("batch", 4);

    let presets = Presets::load_default()?;
    let model = presets.model(&preset)?;
    let dims = model.sim.clone();
    let cost = CostModel::new(model, presets.hw("local-pc")?);
    let calib = prep::ensure_calib(&preset)?;
    let trace = prep::ensure_trace(&preset, "wikitext-sim", 16, 16, 48)?;
    let seq_ids: Vec<usize> = (0..batch).collect();
    let cache_size = (dims.n_routed / 2).max(1);

    // --- hit rate as the sequence progresses (Fig. 18d style) ---------------
    println!("cache hit rate vs token position ({preset}, workload-aware cache):\n");
    let bundle = PolicyBundle {
        assigner: Box::new(GreedyAssigner::new()),
        prefetcher: Box::new(NoPrefetcher),
        cache: Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, cache_size, 8, 1, 3)),
        prefetch_size: 0,
        cpu_eff: 1.0,
        layer_overhead_ns: 0,
        gpu_free_slots: dims.n_routed,
        solve_cost: Default::default(),
        placement: Default::default(),
    };
    let mut sim = StepSimulator::new(
        &cost, bundle, &calib.freq, dims.layers, dims.n_routed, dims.n_shared, 5,
    );
    sim.run_step(&trace.compose_prefill(&seq_ids), 8, Phase::Prefill);
    sim.reset_metrics();
    let group = 8;
    let mut last = (0u64, 0u64);
    for s in 0..trace.min_steps() {
        sim.run_step(&trace.compose_decode(&seq_ids, s), 16 + s, Phase::Decode);
        if (s + 1) % group == 0 {
            let hits = sim.metrics.cache_hits - last.0;
            let looks = sim.metrics.cache_lookups - last.1;
            last = (sim.metrics.cache_hits, sim.metrics.cache_lookups);
            let rate = if looks > 0 { hits as f64 / looks as f64 } else { 0.0 };
            let bar = "#".repeat((rate * 40.0) as usize);
            println!("tokens {:3}-{:3}: {:5.1}%  {bar}", s + 2 - group, s + 1, rate * 100.0);
        }
    }

    // --- policy comparison on the same trace ---------------------------------
    println!("\ncache policy comparison (same trace, same assignment):\n");
    let mut table = Table::new(vec!["policy", "hit rate", "tokens/s"]);
    for which in ["lru", "score", "workload_aware"] {
        let cache: Box<dyn dali::coordinator::cache::ExpertCache> = match which {
            "lru" => Box::new(LruCache::new(dims.layers, dims.n_routed, cache_size, 3)),
            "score" => Box::new(ScoreCache::new(dims.layers, dims.n_routed, cache_size, 3)),
            _ => Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, cache_size, 4, 1, 3)),
        };
        let bundle = PolicyBundle {
            assigner: Box::new(GreedyAssigner::new()),
            prefetcher: Box::new(NoPrefetcher),
            cache,
            prefetch_size: 0,
            cpu_eff: 1.0,
            layer_overhead_ns: 0,
            gpu_free_slots: dims.n_routed,
            solve_cost: Default::default(),
            placement: Default::default(),
        };
        let m = dali::coordinator::simrun::replay_decode(
            &trace, &seq_ids, 48, &cost, bundle, &calib.freq, dims.n_shared, 5,
        );
        table.row(vec![
            which.to_string(),
            format!("{:.1}%", 100.0 * m.cache_hit_rate()),
            format!("{:.2}", m.tokens_per_s()),
        ]);
    }
    table.print();
    Ok(())
}
