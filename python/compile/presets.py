"""Loader for configs/presets.json — the shared python/rust config source."""

import json
import os
from dataclasses import dataclass

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
PRESETS_PATH = os.path.join(_REPO_ROOT, "configs", "presets.json")


@dataclass(frozen=True)
class ModelPreset:
    name: str
    layers: int
    hidden: int
    heads: int
    head_dim: int
    n_routed: int
    top_k: int
    n_shared: int
    moe_inter: int
    vocab: int
    max_seq: int

    @property
    def n_experts(self) -> int:
        """Routed + shared experts per layer."""
        return self.n_routed + self.n_shared


def load_raw() -> dict:
    with open(PRESETS_PATH) as f:
        return json.load(f)


def load_preset(name: str) -> ModelPreset:
    raw = load_raw()["models"][name]["sim"]
    return ModelPreset(name=name, **raw)


def preset_names() -> list:
    return sorted(load_raw()["models"].keys())


def buckets() -> dict:
    return load_raw()["buckets"]
