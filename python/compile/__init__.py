"""Build-time compile package: Layer-2 JAX model + Layer-1 Pallas kernels + AOT.

Never imported at runtime — `make artifacts` runs `python -m compile.aot`
once, and the Rust binary is self-contained afterwards.
"""
