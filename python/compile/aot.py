"""AOT compile path: lower every Layer-2 function to HLO *text* artifacts.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Per preset this writes

    artifacts/<preset>/
      embed_t{T}.hlo.txt  gate_t{T}.hlo.txt  expert_t{T}.hlo.txt
      head_t{T}.hlo.txt   attn_prefill_s{S}.hlo.txt attn_decode_b{B}.hlo.txt
      weights/<name>.bin          # flat f32 little-endian
      manifest.json               # dims, buckets, artifact + weight index
      golden.json                 # python-reference activations for rust tests

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .presets import buckets, load_preset, preset_names


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def emit_preset(p, out_dir: str, bk: dict, quick: bool) -> dict:
    """Lower all artifacts for one preset; returns the manifest dict."""
    d, f, n, v = p.hidden, p.moe_inter, p.n_routed, p.vocab
    os.makedirs(out_dir, exist_ok=True)
    t_buckets = bk["tokens"][:4] if quick else bk["tokens"]
    s_buckets = bk["prefill_seq"][:2] if quick else bk["prefill_seq"]
    b_buckets = bk["decode_batch"][:2] if quick else bk["decode_batch"]
    artifacts = {}

    def emit(name, fn, *specs):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        t0 = time.time()
        text = lower(fn, *specs)
        with open(path, "w") as fh:
            fh.write(text)
        artifacts[name] = os.path.basename(path)
        print(f"  {name}: {len(text)} chars ({time.time() - t0:.2f}s)")

    for t in t_buckets:
        emit("embed_t%d" % t, M.embed, i32(t), i32(t), f32(v, d), f32(p.max_seq, d))
        emit("gate_t%d" % t, M.gate, f32(t, d), f32(d), f32(d, n))
        emit("expert_t%d" % t, M.expert, f32(t, d), f32(d, f), f32(f, d), f32(d, f))
        emit("head_t%d" % t, M.head, f32(t, d), f32(d), f32(v, d))
    ap = partial(M.attn_prefill, heads=p.heads, head_dim=p.head_dim)
    for s in s_buckets:
        emit(
            "attn_prefill_s%d" % s,
            ap,
            f32(s, d), f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d),
        )
    ad = partial(M.attn_decode, heads=p.heads, head_dim=p.head_dim)
    cache = f32(0, p.max_seq, p.heads, p.head_dim)
    for b in b_buckets:
        cache = f32(b, p.max_seq, p.heads, p.head_dim)
        emit(
            "attn_decode_b%d" % b,
            ad,
            f32(b, d), cache, cache, i32(b),
            f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d),
        )

    # --- weights -----------------------------------------------------------
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)
    weights = M.gen_weights(p)
    windex = {}
    for name, arr in weights.items():
        fname = name.replace("/", "_") + ".bin"
        arr.astype("<f4").tofile(os.path.join(wdir, fname))
        windex[name] = {"file": f"weights/{fname}", "shape": list(arr.shape)}

    manifest = {
        "preset": p.name,
        "dims": {
            "layers": p.layers, "hidden": d, "heads": p.heads,
            "head_dim": p.head_dim, "n_routed": n, "top_k": p.top_k,
            "n_shared": p.n_shared, "moe_inter": f, "vocab": v,
            "max_seq": p.max_seq,
        },
        "buckets": {
            "tokens": t_buckets, "prefill_seq": s_buckets,
            "decode_batch": b_buckets,
        },
        "artifacts": artifacts,
        "weights": windex,
        "golden": "golden.json",
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    return manifest


def emit_golden(p, out_dir: str, quick: bool) -> None:
    """Run the python reference end-to-end on tiny fixed inputs and record
    activations for the rust integration tests."""
    w = {k: jnp.asarray(v) for k, v in M.gen_weights(p).items()}
    rng = np.random.default_rng(7)
    seqs = [rng.integers(0, p.vocab, size=8).tolist() for _ in range(2)]
    decode_steps = 2 if quick else 4
    golden = {"prompts": seqs, "decode_steps": decode_steps, "sequences": []}
    for tokens in seqs:
        x, kv, route_log = M.forward_prefill_ref(p, w, np.asarray(tokens))
        logits = M.head(x, w["final.norm"], w["embed.table"])
        entry = {
            "prefill_routes": [r.tolist() for r in route_log],
            "prefill_last_logits8": np.asarray(logits[-1][:8]).round(5).tolist(),
            "decode": [],
        }
        pos = len(tokens)
        tok = int(np.argmax(np.asarray(logits[-1])))
        for _ in range(decode_steps):
            logit, routes = M.forward_decode_ref(p, w, kv, tok, pos)
            entry["decode"].append(
                {
                    "token_in": tok,
                    "pos": pos,
                    "routes": [r.tolist() for r in routes],
                    "logits8": logit[:8].round(5).tolist(),
                    "argmax": int(np.argmax(logit)),
                }
            )
            tok = int(np.argmax(logit))
            pos += 1
        golden["sequences"].append(entry)
    with open(os.path.join(out_dir, "golden.json"), "w") as fh:
        json.dump(golden, fh)
    print(f"  golden.json written ({decode_steps} decode steps x 2 seqs)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", action="append", help="subset of presets")
    ap.add_argument("--quick", action="store_true", help="small bucket set (CI)")
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()

    names = args.preset or preset_names()
    bk = buckets()
    for name in names:
        p = load_preset(name)
        out = os.path.join(args.out_dir, name)
        print(f"[aot] preset {name} → {out}")
        emit_preset(p, out, bk, args.quick)
        if not args.skip_golden:
            emit_golden(p, out, args.quick)
    # Stamp file consumed by the Makefile's up-to-date check.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as fh:
        fh.write(json.dumps({"presets": names, "time": time.time()}))
    print("[aot] done")


if __name__ == "__main__":
    main()
