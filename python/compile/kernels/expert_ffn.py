"""Pallas kernel for the MoE expert FFN (SwiGLU) — the paper's compute hot-spot.

The paper's experts run as cuBLAS GEMMs inside CUDA threadblocks. On TPU the
same insight (stream the wide FFN weight matrices through fast on-chip memory
while the token block stays resident) maps onto a Pallas grid:

* grid = (T_tiles, F_tiles), with the FFN-hidden axis F innermost so the
  ``x`` block (T_t × d) stays in VMEM while w1/w3/w2 tiles stream HBM→VMEM —
  the BlockSpec index maps express the overlap the paper gets from CUDA
  streams / shared-memory double buffering.
* tile shapes are chosen as multiples of the 128-lane MXU dimension when the
  problem is large enough (the scaled sim models are smaller, so tiles clamp
  to the full axis; the MXU-utilisation estimate lives in DESIGN.md §Perf).
* the output block is revisited across the F grid axis (innermost, so the
  revisit is consecutive — a Pallas requirement) and accumulated in f32.

Computation (one expert, T tokens):

    y = (silu(x @ w1) * (x @ w3)) @ w2          x: (T, d)   w1, w3: (d, F)
                                                 w2: (F, d)  y: (T, d)
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def expert_ffn_block_plan(tokens: int, hidden: int, inter: int):
    """Pick (T_tile, F_tile) for the kernel grid.

    Prefers MXU-friendly 128 multiples, clamping to the actual axis size for
    the scaled sim models. Returns (t_tile, f_tile, t_tiles, f_tiles).
    """
    t_tile = min(tokens, 128)
    while tokens % t_tile != 0:  # buckets are powers of two, so this is cheap
        t_tile //= 2
    f_tile = min(inter, 128)
    while inter % f_tile != 0:
        f_tile //= 2
    return t_tile, f_tile, tokens // t_tile, inter // f_tile


def _expert_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, *, f_tiles: int):
    """One (t, f) grid step: accumulate the f-slice's contribution to o."""
    f_idx = pl.program_id(1)

    @pl.when(f_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (T_t, d) — resident across the whole f sweep
    up = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    gate = jnp.dot(x, w3_ref[...], preferred_element_type=jnp.float32)
    act = jax.nn.silu(up) * gate  # (T_t, F_t)
    o_ref[...] += jnp.dot(act, w2_ref[...], preferred_element_type=jnp.float32)


def expert_ffn(x: jax.Array, w1: jax.Array, w2: jax.Array, w3: jax.Array) -> jax.Array:
    """SwiGLU expert FFN via Pallas. x: (T, d); w1/w3: (d, F); w2: (F, d)."""
    tokens, hidden = x.shape
    inter = w1.shape[1]
    t_tile, f_tile, t_tiles, f_tiles = expert_ffn_block_plan(tokens, hidden, inter)

    return pl.pallas_call(
        partial(_expert_kernel, f_tiles=f_tiles),
        grid=(t_tiles, f_tiles),
        in_specs=[
            # x: one token tile, full hidden; constant across the f sweep.
            pl.BlockSpec((t_tile, hidden), lambda t, f: (t, 0)),
            # w1 / w3: stream F tiles through VMEM.
            pl.BlockSpec((hidden, f_tile), lambda t, f: (0, f)),
            pl.BlockSpec((hidden, f_tile), lambda t, f: (0, f)),
            # w2: the matching F-tile of the down projection.
            pl.BlockSpec((f_tile, hidden), lambda t, f: (f, 0)),
        ],
        out_specs=pl.BlockSpec((t_tile, hidden), lambda t, f: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((tokens, hidden), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, w1, w3, w2)


def vmem_footprint_bytes(tokens: int, hidden: int, inter: int) -> int:
    """Estimated VMEM working set of one grid step (f32), for DESIGN.md §Perf."""
    t_tile, f_tile, _, _ = expert_ffn_block_plan(tokens, hidden, inter)
    words = (
        t_tile * hidden  # x block
        + 2 * hidden * f_tile  # w1, w3 tiles
        + f_tile * hidden  # w2 tile
        + t_tile * f_tile  # activation
        + t_tile * hidden  # output accumulator
    )
    return 4 * words
