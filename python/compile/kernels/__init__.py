"""Layer-1 Pallas kernels for the DALI reproduction.

Everything here is *build-time only*: kernels are authored in Pallas, verified
against the pure-jnp oracle in `ref.py`, lowered (inside the Layer-2 jax
functions of `compile.model`) to HLO text by `compile.aot`, and executed from
Rust via the PJRT CPU client. Kernels use ``interpret=True`` because the CPU
PJRT plugin cannot run Mosaic custom-calls; on a real TPU the same BlockSpec
structure compiles natively (see DESIGN.md §Hardware-Adaptation).
"""

from .expert_ffn import expert_ffn, expert_ffn_block_plan, vmem_footprint_bytes
from .gate import gate_probs

__all__ = ["expert_ffn", "expert_ffn_block_plan", "vmem_footprint_bytes", "gate_probs"]
