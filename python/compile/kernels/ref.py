"""Pure-jnp oracles for the Pallas kernels. Used by pytest only."""

import jax
import jax.numpy as jnp

RMS_EPS = 1e-6


def expert_ffn_ref(x, w1, w2, w3):
    """SwiGLU expert FFN: (silu(x@w1) * (x@w3)) @ w2."""
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def rmsnorm_ref(h, gamma):
    ms = jnp.mean(h * h, axis=-1, keepdims=True)
    return h * jax.lax.rsqrt(ms + RMS_EPS) * gamma


def gate_probs_ref(h, gamma, wg):
    xn = rmsnorm_ref(h, gamma)
    return jax.nn.softmax(xn @ wg, axis=-1), xn
