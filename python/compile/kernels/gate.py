"""Pallas kernel for the MoE gate: RMSNorm + gate GEMM + softmax, fused.

The gate is latency-critical on the request path (it runs once per layer per
batch step, and a *second* time per layer for residual-based prefetch
prediction — paper §4.2), so it is fused into a single VMEM-resident kernel:
the token block is normalised, multiplied by Wg, and softmaxed without
round-tripping to HBM. Outputs both the gate probabilities and the normalised
activations (the same normalised activations feed the experts, so the norm is
computed exactly once).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RMS_EPS = 1e-6


def _gate_kernel(h_ref, g_ref, wg_ref, probs_ref, xn_ref):
    h = h_ref[...]  # (T_t, d)
    ms = jnp.mean(h * h, axis=-1, keepdims=True)
    xn = h * jax.lax.rsqrt(ms + RMS_EPS) * g_ref[...]
    logits = jnp.dot(xn, wg_ref[...], preferred_element_type=jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)
    xn_ref[...] = xn


def gate_probs(h: jax.Array, gamma: jax.Array, wg: jax.Array):
    """Fused RMSNorm + gate + softmax.

    h: (T, d) raw residual-stream input; gamma: (d,) RMSNorm weight;
    wg: (d, N) gate weight. Returns (probs (T, N), xn (T, d)).
    """
    tokens, hidden = h.shape
    n_exp = wg.shape[1]
    t_tile = min(tokens, 128)
    while tokens % t_tile != 0:
        t_tile //= 2
    t_tiles = tokens // t_tile

    return pl.pallas_call(
        _gate_kernel,
        grid=(t_tiles,),
        in_specs=[
            pl.BlockSpec((t_tile, hidden), lambda t: (t, 0)),
            pl.BlockSpec((hidden,), lambda t: (0,)),
            pl.BlockSpec((hidden, n_exp), lambda t: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((t_tile, n_exp), lambda t: (t, 0)),
            pl.BlockSpec((t_tile, hidden), lambda t: (t, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((tokens, n_exp), jnp.float32),
            jax.ShapeDtypeStruct((tokens, hidden), jnp.float32),
        ),
        interpret=True,
    )(h, gamma, wg)
