"""Layer-2 JAX model: the scaled MoE transformer the Rust engine composes.

Each public function here becomes one AOT artifact (per shape bucket). The
Rust coordinator (Layer 3) owns all *state* (KV caches, residual stream,
expert selection, weighted combination of expert outputs) and calls these
pure functions through PJRT; python is never on the request path.

Decomposition mirrors the paper's execution model: the gate runs first and
its output drives the scheduler (assignment/prefetch/cache), then individual
experts execute on whichever simulated device the scheduler picked — hence
`expert_ffn` is a standalone per-expert artifact rather than a fused MoE
layer.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import expert_ffn, gate_probs
from .kernels.ref import RMS_EPS
from .presets import ModelPreset

# ---------------------------------------------------------------------------
# Model pieces (one artifact each)
# ---------------------------------------------------------------------------


def rmsnorm(h, gamma):
    ms = jnp.mean(h * h, axis=-1, keepdims=True)
    return h * jax.lax.rsqrt(ms + RMS_EPS) * gamma


def embed(tokens, pos, table, pos_table):
    """Token embedding + sinusoidal-style learned position table.

    tokens, pos: (T,) i32; table: (V, d); pos_table: (S_max, d) → (T, d).
    """
    return table[tokens] + pos_table[pos]


def gate(h, gamma, wg):
    """Fused RMSNorm + gate GEMM + softmax (Pallas kernel, paper Eq. 1).

    Returns (probs (T, N), xn (T, d)); `xn` is reused as the expert input so
    the norm is computed exactly once per layer.
    """
    return gate_probs(h, gamma, wg)


def expert(xn, w1, w2, w3):
    """One expert's SwiGLU FFN on its routed token block (Pallas kernel)."""
    return expert_ffn(xn, w1, w2, w3)


def attn_prefill(x, gamma, wq, wk, wv, wo, *, heads, head_dim):
    """Causal self-attention over a full prompt (one sequence).

    x: (S, d). Returns (h (S, d), k (S, H, hd), v (S, H, hd)); h includes the
    residual connection, k/v seed the decode KV cache.
    """
    seq, hidden = x.shape
    xn = rmsnorm(x, gamma)
    q = (xn @ wq).reshape(seq, heads, head_dim)
    k = (xn @ wk).reshape(seq, heads, head_dim)
    v = (xn @ wv).reshape(seq, heads, head_dim)
    scores = jnp.einsum("shd,thd->hst", q, k) / np.sqrt(head_dim)
    causal = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hst,thd->shd", probs, v).reshape(seq, hidden)
    return x + out @ wo, k, v


def attn_decode(x, k_cache, v_cache, pos, gamma, wq, wk, wv, wo, *, heads, head_dim):
    """Single-step attention against the KV cache for a batch of sequences.

    x: (B, d); k_cache/v_cache: (B, S_max, H, hd); pos: (B,) i32 — the index
    this step's token occupies (== current sequence length). Returns
    (h (B, d), k_cache', v_cache') with the new K/V written at `pos`.
    """
    batch, hidden = x.shape
    s_max = k_cache.shape[1]
    xn = rmsnorm(x, gamma)
    q = (xn @ wq).reshape(batch, heads, head_dim)
    k_new = (xn @ wk).reshape(batch, heads, head_dim)
    v_new = (xn @ wv).reshape(batch, heads, head_dim)

    def upd(cache, new, p):
        return jax.lax.dynamic_update_slice_in_dim(cache, new[None], p, axis=0)

    k_cache = jax.vmap(upd)(k_cache, k_new, pos)
    v_cache = jax.vmap(upd)(v_cache, v_new, pos)

    scores = jnp.einsum("bhd,bshd->bhs", q, k_cache) / np.sqrt(head_dim)
    valid = jnp.arange(s_max)[None, :] <= pos[:, None]  # (B, S)
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, v_cache).reshape(batch, hidden)
    return x + out @ wo, k_cache, v_cache


def head(h, gamma, table):
    """Final RMSNorm + tied-embedding LM head. h: (T, d) → logits (T, V)."""
    return rmsnorm(h, gamma) @ table.T


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

# Topic clusters in the synthetic vocab (mirrored by rust workload/corpus.rs).
N_CLUSTERS = 16


def _rng(preset: str, *parts) -> np.random.Generator:
    seed = abs(hash((preset,) + parts)) % (2**31)
    # hash() is salted per-process; use a deterministic fold instead.
    acc = 0
    for ch in "/".join([preset] + [str(p) for p in parts]):
        acc = (acc * 131 + ord(ch)) % (2**31 - 1)
    return np.random.default_rng(acc)


def gen_weights(p: ModelPreset) -> dict:
    """Deterministic synthetic weights for a preset.

    Returns {name: np.ndarray(f32)}. Names are flat strings mirrored by the
    rust loader (see artifacts/<preset>/manifest.json).
    """
    d, f, n = p.hidden, p.moe_inter, p.n_routed
    w = {}
    std = 0.05

    def mat(name, shape, scale=std):
        w[name] = _rng(p.name, name).normal(0.0, scale, size=shape).astype(np.float32)

    # Clustered token embeddings: the vocab is partitioned into N_CLUSTERS
    # contiguous blocks ("topics"); tokens within a block share a cluster
    # centre plus noise. The synthetic corpus generator (rust
    # workload/corpus.rs) emits sequences that dwell within a topic, which
    # produces the adjacent-token routing locality the paper measures in
    # Fig. 8 and exploits in §4.3 — real corpora get this from semantics.
    n_clusters = N_CLUSTERS
    block = p.vocab // n_clusters
    centers = _rng(p.name, "embed.centers").normal(0.0, 1.0, size=(n_clusters, d))
    noise = _rng(p.name, "embed.noise").normal(0.0, 0.35, size=(p.vocab, d))
    table = centers[np.minimum(np.arange(p.vocab) // block, n_clusters - 1)] + noise
    w["embed.table"] = table.astype(np.float32)
    mat("embed.pos", (p.max_seq, d), 0.1)
    w["final.norm"] = np.ones(d, dtype=np.float32)
    for l in range(p.layers):
        w[f"layer.{l}.attn.norm"] = np.ones(d, dtype=np.float32)
        for nm in ("wq", "wk", "wv", "wo"):
            mat(f"layer.{l}.attn.{nm}", (d, d), std)
        w[f"layer.{l}.moe.norm"] = np.ones(d, dtype=np.float32)
        # Gate weights get a larger scale so softmax scores are peaked enough
        # to produce the skewed, input-dependent workloads the paper studies.
        mat(f"layer.{l}.moe.gate", (d, n), 0.5)
        for e in range(p.n_routed + p.n_shared):
            kind = "expert" if e < p.n_routed else "shared"
            idx = e if e < p.n_routed else e - p.n_routed
            mat(f"layer.{l}.moe.{kind}.{idx}.w1", (d, f), std)
            mat(f"layer.{l}.moe.{kind}.{idx}.w2", (f, d), std)
            mat(f"layer.{l}.moe.{kind}.{idx}.w3", (d, f), std)
    return w


# ---------------------------------------------------------------------------
# Full-model python reference (golden generation + pytest only)
# ---------------------------------------------------------------------------


def forward_prefill_ref(p: ModelPreset, w: dict, tokens: np.ndarray):
    """Reference prefill of one sequence. tokens: (S,) → (h, kv, route_log).

    route_log[l] = top-k expert ids per token (S, k) — lets rust verify its
    routing byte-for-byte.
    """
    seq = tokens.shape[0]
    x = embed(jnp.asarray(tokens), jnp.arange(seq), w["embed.table"], w["embed.pos"])
    kv = []
    route_log = []
    for l in range(p.layers):
        h, k, v = attn_prefill(
            x,
            w[f"layer.{l}.attn.norm"],
            w[f"layer.{l}.attn.wq"],
            w[f"layer.{l}.attn.wk"],
            w[f"layer.{l}.attn.wv"],
            w[f"layer.{l}.attn.wo"],
            heads=p.heads,
            head_dim=p.head_dim,
        )
        kv.append((k, v))
        probs, xn = gate(h, w[f"layer.{l}.moe.norm"], w[f"layer.{l}.moe.gate"])
        x = moe_combine_ref(p, w, l, h, probs, xn, route_log)
    return x, kv, route_log


def moe_combine_ref(p: ModelPreset, w: dict, l: int, h, probs, xn, route_log):
    """Paper Eq. 2: h + sum_i G(x)_i E_i(xn) + shared experts."""
    topk_val, topk_idx = jax.lax.top_k(probs, p.top_k)
    route_log.append(np.asarray(topk_idx))
    out = jnp.zeros_like(h)
    for e in range(p.n_routed):
        sel = (topk_idx == e).any(axis=-1)  # (T,)
        if not bool(sel.any()):
            continue
        rows = jnp.where(sel)[0]
        score = jnp.where(topk_idx == e, topk_val, 0.0).sum(axis=-1)[rows]
        y = expert(
            xn[rows],
            w[f"layer.{l}.moe.expert.{e}.w1"],
            w[f"layer.{l}.moe.expert.{e}.w2"],
            w[f"layer.{l}.moe.expert.{e}.w3"],
        )
        out = out.at[rows].add(score[:, None] * y)
    for s in range(p.n_shared):
        out = out + expert(
            xn,
            w[f"layer.{l}.moe.shared.{s}.w1"],
            w[f"layer.{l}.moe.shared.{s}.w2"],
            w[f"layer.{l}.moe.shared.{s}.w3"],
        )
    return h + out


def forward_decode_ref(p: ModelPreset, w: dict, kv, token: int, pos: int):
    """Reference single-token decode for one sequence with list-based kv.

    kv: list of (k (S,H,hd), v (S,H,hd)) grown in place. Returns
    (logits (V,), route_log list of (k,) per layer).
    """
    x = embed(
        jnp.asarray([token]), jnp.asarray([pos]), w["embed.table"], w["embed.pos"]
    )
    route_log = []
    for l in range(p.layers):
        k_old, v_old = kv[l]
        s_max = p.max_seq
        k_cache = jnp.zeros((1, s_max, p.heads, p.head_dim)).at[0, : k_old.shape[0]].set(k_old)
        v_cache = jnp.zeros((1, s_max, p.heads, p.head_dim)).at[0, : v_old.shape[0]].set(v_old)
        h, k_cache, v_cache = attn_decode(
            x,
            k_cache,
            v_cache,
            jnp.asarray([pos], dtype=jnp.int32),
            w[f"layer.{l}.attn.norm"],
            w[f"layer.{l}.attn.wq"],
            w[f"layer.{l}.attn.wk"],
            w[f"layer.{l}.attn.wv"],
            w[f"layer.{l}.attn.wo"],
            heads=p.heads,
            head_dim=p.head_dim,
        )
        kv[l] = (k_cache[0, : pos + 1], v_cache[0, : pos + 1])
        probs, xn = gate(h, w[f"layer.{l}.moe.norm"], w[f"layer.{l}.moe.gate"])
        x = moe_combine_ref(p, w, l, h, probs, xn, route_log)
    logits = head(x, w["final.norm"], w["embed.table"])
    return np.asarray(logits[0]), [r[0] for r in route_log]
