"""AOT pipeline: lowering produces valid HLO text; manifest is consistent."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.presets import buckets, load_preset, preset_names


def test_presets_load():
    names = preset_names()
    assert {"deepseek-sim", "qwen-sim", "mixtral-sim"} <= set(names)
    for n in names:
        p = load_preset(n)
        assert p.hidden % p.heads == 0 or p.heads * p.head_dim == p.hidden
        assert p.top_k <= p.n_routed


def test_lower_expert_produces_hlo_text():
    p = load_preset("mixtral-sim")
    text = aot.lower(
        M.expert,
        aot.f32(4, p.hidden),
        aot.f32(p.hidden, p.moe_inter),
        aot.f32(p.moe_inter, p.hidden),
        aot.f32(p.hidden, p.moe_inter),
    )
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lower_gate_produces_hlo_text():
    p = load_preset("mixtral-sim")
    text = aot.lower(M.gate, aot.f32(2, p.hidden), aot.f32(p.hidden),
                     aot.f32(p.hidden, p.n_routed))
    assert "HloModule" in text


def test_emit_preset_quick(tmp_path):
    p = load_preset("mixtral-sim")
    man = aot.emit_preset(p, str(tmp_path), buckets(), quick=True)
    # every artifact listed exists on disk
    for fname in man["artifacts"].values():
        assert (tmp_path / fname).exists()
    # every weight listed exists and has the right byte size
    for name, meta in man["weights"].items():
        f = tmp_path / meta["file"]
        assert f.exists()
        n_elems = 1
        for s in meta["shape"]:
            n_elems *= s
        assert f.stat().st_size == 4 * n_elems
    assert man["dims"]["n_routed"] == p.n_routed
    assert (tmp_path / "manifest.json").exists()
