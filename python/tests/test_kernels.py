"""L1 correctness: Pallas kernels vs pure-jnp oracle, incl. hypothesis sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import expert_ffn, expert_ffn_block_plan, gate_probs
from compile.kernels.expert_ffn import vmem_footprint_bytes
from compile.kernels.ref import expert_ffn_ref, gate_probs_ref

RNG = np.random.default_rng(0)


def rand(*shape, scale=0.5):
    return jnp.asarray(RNG.normal(0, scale, size=shape).astype(np.float32))


# --- expert_ffn -------------------------------------------------------------


@pytest.mark.parametrize("t", [1, 2, 8, 32, 128, 256])
def test_expert_matches_ref_token_buckets(t):
    d, f = 64, 48
    x, w1, w2, w3 = rand(t, d), rand(d, f), rand(f, d), rand(d, f)
    got = expert_ffn(x, w1, w2, w3)
    want = expert_ffn_ref(x, w1, w2, w3)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("d,f", [(256, 176), (256, 128), (256, 512)])
def test_expert_matches_ref_preset_dims(d, f):
    x, w1, w2, w3 = rand(16, d), rand(d, f), rand(f, d), rand(d, f)
    np.testing.assert_allclose(
        expert_ffn(x, w1, w2, w3), expert_ffn_ref(x, w1, w2, w3), rtol=2e-4, atol=1e-3
    )


def test_expert_multi_tile_grid():
    # force t_tiles > 1 and f_tiles > 1 so accumulation-over-revisits is hit
    t, d, f = 256, 32, 256
    t_tile, f_tile, t_tiles, f_tiles = expert_ffn_block_plan(t, d, f)
    assert t_tiles > 1 and f_tiles > 1
    x, w1, w2, w3 = rand(t, d), rand(d, f), rand(f, d), rand(d, f)
    np.testing.assert_allclose(
        expert_ffn(x, w1, w2, w3), expert_ffn_ref(x, w1, w2, w3), rtol=2e-4, atol=1e-3
    )


def test_expert_zero_input_is_zero():
    d, f = 32, 16
    x = jnp.zeros((4, d))
    out = expert_ffn(x, rand(d, f), rand(f, d), rand(d, f))
    np.testing.assert_allclose(out, jnp.zeros((4, d)), atol=1e-7)


def test_expert_jit_composes():
    d, f = 32, 16
    fn = jax.jit(expert_ffn)
    x, w1, w2, w3 = rand(8, d), rand(d, f), rand(f, d), rand(d, f)
    np.testing.assert_allclose(
        fn(x, w1, w2, w3), expert_ffn_ref(x, w1, w2, w3), rtol=2e-5, atol=2e-5
    )


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
    d=st.sampled_from([8, 16, 32, 64, 128, 256]),
    f=st.sampled_from([8, 16, 48, 128, 176, 512]),
    seed=st.integers(0, 2**16),
)
def test_expert_hypothesis_shapes(t, d, f, seed):
    r = np.random.default_rng(seed)

    def a(*s):
        return jnp.asarray(r.normal(0, 0.5, size=s).astype(np.float32))

    x, w1, w2, w3 = a(t, d), a(d, f), a(f, d), a(d, f)
    np.testing.assert_allclose(
        expert_ffn(x, w1, w2, w3), expert_ffn_ref(x, w1, w2, w3), rtol=2e-4, atol=1e-3
    )


def test_block_plan_divides_axes():
    for t in [1, 2, 4, 8, 16, 32, 64, 128, 256]:
        for f in [16, 48, 128, 176, 512]:
            tt, ft, tn, fn = expert_ffn_block_plan(t, 256, f)
            assert tt * tn == t and ft * fn == f
            assert tt <= 128 and ft <= 128


def test_vmem_footprint_under_budget():
    # TPU v4 VMEM ~16 MiB/core; the paper-scale mixtral expert tiles must fit.
    assert vmem_footprint_bytes(256, 4096, 14336) < 16 * 2**20


# --- gate -------------------------------------------------------------------


@pytest.mark.parametrize("t,n", [(1, 8), (4, 16), (32, 32), (128, 128)])
def test_gate_matches_ref(t, n):
    d = 64
    h, g, wg = rand(t, d), jnp.abs(rand(d)) + 0.5, rand(d, n)
    probs, xn = gate_probs(h, g, wg)
    probs_r, xn_r = gate_probs_ref(h, g, wg)
    np.testing.assert_allclose(probs, probs_r, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(xn, xn_r, rtol=2e-5, atol=2e-6)


def test_gate_rows_sum_to_one():
    h, g, wg = rand(16, 32), jnp.ones(32), rand(32, 8, scale=2.0)
    probs, _ = gate_probs(h, g, wg)
    np.testing.assert_allclose(probs.sum(-1), np.ones(16), rtol=1e-5)


def test_gate_softmax_stable_for_large_logits():
    h, g = rand(4, 32, scale=50.0), jnp.ones(32)
    wg = rand(32, 8, scale=50.0)
    probs, _ = gate_probs(h, g, wg)
    assert bool(jnp.isfinite(probs).all())


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([1, 2, 8, 64]),
    d=st.sampled_from([16, 64, 256]),
    n=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_gate_hypothesis(t, d, n, seed):
    r = np.random.default_rng(seed)

    def a(*s, sc=0.5):
        return jnp.asarray(r.normal(0, sc, size=s).astype(np.float32))

    h, g, wg = a(t, d), jnp.abs(a(d)) + 0.1, a(d, n, sc=1.0)
    probs, xn = gate_probs(h, g, wg)
    probs_r, xn_r = gate_probs_ref(h, g, wg)
    np.testing.assert_allclose(probs, probs_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(xn, xn_r, rtol=1e-4, atol=1e-5)
