"""L2 model correctness: attention semantics, decode/prefill agreement."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.presets import load_preset

P = load_preset("mixtral-sim")
RNG = np.random.default_rng(1)


def rand(*shape, scale=0.3):
    return jnp.asarray(RNG.normal(0, scale, size=shape).astype(np.float32))


def attn_weights(d):
    return dict(
        gamma=jnp.ones(d),
        wq=rand(d, d), wk=rand(d, d), wv=rand(d, d), wo=rand(d, d),
    )


def test_prefill_shapes():
    d, s = P.hidden, 16
    w = attn_weights(d)
    h, k, v = M.attn_prefill(rand(s, d), w["gamma"], w["wq"], w["wk"], w["wv"], w["wo"],
                             heads=P.heads, head_dim=P.head_dim)
    assert h.shape == (s, d)
    assert k.shape == (s, P.heads, P.head_dim)
    assert v.shape == (s, P.heads, P.head_dim)


def test_prefill_is_causal():
    """Changing a later token must not affect earlier outputs."""
    d, s = P.hidden, 8
    w = attn_weights(d)
    x = rand(s, d)
    h1, _, _ = M.attn_prefill(x, w["gamma"], w["wq"], w["wk"], w["wv"], w["wo"],
                              heads=P.heads, head_dim=P.head_dim)
    x2 = x.at[-1].set(x[-1] + 1.0)
    h2, _, _ = M.attn_prefill(x2, w["gamma"], w["wq"], w["wk"], w["wv"], w["wo"],
                              heads=P.heads, head_dim=P.head_dim)
    np.testing.assert_allclose(h1[:-1], h2[:-1], atol=1e-6)
    assert not np.allclose(h1[-1], h2[-1])


def test_decode_matches_prefill_step():
    """attn_decode at position s must equal prefill over s+1 tokens' last row."""
    d, s = P.hidden, 8
    w = attn_weights(d)
    x_full = rand(s + 1, d)
    h_full, _, _ = M.attn_prefill(x_full, w["gamma"], w["wq"], w["wk"], w["wv"], w["wo"],
                                  heads=P.heads, head_dim=P.head_dim)
    # prefill the first s tokens, then decode token s
    _, k, v = M.attn_prefill(x_full[:s], w["gamma"], w["wq"], w["wk"], w["wv"], w["wo"],
                             heads=P.heads, head_dim=P.head_dim)
    smax = P.max_seq
    kc = jnp.zeros((1, smax, P.heads, P.head_dim)).at[0, :s].set(k)
    vc = jnp.zeros((1, smax, P.heads, P.head_dim)).at[0, :s].set(v)
    h_dec, kc2, vc2 = M.attn_decode(
        x_full[s:s + 1], kc, vc, jnp.asarray([s], dtype=jnp.int32),
        w["gamma"], w["wq"], w["wk"], w["wv"], w["wo"],
        heads=P.heads, head_dim=P.head_dim)
    np.testing.assert_allclose(h_dec[0], h_full[-1], rtol=1e-4, atol=1e-5)
    # cache rows 0..s-1 untouched, row s written
    np.testing.assert_allclose(kc2[0, :s], k, atol=1e-6)
    assert not np.allclose(kc2[0, s], np.zeros((P.heads, P.head_dim)))


def test_decode_batch_rows_independent():
    d = P.hidden
    w = attn_weights(d)
    smax = P.max_seq
    kc = rand(2, smax, P.heads, P.head_dim)
    vc = rand(2, smax, P.heads, P.head_dim)
    x = rand(2, d)
    pos = jnp.asarray([3, 5], dtype=jnp.int32)
    h, _, _ = M.attn_decode(x, kc, vc, pos, w["gamma"], w["wq"], w["wk"], w["wv"],
                            w["wo"], heads=P.heads, head_dim=P.head_dim)
    # row 0 must not depend on row 1's inputs
    x2 = x.at[1].set(x[1] * 2 + 1)
    h2, _, _ = M.attn_decode(x2, kc, vc, pos, w["gamma"], w["wq"], w["wk"], w["wv"],
                             w["wo"], heads=P.heads, head_dim=P.head_dim)
    np.testing.assert_allclose(h[0], h2[0], atol=1e-6)


def test_embed_lookup():
    table = rand(P.vocab, P.hidden)
    pos_table = rand(P.max_seq, P.hidden)
    toks = jnp.asarray([3, 5], dtype=jnp.int32)
    pos = jnp.asarray([0, 1], dtype=jnp.int32)
    x = M.embed(toks, pos, table, pos_table)
    np.testing.assert_allclose(x[0], table[3] + pos_table[0], atol=1e-7)
    np.testing.assert_allclose(x[1], table[5] + pos_table[1], atol=1e-7)


def test_head_is_tied_matmul():
    table = rand(P.vocab, P.hidden)
    h = rand(2, P.hidden)
    logits = M.head(h, jnp.ones(P.hidden), table)
    assert logits.shape == (2, P.vocab)


def test_gen_weights_deterministic_and_complete():
    w1 = M.gen_weights(P)
    w2 = M.gen_weights(P)
    assert set(w1) == set(w2)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])
    for l in range(P.layers):
        for e in range(P.n_routed):
            assert f"layer.{l}.moe.expert.{e}.w1" in w1
    assert w1["embed.table"].shape == (P.vocab, P.hidden)


def test_full_forward_ref_runs_and_routes():
    p = load_preset("mixtral-sim")
    w = {k: jnp.asarray(v) for k, v in M.gen_weights(p).items()}
    tokens = np.asarray([1, 2, 3, 4])
    x, kv, routes = M.forward_prefill_ref(p, w, tokens)
    assert x.shape == (4, p.hidden)
    assert len(routes) == p.layers
    assert routes[0].shape == (4, p.top_k)
    assert (routes[0] >= 0).all() and (routes[0] < p.n_routed).all()
    logits, droutes = M.forward_decode_ref(p, w, kv, 5, 4)
    assert logits.shape == (p.vocab,)
    assert len(droutes) == p.layers
