//! Tiered-store scheduling throughput: promote/demote operations per
//! second across tier configurations (host-slot pressure), plus the
//! per-layer residency snapshot (`layer_tiers`) the assignment path reads
//! every MoE layer, and an end-to-end memory-limited decode step.

#[path = "bench_harness.rs"]
mod bench_harness;

use bench_harness::{bench, black_box};
use dali::config::Presets;
use dali::coordinator::assignment::GreedyAssigner;
use dali::coordinator::cache::WorkloadAwareCache;
use dali::coordinator::prefetch::NoPrefetcher;
use dali::coordinator::simrun::{Phase, PolicyBundle, StepSimulator};
use dali::hw::CostModel;
use dali::store::{StoreCfg, TieredStore};
use dali::util::DetRng;
use dali::workload::trace::{BatchStep, LayerStepData};

fn main() {
    let presets = Presets::load_default().unwrap();
    let model = presets.model("mixtral-sim").unwrap();
    let cost = CostModel::new(model, presets.hw("local-pc-ram16").unwrap());
    println!("# bench_store — tiered-store promote/demote scheduling throughput");

    // --- raw promote/spill churn at increasing slot pressure ----------------
    for (layers, n) in [(4usize, 8usize), (8, 16), (16, 64)] {
        let total = layers * n;
        for frac in [4usize, 2] {
            let slots = (total / frac).max(1);
            let mut st =
                TieredStore::new(layers, n, StoreCfg { host_slots: slots, ..Default::default() });
            let mut rng = DetRng::new(11);
            let mut now = 0u64;
            bench(&format!("promote_demote/L{layers}xE{n}/slots{slots}"), || {
                for _ in 0..64 {
                    let l = rng.usize_below(layers);
                    let e = rng.usize_below(n);
                    now += 1;
                    match rng.usize_below(3) {
                        0 => {
                            black_box(st.ensure_host(l, e, now, &cost));
                        }
                        1 => {
                            st.ensure_host(l, e, now, &cost);
                            st.admit_to_gpu(l, e);
                        }
                        _ => st.demote_gpu(l, e),
                    }
                }
            });
        }
    }

    // --- the per-layer residency snapshot read on every MoE layer -----------
    for (layers, n) in [(4usize, 8usize), (16, 64)] {
        let st = TieredStore::new(
            layers,
            n,
            StoreCfg { host_slots: (layers * n / 2).max(1), ..Default::default() },
        );
        bench(&format!("layer_tiers/L{layers}xE{n}"), || {
            for l in 0..layers {
                black_box(st.layer_tiers(l));
            }
        });
    }

    // --- end-to-end: one memory-limited decode step through simrun ----------
    let dims = &model.sim;
    let mk_step = |rng: &mut DetRng| -> BatchStep {
        let layers = (0..dims.layers)
            .map(|_| {
                let mut w = vec![0u32; dims.n_routed];
                for _ in 0..16 * dims.top_k {
                    w[rng.usize_below(dims.n_routed)] += 1;
                }
                LayerStepData {
                    gate_scores: w.iter().map(|&x| x as f32 * 0.3).collect(),
                    pred_raw: w.clone(),
                    pred_res: w.clone(),
                    workloads: w,
                }
            })
            .collect();
        BatchStep { tokens: 16, layers }
    };
    for slots in [usize::MAX, 12, 6] {
        let bundle = PolicyBundle {
            assigner: Box::new(GreedyAssigner::new()),
            prefetcher: Box::new(NoPrefetcher),
            cache: Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, 2, 4, 1, 3)),
            prefetch_size: 0,
            cpu_eff: 1.0,
            layer_overhead_ns: 0,
            gpu_free_slots: dims.n_routed,
            solve_cost: Default::default(),
            placement: Default::default(),
        };
        let cfg = StoreCfg { host_slots: slots, ..Default::default() };
        let store = TieredStore::new(dims.layers, dims.n_routed, cfg);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let mut sim = StepSimulator::new(
            &cost,
            bundle,
            &freq,
            dims.layers,
            dims.n_routed,
            dims.n_shared,
            7,
        )
        .with_store(store);
        let mut rng = DetRng::new(23);
        let label =
            if slots == usize::MAX { "unlimited".to_string() } else { format!("slots{slots}") };
        bench(&format!("simrun_decode_step/{label}"), || {
            sim.run_step(&mk_step(&mut rng), 32, Phase::Decode);
        });
    }
}
