//! Predictor hot-path latency (paper §4.2): ranking cost per layer step for
//! each prediction strategy, plus top-n selection.

#[path = "bench_harness.rs"]
mod bench_harness;

use bench_harness::{bench, black_box};
use dali::coordinator::prefetch::*;
use dali::util::DetRng;

fn main() {
    println!("# bench_prefetch — per-layer prediction + ranking cost");
    for n in [8usize, 16, 32, 128] {
        let mut rng = DetRng::new(3);
        let pred_raw: Vec<u32> = (0..n).map(|_| rng.usize_below(8) as u32).collect();
        let pred_res: Vec<u32> = (0..n).map(|_| rng.usize_below(8) as u32).collect();
        let cur: Vec<u32> = (0..n).map(|_| rng.usize_below(8) as u32).collect();
        let freq: Vec<f64> = (0..n).map(|_| rng.f64()).collect();

        let preds: Vec<(&str, Box<dyn Prefetcher>)> = vec![
            ("residual", Box::new(ResidualPrefetcher)),
            ("feature", Box::new(FeaturePrefetcher)),
            ("statistical", Box::new(StatisticalPrefetcher)),
            ("random", Box::new(RandomPrefetcher)),
        ];
        for (name, mut p) in preds {
            let mut prng = DetRng::new(7);
            bench(&format!("{name}/N{n}"), || {
                let mut ctx = PrefetchCtx {
                    pred_raw: &pred_raw,
                    pred_res: &pred_res,
                    cur_workloads: &cur,
                    true_next: None,
                    calib_freq_next: &freq,
                    rng: &mut prng,
                };
                let scores = p.predict(&mut ctx);
                black_box(top_n(&scores, 4));
            });
        }
    }
}
