//! Assignment-solver latency (paper Fig. 15 / 21 / Table 4 solve costs):
//! greedy vs exact branch-and-bound vs beam vs static, at each model's
//! expert count. The paper's claim: greedy ≈ free, Opt_plan prohibitive.

#[path = "bench_harness.rs"]
mod bench_harness;

use bench_harness::{bench, black_box};
use dali::config::Presets;
use dali::coordinator::assignment::*;
use dali::hw::CostModel;
use dali::util::DetRng;

fn main() {
    let presets = Presets::load_default().unwrap();
    println!("# bench_assignment — per-layer solve latency (paper Table 4 / Fig. 15 / Fig. 21)");
    for (preset, batch) in [("mixtral-sim", 16), ("deepseek-sim", 32), ("qwen-sim", 32)] {
        let model = presets.model(preset).unwrap();
        let cost = CostModel::new(model, presets.hw("local-pc").unwrap());
        let n = model.sim.n_routed;
        let k = model.sim.top_k;
        let mut rng = DetRng::new(9);
        // realistic decode workloads: batch*k token-expert assignments
        let mut workloads = vec![0u32; n];
        for _ in 0..batch * k {
            workloads[rng.usize_below(n)] += 1;
        }
        let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.5)).collect();
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cost,
            gpu_free_slots: n,
            layer: 0,
            layers: model.sim.layers,
            devices: None,
        };
        bench(&format!("greedy/{preset}/N{n}"), || {
            black_box(GreedyAssigner::new().assign(&ctx));
        });
        bench(&format!("beam2/{preset}/N{n}"), || {
            black_box(BeamAssigner::new(2).assign(&ctx));
        });
        bench(&format!("static/{preset}/N{n}"), || {
            black_box(StaticThresholdAssigner::new().assign(&ctx));
        });
        bench(&format!("optimal/{preset}/N{n}"), || {
            black_box(OptimalAssigner::new().assign(&ctx));
        });
    }
}
