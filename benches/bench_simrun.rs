//! Simulator hot-path throughput: replay decode steps/sec per model and
//! policy bundle over a synthetic locality trace (no PJRT / artifacts
//! needed — CI smoke-runs this). This is the perf-trajectory bench for the
//! zero-allocation `run_step` refactor: the flat prefetch-arrival table,
//! `StepScratch` reuse, `compose_decode_into`, and borrowed calibration
//! frequencies all land on this path.
//!
//! `dali bench` reports the same workload machine-readably
//! (`BENCH_simrun.json`) plus the allocation audit.

#[path = "bench_harness.rs"]
mod bench_harness;

use bench_harness::{bench, black_box};
use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::{replay_decode, Phase, StepSimulator};
use dali::hw::CostModel;
use dali::workload::trace::{synthetic_locality_trace, BatchStep};

const STEPS: usize = 64;
const BATCH: usize = 8;

fn main() {
    let presets = Presets::load_default().unwrap();
    println!("# bench_simrun — replay throughput (synthetic locality trace, batch {BATCH})");
    let ids: Vec<usize> = (0..BATCH).collect();
    for preset in ["deepseek-sim", "qwen-sim", "mixtral-sim"] {
        let model = presets.model(preset).unwrap();
        let dims = &model.sim;
        let cost = CostModel::new(model, presets.hw("local-pc").unwrap());
        let trace =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, STEPS, 0xbe7c);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        for fw in [Framework::Dali, Framework::HybriMoE] {
            // full replay: prefill warm-up + STEPS decode steps
            bench(&format!("replay_decode/{preset}/{}", fw.name()), || {
                let bundle = fw.bundle(dims, &cost, &freq, &cfg);
                black_box(replay_decode(
                    &trace,
                    &ids,
                    STEPS,
                    &cost,
                    bundle,
                    &freq,
                    dims.n_shared,
                    7,
                ));
            });
        }
        // single steady-state step (scratch warm, zero-allocation path)
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
        let mut sim =
            StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7);
        let mut stepbuf = BatchStep::default();
        trace.compose_prefill_into(&ids, &mut stepbuf);
        sim.run_step(&stepbuf, 8, Phase::Prefill);
        let mut s = 0usize;
        bench(&format!("steady_step/{preset}/dali"), || {
            trace.compose_decode_into(&ids, s % trace.min_steps(), &mut stepbuf);
            sim.run_step(&stepbuf, 16 + s, Phase::Decode);
            s += 1;
        });
    }
}
