//! End-to-end prefill replay (paper Fig. 13's measurement loop) plus the
//! live-engine prefill wall cost (real PJRT numerics path).
//!
//! Requires artifacts; trace pools are generated on demand.

#[path = "bench_harness.rs"]
mod bench_harness;

use bench_harness::{bench, black_box};
use dali::config::Presets;
use dali::coordinator::engine::InferenceEngine;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::replay_prefill;
use dali::hw::CostModel;
use dali::workload::corpus::{CorpusGen, TaskProfile};
use dali::workload::prep;

fn main() {
    let presets = Presets::load_default().unwrap();
    println!("# bench_prefill_e2e — prefill replay per framework (deepseek-sim, batch 32)");
    let preset = "deepseek-sim";
    let model = presets.model(preset).unwrap();
    let cost = CostModel::new(model, presets.hw("local-pc").unwrap());
    let calib = match prep::ensure_calib(preset) {
        Ok(c) => c,
        Err(e) => {
            println!("SKIP: {e:#} (run `dali prepare`)");
            return;
        }
    };
    let trace = prep::ensure_trace(preset, "c4-sim", 32, 16, 64).expect("trace pool");
    let cfg = FrameworkCfg::paper_default(&model.sim);
    let ids: Vec<usize> = (0..32).collect();
    for fw in [Framework::LlamaCpp, Framework::KTransformers, Framework::HybriMoE, Framework::Dali] {
        let m = replay_prefill(
            &trace, &ids, &cost,
            fw.bundle(&model.sim, &cost, &calib.freq, &cfg),
            &calib.freq, model.sim.n_shared, 7,
        );
        println!("  {:<14} simulated {:.1} tokens/s", fw.name(), m.tokens_per_s());
        bench(&format!("replay_prefill/{}", fw.name()), || {
            black_box(replay_prefill(
                &trace, &ids, &cost,
                fw.bundle(&model.sim, &cost, &calib.freq, &cfg),
                &calib.freq, model.sim.n_shared, 7,
            ));
        });
    }

    // live PJRT prefill wall cost (the real-numerics hot path)
    println!("# live-engine prefill (real PJRT, wall clock)");
    let eng = InferenceEngine::new(preset).expect("artifacts");
    let mut gen = CorpusGen::new(model.sim.vocab, TaskProfile::c4(), 77);
    let prompts = gen.batch(2, 16);
    bench("live_prefill/deepseek-sim/B2xS16", || {
        black_box(eng.run_batch(&prompts, 0, false).unwrap());
    });
}
