//! Cache-policy hot-path latency (paper §4.3 / Table 9 replacement costs):
//! observe + window_tick for each replacement policy at each expert count.

#[path = "bench_harness.rs"]
mod bench_harness;

use bench_harness::bench;
use dali::coordinator::cache::*;
use dali::util::DetRng;

fn churn(c: &mut dyn ExpertCache, n: usize, rng: &mut DetRng, step: usize) {
    let w: Vec<u32> = (0..n).map(|_| rng.usize_below(6) as u32).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
    for l in 0..4 {
        c.observe(l, &w, &g);
        let e = rng.usize_below(n);
        let fetched = !c.is_resident(l, e);
        c.on_gpu_use(l, e, fetched);
        c.window_tick(l, step);
    }
}

fn main() {
    println!("# bench_cache — per-step cache maintenance across policies");
    for n in [8usize, 16, 32, 128] {
        let cap = (n / 2).max(1);
        let mut step = 0usize;

        let mut wa = WorkloadAwareCache::new(4, n, cap, 4, (n / 4).max(1), 1);
        let mut rng = DetRng::new(5);
        bench(&format!("workload_aware/N{n}"), || {
            step += 1;
            churn(&mut wa, n, &mut rng, step);
        });

        let mut lru = LruCache::new(4, n, cap, 1);
        let mut rng = DetRng::new(5);
        bench(&format!("lru/N{n}"), || {
            step += 1;
            churn(&mut lru, n, &mut rng, step);
        });

        let mut sc = ScoreCache::new(4, n, cap, 1);
        let mut rng = DetRng::new(5);
        bench(&format!("score/N{n}"), || {
            step += 1;
            churn(&mut sc, n, &mut rng, step);
        });
    }
}
