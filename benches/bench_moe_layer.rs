//! Single-MoE-layer orchestration cost (paper §4 runtime loop): one
//! simulated layer step per framework policy — the L3 hot path that must
//! never rival the simulated compute it schedules.

#[path = "bench_harness.rs"]
mod bench_harness;

use bench_harness::bench;
use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::{Phase, StepSimulator};
use dali::hw::CostModel;
use dali::util::DetRng;
use dali::workload::trace::{BatchStep, LayerStepData};

fn mk_step(layers: usize, n: usize, tokens: usize, rng: &mut DetRng) -> BatchStep {
    let layers_data: Vec<LayerStepData> = (0..layers)
        .map(|_| {
            let mut workloads = vec![0u32; n];
            for _ in 0..tokens * 2 {
                workloads[rng.usize_below(n)] += 1;
            }
            LayerStepData {
                gate_scores: workloads.iter().map(|&w| w as f32 * 0.3).collect(),
                pred_raw: workloads.clone(),
                pred_res: workloads.clone(),
                workloads,
            }
        })
        .collect();
    BatchStep { tokens, layers: layers_data }
}

fn main() {
    let presets = Presets::load_default().unwrap();
    println!("# bench_moe_layer — one simulated batch step (all layers) per framework");
    for preset in ["mixtral-sim", "qwen-sim"] {
        let model = presets.model(preset).unwrap();
        let dims = &model.sim;
        let cost = CostModel::new(model, presets.hw("local-pc").unwrap());
        let cfg = FrameworkCfg::paper_default(dims);
        let freq = vec![vec![1.0 / dims.n_routed as f64; dims.n_routed]; dims.layers];
        for fw in [Framework::Dali, Framework::HybriMoE, Framework::KTransformers, Framework::DaliOpt] {
            let bundle = fw.bundle(dims, &cost, &freq, &cfg);
            let mut sim = StepSimulator::new(
                &cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 1,
            );
            let mut rng = DetRng::new(11);
            let mut kv = 16usize;
            bench(&format!("{}/{preset}/B16", fw.name()), || {
                let step = mk_step(dims.layers, dims.n_routed, 16, &mut rng);
                sim.run_step(&step, kv, Phase::Decode);
                kv += 1;
            });
        }
    }
}
