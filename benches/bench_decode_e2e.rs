//! End-to-end decode replay (paper Fig. 12's measurement loop): full
//! 32-step decode over the recorded C4 trace per framework. Wall-clock here
//! is the coordinator's own cost of simulating/scheduling the run; the
//! reported simulated tokens/s is the paper metric (printed once).
//!
//! Requires trace pools (`dali prepare`).

#[path = "bench_harness.rs"]
mod bench_harness;

use bench_harness::{bench, black_box};
use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::replay_decode;
use dali::hw::CostModel;
use dali::workload::prep;

fn main() {
    let presets = Presets::load_default().unwrap();
    println!("# bench_decode_e2e — 32-step decode replay per framework (mixtral-sim, batch 16)");
    let preset = "mixtral-sim";
    let model = presets.model(preset).unwrap();
    let cost = CostModel::new(model, presets.hw("local-pc").unwrap());
    let calib = match prep::ensure_calib(preset) {
        Ok(c) => c,
        Err(e) => {
            println!("SKIP: {e:#} (run `dali prepare`)");
            return;
        }
    };
    let trace = prep::ensure_trace(preset, "c4-sim", 32, 16, 64).expect("trace pool");
    let cfg = FrameworkCfg::paper_default(&model.sim);
    let ids: Vec<usize> = (0..16).collect();
    for fw in [
        Framework::Naive,
        Framework::LlamaCpp,
        Framework::KTransformers,
        Framework::MoELightning,
        Framework::HybriMoE,
        Framework::Dali,
    ] {
        // report the paper metric once
        let m = replay_decode(
            &trace, &ids, 32, &cost,
            fw.bundle(&model.sim, &cost, &calib.freq, &cfg),
            &calib.freq, model.sim.n_shared, 7,
        );
        println!("  {:<14} simulated {:.2} tokens/s", fw.name(), m.tokens_per_s());
        bench(&format!("replay_decode/{}", fw.name()), || {
            black_box(replay_decode(
                &trace, &ids, 32, &cost,
                fw.bundle(&model.sim, &cost, &calib.freq, &cfg),
                &calib.freq, model.sim.n_shared, 7,
            ));
        });
    }
}
