//! Minimal benchmark harness (the offline build has no criterion).
//!
//! Usage inside a `harness = false` bench target:
//!
//! ```ignore
//! #[path = "bench_harness.rs"] mod bench_harness;
//! use bench_harness::bench;
//! bench("greedy_n16", || { ... });
//! ```
//!
//! Reports mean / p50 / min / stddev over timed iterations after warm-up,
//! in a stable plain-text format captured into bench_output.txt.

use std::time::{Duration, Instant};

/// Budget per benchmark (after warm-up).
const BUDGET: Duration = Duration::from_millis(1200);
const MAX_ITERS: usize = 2000;
const WARMUP: usize = 3;

pub fn bench<F: FnMut()>(name: &str, mut f: F) {
    for _ in 0..WARMUP {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < BUDGET && samples.len() < MAX_ITERS {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e9);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
    let p50 = samples[n / 2];
    let min = samples[0];
    println!(
        "bench {name:<42} iters {n:>5}  mean {}  p50 {}  min {}  sd {}",
        fmt(mean),
        fmt(p50),
        fmt(min),
        fmt(var.sqrt())
    );
}

fn fmt(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:>8.3}s ", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:>8.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:>8.3}us", ns / 1e3)
    } else {
        format!("{:>8.0}ns", ns)
    }
}

/// Keep a value alive / defeat dead-code elimination.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
