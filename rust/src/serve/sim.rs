//! Multi-tenant continuous-batching serving simulation in virtual time.
//!
//! Seeded arrival processes ([`ArrivalSpec`]) generate many concurrent
//! request streams; a continuous batcher admits queued requests into free
//! batch slots and retires finished ones *per decode step*; every stream
//! contends for **one shared** [`StepSimulator`] pipeline — one GPU
//! cache, one tiered store, one set of NVMe/PCIe/transcode lanes — so
//! cross-request expert locality (or thrash) is actually modeled instead
//! of assumed away. This is the subsystem the wall-clock [`Batcher`]
//! (`serve/batcher.rs`) cannot be: deterministic, artifact-free, and
//! aware of the memory hierarchy.
//!
//! On top of the PR 8 tick loop sits **SLO-guarded overload protection**
//! ([`SloSpec`], `serve/slo.rs`), three independent mechanisms with one
//! policy knob:
//!
//! - **Admission control** — the pending queue is bounded
//!   (`queue_cap`), and a request whose jittered TTFT/completion
//!   deadline is already blown — or predicted blown, using the rolling
//!   step-latency estimate — at slot-grant time is rejected instead of
//!   burning a slot (`request_reject`).
//! - **Load shedding** — after each tick, the running request with the
//!   most-blown completion deadline is evicted (at most one per tick),
//!   freeing its batch slot and its share of the compose workload
//!   (`request_evict`).
//! - **Degradation ladder** — a hysteretic [`OverloadController`]
//!   watches queue depth and rolling step latency and climbs
//!   shrink-prefetch → pause-promote-ahead → degraded (CPU-shifted)
//!   assignment costs, de-escalating with dwell hysteresis
//!   (`degrade_enter` / `degrade_exit`).
//!
//! The whole layer is digest-transparent when disarmed: an unlimited
//! spec (the default) takes none of the guarded branches, so reports are
//! bit-identical to the unguarded simulator, and an `enforce=false` spec
//! *scores* deadlines in the report while leaving the digest untouched —
//! the fair baseline for guarded-vs-unguarded comparisons.
//!
//! Request lifecycle joins the trace stream (`request_arrive` /
//! `request_admit` / `request_first_token` / `request_finish` plus the
//! overload events above), so one FNV digest locks scheduling *and* SLO
//! accounting: same-seed serve cells are bit-identical, which
//! `rust/tests/serve_sim.rs` and the CI serve-determinism check enforce.
//!
//! The tick loop is allocation-free in steady state (audited alongside
//! `run_step`): requests, stats, deadlines, and compose buffers are
//! preallocated at construction, the degraded cost view is cloned once
//! at install, and the one shared [`BatchStep`] is reused for prefill
//! and decode composition alike.
//!
//! [`Batcher`]: super::batcher::Batcher

use anyhow::{bail, Result};

use crate::config::Presets;
use crate::coordinator::frameworks::{Framework, FrameworkCfg};
use crate::coordinator::simrun::{Phase, StepSimulator};
use crate::fault::FaultPlan;
use crate::hw::{CostModel, Ns};
use crate::metrics::{RequestOutcome, RequestStat, ServeReport};
use crate::store::TieredStore;
use crate::trace::{DigestSink, Event, TraceSink};
use crate::workload::trace::{synthetic_locality_trace, BatchStep};
use crate::workload::Trace;

use super::arrival::ArrivalSpec;
use super::slo::{OverloadController, SloSpec};

/// GPU-cost multiplier of the degraded assignment view the ladder's top
/// rung prices through (PCIe below). Mild on purpose: the view only has
/// to tip marginal GPU-vs-CPU choices, not caricature the hardware.
const DEGRADE_GPU_MULT: f64 = 1.5;
const DEGRADE_PCIE_MULT: f64 = 2.0;

/// `request_reject` reason: deadline already blown at slot-grant time.
pub const REJECT_DEADLINE_BLOWN: u32 = 0;
/// `request_reject` reason: pending queue at capacity on arrival.
pub const REJECT_QUEUE_FULL: u32 = 1;
/// `request_reject` reason: predicted TTFT exceeds the deadline.
pub const REJECT_PREDICTED_TTFT: u32 = 2;

/// Configuration of one serving-simulation run.
#[derive(Debug, Clone)]
pub struct ServeSimCfg {
    /// Arrival process generating the request script (and, when enabled,
    /// the per-request decode-length distribution).
    pub arrival: ArrivalSpec,
    /// Total requests to serve (the run ends when all are resolved —
    /// finished, rejected, or evicted).
    pub n_requests: usize,
    /// Continuous-batching slot budget: max requests decoding at once.
    pub max_batch: usize,
    /// Decode tokens requested per request (clamped to the backing
    /// stream's recorded length; overridden per request when the arrival
    /// spec carries a length distribution).
    pub max_tokens: usize,
    /// SLO policy: deadlines, admission control, and the degradation
    /// ladder. The default (unlimited) leaves the run bit-identical to
    /// an unguarded simulation.
    pub slo: SloSpec,
    /// Seed for the arrival script, deadline jitter, and the simulator's
    /// own RNG stream.
    pub seed: u64,
}

impl Default for ServeSimCfg {
    fn default() -> Self {
        ServeSimCfg {
            arrival: ArrivalSpec::default(),
            n_requests: 32,
            max_batch: 8,
            max_tokens: 16,
            slo: SloSpec::default(),
            seed: 0x5e11,
        }
    }
}

/// One request currently holding a batch slot.
#[derive(Debug, Clone, Copy)]
struct Active {
    req: usize,
    /// Decode tokens generated so far (== this stream's next step index).
    generated: usize,
    /// Tokens this request will generate before leaving the batch.
    decode_len: usize,
    prompt_len: usize,
}

/// The continuous-batching serving simulator: an arrival queue + a
/// running set, ticked in virtual time over one shared [`StepSimulator`].
pub struct ServeSim<'a, S: TraceSink> {
    sim: StepSimulator<'a, S>,
    trace: &'a Trace,
    cfg: ServeSimCfg,
    /// Sorted arrival instants, one per request (request id = index).
    arrivals: Vec<Ns>,
    /// Per-request decode lengths (empty = flat `cfg.max_tokens`).
    lengths: Vec<usize>,
    /// Next not-yet-drained arrival index.
    next_arrival: usize,
    /// Arrived-but-not-admitted request ids, FIFO: `pending[pending_head..]`
    /// is the live queue (preallocated; the head cursor replaces pop-front
    /// so the tick loop never shifts or reallocates).
    pending: Vec<u32>,
    pending_head: usize,
    running: Vec<Active>,
    /// Request ids admitted this tick (prefill batch composition).
    admit_buf: Vec<usize>,
    /// `(seq_id, stream step)` pairs for multi-stream decode composition.
    active_buf: Vec<(usize, usize)>,
    /// The one reused compose buffer (prefill and decode alike).
    step: BatchStep,
    stats: Vec<RequestStat>,
    /// True when the SLO spec actually intervenes (enforced and not
    /// unlimited). False takes none of the guarded branches — the
    /// digest-transparency invariant.
    guarded: bool,
    ctrl: OverloadController,
    /// The bundle's prefetch window before any ladder shrink.
    base_prefetch: usize,
    /// Virtual time spent with the ladder above rung 0.
    degraded_ns: Ns,
    admitted: usize,
    rejected: usize,
    evicted: usize,
    finished: usize,
    /// finished + rejected + evicted — the run ends at `n_requests`.
    done: usize,
}

impl<'a, S: TraceSink> ServeSim<'a, S> {
    /// Build a serving run over an already-configured simulator (sink,
    /// store, and faults installed by the caller). Preallocates every
    /// tick-loop buffer and, when the spec is guarded, the degraded
    /// assignment cost view.
    pub fn new(
        mut sim: StepSimulator<'a, S>,
        trace: &'a Trace,
        cfg: ServeSimCfg,
    ) -> Result<Self> {
        if cfg.n_requests == 0 || cfg.max_batch == 0 || cfg.max_tokens == 0 {
            bail!(
                "serve sim needs n_requests/max_batch/max_tokens >= 1 \
                 (got {}/{}/{})",
                cfg.n_requests,
                cfg.max_batch,
                cfg.max_tokens
            );
        }
        if trace.seqs.is_empty() || trace.min_steps() == 0 {
            bail!("serve sim needs a non-empty trace pool with decode steps");
        }
        cfg.slo.validate()?;
        let mut arrivals = Vec::new();
        cfg.arrival.generate_into(cfg.n_requests, cfg.seed, &mut arrivals);
        let mut lengths = Vec::new();
        cfg.arrival.lengths_into(cfg.n_requests, cfg.seed, &mut lengths);
        let mut stats = vec![RequestStat::default(); cfg.n_requests];
        // Deadlines are stamped whenever budgets exist — enforced or not —
        // so observe-mode runs score attainment over identical traffic.
        if !cfg.slo.is_unlimited() {
            for (req, s) in stats.iter_mut().enumerate() {
                let (ttft, total) = cfg.slo.deadlines(cfg.seed, req, arrivals[req]);
                s.ttft_deadline_ns = ttft;
                s.deadline_ns = total;
            }
        }
        let guarded = cfg.slo.is_guarded();
        if guarded {
            sim.install_degraded_assign_view(DEGRADE_GPU_MULT, DEGRADE_PCIE_MULT);
        }
        let base_prefetch = sim.policy.prefetch_size;
        Ok(ServeSim {
            trace,
            arrivals,
            lengths,
            next_arrival: 0,
            pending: Vec::with_capacity(cfg.n_requests),
            pending_head: 0,
            running: Vec::with_capacity(cfg.max_batch),
            admit_buf: Vec::with_capacity(cfg.max_batch),
            active_buf: Vec::with_capacity(cfg.max_batch),
            step: BatchStep::default(),
            stats,
            guarded,
            ctrl: OverloadController::new(cfg.slo),
            base_prefetch,
            degraded_ns: 0,
            admitted: 0,
            rejected: 0,
            evicted: 0,
            finished: 0,
            done: 0,
            sim,
            cfg,
        })
    }

    /// Requests that ran to completion so far.
    pub fn finished(&self) -> usize {
        self.finished
    }

    /// Requests admitted into the batch so far. Once `admitted() +
    /// rejected()` reaches `n_requests`, remaining ticks are pure decode
    /// — the window the allocation audit measures.
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    /// Requests turned away by admission control so far.
    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Running requests evicted by deadline load-shedding so far.
    pub fn evicted(&self) -> usize {
        self.evicted
    }

    /// Current degradation rung (0 = healthy).
    pub fn rung(&self) -> u8 {
        self.ctrl.rung()
    }

    /// Per-request lifecycle stats (index = request id). Timestamps are
    /// only final once the run is over.
    pub fn stats(&self) -> &[RequestStat] {
        &self.stats
    }

    /// The decode budget request `req` would be admitted with.
    fn decode_len(&self, req: usize) -> usize {
        let want =
            if self.lengths.is_empty() { self.cfg.max_tokens } else { self.lengths[req] };
        want.min(self.trace.decode_len(req)).max(1)
    }

    /// Admission control turned `req` away: stamp its terminal stats and
    /// emit its (arrive, reject) lifecycle pair.
    fn reject(&mut self, req: usize, reason: u32) {
        let arrival = self.arrivals[req];
        let now = self.sim.now();
        self.stats[req].arrival_ns = arrival;
        self.stats[req].finish_ns = now;
        self.stats[req].outcome = RequestOutcome::Rejected;
        self.sim.note_event(Event::RequestArrive {
            req: req as u32,
            at: arrival,
            prompt_len: self.trace.prompt_len(req) as u32,
            max_tokens: self.decode_len(req) as u32,
        });
        self.sim.note_event(Event::RequestReject { req: req as u32, at: now, reason });
        self.rejected += 1;
        self.done += 1;
    }

    /// Apply one ladder rung's cumulative interventions to the pipeline.
    fn apply_rung(&mut self, r: u8) {
        // rung >= 1: halve the speculative prefetch window (floor 1 so a
        // bundle that prefetches at all keeps its pipeline shape)
        self.sim.policy.prefetch_size = if r >= 1 && self.base_prefetch > 0 {
            (self.base_prefetch / 2).max(1)
        } else {
            self.base_prefetch
        };
        // rung >= 2: stop predictive NVMe→host promote-ahead
        self.sim.set_promote_paused(r >= 2);
        // rung 3: price assignment through the CPU-shifted cost view
        self.sim.set_degraded_assign(r >= 3);
    }

    /// One continuous-batching tick: drain due arrivals into the pending
    /// queue (bounded when guarded), admit from the queue head into free
    /// slots (rejecting hopeless deadlines when guarded), let the
    /// overload controller move the degradation ladder, prefill the
    /// newcomers, advance every running stream by one decode step on the
    /// shared pipeline, retire first-token/finish edges, and — when
    /// guarded — evict the most-blown running request. Returns `false`
    /// once every request is resolved.
    pub fn tick(&mut self) -> bool {
        if self.done == self.cfg.n_requests {
            return false;
        }
        // an empty pipeline idles forward to the next arrival — run_step
        // never moves the clock for an empty step
        if self.running.is_empty()
            && self.pending_head == self.pending.len()
            && self.next_arrival < self.cfg.n_requests
        {
            self.sim.advance_to(self.arrivals[self.next_arrival]);
        }
        let tick_start = self.sim.now();
        // drain due arrivals into the pending queue, in arrival order;
        // a guarded run bounds the queue and 503s the overflow
        while self.next_arrival < self.cfg.n_requests
            && self.arrivals[self.next_arrival] <= self.sim.now()
        {
            let req = self.next_arrival;
            self.next_arrival += 1;
            if self.guarded
                && self.cfg.slo.queue_cap > 0
                && self.pending.len() - self.pending_head >= self.cfg.slo.queue_cap
            {
                self.reject(req, REJECT_QUEUE_FULL);
                continue;
            }
            self.pending.push(req as u32);
        }
        // admission: the queue head fills free batch slots in arrival
        // order; a guarded run skips requests whose deadline is blown
        // (satellite bugfix: such requests must not burn a slot) or
        // predicted blown by the rolling step-latency estimate
        self.admit_buf.clear();
        while self.running.len() < self.cfg.max_batch
            && self.pending_head < self.pending.len()
        {
            let req = self.pending[self.pending_head] as usize;
            self.pending_head += 1;
            let now = self.sim.now();
            if self.guarded {
                let st = &self.stats[req];
                if now >= st.ttft_deadline_ns || now >= st.deadline_ns {
                    self.reject(req, REJECT_DEADLINE_BLOWN);
                    continue;
                }
                // one-tick lookahead: an admitted request's first token
                // lands at the end of its admission tick, so one rolling
                // step span is the whole remaining TTFT estimate
                let predicted = now.saturating_add(self.ctrl.ewma_step_ns());
                if self.ctrl.ewma_step_ns() > 0 && predicted > st.ttft_deadline_ns {
                    self.reject(req, REJECT_PREDICTED_TTFT);
                    continue;
                }
            }
            let arrival = self.arrivals[req];
            let prompt_len = self.trace.prompt_len(req);
            let decode_len = self.decode_len(req);
            self.stats[req].arrival_ns = arrival;
            self.stats[req].admit_ns = now;
            self.sim.note_event(Event::RequestArrive {
                req: req as u32,
                at: arrival,
                prompt_len: prompt_len as u32,
                max_tokens: decode_len as u32,
            });
            self.sim.note_event(Event::RequestAdmit {
                req: req as u32,
                at: now,
                queue_ns: now.saturating_sub(arrival),
            });
            self.running.push(Active { req, generated: 0, decode_len, prompt_len });
            self.admit_buf.push(req);
            self.admitted += 1;
        }
        // overload controller: one observation per tick on the post-
        // admission backlog; at most one rung transition, hysteretic
        if self.guarded {
            let depth = self.pending.len() - self.pending_head;
            if let Some((from, to)) = self.ctrl.observe(depth) {
                let at = self.sim.now();
                let (from, to, queue_depth) = (from as u32, to as u32, depth as u32);
                self.sim.note_event(if to > from {
                    Event::DegradeEnter { at, from, to, queue_depth }
                } else {
                    Event::DegradeExit { at, from, to, queue_depth }
                });
                self.apply_rung(self.ctrl.rung());
            }
        }
        // prefill the newcomers as one batch step on the shared pipeline
        // (continuous batching without chunked prefill: the prefill step
        // briefly stalls ongoing decodes, which TPOT then reflects)
        if !self.admit_buf.is_empty() {
            self.trace.compose_prefill_into(&self.admit_buf, &mut self.step);
            let kv = self
                .admit_buf
                .iter()
                .map(|&r| self.trace.prompt_len(r))
                .sum::<usize>()
                / (2 * self.admit_buf.len());
            self.sim.run_step(&self.step, kv.max(1), Phase::Prefill);
        }
        // one decode step over every running stream, each at its own
        // per-request offset (an all-rejected tick composes an empty
        // step, which run_step ignores without moving the clock)
        self.active_buf.clear();
        let mut kv_sum = 0usize;
        for a in &self.running {
            self.active_buf.push((a.req, a.generated));
            kv_sum += a.prompt_len + a.generated;
        }
        self.trace.compose_multi_into(&self.active_buf, &mut self.step);
        let kv = (kv_sum / self.running.len().max(1)).max(1);
        self.sim.run_step(&self.step, kv, Phase::Decode);
        let now = self.sim.now();
        // retire first-token and finish edges at the post-step clock
        let mut i = 0;
        while i < self.running.len() {
            self.running[i].generated += 1;
            let Active { req, generated, decode_len, .. } = self.running[i];
            if generated == 1 {
                self.stats[req].first_token_ns = now;
                let ttft = now.saturating_sub(self.stats[req].arrival_ns);
                self.sim.note_event(Event::RequestFirstToken {
                    req: req as u32,
                    at: now,
                    ttft_ns: ttft,
                });
            }
            if generated >= decode_len {
                self.stats[req].finish_ns = now;
                self.stats[req].tokens = generated as u64;
                self.sim.note_event(Event::RequestFinish {
                    req: req as u32,
                    at: now,
                    tokens: generated as u32,
                });
                self.finished += 1;
                self.done += 1;
                self.running.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if self.guarded {
            // feed the rolling step-latency estimate (idle gaps excluded:
            // tick_start is taken after the idle advance)
            if now > tick_start {
                self.ctrl.note_step(now - tick_start);
                if self.ctrl.rung() > 0 {
                    self.degraded_ns += now - tick_start;
                }
            }
            // load shedding: evict the running request with the most-
            // blown completion deadline (at most one per tick), freeing
            // its slot and its share of the compose workload
            let mut worst: Option<(usize, Ns)> = None;
            for (i, a) in self.running.iter().enumerate() {
                let over = now.saturating_sub(self.stats[a.req].deadline_ns);
                if over > 0 && worst.map(|(_, w)| over > w).unwrap_or(true) {
                    worst = Some((i, over));
                }
            }
            if let Some((i, over)) = worst {
                let a = self.running[i];
                self.stats[a.req].finish_ns = now;
                self.stats[a.req].tokens = a.generated as u64;
                self.stats[a.req].outcome = RequestOutcome::Evicted;
                self.sim.note_event(Event::RequestEvict {
                    req: a.req as u32,
                    at: now,
                    generated: a.generated as u32,
                    overdue_ns: over,
                });
                self.evicted += 1;
                self.done += 1;
                self.running.swap_remove(i);
            }
        }
        self.done < self.cfg.n_requests
    }

    /// Drive the run to completion.
    pub fn run(&mut self) {
        while self.tick() {}
    }

    /// Finish: per-request SLO aggregation over the underlying replay's
    /// metrics (call after [`Self::run`]; unresolved requests would
    /// report zero timestamps).
    pub fn finish(self) -> ServeReport {
        let mut r = ServeReport::from_stats(&self.stats, self.sim.finish());
        r.degraded_ns = self.degraded_ns;
        r
    }

    /// [`Self::finish`] that also hands back the sink.
    pub fn finish_with_sink(self) -> (ServeReport, S) {
        let (run, sink) = self.sim.finish_with_sink();
        let mut r = ServeReport::from_stats(&self.stats, run);
        r.degraded_ns = self.degraded_ns;
        (r, sink)
    }
}

/// One self-contained serving cell: build the scenario's cost model,
/// synthetic stream pool, and policy bundle, attach the shared tiered
/// store (when the scenario is memory-limited) and an optional fault
/// plan, serve every request under the configured SLO policy, and report
/// — with the whole-run digest covering scheduling, request lifecycle,
/// and overload-protection decisions alike. This is the unit the
/// `expt serve` sweep, `dali serve --sim`, and the serve bench tier all
/// share.
pub fn simulate_serve(
    presets: &Presets,
    scenario: &str,
    fw: Framework,
    cfg: &ServeSimCfg,
    faults: Option<FaultPlan>,
) -> Result<ServeReport> {
    let (model, hw) = presets.scenario(scenario)?;
    let dims = &model.sim;
    let cost = CostModel::for_scenario(presets, scenario)?;
    // stream pool: 16 synthetic locality streams, long enough that no
    // request is clamped below its requested max_tokens (heterogeneous
    // length draws included)
    let trace = synthetic_locality_trace(
        dims.layers,
        dims.n_routed,
        dims.top_k,
        16,
        cfg.max_tokens.max(cfg.arrival.len_max).max(16),
        cfg.seed ^ 0x7ace,
    );
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let fwcfg = FrameworkCfg::paper_default(dims);
    let bundle = fw.bundle(dims, &cost, &freq, &fwcfg);
    // honor the scenario's device count: multi-GPU hardware presets serve
    // with expert-parallel sharded pipelines (num_gpus = 1 is unchanged)
    let mut sim =
        StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7)
            .with_gpus(hw.num_gpus)
            .with_sink(DigestSink::new());
    if let Some(plan) = faults {
        sim = sim.with_faults(plan);
    }
    let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
    if !store.is_unlimited() {
        sim = sim.with_store(store);
    }
    let mut serve = ServeSim::new(sim, &trace, cfg.clone())?;
    serve.run();
    Ok(serve.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_sim(cfg: &ServeSimCfg) -> ServeReport {
        let presets = Presets::load_default().unwrap();
        simulate_serve(&presets, "mixtral-sim-ram16", Framework::Dali, cfg, None).unwrap()
    }

    /// A bursty cell hot enough that a tight SLO policy has real work to
    /// do: every slot contended, deep queue, long waits.
    fn overload_cfg() -> ServeSimCfg {
        ServeSimCfg {
            arrival: ArrivalSpec::parse_spec("kind=bursty,rate=256,burst=8").unwrap(),
            n_requests: 24,
            max_batch: 4,
            max_tokens: 8,
            ..Default::default()
        }
    }

    #[test]
    fn every_request_finishes_with_sane_lifecycle() {
        let cfg = ServeSimCfg { n_requests: 12, max_batch: 4, max_tokens: 8, ..Default::default() };
        let r = mk_sim(&cfg);
        assert_eq!(r.requests, 12);
        assert_eq!(r.finished, 12, "unguarded runs resolve everything as finished");
        assert_eq!((r.rejected, r.evicted), (0, 0));
        assert_eq!(r.tokens_out, 12 * 8, "every request generates its full budget");
        assert!(r.makespan_ns > 0);
        assert!(r.ttft_p50_ns > 0 && r.ttft_p99_ns >= r.ttft_p50_ns);
        assert!(r.tpot_p50_ns > 0 && r.tpot_p99_ns >= r.tpot_p50_ns);
        assert!(r.run.trace_digest.is_some(), "serve cells are digest-locked");
        assert_eq!(r.run.tokens_out, r.tokens_out, "sim and SLO views agree on tokens");
        // no deadlines installed: everything trivially attains
        assert_eq!(r.slo_attained, 12);
        assert_eq!(r.goodput_tokens, r.tokens_out);
        assert_eq!(r.degraded_ns, 0);
    }

    #[test]
    fn same_seed_cells_are_bit_identical() {
        let cfg = ServeSimCfg { n_requests: 10, max_batch: 4, ..Default::default() };
        let a = mk_sim(&cfg);
        let b = mk_sim(&cfg);
        assert_eq!(a, b, "same-seed serve cells must be bit-identical");
        let c = mk_sim(&ServeSimCfg { seed: cfg.seed + 1, ..cfg });
        assert_ne!(a.run.trace_digest, c.run.trace_digest, "seed must matter");
    }

    #[test]
    fn higher_load_does_not_improve_tail_ttft() {
        let base = ServeSimCfg { n_requests: 24, max_batch: 4, max_tokens: 8, ..Default::default() };
        let light = mk_sim(&ServeSimCfg { arrival: base.arrival.with_rate(1.0), ..base.clone() });
        let heavy = mk_sim(&ServeSimCfg { arrival: base.arrival.with_rate(512.0), ..base });
        assert!(
            heavy.ttft_p99_ns >= light.ttft_p99_ns,
            "overload must not beat light load on tail TTFT: {} < {}",
            heavy.ttft_p99_ns,
            light.ttft_p99_ns
        );
        assert!(heavy.queue_p99_ns >= light.queue_p99_ns);
    }

    #[test]
    fn batch_slots_are_respected_and_queue_drains_in_order() {
        // a single-slot server serializes everything: makespan is at
        // least the sum of any one request's span, and queueing shows up
        let presets = Presets::load_default().unwrap();
        let cfg = ServeSimCfg {
            arrival: ArrivalSpec::default().with_rate(1000.0),
            n_requests: 6,
            max_batch: 1,
            max_tokens: 4,
            ..Default::default()
        };
        let r =
            simulate_serve(&presets, "mixtral-sim", Framework::Dali, &cfg, None).unwrap();
        assert_eq!(r.requests, 6);
        assert!(r.queue_p99_ns > 0, "slot contention must produce queueing");
    }

    #[test]
    fn serve_sim_rejects_degenerate_configs() {
        let presets = Presets::load_default().unwrap();
        let (model, _) = presets.scenario("mixtral-sim").unwrap();
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, "mixtral-sim").unwrap();
        let trace =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 4, 16, 1);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let fwcfg = FrameworkCfg::paper_default(dims);
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &fwcfg);
        let sim =
            StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7);
        let bad = ServeSimCfg { max_batch: 0, ..Default::default() };
        assert!(ServeSim::new(sim, &trace, bad).is_err());
        // an invalid SLO spec is rejected at construction too
        let presets2 = Presets::load_default().unwrap();
        let bad_slo = ServeSimCfg {
            slo: SloSpec { jitter: 2.0, ..SloSpec::default() },
            ..Default::default()
        };
        assert!(
            simulate_serve(&presets2, "mixtral-sim", Framework::Dali, &bad_slo, None).is_err()
        );
    }

    // --- overload protection -------------------------------------------

    #[test]
    fn unlimited_and_observe_specs_are_digest_transparent() {
        let cfg = overload_cfg();
        let base = mk_sim(&cfg);
        // the unlimited named spec is the default — same report, bit for bit
        let unlimited = mk_sim(&ServeSimCfg {
            slo: SloSpec::named("unlimited").unwrap(),
            ..cfg.clone()
        });
        assert_eq!(base, unlimited, "unlimited SLO must be a no-op");
        // observe mode scores tight deadlines without acting: identical
        // digest, but attainment now reflects the missed budgets
        let observe =
            mk_sim(&ServeSimCfg { slo: SloSpec::named("observe").unwrap(), ..cfg.clone() });
        assert_eq!(
            observe.run.trace_digest, base.run.trace_digest,
            "observe mode must not change a single event"
        );
        assert_eq!(observe.finished, base.finished);
        assert_eq!((observe.rejected, observe.evicted), (0, 0));
        assert!(
            observe.slo_attained < observe.finished,
            "a tight budget on an overloaded cell must show misses \
             ({} attained of {})",
            observe.slo_attained,
            observe.finished
        );
    }

    #[test]
    fn blown_deadline_at_admit_is_rejected_not_admitted() {
        // Regression (satellite): the PR 8 admission loop filled free
        // slots in arrival order even when a request's deadline had
        // already passed at admit time. With a microscopic TTFT budget,
        // every queued request is blown by the time a slot frees up —
        // admission must reject them, not let them burn slots.
        let spec = SloSpec {
            ttft_ms: 0.01, // 10 µs: only an instant admission could meet it
            jitter: 0.0,
            ..SloSpec::default()
        };
        let r = mk_sim(&ServeSimCfg { slo: spec, ..overload_cfg() });
        assert_eq!(
            r.finished + r.rejected + r.evicted,
            r.requests,
            "every request resolves exactly once"
        );
        assert!(r.rejected > 0, "queued-past-deadline requests must be rejected");
        // rejected requests never produced tokens and never held a slot
        assert!(r.tokens_out <= (r.finished + r.evicted) * 8);
    }

    #[test]
    fn guarded_overload_cell_rejects_and_conserves() {
        let r = mk_sim(&ServeSimCfg {
            slo: SloSpec::named("tight").unwrap(),
            ..overload_cfg()
        });
        assert_eq!(r.finished + r.rejected + r.evicted, r.requests);
        assert!(r.rejected > 0, "a tight policy on an overload cell must shed");
        assert!(r.slo_attained <= r.finished);
        assert!(r.goodput_tokens <= r.tokens_out);
        let att = r.slo_attainment();
        assert!((0.0..=1.0).contains(&att) && att.is_finite());
        // determinism holds with the full guard stack active
        let again = mk_sim(&ServeSimCfg {
            slo: SloSpec::named("tight").unwrap(),
            ..overload_cfg()
        });
        assert_eq!(r, again, "guarded cells stay bit-identical");
    }

    #[test]
    fn eviction_frees_slots_for_blown_completion_deadlines() {
        // completion budget only (TTFT unlimited): nothing is rejected
        // for TTFT, but long-running requests blow the completion
        // deadline mid-decode and must be evicted with partial output.
        let base = mk_sim(&overload_cfg());
        let spec = SloSpec {
            total_ms: (base.makespan_ns / 4).max(1) as f64 / 1e6,
            jitter: 0.0,
            ..SloSpec::default()
        };
        let r = mk_sim(&ServeSimCfg { slo: spec, ..overload_cfg() });
        assert_eq!(r.finished + r.rejected + r.evicted, r.requests);
        assert!(
            r.evicted > 0 || r.rejected > 0,
            "a quarter-makespan completion budget must shed load \
             (finished {} rejected {} evicted {})",
            r.finished,
            r.rejected,
            r.evicted
        );
        // evicted requests surrender their slot but keep partial tokens
        assert!(r.tokens_out > 0);
    }

    #[test]
    fn heterogeneous_lengths_change_tokens_only_when_enabled() {
        let uniform = mk_sim(&ServeSimCfg { n_requests: 12, max_batch: 4, ..Default::default() });
        assert_eq!(uniform.tokens_out, 12 * 16, "flat budget without a length distribution");
        let mixed = mk_sim(&ServeSimCfg {
            arrival: ArrivalSpec::parse_spec("len_min=2,len_max=24").unwrap(),
            n_requests: 12,
            max_batch: 4,
            ..Default::default()
        });
        assert_eq!(mixed.finished, 12);
        assert_ne!(
            mixed.tokens_out,
            12 * 16,
            "a 2..=24 draw over 12 requests landing exactly on 192 tokens \
             would be a one-in-many coincidence worth investigating"
        );
        assert!(mixed.tokens_out >= 12 * 2 && mixed.tokens_out <= 12 * 24);
        assert_ne!(
            mixed.run.trace_digest, uniform.run.trace_digest,
            "length draws legitimately change the schedule"
        );
    }
}
