//! Multi-tenant continuous-batching serving simulation in virtual time.
//!
//! Seeded arrival processes ([`ArrivalSpec`]) generate many concurrent
//! request streams; a continuous batcher admits queued requests into free
//! batch slots and retires finished ones *per decode step*; every stream
//! contends for **one shared** [`StepSimulator`] pipeline — one GPU
//! cache, one tiered store, one set of NVMe/PCIe/transcode lanes — so
//! cross-request expert locality (or thrash) is actually modeled instead
//! of assumed away. This is the subsystem the wall-clock [`Batcher`]
//! (`serve/batcher.rs`) cannot be: deterministic, artifact-free, and
//! aware of the memory hierarchy.
//!
//! Request lifecycle joins the trace stream (`request_arrive` /
//! `request_admit` / `request_first_token` / `request_finish` events), so
//! one FNV digest locks scheduling *and* SLO accounting: same-seed serve
//! cells are bit-identical, which `rust/tests/serve_sim.rs` and the CI
//! serve-determinism check enforce.
//!
//! The tick loop is allocation-free in steady state (audited alongside
//! `run_step`): requests, stats, and compose buffers are preallocated at
//! construction, and the one shared [`BatchStep`] is reused for prefill
//! and decode composition alike.
//!
//! [`Batcher`]: super::batcher::Batcher

use anyhow::{bail, Result};

use crate::config::Presets;
use crate::coordinator::frameworks::{Framework, FrameworkCfg};
use crate::coordinator::simrun::{Phase, StepSimulator};
use crate::fault::FaultPlan;
use crate::hw::{CostModel, Ns};
use crate::metrics::{RequestStat, ServeReport};
use crate::store::TieredStore;
use crate::trace::{DigestSink, Event, TraceSink};
use crate::workload::trace::{synthetic_locality_trace, BatchStep};
use crate::workload::Trace;

use super::arrival::ArrivalSpec;

/// Configuration of one serving-simulation run.
#[derive(Debug, Clone)]
pub struct ServeSimCfg {
    /// Arrival process generating the request script.
    pub arrival: ArrivalSpec,
    /// Total requests to serve (the run ends when all have finished).
    pub n_requests: usize,
    /// Continuous-batching slot budget: max requests decoding at once.
    pub max_batch: usize,
    /// Decode tokens requested per request (clamped to the backing
    /// stream's recorded length).
    pub max_tokens: usize,
    /// Seed for the arrival script and the simulator's own RNG stream.
    pub seed: u64,
}

impl Default for ServeSimCfg {
    fn default() -> Self {
        ServeSimCfg {
            arrival: ArrivalSpec::default(),
            n_requests: 32,
            max_batch: 8,
            max_tokens: 16,
            seed: 0x5e11,
        }
    }
}

/// One request currently holding a batch slot.
#[derive(Debug, Clone, Copy)]
struct Active {
    req: usize,
    /// Decode tokens generated so far (== this stream's next step index).
    generated: usize,
    /// Tokens this request will generate before leaving the batch.
    decode_len: usize,
    prompt_len: usize,
}

/// The continuous-batching serving simulator: an arrival queue + a
/// running set, ticked in virtual time over one shared [`StepSimulator`].
pub struct ServeSim<'a, S: TraceSink> {
    sim: StepSimulator<'a, S>,
    trace: &'a Trace,
    cfg: ServeSimCfg,
    /// Sorted arrival instants, one per request (request id = index).
    arrivals: Vec<Ns>,
    /// Next not-yet-admitted request id.
    next_arrival: usize,
    running: Vec<Active>,
    /// Request ids admitted this tick (prefill batch composition).
    admit_buf: Vec<usize>,
    /// `(seq_id, stream step)` pairs for multi-stream decode composition.
    active_buf: Vec<(usize, usize)>,
    /// The one reused compose buffer (prefill and decode alike).
    step: BatchStep,
    stats: Vec<RequestStat>,
    finished: usize,
}

impl<'a, S: TraceSink> ServeSim<'a, S> {
    /// Build a serving run over an already-configured simulator (sink,
    /// store, and faults installed by the caller). Preallocates every
    /// tick-loop buffer.
    pub fn new(
        sim: StepSimulator<'a, S>,
        trace: &'a Trace,
        cfg: ServeSimCfg,
    ) -> Result<Self> {
        if cfg.n_requests == 0 || cfg.max_batch == 0 || cfg.max_tokens == 0 {
            bail!(
                "serve sim needs n_requests/max_batch/max_tokens >= 1 \
                 (got {}/{}/{})",
                cfg.n_requests,
                cfg.max_batch,
                cfg.max_tokens
            );
        }
        if trace.seqs.is_empty() || trace.min_steps() == 0 {
            bail!("serve sim needs a non-empty trace pool with decode steps");
        }
        let mut arrivals = Vec::new();
        cfg.arrival.generate_into(cfg.n_requests, cfg.seed, &mut arrivals);
        let stats = vec![RequestStat::default(); cfg.n_requests];
        Ok(ServeSim {
            sim,
            trace,
            arrivals,
            next_arrival: 0,
            running: Vec::with_capacity(cfg.max_batch),
            admit_buf: Vec::with_capacity(cfg.max_batch),
            active_buf: Vec::with_capacity(cfg.max_batch),
            step: BatchStep::default(),
            stats,
            finished: 0,
            cfg,
        })
    }

    /// Requests that have run to completion so far.
    pub fn finished(&self) -> usize {
        self.finished
    }

    /// Requests admitted into the batch so far (arrivals consumed).
    /// Once this reaches `n_requests`, remaining ticks are pure decode —
    /// the window the allocation audit measures.
    pub fn admitted(&self) -> usize {
        self.next_arrival
    }

    /// One continuous-batching tick: admit due arrivals into free slots
    /// (prefilling the newcomers as one batch step), then advance every
    /// running stream by one decode step on the shared pipeline, retiring
    /// first-token and finish edges at the post-step clock. Returns
    /// `false` once every request has finished.
    pub fn tick(&mut self) -> bool {
        if self.finished == self.cfg.n_requests {
            return false;
        }
        // an empty batch idles the pipeline forward to the next arrival —
        // run_step never moves the clock for an empty step
        if self.running.is_empty() {
            self.sim.advance_to(self.arrivals[self.next_arrival]);
        }
        // admission: due arrivals fill free batch slots in arrival order
        self.admit_buf.clear();
        while self.running.len() < self.cfg.max_batch
            && self.next_arrival < self.cfg.n_requests
            && self.arrivals[self.next_arrival] <= self.sim.now()
        {
            let req = self.next_arrival;
            self.next_arrival += 1;
            let arrival = self.arrivals[req];
            let now = self.sim.now();
            let prompt_len = self.trace.prompt_len(req);
            let decode_len = self.cfg.max_tokens.min(self.trace.decode_len(req)).max(1);
            self.stats[req].arrival_ns = arrival;
            self.stats[req].admit_ns = now;
            self.sim.note_event(Event::RequestArrive {
                req: req as u32,
                at: arrival,
                prompt_len: prompt_len as u32,
                max_tokens: decode_len as u32,
            });
            self.sim.note_event(Event::RequestAdmit {
                req: req as u32,
                at: now,
                queue_ns: now.saturating_sub(arrival),
            });
            self.running.push(Active { req, generated: 0, decode_len, prompt_len });
            self.admit_buf.push(req);
        }
        // prefill the newcomers as one batch step on the shared pipeline
        // (continuous batching without chunked prefill: the prefill step
        // briefly stalls ongoing decodes, which TPOT then reflects)
        if !self.admit_buf.is_empty() {
            self.trace.compose_prefill_into(&self.admit_buf, &mut self.step);
            let kv = self
                .admit_buf
                .iter()
                .map(|&r| self.trace.prompt_len(r))
                .sum::<usize>()
                / (2 * self.admit_buf.len());
            self.sim.run_step(&self.step, kv.max(1), Phase::Prefill);
        }
        // one decode step over every running stream, each at its own
        // per-request offset
        self.active_buf.clear();
        let mut kv_sum = 0usize;
        for a in &self.running {
            self.active_buf.push((a.req, a.generated));
            kv_sum += a.prompt_len + a.generated;
        }
        self.trace.compose_multi_into(&self.active_buf, &mut self.step);
        let kv = (kv_sum / self.running.len().max(1)).max(1);
        self.sim.run_step(&self.step, kv, Phase::Decode);
        let now = self.sim.now();
        // retire first-token and finish edges at the post-step clock
        let mut i = 0;
        while i < self.running.len() {
            self.running[i].generated += 1;
            let Active { req, generated, decode_len, .. } = self.running[i];
            if generated == 1 {
                self.stats[req].first_token_ns = now;
                let ttft = now.saturating_sub(self.stats[req].arrival_ns);
                self.sim.note_event(Event::RequestFirstToken {
                    req: req as u32,
                    at: now,
                    ttft_ns: ttft,
                });
            }
            if generated >= decode_len {
                self.stats[req].finish_ns = now;
                self.stats[req].tokens = generated as u64;
                self.sim.note_event(Event::RequestFinish {
                    req: req as u32,
                    at: now,
                    tokens: generated as u32,
                });
                self.finished += 1;
                self.running.swap_remove(i);
            } else {
                i += 1;
            }
        }
        self.finished < self.cfg.n_requests
    }

    /// Drive the run to completion.
    pub fn run(&mut self) {
        while self.tick() {}
    }

    /// Finish: per-request SLO aggregation over the underlying replay's
    /// metrics (call after [`Self::run`]; unfinished requests would
    /// report zero timestamps).
    pub fn finish(self) -> ServeReport {
        ServeReport::from_stats(&self.stats, self.sim.finish())
    }

    /// [`Self::finish`] that also hands back the sink.
    pub fn finish_with_sink(self) -> (ServeReport, S) {
        let (run, sink) = self.sim.finish_with_sink();
        (ServeReport::from_stats(&self.stats, run), sink)
    }
}

/// One self-contained serving cell: build the scenario's cost model,
/// synthetic stream pool, and policy bundle, attach the shared tiered
/// store (when the scenario is memory-limited) and an optional fault
/// plan, serve every request, and report — with the whole-run digest
/// covering scheduling and request lifecycle alike. This is the unit the
/// `expt serve` sweep, `dali serve --sim`, and the serve bench tier all
/// share.
pub fn simulate_serve(
    presets: &Presets,
    scenario: &str,
    fw: Framework,
    cfg: &ServeSimCfg,
    faults: Option<FaultPlan>,
) -> Result<ServeReport> {
    let (model, hw) = presets.scenario(scenario)?;
    let dims = &model.sim;
    let cost = CostModel::for_scenario(presets, scenario)?;
    // stream pool: 16 synthetic locality streams, long enough that no
    // request is clamped below its requested max_tokens
    let trace = synthetic_locality_trace(
        dims.layers,
        dims.n_routed,
        dims.top_k,
        16,
        cfg.max_tokens.max(16),
        cfg.seed ^ 0x7ace,
    );
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let fwcfg = FrameworkCfg::paper_default(dims);
    let bundle = fw.bundle(dims, &cost, &freq, &fwcfg);
    let mut sim =
        StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7)
            .with_sink(DigestSink::new());
    if let Some(plan) = faults {
        sim = sim.with_faults(plan);
    }
    let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
    if !store.is_unlimited() {
        sim = sim.with_store(store);
    }
    let mut serve = ServeSim::new(sim, &trace, cfg.clone())?;
    serve.run();
    Ok(serve.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_sim(cfg: &ServeSimCfg) -> ServeReport {
        let presets = Presets::load_default().unwrap();
        simulate_serve(&presets, "mixtral-sim-ram16", Framework::Dali, cfg, None).unwrap()
    }

    #[test]
    fn every_request_finishes_with_sane_lifecycle() {
        let cfg = ServeSimCfg { n_requests: 12, max_batch: 4, max_tokens: 8, ..Default::default() };
        let r = mk_sim(&cfg);
        assert_eq!(r.requests, 12);
        assert_eq!(r.tokens_out, 12 * 8, "every request generates its full budget");
        assert!(r.makespan_ns > 0);
        assert!(r.ttft_p50_ns > 0 && r.ttft_p99_ns >= r.ttft_p50_ns);
        assert!(r.tpot_p50_ns > 0 && r.tpot_p99_ns >= r.tpot_p50_ns);
        assert!(r.run.trace_digest.is_some(), "serve cells are digest-locked");
        assert_eq!(r.run.tokens_out, r.tokens_out, "sim and SLO views agree on tokens");
    }

    #[test]
    fn same_seed_cells_are_bit_identical() {
        let cfg = ServeSimCfg { n_requests: 10, max_batch: 4, ..Default::default() };
        let a = mk_sim(&cfg);
        let b = mk_sim(&cfg);
        assert_eq!(a, b, "same-seed serve cells must be bit-identical");
        let c = mk_sim(&ServeSimCfg { seed: cfg.seed + 1, ..cfg });
        assert_ne!(a.run.trace_digest, c.run.trace_digest, "seed must matter");
    }

    #[test]
    fn higher_load_does_not_improve_tail_ttft() {
        let base = ServeSimCfg { n_requests: 24, max_batch: 4, max_tokens: 8, ..Default::default() };
        let light = mk_sim(&ServeSimCfg { arrival: base.arrival.with_rate(1.0), ..base.clone() });
        let heavy = mk_sim(&ServeSimCfg { arrival: base.arrival.with_rate(512.0), ..base });
        assert!(
            heavy.ttft_p99_ns >= light.ttft_p99_ns,
            "overload must not beat light load on tail TTFT: {} < {}",
            heavy.ttft_p99_ns,
            light.ttft_p99_ns
        );
        assert!(heavy.queue_p99_ns >= light.queue_p99_ns);
    }

    #[test]
    fn batch_slots_are_respected_and_queue_drains_in_order() {
        // a single-slot server serializes everything: makespan is at
        // least the sum of any one request's span, and queueing shows up
        let presets = Presets::load_default().unwrap();
        let cfg = ServeSimCfg {
            arrival: ArrivalSpec::default().with_rate(1000.0),
            n_requests: 6,
            max_batch: 1,
            max_tokens: 4,
            ..Default::default()
        };
        let r =
            simulate_serve(&presets, "mixtral-sim", Framework::Dali, &cfg, None).unwrap();
        assert_eq!(r.requests, 6);
        assert!(r.queue_p99_ns > 0, "slot contention must produce queueing");
    }

    #[test]
    fn serve_sim_rejects_degenerate_configs() {
        let presets = Presets::load_default().unwrap();
        let (model, _) = presets.scenario("mixtral-sim").unwrap();
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, "mixtral-sim").unwrap();
        let trace =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 4, 16, 1);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let fwcfg = FrameworkCfg::paper_default(dims);
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &fwcfg);
        let sim =
            StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7);
        let bad = ServeSimCfg { max_batch: 0, ..Default::default() };
        assert!(ServeSim::new(sim, &trace, bad).is_err());
    }
}
