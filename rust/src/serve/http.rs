//! Minimal HTTP/1.1 request/response handling over std::net.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Read one HTTP request from a stream (supports Content-Length bodies).
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line: {line:?}");
    }
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body).context("reading body")?;
    }
    Ok(Request { method, path, body })
}

/// Write an HTTP response with a JSON (or plain) body.
pub fn write_response(stream: &mut TcpStream, status: u16, content_type: &str, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        500 => "Internal Server Error",
        _ => "Unknown",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Tiny blocking HTTP client for tests/examples: POST or GET, returns body.
pub fn http_call(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<String> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let b = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{b}",
        b.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf)?;
    let idx = buf.find("\r\n\r\n").context("no header/body separator")?;
    Ok(buf[idx + 4..].to_string())
}
