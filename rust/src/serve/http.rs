//! Minimal HTTP/1.1 request/response handling over std::net.
//!
//! Parsing is defensive: the request head is read through a byte-capped
//! reader (so an endless header stream cannot grow memory), header count
//! and line length are bounded, and the body allocation is capped at
//! [`MAX_BODY_BYTES`] *before* trusting Content-Length — a hostile
//! `Content-Length: 99999999999` gets a 413, not a multi-GB `vec!`.
//! Parse failures carry their HTTP status so the server can answer with
//! the right code instead of dropping the connection.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

/// Largest request body accepted (larger gets 413 Payload Too Large).
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Most header lines accepted (more gets 431).
pub const MAX_HEADER_LINES: usize = 64;
/// Longest single header (or request) line accepted (longer gets 431).
pub const MAX_HEADER_LINE_BYTES: usize = 8 * 1024;

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// A request-reading failure with the HTTP status the client should see.
#[derive(Debug, Clone)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.status, self.msg)
    }
}

impl std::error::Error for HttpError {}

fn err(status: u16, msg: impl Into<String>) -> HttpError {
    HttpError { status, msg: msg.into() }
}

/// Read one HTTP request from a stream (supports Content-Length bodies).
/// Bounded: header bytes/lines and body size are all capped; see the
/// module doc.
pub fn read_request(stream: &mut TcpStream) -> Result<Request, HttpError> {
    let reader = BufReader::new(
        stream.try_clone().map_err(|e| err(500, format!("stream clone: {e}")))?,
    );
    // cap the whole head: even a stream that never sends a newline can
    // only make read_line buffer this many bytes
    let mut head = reader.take(((MAX_HEADER_LINES + 1) * MAX_HEADER_LINE_BYTES) as u64);
    let mut line = String::new();
    head.read_line(&mut line).map_err(|e| err(400, format!("reading request line: {e}")))?;
    if line.len() > MAX_HEADER_LINE_BYTES {
        return Err(err(431, "request line too long"));
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(err(400, format!("malformed request line: {line:?}")));
    }
    let mut content_length = 0usize;
    let mut n_headers = 0usize;
    loop {
        let mut h = String::new();
        let n = head.read_line(&mut h).map_err(|e| err(400, format!("reading header: {e}")))?;
        if n == 0 {
            // EOF (or the head cap) before the blank line ending headers
            return Err(err(431, "request head too large or truncated"));
        }
        if h.len() > MAX_HEADER_LINE_BYTES {
            return Err(err(431, "header line too long"));
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > MAX_HEADER_LINES {
            return Err(err(431, "too many headers"));
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| err(400, format!("bad content-length: {:?}", v.trim())))?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(err(
            413,
            format!("body of {content_length} bytes exceeds cap of {MAX_BODY_BYTES}"),
        ));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        head.into_inner()
            .read_exact(&mut body)
            .map_err(|e| err(400, format!("reading body: {e}")))?;
    }
    Ok(Request { method, path, body })
}

/// Write an HTTP response with a JSON (or plain) body.
pub fn write_response(stream: &mut TcpStream, status: u16, content_type: &str, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Tiny blocking HTTP client for tests/examples: POST or GET, returns body.
pub fn http_call(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<String> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let b = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{b}",
        b.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf)?;
    let idx = buf.find("\r\n\r\n").context("no header/body separator")?;
    Ok(buf[idx + 4..].to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Run `read_request` against raw bytes sent over a real loopback
    /// socket pair.
    fn parse_raw(raw: &[u8]) -> Result<Request, HttpError> {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&raw).unwrap();
        });
        let (mut stream, _) = listener.accept().unwrap();
        let r = read_request(&mut stream);
        writer.join().unwrap();
        r
    }

    #[test]
    fn well_formed_request_parses() {
        let r = parse_raw(b"POST /generate HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd").unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/generate");
        assert_eq!(r.body, b"abcd");
    }

    #[test]
    fn hostile_content_length_is_rejected_not_allocated() {
        let e = parse_raw(b"POST /generate HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n")
            .unwrap_err();
        assert_eq!(e.status, 413, "{e}");
    }

    #[test]
    fn unparseable_content_length_is_a_400() {
        let e = parse_raw(b"POST / HTTP/1.1\r\nContent-Length: lots\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 400, "{e}");
    }

    #[test]
    fn header_count_is_bounded() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..(MAX_HEADER_LINES + 1) {
            raw.extend_from_slice(format!("X-H{i}: v\r\n").as_bytes());
        }
        raw.extend_from_slice(b"\r\n");
        let e = parse_raw(&raw).unwrap_err();
        assert_eq!(e.status, 431, "{e}");
    }

    #[test]
    fn header_line_length_is_bounded() {
        let mut raw = b"GET / HTTP/1.1\r\nX-Big: ".to_vec();
        raw.extend(std::iter::repeat(b'a').take(MAX_HEADER_LINE_BYTES + 16));
        raw.extend_from_slice(b"\r\n\r\n");
        let e = parse_raw(&raw).unwrap_err();
        assert_eq!(e.status, 431, "{e}");
    }

    #[test]
    fn truncated_head_is_an_error_not_a_hang() {
        // no terminating blank line and the peer closes: parser must
        // return, not loop
        let e = parse_raw(b"GET / HTTP/1.1\r\nX-H: v\r\n").unwrap_err();
        assert_eq!(e.status, 431, "{e}");
    }
}
