//! HTTP server: routes requests into the [`Batcher`].
//!
//! Endpoints:
//! * `GET /health` — liveness + preset info;
//! * `GET /metrics` — aggregate serving counters (JSON);
//! * `POST /generate` — `{"prompt": [int token ids], "max_tokens": n}` →
//!   `{"tokens": [...], "queue_ms": ..., "exec_ms": ..., "wall_ms":
//!   queue+exec, "sim_ms": ..., "sim_tokens_per_s": ..., "batch_size": ...}`.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::batcher::{Batcher, BatcherCfg, GenRequest};
use super::http::{read_request, write_response};
use crate::coordinator::frameworks::Framework;
use crate::util::json::Value;

fn handle(batcher: &Arc<Batcher>, preset: &str, stream: &mut TcpStream) -> Result<()> {
    let req = match read_request(stream) {
        Ok(r) => r,
        Err(e) => {
            // bounded-parse failures (413 oversized body, 431 header
            // limits, 400 malformed) answer with their status instead of
            // dropping the connection
            return write_response(stream, e.status, "application/json",
                &Value::obj(vec![("error", Value::str(e.msg))]).to_json());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            let body = Value::obj(vec![
                ("status", Value::str("ok")),
                ("preset", Value::str(preset)),
            ]);
            write_response(stream, 200, "application/json", &body.to_json())
        }
        ("GET", "/metrics") => {
            let m = batcher.metrics.lock().unwrap().clone();
            let body = Value::obj(vec![
                ("requests", Value::num(m.requests as f64)),
                ("batches", Value::num(m.batches as f64)),
                ("tokens_out", Value::num(m.tokens_out as f64)),
                ("errors", Value::num(m.errors as f64)),
                ("queue_ms_sum", Value::num(m.queue_ms_sum)),
                ("exec_ms_sum", Value::num(m.exec_ms_sum)),
                ("wall_ms_sum", Value::num(m.queue_ms_sum + m.exec_ms_sum)),
                ("sim_ms_sum", Value::num(m.sim_ms_sum)),
                (
                    "avg_batch",
                    Value::num(if m.batches > 0 {
                        m.requests as f64 / m.batches as f64
                    } else {
                        0.0
                    }),
                ),
            ]);
            write_response(stream, 200, "application/json", &body.to_json())
        }
        ("POST", "/generate") => {
            let text = String::from_utf8(req.body).context("body not utf-8")?;
            let v = match Value::parse(&text) {
                Ok(v) => v,
                Err(e) => {
                    return write_response(stream, 400, "application/json",
                        &Value::obj(vec![("error", Value::str(format!("bad json: {e}")))]).to_json());
                }
            };
            let prompt: Vec<i32> = match v.get("prompt").and_then(|p| p.as_usize_vec()) {
                Ok(p) => p.into_iter().map(|t| t as i32).collect(),
                Err(e) => {
                    return write_response(stream, 400, "application/json",
                        &Value::obj(vec![("error", Value::str(format!("{e}")))]).to_json());
                }
            };
            let max_tokens = v.opt("max_tokens").and_then(|x| x.as_usize().ok()).unwrap_or(16);
            let rx = batcher.submit(GenRequest { prompt, max_tokens });
            match rx.recv() {
                Ok(Ok(resp)) => {
                    let body = Value::obj(vec![
                        (
                            "tokens",
                            Value::arr(resp.tokens.iter().map(|&t| Value::num(t as f64)).collect()),
                        ),
                        ("queue_ms", Value::num(resp.queue_ms)),
                        ("exec_ms", Value::num(resp.exec_ms)),
                        ("wall_ms", Value::num(resp.wall_ms)),
                        ("sim_ms", Value::num(resp.sim_ms)),
                        ("sim_tokens_per_s", Value::num(resp.sim_tokens_per_s)),
                        ("batch_size", Value::num(resp.batch_size as f64)),
                    ]);
                    write_response(stream, 200, "application/json", &body.to_json())
                }
                Ok(Err(e)) => write_response(stream, 500, "application/json",
                    &Value::obj(vec![("error", Value::str(e))]).to_json()),
                Err(_) => write_response(stream, 500, "application/json",
                    &Value::obj(vec![("error", Value::str("worker gone"))]).to_json()),
            }
        }
        _ => write_response(stream, 404, "application/json",
            &Value::obj(vec![("error", Value::str("not found"))]).to_json()),
    }
}

/// Start serving and never return (unless bind/engine setup fails).
pub fn serve_blocking(preset: &str, port: u16, framework: Framework) -> Result<()> {
    let batcher = Batcher::start(preset, BatcherCfg { framework, ..Default::default() })?;
    let listener =
        TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("binding port {port}"))?;
    eprintln!("[serve] {preset} via {} on http://127.0.0.1:{port}", framework.name());
    accept_loop(listener, batcher, preset)
}

/// Bind to an ephemeral port and return (port, join-handle). Used by tests
/// and the serve_batch example.
pub fn serve_background(preset: &str, framework: Framework, cfg: BatcherCfg) -> Result<u16> {
    let batcher = Batcher::start(preset, BatcherCfg { framework, ..cfg })?;
    let listener = TcpListener::bind(("127.0.0.1", 0)).context("binding ephemeral port")?;
    let port = listener.local_addr()?.port();
    let preset = preset.to_string();
    std::thread::spawn(move || {
        let _ = accept_loop(listener, batcher, &preset);
    });
    Ok(port)
}

fn accept_loop(listener: TcpListener, batcher: Arc<Batcher>, preset: &str) -> Result<()> {
    for stream in listener.incoming() {
        match stream {
            Ok(mut s) => {
                let b = batcher.clone();
                let p = preset.to_string();
                std::thread::spawn(move || {
                    if let Err(e) = handle(&b, &p, &mut s) {
                        eprintln!("[serve] connection error: {e:#}");
                    }
                });
            }
            Err(e) => eprintln!("[serve] accept error: {e}"),
        }
    }
    Ok(())
}
