//! Seeded request-arrival processes for the serving simulation.
//!
//! An [`ArrivalSpec`] describes *when* requests reach the server in
//! virtual time: steady Poisson traffic, bursty traffic (Poisson burst
//! heads with several requests landing together), or a diurnal rate
//! modulated over a cycle. Generation is driven by a [`DetRng`] stream,
//! so the same (spec, seed) pair always yields the same arrival script —
//! the serving sweep's bit-identical-cells guarantee starts here.
//!
//! Named presets live in the `arrival` section of `configs/presets.json`
//! (resolved through [`crate::config::Presets::arrival`], with the same
//! presets → built-ins → inline-spec fallback chain as fault profiles).

use anyhow::{bail, Result};

use crate::hw::Ns;
use crate::util::DetRng;

/// The shape of the arrival process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Memoryless arrivals at a constant mean rate.
    Poisson,
    /// Poisson burst heads; each head brings a small pack of requests
    /// spaced 100 µs apart (a client fanning out, a retry storm).
    Bursty,
    /// Poisson thinned by a cosine day/night cycle: the instantaneous
    /// rate swings between `rate * (1 - depth)` and `rate`.
    Diurnal,
}

impl ArrivalKind {
    pub fn name(self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Diurnal => "diurnal",
        }
    }
}

/// A parsed arrival process: kind + rate knobs. `Copy`, validated at
/// parse time, and renderable back to the `key=value` spec form.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalSpec {
    pub kind: ArrivalKind,
    /// Mean request rate (requests per virtual second) at the peak; the
    /// long-run mean for poisson/bursty, the cycle peak for diurnal.
    pub rate: f64,
    /// Bursty only: mean requests per burst (>= 1).
    pub burst: f64,
    /// Diurnal only: cycle period in virtual seconds.
    pub period_s: f64,
    /// Diurnal only: modulation depth in [0, 1) — 0 degenerates to
    /// Poisson, 0.9 means the trough runs at 10% of the peak rate.
    pub depth: f64,
    /// Heterogeneous per-request decode lengths: lower bound of the
    /// seeded uniform draw. 0 (with `len_max` 0) disables the
    /// distribution — every request then uses the run's flat
    /// `max_tokens`, and existing digests are untouched.
    pub len_min: usize,
    /// Upper bound (inclusive) of the per-request length draw; 0
    /// disables.
    pub len_max: usize,
}

impl Default for ArrivalSpec {
    fn default() -> Self {
        ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate: 4.0,
            burst: 4.0,
            period_s: 2.0,
            depth: 0.8,
            len_min: 0,
            len_max: 0,
        }
    }
}

impl ArrivalSpec {
    /// Built-in named processes (work without a presets file, and are
    /// mirrored by the `arrival` section of `configs/presets.json`).
    pub fn named(name: &str) -> Option<ArrivalSpec> {
        match name {
            "steady" | "poisson" => {
                Some(ArrivalSpec { kind: ArrivalKind::Poisson, ..Default::default() })
            }
            "bursty" => Some(ArrivalSpec {
                kind: ArrivalKind::Bursty,
                rate: 8.0,
                ..Default::default()
            }),
            "diurnal" => {
                Some(ArrivalSpec { kind: ArrivalKind::Diurnal, ..Default::default() })
            }
            // bursty traffic with heterogeneous request lengths — the
            // overload sweep's mixed-workload cell
            "bursty-mixed" => Some(ArrivalSpec {
                len_min: 4,
                len_max: 32,
                ..ArrivalSpec::named("bursty").unwrap()
            }),
            _ => None,
        }
    }

    /// Parse a `key=value,...` spec, e.g. `kind=bursty,rate=8,burst=4`.
    /// Unknown keys are errors (a typo must not silently mean defaults).
    pub fn parse_spec(spec: &str) -> Result<ArrivalSpec> {
        let mut s = ArrivalSpec::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = match part.split_once('=') {
                Some(kv) => kv,
                None => bail!("arrival spec entry '{part}' is not key=value"),
            };
            let (k, v) = (k.trim(), v.trim());
            match k {
                "kind" => {
                    s.kind = match v {
                        "poisson" => ArrivalKind::Poisson,
                        "bursty" => ArrivalKind::Bursty,
                        "diurnal" => ArrivalKind::Diurnal,
                        _ => bail!("unknown arrival kind '{v}' (poisson|bursty|diurnal)"),
                    }
                }
                "rate" => s.rate = v.parse()?,
                "burst" => s.burst = v.parse()?,
                "period_s" => s.period_s = v.parse()?,
                "depth" => s.depth = v.parse()?,
                "len_min" => s.len_min = v.parse()?,
                "len_max" => s.len_max = v.parse()?,
                _ => bail!("unknown arrival spec key '{k}'"),
            }
        }
        s.validate()?;
        Ok(s)
    }

    fn validate(&self) -> Result<()> {
        if !(self.rate > 0.0 && self.rate.is_finite()) {
            bail!("arrival rate must be positive, got {}", self.rate);
        }
        if !(self.burst >= 1.0 && self.burst.is_finite()) {
            bail!("arrival burst must be >= 1, got {}", self.burst);
        }
        if !(self.period_s > 0.0 && self.period_s.is_finite()) {
            bail!("arrival period_s must be positive, got {}", self.period_s);
        }
        if !(0.0..1.0).contains(&self.depth) {
            bail!("arrival depth must be in [0, 1), got {}", self.depth);
        }
        if (self.len_min != 0 || self.len_max != 0)
            && !(1 <= self.len_min && self.len_min <= self.len_max)
        {
            bail!(
                "arrival lengths need 1 <= len_min <= len_max (got {}..{}); \
                 both 0 disables the distribution",
                self.len_min,
                self.len_max
            );
        }
        Ok(())
    }

    /// True when the per-request length distribution is enabled.
    pub fn has_lengths(&self) -> bool {
        self.len_max > 0
    }

    /// Same spec with the mean rate replaced — the load axis of the
    /// `expt serve` sweep.
    pub fn with_rate(mut self, rate: f64) -> Self {
        self.rate = rate;
        self
    }

    /// Generate `n` arrival instants (virtual ns, non-decreasing) into
    /// `out`, deterministically from `seed`.
    pub fn generate_into(&self, n: usize, seed: u64, out: &mut Vec<Ns>) {
        out.clear();
        out.reserve(n);
        let mut rng = DetRng::new(seed ^ 0xa221_7a1e);
        // exponential inter-arrival with mean 1/rate (u in [0,1) so the
        // log argument stays strictly positive)
        let exp_gap =
            |rng: &mut DetRng, rate: f64| -> f64 { -(1.0 - rng.f64()).ln() / rate };
        let mut t = 0.0f64;
        match self.kind {
            ArrivalKind::Poisson => {
                while out.len() < n {
                    t += exp_gap(&mut rng, self.rate);
                    out.push((t * 1e9) as Ns);
                }
            }
            ArrivalKind::Bursty => {
                // burst heads at rate/burst keep the long-run mean at
                // `rate`; burst sizes are uniform on [1, 2*burst] (mean
                // ~burst), members 100 µs apart
                let head_rate = self.rate / self.burst;
                while out.len() < n {
                    t += exp_gap(&mut rng, head_rate);
                    let span = (2.0 * self.burst) as usize;
                    let size = 1 + rng.usize_below(span.max(1));
                    for i in 0..size {
                        if out.len() < n {
                            out.push(((t + i as f64 * 100e-6) * 1e9) as Ns);
                        }
                    }
                }
            }
            ArrivalKind::Diurnal => {
                // thinning: homogeneous arrivals at the peak rate,
                // accepted with probability lambda(t)/rate; lambda dips
                // to rate*(1-depth) at the start of each cycle
                while out.len() < n {
                    t += exp_gap(&mut rng, self.rate);
                    let phase = (t / self.period_s) * 2.0 * std::f64::consts::PI;
                    let lambda_frac = 1.0 - self.depth * 0.5 * (1.0 + phase.cos());
                    if rng.chance(lambda_frac) {
                        out.push((t * 1e9) as Ns);
                    }
                }
            }
        }
    }

    /// [`Self::generate_into`] returning a fresh vec.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<Ns> {
        let mut v = Vec::new();
        self.generate_into(n, seed, &mut v);
        v
    }

    /// Per-request decode lengths, uniform on `[len_min, len_max]`, drawn
    /// from an RNG stream *independent* of the arrival-instant stream (so
    /// enabling lengths never perturbs arrival times). `out` stays empty
    /// when the distribution is disabled — the caller falls back to its
    /// flat `max_tokens` and existing digests are untouched.
    pub fn lengths_into(&self, n: usize, seed: u64, out: &mut Vec<usize>) {
        out.clear();
        if !self.has_lengths() {
            return;
        }
        out.reserve(n);
        let mut rng = DetRng::new(seed ^ 0x1e57_71e5);
        let span = self.len_max - self.len_min + 1;
        for _ in 0..n {
            out.push(self.len_min + rng.usize_below(span));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_knobs() {
        let s = ArrivalSpec::parse_spec("kind=bursty,rate=8,burst=4").unwrap();
        assert_eq!(s.kind, ArrivalKind::Bursty);
        assert_eq!(s.rate, 8.0);
        assert_eq!(s.burst, 4.0);
        let d = ArrivalSpec::parse_spec("kind=diurnal,rate=2,period_s=5,depth=0.5").unwrap();
        assert_eq!(d.kind, ArrivalKind::Diurnal);
        assert_eq!(d.period_s, 5.0);
        assert!(ArrivalSpec::parse_spec("kind=warp").is_err());
        assert!(ArrivalSpec::parse_spec("rate=-1").is_err());
        assert!(ArrivalSpec::parse_spec("depth=1.5,kind=diurnal").is_err());
        assert!(ArrivalSpec::parse_spec("frobnicate=1").is_err());
        let lens = ArrivalSpec::parse_spec("kind=bursty,len_min=4,len_max=32").unwrap();
        assert_eq!((lens.len_min, lens.len_max), (4, 32));
        assert!(lens.has_lengths());
        assert!(ArrivalSpec::parse_spec("len_min=8,len_max=4").is_err());
        assert!(ArrivalSpec::parse_spec("len_max=4").is_err(), "len_min 0 with len_max set");
        assert!(ArrivalSpec::parse_spec("len_min=4").is_err(), "len_max 0 with len_min set");
    }

    #[test]
    fn named_processes_exist() {
        assert_eq!(ArrivalSpec::named("steady").unwrap().kind, ArrivalKind::Poisson);
        assert_eq!(ArrivalSpec::named("bursty").unwrap().kind, ArrivalKind::Bursty);
        assert_eq!(ArrivalSpec::named("diurnal").unwrap().kind, ArrivalKind::Diurnal);
        let mixed = ArrivalSpec::named("bursty-mixed").unwrap();
        assert_eq!(mixed.kind, ArrivalKind::Bursty);
        assert!(mixed.has_lengths() && mixed.len_min == 4 && mixed.len_max == 32);
        assert!(ArrivalSpec::named("no-such").is_none());
    }

    #[test]
    fn length_draws_are_seeded_bounded_and_off_by_default() {
        // disabled (the default): the out vec stays empty, signalling the
        // caller to use its flat max_tokens — digest-transparent
        let mut lens = vec![99; 4];
        ArrivalSpec::default().lengths_into(16, 7, &mut lens);
        assert!(lens.is_empty(), "disabled distribution must clear the buffer");
        let spec = ArrivalSpec::named("bursty-mixed").unwrap();
        let mut a = Vec::new();
        let mut b = Vec::new();
        spec.lengths_into(256, 0x5eed, &mut a);
        spec.lengths_into(256, 0x5eed, &mut b);
        assert_eq!(a, b, "same seed, same lengths");
        assert_eq!(a.len(), 256);
        assert!(a.iter().all(|&l| (4..=32).contains(&l)), "draws stay in [len_min, len_max]");
        assert!(a.iter().any(|&l| l != a[0]), "the distribution actually varies");
        let mut c = Vec::new();
        spec.lengths_into(256, 0x5eee, &mut c);
        assert_ne!(a, c, "seed must matter");
        // the length stream is independent of the arrival stream: enabling
        // it must not move a single arrival instant
        let plain = ArrivalSpec::named("bursty").unwrap();
        assert_eq!(spec.generate(64, 9), plain.generate(64, 9));
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        for name in ["steady", "bursty", "diurnal"] {
            let spec = ArrivalSpec::named(name).unwrap();
            let a = spec.generate(64, 0x5eed);
            let b = spec.generate(64, 0x5eed);
            assert_eq!(a, b, "{name}: same seed, same script");
            assert_eq!(a.len(), 64);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{name}: non-decreasing");
            let c = spec.generate(64, 0x5eee);
            assert_ne!(a, c, "{name}: different seed, different script");
        }
    }

    #[test]
    fn mean_rate_is_roughly_honoured() {
        let spec = ArrivalSpec::named("steady").unwrap().with_rate(10.0);
        let a = spec.generate(1000, 7);
        let span_s = *a.last().unwrap() as f64 / 1e9;
        let rate = 1000.0 / span_s;
        assert!((5.0..20.0).contains(&rate), "poisson observed rate {rate}");
        // bursty arrivals cluster: many gaps are tiny, some are long
        let b = ArrivalSpec::named("bursty").unwrap().generate(1000, 7);
        let tiny = b.windows(2).filter(|w| w[1] - w[0] <= 100_000).count();
        assert!(tiny > 200, "bursty must cluster ({tiny} tight gaps)");
    }
}
