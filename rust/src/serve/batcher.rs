//! Dynamic request batcher + engine worker.
//!
//! Requests are grouped by (prompt length, max_tokens); a group is
//! dispatched when it reaches `max_batch` or its oldest request has waited
//! `max_wait`. The worker thread sleeps on a condvar between dispatches
//! (woken by `submit` and timed out at the oldest request's deadline — no
//! polling), owns the live engine, and runs a fresh [`StepSimulator`] per
//! batch, so each response carries the simulated local-PC latency
//! alongside the wall-clock numbers.
//!
//! Latency is reported in two explicit components, both per request:
//! `queue_ms` (enqueue → batch dispatch) and `exec_ms` (dispatch →
//! response, shared by the whole batch). `wall_ms` is always their sum,
//! and `/metrics` accumulates the same two components — one definition,
//! used everywhere.
//!
//! The engine side is abstracted behind [`BatchRunner`] so the batching,
//! shutdown, and accounting logic is testable without PJRT; the real
//! [`InferenceEngine`] (holding `Rc` PJRT handles, so `!Send`) is
//! constructed by a factory *inside* the worker thread, with readiness
//! signalled back so `start` fails fast on load errors.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ModelDims, Presets};
use crate::coordinator::engine::InferenceEngine;
use crate::coordinator::frameworks::{Framework, FrameworkCfg};
use crate::coordinator::simrun::{Phase, StepSimulator};
use crate::hw::CostModel;
use crate::workload::prep;
use crate::workload::trace::BatchStep;

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    /// Wall-clock time this request waited in the arrival queue
    /// (enqueue → batch dispatch).
    pub queue_ms: f64,
    /// Wall-clock execution time of the batch that served this request
    /// (dispatch → response).
    pub exec_ms: f64,
    /// Total wall-clock latency: always `queue_ms + exec_ms`.
    pub wall_ms: f64,
    /// Simulated local-PC time for the batch that served this request.
    pub sim_ms: f64,
    /// Simulated decode throughput of that batch.
    pub sim_tokens_per_s: f64,
    pub batch_size: usize,
}

#[derive(Debug, Clone)]
pub struct BatcherCfg {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub framework: Framework,
    /// Hardware preset timing the virtual pass (a `Presets::hw` name).
    pub hw: String,
    /// Admission control: max requests pending across all groups before
    /// `submit` rejects (503). 0 = unbounded — the pre-SLO behavior.
    pub queue_cap: usize,
    /// Load shedding: a request that waited in the queue longer than
    /// this is rejected at dispatch instead of run. ZERO = off.
    pub queue_deadline: Duration,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            framework: Framework::Dali,
            hw: "local-pc".to_string(),
            queue_cap: 0,
            queue_deadline: Duration::ZERO,
        }
    }
}

struct Pending {
    req: GenRequest,
    resp_tx: Sender<Result<GenResponse, String>>,
    enqueued: Instant,
}

/// Aggregate serving metrics (exposed at `/metrics`). Queue and exec
/// sums are per-request, matching the per-response split exactly:
/// `queue_ms_sum + exec_ms_sum` over `requests` is the mean wall latency.
#[derive(Debug, Default, Clone)]
pub struct ServeMetrics {
    pub requests: u64,
    pub batches: u64,
    /// Tokens actually generated (not the requested budget).
    pub tokens_out: u64,
    pub queue_ms_sum: f64,
    pub exec_ms_sum: f64,
    pub sim_ms_sum: f64,
    pub errors: u64,
    /// Requests turned away by overload protection (queue cap at submit,
    /// queue deadline at dispatch) — distinct from `errors`, which
    /// counts engine failures on work that was admitted.
    pub rejected: u64,
}

/// Outcome of one executed batch, as produced by a [`BatchRunner`].
pub struct BatchOutcome {
    pub generated: Vec<Vec<i32>>,
    pub sim_ms: f64,
    pub sim_tokens_per_s: f64,
}

/// The engine-facing half of the batcher: run one batch of prompts and
/// report what was generated plus the simulated timing. Implemented by
/// the live-engine runner and by in-test fakes.
pub trait BatchRunner {
    fn run(&mut self, prompts: &[Vec<i32>], max_tokens: usize) -> Result<BatchOutcome, String>;
}

/// Live numerics + virtual-time replay. Owns one reused [`BatchStep`]
/// and compose buffers across batches; the replay covers each sequence
/// for exactly the decode steps it actually generated (so the simulated
/// pass and the token accounting describe the same work).
struct EngineRunner {
    engine: InferenceEngine,
    cost: CostModel,
    calib_freq: Vec<Vec<f64>>,
    fwcfg: FrameworkCfg,
    dims: ModelDims,
    framework: Framework,
    step: BatchStep,
    ids: Vec<usize>,
    active: Vec<(usize, usize)>,
}

impl BatchRunner for EngineRunner {
    fn run(&mut self, prompts: &[Vec<i32>], max_tokens: usize) -> Result<BatchOutcome, String> {
        // live numerics (record a trace so the simulator can time it)
        let out = self
            .engine
            .run_batch(prompts, max_tokens, true)
            .map_err(|e| format!("engine error: {e:#}"))?;
        let trace = out.trace.as_ref().expect("trace requested");
        let nb = prompts.len();
        let bundle = self.framework.bundle(&self.dims, &self.cost, &self.calib_freq, &self.fwcfg);
        let mut sim = StepSimulator::new(
            &self.cost,
            bundle,
            &self.calib_freq,
            self.dims.layers,
            self.dims.n_routed,
            self.dims.n_shared,
            42,
        );
        self.ids.clear();
        self.ids.extend(0..nb);
        trace.compose_prefill_into(&self.ids, &mut self.step);
        sim.run_step(&self.step, prompts[0].len() / 2, Phase::Prefill);
        // replay every decode step any sequence actually ran: sequences
        // that stopped early drop out of the composed step (and the token
        // count) together
        let longest = out.generated.iter().map(|g| g.len()).max().unwrap_or(0);
        for s in 0..longest {
            self.active.clear();
            self.active.extend((0..nb).map(|i| (i, s)));
            trace.compose_multi_into(&self.active, &mut self.step);
            sim.run_step(&self.step, prompts[0].len() + s, Phase::Decode);
        }
        let metrics = sim.finish();
        Ok(BatchOutcome {
            generated: out.generated,
            sim_ms: metrics.total_ns as f64 / 1e6,
            sim_tokens_per_s: metrics.tokens_per_s(),
        })
    }
}

struct QueueInner {
    groups: BTreeMap<(usize, usize), Vec<Pending>>,
    stop: bool,
}

/// The batching router. Handles enqueue from any thread; a single worker
/// thread drains groups into the engine.
pub struct Batcher {
    queue: Arc<(Mutex<QueueInner>, Condvar)>,
    pub metrics: Arc<Mutex<ServeMetrics>>,
    cfg: BatcherCfg,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl Batcher {
    /// Start the live-engine worker for `preset`, timing the virtual pass
    /// with the hardware preset named by `cfg.hw`. Blocks until the
    /// engine has loaded (so the server only accepts once ready).
    pub fn start(preset: &str, cfg: BatcherCfg) -> Result<Arc<Batcher>> {
        let presets = Presets::load_default()?;
        let model = presets.model(preset)?;
        let hw = presets.hw(&cfg.hw)?;
        let cost = CostModel::new(model, hw);
        let calib = prep::ensure_calib(preset)?;
        let dims = model.sim.clone();
        let framework = cfg.framework;
        let preset = preset.to_string();
        Self::start_with(cfg, move || {
            // the engine holds PJRT handles (Rc, not Send): created and
            // owned entirely inside the worker thread
            let engine = InferenceEngine::new(&preset).map_err(|e| format!("{e:#}"))?;
            let fwcfg = FrameworkCfg::paper_default(&dims);
            Ok(Box::new(EngineRunner {
                engine,
                cost,
                calib_freq: calib.freq,
                fwcfg,
                dims,
                framework,
                step: BatchStep::default(),
                ids: Vec::new(),
                active: Vec::new(),
            }) as Box<dyn BatchRunner>)
        })
    }

    /// Start a worker around any [`BatchRunner`] factory (run inside the
    /// worker thread, so the runner itself need not be `Send`). Blocks
    /// until the factory reports ready or fails.
    pub fn start_with<F>(cfg: BatcherCfg, factory: F) -> Result<Arc<Batcher>>
    where
        F: FnOnce() -> Result<Box<dyn BatchRunner>, String> + Send + 'static,
    {
        let b = Arc::new(Batcher {
            queue: Arc::new((
                Mutex::new(QueueInner { groups: BTreeMap::new(), stop: false }),
                Condvar::new(),
            )),
            metrics: Arc::new(Mutex::new(ServeMetrics::default())),
            cfg: cfg.clone(),
            worker: Mutex::new(None),
        });
        let bw = b.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = std::thread::spawn(move || {
            let mut runner = match factory() {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            bw.worker_loop(runner.as_mut());
        });
        *b.worker.lock().unwrap() = Some(handle);
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(b),
            Ok(Err(e)) => anyhow::bail!("engine load failed: {e}"),
            Err(_) => anyhow::bail!("engine worker died during startup"),
        }
    }

    /// Stop the worker and wait for it to exit. Every request still
    /// queued gets an explicit "server shutting down" error (nothing is
    /// silently dropped), and an in-flight batch finishes normally first.
    /// Idempotent.
    pub fn shutdown(&self) {
        let (lock, cv) = &*self.queue;
        lock.lock().unwrap().stop = true;
        cv.notify_all();
        let handle = self.worker.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Enqueue a request; returns a receiver for the response. After
    /// shutdown the receiver yields an immediate error.
    pub fn submit(&self, req: GenRequest) -> Receiver<Result<GenResponse, String>> {
        let (tx, rx) = channel();
        let key = (req.prompt.len(), req.max_tokens);
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        if q.stop {
            let _ = tx.send(Err("server shutting down".to_string()));
            return rx;
        }
        // admission control: bounded queue across all groups. Reject at
        // the door — cheaper for everyone than queueing a request that
        // will blow its deadline anyway.
        if self.cfg.queue_cap > 0 {
            let depth: usize = q.groups.values().map(|v| v.len()).sum();
            if depth >= self.cfg.queue_cap {
                self.metrics.lock().unwrap().rejected += 1;
                let _ = tx.send(Err(format!(
                    "queue full ({depth} pending, cap {})",
                    self.cfg.queue_cap
                )));
                return rx;
            }
        }
        q.groups.entry(key).or_default().push(Pending {
            req,
            resp_tx: tx,
            enqueued: Instant::now(),
        });
        cv.notify_one();
        rx
    }

    fn worker_loop(&self, runner: &mut dyn BatchRunner) {
        loop {
            let group = {
                let (lock, cv) = &*self.queue;
                let mut q = lock.lock().unwrap();
                loop {
                    if q.stop {
                        for (_, pendings) in std::mem::take(&mut q.groups) {
                            for p in pendings {
                                let _ =
                                    p.resp_tx.send(Err("server shutting down".to_string()));
                            }
                        }
                        return;
                    }
                    if let Some(g) =
                        take_ready(&mut q.groups, self.cfg.max_batch, self.cfg.max_wait)
                    {
                        break g;
                    }
                    // sleep until woken by submit/shutdown, or until the
                    // oldest pending request's dispatch deadline
                    q = match earliest_deadline(&q.groups, self.cfg.max_wait) {
                        None => cv.wait(q).unwrap(),
                        Some(deadline) => {
                            let wait = deadline.saturating_duration_since(Instant::now());
                            cv.wait_timeout(q, wait).unwrap().0
                        }
                    };
                }
            };
            self.run_group(runner, group);
        }
    }

    fn run_group(&self, runner: &mut dyn BatchRunner, mut group: Vec<Pending>) {
        let t0 = Instant::now();
        // load shedding: requests that already overstayed their queue
        // deadline are rejected at dispatch instead of holding the
        // engine for an answer nobody is waiting for anymore
        if self.cfg.queue_deadline > Duration::ZERO {
            let deadline = self.cfg.queue_deadline;
            let mut shed = 0u64;
            group.retain(|p| {
                let waited = t0.duration_since(p.enqueued);
                if waited >= deadline {
                    shed += 1;
                    let _ = p.resp_tx.send(Err(format!(
                        "queue deadline exceeded ({:.1} ms waited, budget {:.1} ms)",
                        waited.as_secs_f64() * 1e3,
                        deadline.as_secs_f64() * 1e3
                    )));
                    false
                } else {
                    true
                }
            });
            if shed > 0 {
                self.metrics.lock().unwrap().rejected += shed;
            }
            if group.is_empty() {
                return;
            }
        }
        let prompts: Vec<Vec<i32>> = group.iter().map(|p| p.req.prompt.clone()).collect();
        let max_tokens = group[0].req.max_tokens;
        let nb = group.len();
        match runner.run(&prompts, max_tokens) {
            Err(e) => {
                self.metrics.lock().unwrap().errors += nb as u64;
                for p in group {
                    let _ = p.resp_tx.send(Err(e.clone()));
                }
            }
            Ok(out) => {
                let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                let queue_ms: Vec<f64> = group
                    .iter()
                    .map(|p| t0.duration_since(p.enqueued).as_secs_f64() * 1e3)
                    .collect();
                let tokens_out: u64 = out.generated.iter().map(|g| g.len() as u64).sum();
                {
                    let mut m = self.metrics.lock().unwrap();
                    m.requests += nb as u64;
                    m.batches += 1;
                    m.tokens_out += tokens_out;
                    m.queue_ms_sum += queue_ms.iter().sum::<f64>();
                    m.exec_ms_sum += exec_ms * nb as f64;
                    m.sim_ms_sum += out.sim_ms;
                }
                for ((i, p), q_ms) in group.into_iter().enumerate().zip(queue_ms) {
                    let _ = p.resp_tx.send(Ok(GenResponse {
                        tokens: out.generated[i].clone(),
                        queue_ms: q_ms,
                        exec_ms,
                        wall_ms: q_ms + exec_ms,
                        sim_ms: out.sim_ms,
                        sim_tokens_per_s: out.sim_tokens_per_s,
                        batch_size: nb,
                    }));
                }
            }
        }
    }
}

fn take_ready(
    groups: &mut BTreeMap<(usize, usize), Vec<Pending>>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<Vec<Pending>> {
    let key = groups
        .iter()
        .filter(|(_, v)| !v.is_empty())
        .find(|(_, v)| {
            v.len() >= max_batch || v.iter().any(|p| p.enqueued.elapsed() >= max_wait)
        })
        .map(|(k, _)| *k)?;
    let v = groups.get_mut(&key).unwrap();
    let n = v.len().min(max_batch);
    let group: Vec<Pending> = v.drain(..n).collect();
    if v.is_empty() {
        groups.remove(&key);
    }
    Some(group)
}

fn earliest_deadline(
    groups: &BTreeMap<(usize, usize), Vec<Pending>>,
    max_wait: Duration,
) -> Option<Instant> {
    groups.values().flatten().map(|p| p.enqueued + max_wait).min()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine-free runner: echoes `max_tokens` tokens per prompt, except
    /// every odd-indexed prompt stops one token early (exercising
    /// actual-vs-requested accounting).
    struct EchoRunner;

    impl BatchRunner for EchoRunner {
        fn run(
            &mut self,
            prompts: &[Vec<i32>],
            max_tokens: usize,
        ) -> Result<BatchOutcome, String> {
            Ok(BatchOutcome {
                generated: prompts
                    .iter()
                    .enumerate()
                    .map(|(i, _)| vec![7; max_tokens - (i % 2)])
                    .collect(),
                sim_ms: 1.0,
                sim_tokens_per_s: 100.0,
            })
        }
    }

    struct FailRunner;

    impl BatchRunner for FailRunner {
        fn run(&mut self, _: &[Vec<i32>], _: usize) -> Result<BatchOutcome, String> {
            Err("boom".to_string())
        }
    }

    fn echo_batcher(max_batch: usize, max_wait: Duration) -> Arc<Batcher> {
        let cfg = BatcherCfg { max_batch, max_wait, ..Default::default() };
        Batcher::start_with(cfg, || Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>)).unwrap()
    }

    #[test]
    fn tokens_out_counts_generated_not_requested() {
        let b = echo_batcher(2, Duration::from_secs(10));
        let rx0 = b.submit(GenRequest { prompt: vec![1, 2], max_tokens: 4 });
        let rx1 = b.submit(GenRequest { prompt: vec![3, 4], max_tokens: 4 });
        let r0 = rx0.recv().unwrap().unwrap();
        let r1 = rx1.recv().unwrap().unwrap();
        assert_eq!(r0.tokens.len(), 4);
        assert_eq!(r1.tokens.len(), 3, "odd request stops one token early");
        let m = b.metrics.lock().unwrap().clone();
        assert_eq!(m.tokens_out, 7, "bill what was produced, not steps * batch");
        assert_eq!(m.requests, 2);
        assert_eq!(m.batches, 1);
        b.shutdown();
    }

    #[test]
    fn latency_split_is_consistent_between_response_and_metrics() {
        let b = echo_batcher(1, Duration::from_secs(10));
        let rx = b.submit(GenRequest { prompt: vec![1], max_tokens: 2 });
        let r = rx.recv().unwrap().unwrap();
        assert!((r.wall_ms - (r.queue_ms + r.exec_ms)).abs() < 1e-9);
        let m = b.metrics.lock().unwrap().clone();
        assert!((m.queue_ms_sum - r.queue_ms).abs() < 1e-9);
        assert!((m.exec_ms_sum - r.exec_ms).abs() < 1e-9);
        b.shutdown();
    }

    #[test]
    fn shutdown_joins_and_drains_pending_with_errors() {
        // nothing dispatches: batch threshold and wait are both out of reach
        let b = echo_batcher(8, Duration::from_secs(3600));
        let rx0 = b.submit(GenRequest { prompt: vec![1], max_tokens: 4 });
        let rx1 = b.submit(GenRequest { prompt: vec![1, 2], max_tokens: 4 });
        b.shutdown();
        for rx in [rx0, rx1] {
            let err = rx.recv().expect("drained, not dropped").unwrap_err();
            assert!(err.contains("shutting down"), "got: {err}");
        }
        // post-shutdown submits fail immediately instead of hanging
        let rx = b.submit(GenRequest { prompt: vec![1], max_tokens: 4 });
        assert!(rx.recv().unwrap().is_err());
        b.shutdown(); // idempotent
    }

    #[test]
    fn runner_errors_propagate_to_every_request_in_the_batch() {
        let cfg = BatcherCfg {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
            ..Default::default()
        };
        let b = Batcher::start_with(cfg, || Ok(Box::new(FailRunner) as Box<dyn BatchRunner>))
            .unwrap();
        let rx0 = b.submit(GenRequest { prompt: vec![1], max_tokens: 4 });
        let rx1 = b.submit(GenRequest { prompt: vec![2], max_tokens: 4 });
        assert!(rx0.recv().unwrap().is_err());
        assert!(rx1.recv().unwrap().is_err());
        assert_eq!(b.metrics.lock().unwrap().errors, 2);
        b.shutdown();
    }

    #[test]
    fn bounded_queue_rejects_at_submit_with_503_semantics() {
        // nothing ever dispatches (threshold and wait out of reach), so
        // the first submit parks in the queue and the second hits the cap
        let cfg = BatcherCfg {
            max_batch: 8,
            max_wait: Duration::from_secs(3600),
            queue_cap: 1,
            ..Default::default()
        };
        let b = Batcher::start_with(cfg, || Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>))
            .unwrap();
        let rx0 = b.submit(GenRequest { prompt: vec![1], max_tokens: 4 });
        let rx1 = b.submit(GenRequest { prompt: vec![2], max_tokens: 4 });
        let err = rx1.recv().expect("rejection is an immediate reply").unwrap_err();
        assert!(err.contains("queue full"), "got: {err}");
        assert_eq!(b.metrics.lock().unwrap().rejected, 1);
        // the parked request is drained with an explicit shutdown error,
        // not silently dropped, and is not double-counted as rejected
        b.shutdown();
        assert!(rx0.recv().unwrap().unwrap_err().contains("shutting down"));
        assert_eq!(b.metrics.lock().unwrap().rejected, 1);
    }

    #[test]
    fn queue_deadline_sheds_stale_requests_at_dispatch() {
        // dispatch happens via the max_wait timeout (~5 ms), far past the
        // 1 ns queue deadline: every request in the group is shed
        let cfg = BatcherCfg {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_deadline: Duration::from_nanos(1),
            ..Default::default()
        };
        let b = Batcher::start_with(cfg, || Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>))
            .unwrap();
        let rx0 = b.submit(GenRequest { prompt: vec![1], max_tokens: 4 });
        let rx1 = b.submit(GenRequest { prompt: vec![2], max_tokens: 4 });
        for rx in [rx0, rx1] {
            let err = rx.recv().unwrap().unwrap_err();
            assert!(err.contains("deadline"), "got: {err}");
        }
        let m = b.metrics.lock().unwrap().clone();
        assert_eq!(m.rejected, 2);
        assert_eq!(m.requests, 0, "shed requests never reach the runner");
        assert_eq!(m.batches, 0);
        b.shutdown();
    }

    #[test]
    fn factory_failure_surfaces_from_start_with() {
        let r = Batcher::start_with(BatcherCfg::default(), || Err("no engine".to_string()));
        assert!(r.unwrap_err().to_string().contains("no engine"));
    }
}
