//! Dynamic request batcher + engine worker.
//!
//! Requests are grouped by (prompt length, max_tokens); a group is
//! dispatched when it reaches `max_batch` or its oldest request has waited
//! `max_wait`. The worker thread owns the live engine and a fresh
//! [`StepSimulator`] per batch, so each response carries the simulated
//! local-PC latency alongside the wall-clock numbers.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::Presets;
use crate::coordinator::engine::InferenceEngine;
use crate::coordinator::frameworks::{Framework, FrameworkCfg};
use crate::coordinator::simrun::{Phase, StepSimulator};
use crate::hw::CostModel;
use crate::workload::prep;

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    /// Wall-clock time this request spent queued + executing.
    pub wall_ms: f64,
    /// Simulated local-PC time for the batch that served this request.
    pub sim_ms: f64,
    /// Simulated decode throughput of that batch.
    pub sim_tokens_per_s: f64,
    pub batch_size: usize,
}

#[derive(Debug, Clone)]
pub struct BatcherCfg {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub framework: Framework,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_batch: 8, max_wait: Duration::from_millis(50), framework: Framework::Dali }
    }
}

struct Pending {
    req: GenRequest,
    resp_tx: Sender<Result<GenResponse, String>>,
    enqueued: Instant,
}

/// Aggregate serving metrics (exposed at `/metrics`).
#[derive(Debug, Default, Clone)]
pub struct ServeMetrics {
    pub requests: u64,
    pub batches: u64,
    pub tokens_out: u64,
    pub wall_ms_sum: f64,
    pub sim_ms_sum: f64,
    pub errors: u64,
}

/// The batching router. Handles enqueue from any thread; a single worker
/// thread drains groups into the engine.
pub struct Batcher {
    queue: Arc<Mutex<BTreeMap<(usize, usize), Vec<Pending>>>>,
    pub metrics: Arc<Mutex<ServeMetrics>>,
    cfg: BatcherCfg,
    stop: Arc<Mutex<bool>>,
}

impl Batcher {
    /// Start the worker thread for `preset`. Blocks until the engine has
    /// loaded (so the server only accepts once ready).
    pub fn start(preset: &str, cfg: BatcherCfg) -> Result<Arc<Batcher>> {
        let presets = Presets::load_default()?;
        let model = presets.model(preset)?;
        let hw = presets.hw("local-pc")?;
        let cost = CostModel::new(model, hw);
        let calib = prep::ensure_calib(preset)?;
        let dims = model.sim.clone();
        let b = Arc::new(Batcher {
            queue: Arc::new(Mutex::new(BTreeMap::new())),
            metrics: Arc::new(Mutex::new(ServeMetrics::default())),
            cfg: cfg.clone(),
            stop: Arc::new(Mutex::new(false)),
        });
        let bw = b.clone();
        let preset = preset.to_string();
        // The engine holds PJRT handles (Rc, not Send): it is created and
        // owned entirely inside the worker thread; readiness is signalled
        // back so start() fails fast on load errors.
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        std::thread::spawn(move || {
            let engine = match InferenceEngine::new(&preset) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            let fwcfg = FrameworkCfg::paper_default(&dims);
            loop {
                if *bw.stop.lock().unwrap() {
                    break;
                }
                let batch = bw.take_ready_batch();
                match batch {
                    None => std::thread::sleep(Duration::from_millis(2)),
                    Some(group) => {
                        bw.run_group(&engine, &cost, &calib.freq, &fwcfg, &dims, group);
                    }
                }
            }
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(b),
            Ok(Err(e)) => anyhow::bail!("engine load failed: {e}"),
            Err(_) => anyhow::bail!("engine worker died during startup"),
        }
    }

    pub fn shutdown(&self) {
        *self.stop.lock().unwrap() = true;
    }

    /// Enqueue a request; returns a receiver for the response.
    pub fn submit(&self, req: GenRequest) -> Receiver<Result<GenResponse, String>> {
        let (tx, rx) = channel();
        let key = (req.prompt.len(), req.max_tokens);
        self.queue
            .lock()
            .unwrap()
            .entry(key)
            .or_default()
            .push(Pending { req, resp_tx: tx, enqueued: Instant::now() });
        rx
    }

    fn take_ready_batch(&self) -> Option<Vec<Pending>> {
        let mut q = self.queue.lock().unwrap();
        let key = q
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .find(|(_, v)| {
                v.len() >= self.cfg.max_batch
                    || v.iter().any(|p| p.enqueued.elapsed() >= self.cfg.max_wait)
            })
            .map(|(k, _)| *k)?;
        let v = q.get_mut(&key).unwrap();
        let n = v.len().min(self.cfg.max_batch);
        let group: Vec<Pending> = v.drain(..n).collect();
        if v.is_empty() {
            q.remove(&key);
        }
        Some(group)
    }

    fn run_group(
        &self,
        engine: &InferenceEngine,
        cost: &CostModel,
        calib_freq: &[Vec<f64>],
        fwcfg: &FrameworkCfg,
        dims: &crate::config::ModelDims,
        group: Vec<Pending>,
    ) {
        let t0 = Instant::now();
        let prompts: Vec<Vec<i32>> = group.iter().map(|p| p.req.prompt.clone()).collect();
        let steps = group[0].req.max_tokens;
        let nb = group.len();
        // live numerics (record a trace so the simulator can time it)
        let result = engine.run_batch(&prompts, steps, true);
        match result {
            Err(e) => {
                let mut m = self.metrics.lock().unwrap();
                m.errors += group.len() as u64;
                drop(m);
                for p in group {
                    let _ = p.resp_tx.send(Err(format!("engine error: {e:#}")));
                }
            }
            Ok(out) => {
                // virtual-time pass over the recorded routing
                let trace = out.trace.as_ref().expect("trace requested");
                let bundle = self.cfg.framework.bundle(dims, cost, calib_freq, fwcfg);
                let mut sim = StepSimulator::new(
                    cost,
                    bundle,
                    calib_freq,
                    dims.layers,
                    dims.n_routed,
                    dims.n_shared,
                    42,
                );
                let ids: Vec<usize> = (0..nb).collect();
                sim.run_step(&trace.compose_prefill(&ids), prompts[0].len() / 2, Phase::Prefill);
                for s in 0..trace.min_steps() {
                    sim.run_step(&trace.compose_decode(&ids, s), prompts[0].len() + s, Phase::Decode);
                }
                let metrics = sim.finish();
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                let sim_ms = metrics.total_ns as f64 / 1e6;
                let tps = metrics.tokens_per_s();
                {
                    let mut m = self.metrics.lock().unwrap();
                    m.requests += nb as u64;
                    m.batches += 1;
                    m.tokens_out += (steps * nb) as u64;
                    m.wall_ms_sum += wall_ms;
                    m.sim_ms_sum += sim_ms;
                }
                for (i, p) in group.into_iter().enumerate() {
                    let _ = p.resp_tx.send(Ok(GenResponse {
                        tokens: out.generated[i].clone(),
                        wall_ms: p.enqueued.elapsed().as_secs_f64() * 1e3,
                        sim_ms,
                        sim_tokens_per_s: tps,
                        batch_size: nb,
                    }));
                }
            }
        }
    }
}
