//! Serving front-end: request router + dynamic batcher + HTTP server.
//!
//! The paper's system is an inference *server* for local PCs; this module
//! is the deployment wrapper around the engine: requests arrive over HTTP,
//! are bucketed by prompt length and dynamically batched (vLLM-router
//! style), executed by a dedicated engine worker thread (real PJRT
//! numerics + DALI-scheduled virtual timing), and answered with generated
//! tokens plus both wall-clock and simulated-platform latencies.
//!
//! The offline build has no tokio; the server is a small, dependency-free
//! threaded HTTP/1.1 implementation (`http.rs`) — connection-per-thread is
//! entirely adequate for a local-PC serving frontend.

pub mod batcher;
pub mod http;
pub mod server;

pub use batcher::{Batcher, BatcherCfg, GenRequest, GenResponse};
