//! Serving layer: virtual-time serving *simulation* plus the wall-clock
//! HTTP front-end.
//!
//! Two halves, one request model:
//!
//! - **Simulation** (`arrival.rs`, `sim.rs`) — the paper-facing path.
//!   Seeded arrival processes (Poisson / bursty / diurnal) feed a
//!   continuous batcher that admits and retires requests per decode step
//!   in virtual time; every stream contends for one shared
//!   [`StepSimulator`](crate::coordinator::simrun::StepSimulator)
//!   pipeline (GPU cache, tiered store, NVMe/PCIe/transcode lanes), so
//!   cross-request expert locality and thrash are modeled. Reports are
//!   per-request TTFT/TPOT/queue percentiles
//!   ([`ServeReport`](crate::metrics::ServeReport)), digest-locked and
//!   bit-identical for the same seed.
//!
//! - **Front-end** (`batcher.rs`, `http.rs`, `server.rs`) — the
//!   deployment wrapper around the engine: requests arrive over HTTP, are
//!   bucketed by shape and dynamically batched, executed by a dedicated
//!   engine worker thread (real PJRT numerics + DALI-scheduled virtual
//!   timing), and answered with generated tokens plus explicit queue and
//!   execution latencies. No tokio: a small, dependency-free threaded
//!   HTTP/1.1 implementation is entirely adequate for a local-PC serving
//!   frontend.

pub mod arrival;
pub mod batcher;
pub mod http;
pub mod server;
pub mod sim;
pub mod slo;

pub use arrival::{ArrivalKind, ArrivalSpec};
pub use batcher::{Batcher, BatcherCfg, GenRequest, GenResponse};
pub use sim::{simulate_serve, ServeSim, ServeSimCfg};
pub use slo::{OverloadController, SloSpec};
