//! Per-request SLO specifications and the hysteretic overload controller.
//!
//! An [`SloSpec`] carries two families of knobs:
//!
//! - **Deadlines** — per-request TTFT and completion budgets (ms), spread
//!   deterministically around the nominal value by a seeded jitter factor,
//!   plus a pending-queue capacity. These drive *admission control*
//!   (reject a request whose deadline is already hopeless) and *load
//!   shedding* (evict the running request with the most-blown deadline).
//! - **Watermarks** — queue-depth and rolling step-latency thresholds
//!   with dwell counters that drive the [`OverloadController`]'s
//!   degradation ladder.
//!
//! Everything is `Copy`, parsed from the same `key=value,...` spec form
//! as [`ArrivalSpec`](super::ArrivalSpec), named in the `slo` section of
//! `configs/presets.json`, and — critically — *inert by default*: the
//! unlimited spec leaves the serving simulation bit-identical to an
//! unguarded run (the transparency lock in `rust/tests/serve_sim.rs`).
//!
//! The controller is a small hysteretic state machine over rungs
//! `0..=3` (healthy → shrink-prefetch → pause-promote-ahead → degraded
//! assignment costs). Escalation needs `dwell_up` consecutive hot
//! observations, de-escalation `dwell_down` consecutive cool ones, and
//! the band between the watermarks resets both counters — so a load
//! hovering at the threshold holds the current rung instead of
//! oscillating. At most one rung transition happens per tick.

use anyhow::{bail, Result};

use crate::hw::Ns;

/// splitmix64-style finalizer: the same stateless mixer the fault plans
/// use, so per-request deadline jitter is a pure function of
/// `(seed, request id)` — no RNG stream to keep in sync with arrivals.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// An SLO policy: per-request deadline budgets + overload watermarks.
/// `Copy`, validated at parse time, zero values switch each knob off.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Nominal time-to-first-token budget in ms (0 = unlimited).
    pub ttft_ms: f64,
    /// Nominal completion budget in ms from arrival (0 = unlimited).
    pub total_ms: f64,
    /// Deadline spread in [0, 1): each request's budgets are scaled by a
    /// deterministic factor in `[1 - jitter, 1 + jitter)`.
    pub jitter: f64,
    /// Pending-queue capacity; arrivals beyond it are rejected
    /// (0 = unbounded).
    pub queue_cap: usize,
    /// `false` = observe-only: deadlines are scored in the report but
    /// nothing is rejected, evicted, or degraded — the digest stays
    /// identical to the unguarded run (the fair comparison baseline).
    pub enforce: bool,
    /// Queue depth at or above which a tick counts as hot (0 = axis off).
    pub hi_queue: usize,
    /// Queue depth at or below which a tick can count as cool.
    pub lo_queue: usize,
    /// Rolling (EWMA) step latency in ms above which a tick counts as
    /// hot (0 = axis off).
    pub hi_step_ms: f64,
    /// Rolling step latency in ms below which a tick can count as cool.
    pub lo_step_ms: f64,
    /// Consecutive hot ticks required to escalate one rung (>= 1).
    pub dwell_up: u32,
    /// Consecutive cool ticks required to de-escalate one rung (>= 1).
    pub dwell_down: u32,
}

impl Default for SloSpec {
    /// The unlimited policy: no deadlines, no queue bound, no ladder.
    fn default() -> Self {
        SloSpec {
            ttft_ms: 0.0,
            total_ms: 0.0,
            jitter: 0.0,
            queue_cap: 0,
            enforce: true,
            hi_queue: 0,
            lo_queue: 0,
            hi_step_ms: 0.0,
            lo_step_ms: 0.0,
            dwell_up: 2,
            dwell_down: 4,
        }
    }
}

impl SloSpec {
    /// True when every protective knob is off — the spec that must leave
    /// the serving digest bit-identical to an unguarded run.
    pub fn is_unlimited(&self) -> bool {
        self.ttft_ms == 0.0
            && self.total_ms == 0.0
            && self.queue_cap == 0
            && self.hi_queue == 0
            && self.hi_step_ms == 0.0
    }

    /// True when the spec actually changes serving behavior (deadlines
    /// or ladder active *and* enforcement on).
    pub fn is_guarded(&self) -> bool {
        self.enforce && !self.is_unlimited()
    }

    /// Built-in named policies (work without a presets file; mirrored by
    /// the `slo` section of `configs/presets.json`).
    pub fn named(name: &str) -> Option<SloSpec> {
        match name {
            "unlimited" | "none" | "off" => Some(SloSpec::default()),
            "tight" => Some(SloSpec {
                ttft_ms: 50.0,
                total_ms: 400.0,
                jitter: 0.25,
                queue_cap: 16,
                enforce: true,
                hi_queue: 8,
                lo_queue: 2,
                hi_step_ms: 20.0,
                lo_step_ms: 5.0,
                dwell_up: 2,
                dwell_down: 4,
            }),
            "lenient" => Some(SloSpec {
                ttft_ms: 500.0,
                total_ms: 5000.0,
                jitter: 0.25,
                queue_cap: 64,
                enforce: true,
                hi_queue: 24,
                lo_queue: 6,
                hi_step_ms: 50.0,
                lo_step_ms: 10.0,
                dwell_up: 3,
                dwell_down: 6,
            }),
            // same budgets as `tight`, but scored without acting — the
            // digest-identical baseline for guarded-vs-unguarded tables
            "observe" => Some(SloSpec { enforce: false, ..SloSpec::named("tight").unwrap() }),
            _ => None,
        }
    }

    /// Parse a `key=value,...` spec, e.g.
    /// `ttft_ms=50,total_ms=400,queue_cap=16,hi_queue=8`. The empty
    /// string parses to the unlimited default; unknown keys are errors.
    pub fn parse_spec(spec: &str) -> Result<SloSpec> {
        let mut s = SloSpec::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = match part.split_once('=') {
                Some(kv) => kv,
                None => bail!("slo spec entry '{part}' is not key=value"),
            };
            let (k, v) = (k.trim(), v.trim());
            match k {
                "ttft_ms" => s.ttft_ms = v.parse()?,
                "total_ms" => s.total_ms = v.parse()?,
                "jitter" => s.jitter = v.parse()?,
                "queue_cap" => s.queue_cap = v.parse()?,
                "enforce" => {
                    s.enforce = match v {
                        "1" | "true" => true,
                        "0" | "false" => false,
                        _ => bail!("slo enforce must be 0/1/true/false, got '{v}'"),
                    }
                }
                "hi_queue" => s.hi_queue = v.parse()?,
                "lo_queue" => s.lo_queue = v.parse()?,
                "hi_step_ms" => s.hi_step_ms = v.parse()?,
                "lo_step_ms" => s.lo_step_ms = v.parse()?,
                "dwell_up" => s.dwell_up = v.parse()?,
                "dwell_down" => s.dwell_down = v.parse()?,
                _ => bail!("unknown slo spec key '{k}'"),
            }
        }
        s.validate()?;
        Ok(s)
    }

    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("ttft_ms", self.ttft_ms),
            ("total_ms", self.total_ms),
            ("hi_step_ms", self.hi_step_ms),
            ("lo_step_ms", self.lo_step_ms),
        ] {
            if !(v >= 0.0 && v.is_finite()) {
                bail!("slo {name} must be finite and >= 0, got {v}");
            }
        }
        if !(0.0..1.0).contains(&self.jitter) {
            bail!("slo jitter must be in [0, 1), got {}", self.jitter);
        }
        if self.dwell_up == 0 || self.dwell_down == 0 {
            bail!("slo dwell_up/dwell_down must be >= 1");
        }
        if self.hi_queue > 0 && self.lo_queue > self.hi_queue {
            bail!(
                "slo lo_queue ({}) must not exceed hi_queue ({})",
                self.lo_queue,
                self.hi_queue
            );
        }
        if self.hi_step_ms > 0.0 && self.lo_step_ms > self.hi_step_ms {
            bail!(
                "slo lo_step_ms ({}) must not exceed hi_step_ms ({})",
                self.lo_step_ms,
                self.hi_step_ms
            );
        }
        Ok(())
    }

    /// Per-request absolute deadlines `(ttft_deadline, finish_deadline)`
    /// in virtual ns, jittered deterministically from `(seed, req)`.
    /// An unlimited budget maps to `Ns::MAX`.
    pub fn deadlines(&self, seed: u64, req: usize, arrival: Ns) -> (Ns, Ns) {
        let h = mix(seed ^ 0x51_0dea_d1 ^ (req as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        // 53 uniform mantissa bits -> u in [0, 1)
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        let factor = 1.0 + self.jitter * (2.0 * u - 1.0);
        let budget = |ms: f64| -> Ns {
            if ms <= 0.0 {
                Ns::MAX
            } else {
                arrival.saturating_add((ms * factor * 1e6) as Ns)
            }
        };
        (budget(self.ttft_ms), budget(self.total_ms))
    }
}

/// The degradation rungs, top of the ladder last. Kept as plain `u8`
/// values in the hot path; this enum documents what each rung means.
pub mod rung {
    /// Fully healthy: no intervention.
    pub const HEALTHY: u8 = 0;
    /// Prefetch window halved — less speculative NVMe/PCIe pressure.
    pub const SHRINK_PREFETCH: u8 = 1;
    /// Promote-ahead paused on top of rung 1 — the tiered store stops
    /// issuing predictive NVMe→host promotions.
    pub const PAUSE_PROMOTE: u8 = 2;
    /// Assignment priced through the degraded (CPU-shifted) cost view on
    /// top of rungs 1+2 — Greedy sheds marginal experts CPU-ward.
    pub const DEGRADED_ASSIGN: u8 = 3;
}

/// Hysteretic overload controller: observes queue depth and rolling step
/// latency once per tick, escalates/de-escalates the degradation rung
/// with dwell counters. Pure arithmetic — no allocation, ever.
#[derive(Debug, Clone, Copy)]
pub struct OverloadController {
    spec: SloSpec,
    current: u8,
    hot: u32,
    cool: u32,
    ewma_step_ns: Ns,
}

impl OverloadController {
    pub const MAX_RUNG: u8 = rung::DEGRADED_ASSIGN;

    pub fn new(spec: SloSpec) -> Self {
        OverloadController { spec, current: rung::HEALTHY, hot: 0, cool: 0, ewma_step_ns: 0 }
    }

    /// Current degradation rung (0 = healthy).
    pub fn rung(&self) -> u8 {
        self.current
    }

    /// Rolling step-latency estimate (0 until the first sample).
    pub fn ewma_step_ns(&self) -> Ns {
        self.ewma_step_ns
    }

    /// Fold one tick's wall (virtual) duration into the rolling
    /// step-latency estimate. The first sample seeds the EWMA directly.
    pub fn note_step(&mut self, dur_ns: Ns) {
        self.ewma_step_ns = if self.ewma_step_ns == 0 {
            dur_ns
        } else {
            (self.ewma_step_ns.saturating_mul(3).saturating_add(dur_ns)) / 4
        };
    }

    /// One controller observation. Returns `Some((from, to))` when the
    /// rung changes (at most one step per tick), `None` otherwise.
    ///
    /// Hot when *either* axis is above its high watermark; cool only
    /// when *every* enabled axis is below its low watermark; the band in
    /// between resets both dwell counters (the hysteresis hold).
    pub fn observe(&mut self, queue_depth: usize) -> Option<(u8, u8)> {
        let s = &self.spec;
        let q_axis = s.hi_queue > 0;
        let l_axis = s.hi_step_ms > 0.0;
        if !q_axis && !l_axis {
            return None;
        }
        let step_ms = self.ewma_step_ns as f64 / 1e6;
        let hot = (q_axis && queue_depth >= s.hi_queue)
            || (l_axis && step_ms > s.hi_step_ms);
        let cool = (!q_axis || queue_depth <= s.lo_queue)
            && (!l_axis || step_ms < s.lo_step_ms);
        if hot {
            self.cool = 0;
            self.hot = self.hot.saturating_add(1);
            if self.hot >= s.dwell_up && self.current < Self::MAX_RUNG {
                self.hot = 0;
                let from = self.current;
                self.current += 1;
                return Some((from, self.current));
            }
        } else if cool {
            self.hot = 0;
            self.cool = self.cool.saturating_add(1);
            if self.cool >= s.dwell_down && self.current > rung::HEALTHY {
                self.cool = 0;
                let from = self.current;
                self.current -= 1;
                return Some((from, self.current));
            }
        } else {
            self.hot = 0;
            self.cool = 0;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_knobs() {
        let s = SloSpec::parse_spec(
            "ttft_ms=50,total_ms=400,jitter=0.25,queue_cap=16,hi_queue=8,lo_queue=2,\
             hi_step_ms=20,lo_step_ms=5,dwell_up=2,dwell_down=4",
        )
        .unwrap();
        assert_eq!(s, SloSpec::named("tight").unwrap());
        assert!(s.is_guarded());
        let obs = SloSpec::parse_spec("ttft_ms=50,enforce=false").unwrap();
        assert!(!obs.enforce && !obs.is_guarded() && !obs.is_unlimited());
        // the empty spec is the unlimited default
        let empty = SloSpec::parse_spec("").unwrap();
        assert_eq!(empty, SloSpec::default());
        assert!(empty.is_unlimited() && !empty.is_guarded());
        assert!(SloSpec::parse_spec("jitter=1.5").is_err());
        assert!(SloSpec::parse_spec("ttft_ms=-1").is_err());
        assert!(SloSpec::parse_spec("dwell_up=0").is_err());
        assert!(SloSpec::parse_spec("hi_queue=4,lo_queue=8").is_err());
        assert!(SloSpec::parse_spec("hi_step_ms=5,lo_step_ms=10").is_err());
        assert!(SloSpec::parse_spec("frobnicate=1").is_err());
        assert!(SloSpec::parse_spec("ttft_ms").is_err());
    }

    #[test]
    fn named_policies_exist() {
        assert!(SloSpec::named("unlimited").unwrap().is_unlimited());
        assert!(SloSpec::named("tight").unwrap().is_guarded());
        assert!(SloSpec::named("lenient").unwrap().is_guarded());
        let obs = SloSpec::named("observe").unwrap();
        assert!(!obs.is_guarded() && !obs.is_unlimited());
        assert_eq!(
            SloSpec { enforce: true, ..obs },
            SloSpec::named("tight").unwrap(),
            "observe must score exactly the tight budgets"
        );
        assert!(SloSpec::named("no-such").is_none());
        for name in ["unlimited", "tight", "lenient", "observe"] {
            SloSpec::named(name).unwrap().validate().unwrap();
        }
    }

    #[test]
    fn deadlines_are_deterministic_and_jitter_bounded() {
        let s = SloSpec::named("tight").unwrap();
        for req in 0..64 {
            let (t1, d1) = s.deadlines(7, req, 1_000_000);
            let (t2, d2) = s.deadlines(7, req, 1_000_000);
            assert_eq!((t1, d1), (t2, d2), "deadlines are a pure function");
            // budgets stay within the +/- 25% jitter band of nominal
            let ttft_budget = (t1 - 1_000_000) as f64 / 1e6;
            let total_budget = (d1 - 1_000_000) as f64 / 1e6;
            assert!(
                (37.5..62.5).contains(&ttft_budget),
                "req {req}: ttft budget {ttft_budget}ms outside jitter band"
            );
            assert!(
                (300.0..500.0).contains(&total_budget),
                "req {req}: total budget {total_budget}ms outside jitter band"
            );
        }
        // jitter actually spreads: not every request gets the same budget
        let spread: std::collections::BTreeSet<u64> =
            (0..64).map(|r| s.deadlines(7, r, 0).0).collect();
        assert!(spread.len() > 32, "jitter must spread deadlines");
        // a different seed moves the draw
        assert_ne!(s.deadlines(7, 0, 0), s.deadlines(8, 0, 0));
        // unlimited budgets map to Ns::MAX and never saturate into a real
        // deadline, whatever the arrival instant
        let unlim = SloSpec::default();
        assert_eq!(unlim.deadlines(7, 3, u64::MAX - 5), (Ns::MAX, Ns::MAX));
    }

    #[test]
    fn controller_escalates_and_deescalates_with_dwell() {
        let spec = SloSpec::named("tight").unwrap(); // dwell_up 2, dwell_down 4
        let mut c = OverloadController::new(spec);
        assert_eq!(c.rung(), rung::HEALTHY);
        // constant overload: one rung per dwell_up ticks, capped at 3
        let mut transitions = Vec::new();
        for _ in 0..12 {
            if let Some(t) = c.observe(100) {
                transitions.push(t);
            }
        }
        assert_eq!(transitions, vec![(0, 1), (1, 2), (2, 3)], "monotone ladder up");
        assert_eq!(c.rung(), OverloadController::MAX_RUNG);
        // constant calm: one rung down per dwell_down ticks, floor at 0
        let mut down = Vec::new();
        for _ in 0..24 {
            if let Some(t) = c.observe(0) {
                down.push(t);
            }
        }
        assert_eq!(down, vec![(3, 2), (2, 1), (1, 0)], "monotone ladder down");
        assert_eq!(c.rung(), rung::HEALTHY);
    }

    #[test]
    fn hold_band_prevents_oscillation() {
        let spec = SloSpec::named("tight").unwrap(); // hi_queue 8, lo_queue 2
        let mut c = OverloadController::new(spec);
        for _ in 0..4 {
            c.observe(100);
        }
        let r = c.rung();
        assert!(r > 0, "warm-up must have escalated");
        // depth 5 sits between the watermarks: the controller holds its
        // rung forever instead of flapping
        for _ in 0..64 {
            assert_eq!(c.observe(5), None, "hold band must not transition");
        }
        assert_eq!(c.rung(), r);
        // a single hot tick after a long hold must not instantly escalate
        // (dwell counters were reset by the hold band)
        assert_eq!(c.observe(100), None);
    }

    #[test]
    fn disabled_ladder_never_transitions() {
        let mut c = OverloadController::new(SloSpec::default());
        for depth in [0usize, 5, 1000] {
            for _ in 0..16 {
                c.note_step(50_000_000);
                assert_eq!(c.observe(depth), None);
            }
        }
        assert_eq!(c.rung(), rung::HEALTHY);
    }

    #[test]
    fn ewma_tracks_step_latency() {
        let mut c = OverloadController::new(SloSpec::named("tight").unwrap());
        assert_eq!(c.ewma_step_ns(), 0);
        c.note_step(1000);
        assert_eq!(c.ewma_step_ns(), 1000, "first sample seeds the estimate");
        c.note_step(2000);
        assert_eq!(c.ewma_step_ns(), 1250, "(3*1000 + 2000) / 4");
        for _ in 0..64 {
            c.note_step(2000);
        }
        assert!(c.ewma_step_ns() > 1900, "estimate converges to the plateau");
        // the latency axis alone can drive the ladder
        let mut l = OverloadController::new(SloSpec {
            hi_queue: 0,
            lo_queue: 0,
            ..SloSpec::named("tight").unwrap()
        });
        for _ in 0..8 {
            l.note_step(100_000_000); // 100ms >> hi_step_ms=20
            l.observe(0);
        }
        assert!(l.rung() > 0, "latency axis must escalate on its own");
    }
}
