//! Minimal markdown-ish table printer for experiment outputs.

/// Accumulates rows and renders an aligned markdown table. Every `expt`
/// subcommand prints its paper table/figure through this so EXPERIMENTS.md
/// can embed the output verbatim.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:w$} |", c, w = width[i]));
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["a", "speed"]);
        t.row(vec!["x", "1.0"]);
        t.row(vec!["longer", "2.25"]);
        let s = t.render();
        assert!(s.contains("| a      | speed |"));
        assert!(s.contains("| longer | 2.25  |"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
