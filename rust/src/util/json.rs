//! Minimal JSON parser + writer (the offline build has no serde_json).
//!
//! Supports the full JSON grammar except exotic number forms; numbers are
//! f64 (every value this repo round-trips — ns counts, byte counts, probs —
//! fits in f64's 53-bit integer range).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // --- accessors -----------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers → Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    // --- parsing --------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // --- writing ----------------------------------------------------------------

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    // --- builders ---------------------------------------------------------------

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn arr(v: Vec<Value>) -> Value {
        Value::Arr(v)
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut v = vec![];
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Value::Arr(v));
                        }
                        c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Value::Obj(m));
                        }
                        c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // (surrogate pairs unsupported — not produced by our writers)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // collect UTF-8 continuation bytes verbatim
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
        assert!(v.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"name":"dali \"q\"","nested":{"ok":true,"z":null}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("[1] x").is_err());
        assert!(Value::parse("nul").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Value::parse(r#"{"n": 3, "f": 1.5, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert!(v.get("f").unwrap().as_usize().is_err());
        assert_eq!(v.get("a").unwrap().as_usize_vec().unwrap(), vec![1, 2]);
        assert!(v.get("missing").is_err());
    }

    #[test]
    fn unicode_string_roundtrip() {
        let v = Value::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(v, Value::Str("héllo → 世界".into()));
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn large_integers_exact() {
        let ns: u64 = 1_234_567_890_123;
        let v = Value::parse(&format!("{ns}")).unwrap();
        assert_eq!(v.as_u64().unwrap(), ns);
        assert_eq!(v.to_json(), format!("{ns}"));
    }
}
