//! Dependency-free scoped-thread parallel runner (`std::thread::scope`,
//! no rayon — the offline build vendors everything).
//!
//! Experiment sweeps are embarrassingly parallel: every (model, batch,
//! framework) cell replays an independent deterministic simulation, so
//! [`parallel_map`] preserves input order and cell-level determinism —
//! `--jobs 4` and `--jobs 1` produce bit-identical results.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a `--jobs N` flag: `0` (or unset) = one worker per available
/// core, anything else taken literally.
pub fn resolve_jobs(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Render a caught panic payload as a human-readable message. `panic!`
/// with a format string produces a `String`; a literal produces `&str`;
/// anything else is opaque.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    match payload.downcast::<String>() {
        Ok(s) => *s,
        Err(payload) => match payload.downcast::<&'static str>() {
            Ok(s) => (*s).to_string(),
            Err(_) => "non-string panic payload".to_string(),
        },
    }
}

/// Like [`parallel_map`], but a panicking cell becomes `Err(message)`
/// instead of tearing down the sweep: every other cell still runs to
/// completion and its result is returned. Callers that can tolerate holes
/// (the `expt` driver) inspect the `Err`s; callers that can't should use
/// [`parallel_map`], which consolidates failures into one panic at the end.
pub fn parallel_map_catch<T, R, F>(jobs: usize, items: Vec<T>, f: F) -> Vec<Result<R, String>>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let run = |item: T| -> Result<R, String> {
        catch_unwind(AssertUnwindSafe(|| f(item))).map_err(panic_message)
    };
    let n = items.len();
    let jobs = jobs.max(1).min(n.max(1));
    if jobs <= 1 {
        return items.into_iter().map(run).collect();
    }
    // One slot per item: the input moves out as a worker claims it, the
    // result moves in when it finishes. Slot-level mutexes are uncontended
    // (each slot is touched by exactly one worker).
    let slots: Vec<Mutex<(Option<T>, Option<Result<R, String>>)>> =
        items.into_iter().map(|t| Mutex::new((Some(t), None))).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().0.take().expect("slot claimed once");
                let r = run(item);
                slots[i].lock().unwrap().1 = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().1.expect("worker filled slot"))
        .collect()
}

/// Apply `f` to every item on up to `jobs` scoped worker threads and return
/// the results in input order. Work is claimed from a shared atomic cursor,
/// so long cells never serialize behind short ones. `jobs <= 1` degrades to
/// a plain serial map with zero threading overhead.
///
/// A panicking cell no longer aborts the sweep mid-flight: all remaining
/// cells still run, then the failures are re-raised as a single panic that
/// names every failed cell. Use [`parallel_map_catch`] to keep the partial
/// results instead.
pub fn parallel_map<T, R, F>(jobs: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let results = parallel_map_catch(jobs, items, f);
    let failed: Vec<String> = results
        .iter()
        .enumerate()
        .filter_map(|(i, r)| r.as_ref().err().map(|msg| format!("cell #{i}: {msg}")))
        .collect();
    if !failed.is_empty() {
        panic!("{} of {} cells panicked:\n  {}", failed.len(), n, failed.join("\n  "));
    }
    results.into_iter().map(|r| r.expect("failures re-raised above")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let out = parallel_map(4, (0..100).collect(), |i: usize| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..64).collect();
        let f = |x: u64| x.wrapping_mul(0x9e37_79b9).rotate_left(13);
        let serial = parallel_map(1, items.clone(), f);
        let par = parallel_map(4, items, f);
        assert_eq!(serial, par, "jobs must not change results");
    }

    #[test]
    fn more_jobs_than_items_is_fine() {
        assert_eq!(parallel_map(16, vec![1, 2], |x| x + 1), vec![2, 3]);
        assert_eq!(parallel_map(8, Vec::<usize>::new(), |x| x), Vec::<usize>::new());
    }

    #[test]
    fn zero_jobs_degrades_to_serial() {
        assert_eq!(parallel_map(0, vec![5, 6], |x| x * 2), vec![10, 12]);
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }

    #[test]
    fn panicking_cell_keeps_completed_results() {
        for jobs in [1usize, 4] {
            let out = parallel_map_catch(jobs, (0..16).collect::<Vec<usize>>(), |i| {
                if i % 7 == 3 {
                    panic!("cell {i} exploded");
                }
                i * 10
            });
            assert_eq!(out.len(), 16);
            for (i, r) in out.iter().enumerate() {
                if i % 7 == 3 {
                    let msg = r.as_ref().unwrap_err();
                    assert!(msg.contains("exploded"), "payload preserved: {msg}");
                } else {
                    assert_eq!(*r.as_ref().unwrap(), i * 10, "completed cells survive");
                }
            }
        }
    }

    #[test]
    fn parallel_map_consolidates_panics_after_finishing_all_cells() {
        let ran = AtomicUsize::new(0);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(2, vec![0usize, 1, 2, 3], |i| {
                ran.fetch_add(1, Ordering::SeqCst);
                if i == 1 || i == 2 {
                    panic!("boom {i}");
                }
                i
            })
        }));
        // Every cell ran despite two failures, and the consolidated panic
        // names each failed cell.
        assert_eq!(ran.load(Ordering::SeqCst), 4, "no cell skipped");
        let msg = panic_message(caught.unwrap_err());
        assert!(msg.contains("2 of 4 cells panicked"), "summary line: {msg}");
        assert!(msg.contains("cell #1: boom 1") && msg.contains("cell #2: boom 2"), "{msg}");
    }

    #[test]
    fn parallel_path_leaves_the_caller_thread() {
        // The jobs > 1 path must run cells on worker threads (the caller
        // thread only coordinates). How MANY workers get scheduled is
        // timing-dependent, so only the off-main property is asserted.
        let main_id = std::thread::current().id();
        let ids = parallel_map(4, (0..64).collect::<Vec<usize>>(), |_| {
            std::thread::current().id()
        });
        assert!(ids.iter().all(|&id| id != main_id));
    }
}
