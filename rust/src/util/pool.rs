//! Dependency-free scoped-thread parallel runner (`std::thread::scope`,
//! no rayon — the offline build vendors everything).
//!
//! Experiment sweeps are embarrassingly parallel: every (model, batch,
//! framework) cell replays an independent deterministic simulation, so
//! [`parallel_map`] preserves input order and cell-level determinism —
//! `--jobs 4` and `--jobs 1` produce bit-identical results.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a `--jobs N` flag: `0` (or unset) = one worker per available
/// core, anything else taken literally.
pub fn resolve_jobs(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item on up to `jobs` scoped worker threads and return
/// the results in input order. Work is claimed from a shared atomic cursor,
/// so long cells never serialize behind short ones. `jobs <= 1` degrades to
/// a plain serial map with zero threading overhead.
pub fn parallel_map<T, R, F>(jobs: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let jobs = jobs.max(1).min(n.max(1));
    if jobs <= 1 {
        return items.into_iter().map(f).collect();
    }
    // One slot per item: the input moves out as a worker claims it, the
    // result moves in when it finishes. Slot-level mutexes are uncontended
    // (each slot is touched by exactly one worker).
    let slots: Vec<Mutex<(Option<T>, Option<R>)>> =
        items.into_iter().map(|t| Mutex::new((Some(t), None))).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().0.take().expect("slot claimed once");
                let r = f(item);
                slots[i].lock().unwrap().1 = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().1.expect("worker filled slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let out = parallel_map(4, (0..100).collect(), |i: usize| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..64).collect();
        let f = |x: u64| x.wrapping_mul(0x9e37_79b9).rotate_left(13);
        let serial = parallel_map(1, items.clone(), f);
        let par = parallel_map(4, items, f);
        assert_eq!(serial, par, "jobs must not change results");
    }

    #[test]
    fn more_jobs_than_items_is_fine() {
        assert_eq!(parallel_map(16, vec![1, 2], |x| x + 1), vec![2, 3]);
        assert_eq!(parallel_map(8, Vec::<usize>::new(), |x| x), Vec::<usize>::new());
    }

    #[test]
    fn zero_jobs_degrades_to_serial() {
        assert_eq!(parallel_map(0, vec![5, 6], |x| x * 2), vec![10, 12]);
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }

    #[test]
    fn parallel_path_leaves_the_caller_thread() {
        // The jobs > 1 path must run cells on worker threads (the caller
        // thread only coordinates). How MANY workers get scheduled is
        // timing-dependent, so only the off-main property is asserted.
        let main_id = std::thread::current().id();
        let ids = parallel_map(4, (0..64).collect::<Vec<usize>>(), |_| {
            std::thread::current().id()
        });
        assert!(ids.iter().all(|&id| id != main_id));
    }
}
