//! Tiny CLI argument parser (the offline build has no clap).
//!
//! Grammar: positionals + `--flag value` + `--flag=value` + bare `--flag`
//! (boolean true).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        // NB: a bare flag consumes the next token as its value unless that
        // token is another flag — so bare booleans go last or before flags.
        let a = parse("run fig12 --preset mixtral-sim --batch 8 --verbose");
        assert_eq!(a.positional, vec!["run", "fig12"]);
        assert_eq!(a.get("preset"), Some("mixtral-sim"));
        assert_eq!(a.usize_or("batch", 1), 8);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn bare_flag_before_flag() {
        let a = parse("--verbose --preset x");
        assert!(a.bool("verbose"));
        assert_eq!(a.get("preset"), Some("x"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--steps=64 --rate=1.5");
        assert_eq!(a.usize_or("steps", 0), 64);
        assert!((a.f64_or("rate", 0.0) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn defaults_and_require() {
        let a = parse("cmd");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert!(a.require("missing").is_err());
    }
}
