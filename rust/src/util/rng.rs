//! Deterministic RNG used everywhere randomness is needed (the offline
//! build has no `rand` crate; this is a self-contained PCG-XSH-RR).
//!
//! Every experiment seeds its own [`DetRng`] so tables/figures are exactly
//! reproducible run-to-run; wall-clock nondeterminism never feeds results.

/// Deterministic PCG32 (PCG-XSH-RR 64/32) with convenience helpers.
#[derive(Debug, Clone)]
pub struct DetRng {
    state: u64,
    inc: u64,
}

impl DetRng {
    pub fn new(seed: u64) -> Self {
        let mut r = DetRng { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed ^ 0x853c_49e6_748f_ea9b);
        r.next_u32();
        r
    }

    /// Derive a child RNG from a string tag (stable across runs).
    pub fn derive(&self, tag: &str) -> Self {
        let mut acc: u64 = 0xcbf2_9ce4_8422_2325 ^ self.inc;
        for b in tag.bytes() {
            acc = (acc ^ b as u64).wrapping_mul(0x1000_0000_01b3);
        }
        DetRng::new(acc)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style unbiased bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.usize_below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = DetRng::new(42);
        let mut b = DetRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.usize_below(1000), b.usize_below(1000));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = DetRng::new(1);
        let mut b = DetRng::new(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn derive_differs_by_tag() {
        let root = DetRng::new(7);
        let mut a = root.derive("alpha");
        let mut b = root.derive("beta");
        let va: Vec<usize> = (0..8).map(|_| a.usize_below(100)).collect();
        let vb: Vec<usize> = (0..8).map(|_| b.usize_below(100)).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn bounded_sampling_in_range_and_covers() {
        let mut r = DetRng::new(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let x = r.usize_below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues reachable");
    }

    #[test]
    fn f64_in_unit_interval_and_uniformish() {
        let mut r = DetRng::new(11);
        let xs: Vec<f64> = (0..2000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean = {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = DetRng::new(13);
        let xs: Vec<f64> = (0..4000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.06, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.1, "var = {var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = DetRng::new(1);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..50 {
            assert_eq!(r.weighted(&w), 2);
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = DetRng::new(3);
        assert!(!r.chance(0.0));
        assert!(r.chance(1.0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = DetRng::new(9);
        let mut v: Vec<usize> = (0..32).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..32).collect::<Vec<_>>());
    }
}
