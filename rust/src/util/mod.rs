//! Small shared utilities: repo-relative paths, deterministic RNG, tables.

use std::path::{Path, PathBuf};

pub mod alloc_counter;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod table;

pub use cli::Args;
pub use json::Value;
pub use rng::DetRng;
pub use table::Table;

/// A unique temp directory for tests (no tempfile crate offline).
pub fn test_temp_dir(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "dali-test-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Locate the repository root (the directory containing `configs/presets.json`).
///
/// Resolution order: `$DALI_ROOT`, then the current directory and its
/// ancestors, then the compile-time crate root. Experiments, tests, benches
/// and examples all resolve artifact paths through this.
pub fn repo_root() -> PathBuf {
    if let Ok(root) = std::env::var("DALI_ROOT") {
        return PathBuf::from(root);
    }
    let probe = |p: &Path| p.join("configs").join("presets.json").exists();
    if let Ok(mut cur) = std::env::current_dir() {
        loop {
            if probe(&cur) {
                return cur;
            }
            if !cur.pop() {
                break;
            }
        }
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// `<repo>/artifacts`
pub fn artifacts_dir() -> PathBuf {
    repo_root().join("artifacts")
}

/// Whether `make artifacts` output exists for `preset`. Tests that read
/// artifacts probe this and skip (with a message) when absent, instead of
/// failing on infrastructure the offline build cannot have.
pub fn artifacts_ready(preset: &str) -> bool {
    let ok = artifacts_dir().join(preset).join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts for {preset} not generated (run `make artifacts`)");
    }
    ok
}

/// `<repo>/results` (experiment outputs)
pub fn results_dir() -> PathBuf {
    let d = repo_root().join("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Format a nanosecond count as a human-readable duration.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else {
        format!("{}ns", ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repo_root_has_configs() {
        assert!(repo_root().join("configs/presets.json").exists());
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(5), "5ns");
        assert_eq!(fmt_ns(1500), "1.5us");
        assert_eq!(fmt_ns(2_500_000), "2.500ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.000s");
    }
}
