//! Shared counting global allocator.
//!
//! One implementation serves both consumers — the `dali bench` subcommand
//! (machine-readable allocs/step in `BENCH_simrun.json`, `--strict` CI
//! gate) and the `tests/alloc_audit.rs` integration binary — so the two
//! can never measure subtly different things. The library itself never
//! installs it; each binary opts in with
//! `#[global_allocator] static G: CountingAlloc = CountingAlloc;`.
//!
//! Counting costs two relaxed atomic increments per alloc/dealloc — noise
//! for a syscall-bound CLI or the virtual-time simulator, and the audited
//! hot path allocates nothing, so the counters stay cold exactly where
//! performance matters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static DEALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

/// Pass-through to the system allocator that counts every call.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        DEALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Allocation calls (`alloc` + `realloc`) since process start.
pub fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// Deallocation calls since process start.
pub fn dealloc_calls() -> u64 {
    DEALLOC_CALLS.load(Ordering::Relaxed)
}
