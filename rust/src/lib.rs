//! # DALI — workload-aware MoE offloading for local PCs (paper reproduction)
//!
//! Three-layer architecture (see DESIGN.md):
//!
//! * **Layer 3 (this crate)** — the coordinator: expert assignment
//!   ([`coordinator::assignment`], paper §4.1), residual-based prefetching
//!   ([`coordinator::prefetch`], §4.2), workload-aware expert caching
//!   ([`coordinator::cache`], §4.3), the tiered GPU/host/NVMe expert
//!   [`store`] (residency + async transfer scheduling beyond the paper's
//!   two-tier assumption), the inference engine, baseline frameworks, a
//!   serving front-end, the heterogeneous-platform simulator ([`hw`])
//!   standing in for the paper's RTX 3090 + EPYC testbed, and a structured
//!   step-[`trace`] subsystem (typed events, zero-cost sinks, run digests).
//! * **Layer 2** — the JAX MoE model (`python/compile/model.py`), AOT-lowered
//!   to HLO text artifacts.
//! * **Layer 1** — Pallas kernels for the expert FFN and fused gate
//!   (`python/compile/kernels/`).
//!
//! Python never runs on the request path: [`runtime::PjrtEngine`] loads the
//! HLO artifacts once and executes them via the PJRT CPU client. All *timing*
//! is virtual (from [`hw::CostModel`]); all *numerics* are real.

pub mod config;
pub mod coordinator;
pub mod expt;
pub mod fault;
pub mod hw;
pub mod metrics;
pub mod moe;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod trace;
pub mod util;
pub mod workload;

pub use config::Presets;
pub use fault::{FaultPlan, FaultProfile};
pub use hw::CostModel;
pub use store::{Tier, TieredStore};
