//! MoE-Lightning-style fixed placement: experts pinned on the GPU by an
//! offline search run there; everything else runs on the CPU. No dynamic
//! decisions at runtime — the paper's critique is precisely that this
//! "fixed CPU/GPU placement before inference makes it poorly suited to
//! MoE's dynamic workload patterns" (§6.2).
//!
//! The offline search itself lives in `frameworks.rs` (it pins the experts
//! with the highest calibration-set activation frequency that fit in the
//! memory budget, per MoE-Lightning's performance-model-driven planning).

use super::{AssignCtx, Assigner, Assignment};

pub struct ResidentOnlyAssigner;

impl Default for ResidentOnlyAssigner {
    fn default() -> Self {
        Self::new()
    }
}

impl ResidentOnlyAssigner {
    pub fn new() -> Self {
        ResidentOnlyAssigner
    }
}

impl Assigner for ResidentOnlyAssigner {
    fn name(&self) -> &'static str {
        "resident_only"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        let n = ctx.workloads.len();
        out.reset(n);
        for e in 0..n {
            if ctx.workloads[e] == 0 {
                continue;
            }
            if ctx.resident[e] {
                out.to_gpu[e] = true;
            } else {
                out.to_cpu[e] = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::cost;
    use super::*;

    #[test]
    fn only_pinned_experts_use_gpu() {
        let cm = cost("qwen-sim");
        let workloads = vec![10, 10, 0, 10];
        let resident = vec![true, false, true, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 0,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = ResidentOnlyAssigner::new().assign(&ctx);
        assert_eq!(a.to_gpu, vec![true, false, false, false]);
        assert_eq!(a.to_cpu, vec![false, true, false, true]);
        assert!(a.satisfies_constraints(&ctx));
    }
}
