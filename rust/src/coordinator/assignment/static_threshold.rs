//! Static per-expert placement (Fiddler / HybriMoE, paper §3.1 & Fig. 1b).
//!
//! "Experts exceeding a predefined workload threshold (high-workload
//! experts) are executed on the GPU, while the rest (low-workload experts)
//! are handled by the CPU in parallel." The threshold is a *workload count*
//! (we use the mean active workload), not a cost comparison — the policy
//! neither accounts for transfer cost nor for the cumulative load on either
//! device. That produces both failure modes the paper measures: severe
//! CPU/GPU imbalance (Fig. 4) and PCIe-transfer-bound execution (Fig. 5).
//!
//! Cache-resident experts additionally run on the GPU whenever that is
//! individually cheaper (the cache-exploitation rule every expert-wise
//! framework implements).

use super::{solve_model, AssignCtx, Assigner, Assignment};
use crate::hw::Ns;

/// The visit-order scratch makes repeated solves allocation-free — this is
/// HybriMoE's / Fiddler's assigner on the measured replay paths.
#[derive(Debug, Default)]
pub struct StaticThresholdAssigner {
    order: Vec<usize>,
}

impl StaticThresholdAssigner {
    pub fn new() -> Self {
        StaticThresholdAssigner::default()
    }

    /// The "predefined workload threshold": the mean workload over active
    /// experts this step.
    pub fn threshold(workloads: &[u32]) -> u32 {
        let (mut sum, mut count) = (0u64, 0u64);
        for &w in workloads {
            if w > 0 {
                sum += w as u64;
                count += 1;
            }
        }
        if count == 0 {
            return u32::MAX;
        }
        (sum / count) as u32
    }
}

impl Assigner for StaticThresholdAssigner {
    fn name(&self) -> &'static str {
        "static_threshold"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        let n = ctx.workloads.len();
        out.reset(n);
        let mut slots = ctx.gpu_free_slots;
        let thresh = Self::threshold(ctx.workloads);
        // Visit high-workload experts first so the memory budget goes to
        // the experts the policy most wants on the GPU (index tiebreak
        // reproduces the old stable-sort order).
        let order = &mut self.order;
        order.clear();
        order.extend((0..n).filter(|&e| ctx.workloads[e] > 0));
        order.sort_unstable_by_key(|&e| (std::cmp::Reverse(ctx.workloads[e]), e));
        for &e in order.iter() {
            let resident_win = ctx.resident[e] && ctx.t_gpu(e) < ctx.t_cpu(e);
            let high_workload = ctx.workloads[e] > thresh;
            let needs_slot = !ctx.resident[e];
            if (resident_win || high_workload) && (!needs_slot || slots > 0) {
                out.to_gpu[e] = true;
                if needs_slot {
                    slots -= 1;
                }
            } else {
                out.to_cpu[e] = true;
            }
        }
    }

    fn modeled_solve_ns(&self, ctx: &AssignCtx) -> Ns {
        // threshold pass + workload sort
        solve_model::nlogn(ctx.active_count(), 16)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::cost;
    use super::*;

    #[test]
    fn threshold_is_mean_of_active() {
        assert_eq!(StaticThresholdAssigner::threshold(&[0, 2, 4, 0, 6]), 4);
        assert_eq!(StaticThresholdAssigner::threshold(&[0, 0]), u32::MAX);
    }

    #[test]
    fn high_workload_experts_forced_to_gpu_despite_transfer_cost() {
        // The paper's critique: a high-workload uncached Mixtral expert is
        // sent to the GPU even though its PCIe transfer (~14 ms) exceeds
        // its CPU time — static placement ignores transfer economics.
        let cm = cost("mixtral-sim");
        let workloads = vec![12, 1, 1, 1];
        let resident = vec![false; 4];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 4,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = StaticThresholdAssigner::new().assign(&ctx);
        assert!(a.to_gpu[0], "above-threshold expert goes to GPU");
        assert!(a.to_cpu[1] && a.to_cpu[2] && a.to_cpu[3]);
    }

    #[test]
    fn ignores_load_balance() {
        // Skewed workloads: static dumps every above-mean expert on the
        // GPU; greedy balances and achieves a lower makespan.
        let cm = cost("mixtral-sim");
        let workloads = vec![30, 28, 26, 24, 2, 2, 2, 2];
        let resident = vec![false; 8];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = StaticThresholdAssigner::new().assign(&ctx);
        assert!(a.to_gpu[0] && a.to_gpu[1] && a.to_gpu[2] && a.to_gpu[3]);
        // The true optimum is never worse than the static split (greedy is
        // a heuristic and can occasionally lose on adversarial instances —
        // the paper's own Table 4 concedes ~92% of optimal).
        let o = super::super::OptimalAssigner::new().assign(&ctx);
        assert!(o.makespan_estimate(&ctx) <= a.makespan_estimate(&ctx));
    }

    #[test]
    fn uniform_low_workloads_stay_on_cpu() {
        let cm = cost("mixtral-sim");
        let workloads = vec![2, 2];
        let resident = vec![false, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = StaticThresholdAssigner::new().assign(&ctx);
        assert!(a.to_cpu[0] && a.to_cpu[1]);
    }

    #[test]
    fn cached_expert_prefers_gpu() {
        let cm = cost("mixtral-sim");
        let workloads = vec![2];
        let resident = vec![true];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = StaticThresholdAssigner::new().assign(&ctx);
        assert!(a.to_gpu[0]);
    }
}
