//! "Opt_plan": precise solving of the 0-1 program by exhaustive
//! enumeration — no heuristic seeding, no pruning beyond feasibility.
//!
//! This is the paper's foil: the *exact* schedule whose "runtime solving
//! cost is prohibitively high" (§6.3-1, Fig. 15: 55 % of end-to-end time).
//! The branch-and-bound [`super::OptimalAssigner`] finds the same optimum
//! orders of magnitude faster and exists for verification; Opt_plan
//! experiments use this solver so the measured (and virtually charged)
//! solve cost reflects precise solving, as in the paper.
//!
//! Instances with more than `max_active` activated experts fall back to
//! branch & bound (the paper's N=64/128 models need an ILP solver there
//! too).

use super::{solve_model, AssignCtx, Assigner, Assignment, OptimalAssigner};
use crate::hw::Ns;

pub struct EnumerateAssigner {
    pub max_active: usize,
}

impl Default for EnumerateAssigner {
    fn default() -> Self {
        Self::new()
    }
}

impl EnumerateAssigner {
    pub fn new() -> Self {
        EnumerateAssigner { max_active: 20 }
    }
}

impl Assigner for EnumerateAssigner {
    fn name(&self) -> &'static str {
        "opt_plan"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        let n = ctx.workloads.len();
        let active: Vec<usize> = (0..n).filter(|&e| ctx.workloads[e] > 0).collect();
        if active.len() > self.max_active {
            return OptimalAssigner::new().assign_into(ctx, out);
        }
        let costs: Vec<(u64, u64, bool)> =
            active.iter().map(|&e| (ctx.t_cpu(e), ctx.t_gpu(e), !ctx.resident[e])).collect();
        let mut best_mask = 0u32;
        let mut best = u64::MAX;
        for mask in 0u32..(1u32 << active.len()) {
            let mut t_cpu = 0u64;
            let mut t_gpu = 0u64;
            let mut staged = 0usize;
            for (i, &(c, g, needs_slot)) in costs.iter().enumerate() {
                if mask & (1 << i) != 0 {
                    t_gpu += g;
                    if needs_slot {
                        staged += 1;
                    }
                } else {
                    t_cpu += c;
                }
            }
            if staged > ctx.gpu_free_slots {
                continue;
            }
            let m = t_cpu.max(t_gpu);
            if m < best {
                best = m;
                best_mask = mask;
            }
        }
        out.reset(n);
        for (i, &e) in active.iter().enumerate() {
            if best_mask & (1 << i) != 0 {
                out.to_gpu[e] = true;
            } else {
                out.to_cpu[e] = true;
            }
        }
    }

    fn modeled_solve_ns(&self, ctx: &AssignCtx) -> Ns {
        // 2^n masks, each scanning n experts (~1.5ns/op after optimisation);
        // past max_active the branch & bound fallback kicks in.
        let a = ctx.active_count();
        if a > self.max_active {
            return OptimalAssigner::new().modeled_solve_ns(ctx);
        }
        solve_model::exponential(a, 2, 20)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::cost;
    use super::super::GreedyAssigner;
    use super::*;
    use crate::util::DetRng;

    #[test]
    fn matches_branch_and_bound_optimum() {
        let cm = cost("deepseek-sim");
        let mut rng = DetRng::new(21);
        for _ in 0..25 {
            let n = 12;
            let workloads: Vec<u32> =
                (0..n).map(|_| if rng.chance(0.3) { 0 } else { rng.usize_below(40) as u32 }).collect();
            let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.4)).collect();
            let ctx = AssignCtx {
                workloads: &workloads,
                resident: &resident,
                tiers: None,
                host_wait: None,
                cost: &cm,
                gpu_free_slots: n,
                layer: 0,
                layers: 4,
                devices: None,
            };
            let enumed = EnumerateAssigner::new().assign(&ctx);
            let bnb = OptimalAssigner::new().assign(&ctx);
            assert!(enumed.satisfies_constraints(&ctx));
            assert_eq!(enumed.makespan_estimate(&ctx), bnb.makespan_estimate(&ctx));
        }
    }

    #[test]
    fn enumeration_is_much_slower_than_greedy() {
        // The whole point of Opt_plan: precise solving costs real time.
        let cm = cost("deepseek-sim");
        let workloads: Vec<u32> = (0..16).map(|i| (i % 7 + 1) as u32).collect();
        let resident = vec![false; 16];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 16,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            EnumerateAssigner::new().assign(&ctx);
        }
        let slow = t0.elapsed();
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            GreedyAssigner::new().assign(&ctx);
        }
        let fast = t0.elapsed();
        assert!(slow > fast * 20, "enumeration {slow:?} vs greedy {fast:?}");
    }

    #[test]
    fn large_instances_fall_back() {
        let cm = cost("qwen-sim");
        let workloads: Vec<u32> = (0..32).map(|_| 3).collect();
        let resident = vec![false; 32];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 32,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = EnumerateAssigner::new().assign(&ctx);
        assert!(a.satisfies_constraints(&ctx));
    }
}
