//! Exact solver for the 0-1 assignment program ("Opt_plan").
//!
//! Depth-first branch & bound over activated experts, strongest-first.
//! Bounds: (1) the partial makespan `max(T_cpu, T_gpu)` is monotone, and
//! (2) any completion's makespan is at least
//! `(T_cpu + T_gpu + Σ_remaining min(t_cpu, t_gpu)) / 2` (total-load bound
//! over two machines). Greedy seeds the incumbent.
//!
//! The point of this solver in the paper is that it is *too slow to use
//! online* (55 % end-to-end overhead vs greedy's ~5 %) — its real measured
//! solve time is charged into virtual time by the simulator, reproducing
//! that comparison. A node cap keeps worst cases bounded; on cap the best
//! incumbent is returned.

use super::{greedy::GreedyAssigner, solve_model, AssignCtx, Assigner, Assignment};
use crate::hw::Ns;

pub struct OptimalAssigner {
    /// Safety valve for exponential worst cases.
    pub node_cap: u64,
    nodes: u64,
}

impl Default for OptimalAssigner {
    fn default() -> Self {
        Self::new()
    }
}

impl OptimalAssigner {
    pub fn new() -> Self {
        OptimalAssigner { node_cap: 8_000_000, nodes: 0 }
    }

    #[allow(clippy::too_many_arguments)]
    fn dfs(
        &mut self,
        order: &[usize],
        idx: usize,
        t_cpu: u64,
        t_gpu: u64,
        slots: usize,
        costs: &[(u64, u64, bool)], // (t_cpu, t_gpu, needs_slot) per order pos
        suffix_min: &[u64],
        choice: &mut Vec<bool>, // true = GPU, per order pos
        best: &mut (u64, Vec<bool>),
    ) {
        self.nodes += 1;
        if self.nodes > self.node_cap {
            return;
        }
        let partial = t_cpu.max(t_gpu);
        if partial >= best.0 {
            return; // bound 1
        }
        let lb = partial.max((t_cpu + t_gpu + suffix_min[idx]).div_ceil(2));
        if lb >= best.0 {
            return; // bound 2
        }
        if idx == order.len() {
            best.0 = partial;
            best.1 = choice[..idx].to_vec();
            return;
        }
        let (c, g, needs_slot) = costs[idx];
        // Explore the locally-cheaper branch first for fast incumbents.
        let gpu_first = t_gpu + g <= t_cpu + c;
        for &to_gpu in if gpu_first { &[true, false] } else { &[false, true] } {
            if to_gpu && needs_slot && slots == 0 {
                continue;
            }
            choice[idx] = to_gpu;
            let (nc, ng) = if to_gpu { (t_cpu, t_gpu + g) } else { (t_cpu + c, t_gpu) };
            let ns = if to_gpu && needs_slot { slots - 1 } else { slots };
            self.dfs(order, idx + 1, nc, ng, ns, costs, suffix_min, choice, best);
        }
    }
}

impl Assigner for OptimalAssigner {
    fn name(&self) -> &'static str {
        "opt_plan"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        self.nodes = 0;
        let n = ctx.workloads.len();
        let order: Vec<usize> = {
            let mut v: Vec<usize> = (0..n).filter(|&e| ctx.workloads[e] > 0).collect();
            // strongest decisions first: big max-cost experts
            v.sort_by_key(|&e| std::cmp::Reverse(ctx.t_cpu(e).max(ctx.t_gpu(e))));
            v
        };
        let costs: Vec<(u64, u64, bool)> =
            order.iter().map(|&e| (ctx.t_cpu(e), ctx.t_gpu(e), !ctx.resident[e])).collect();
        let mut suffix_min = vec![0u64; order.len() + 1];
        for i in (0..order.len()).rev() {
            suffix_min[i] = suffix_min[i + 1] + costs[i].0.min(costs[i].1);
        }
        // Seed incumbent with greedy.
        let seed = GreedyAssigner::new().assign(ctx);
        let mut best = (
            seed.makespan_estimate(ctx),
            order.iter().map(|&e| seed.to_gpu[e]).collect::<Vec<bool>>(),
        );
        // Greedy is feasible, so best.1 is a valid fallback. Try to improve:
        let mut choice = vec![false; order.len()];
        self.dfs(&order, 0, 0, 0, ctx.gpu_free_slots, &costs, &suffix_min, &mut choice, &mut best);

        out.reset(n);
        for (i, &e) in order.iter().enumerate() {
            if best.1[i] {
                out.to_gpu[e] = true;
            } else {
                out.to_cpu[e] = true;
            }
        }
    }

    fn modeled_solve_ns(&self, ctx: &AssignCtx) -> Ns {
        // branch & bound with a greedy incumbent prunes aggressively:
        // effective branching ~ 2^(n/2) nodes at ~2ns each.
        let a = ctx.active_count();
        solve_model::exponential(a.div_ceil(2), 4, 24)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::{brute_force, cost};
    use super::*;
    use crate::util::DetRng;

    #[test]
    fn matches_bruteforce_on_random_instances() {
        let cm = cost("mixtral-sim");
        let mut rng = DetRng::new(5);
        for trial in 0..40 {
            let n = 8;
            let workloads: Vec<u32> =
                (0..n).map(|_| if rng.chance(0.25) { 0 } else { rng.usize_below(40) as u32 }).collect();
            let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.4)).collect();
            let slots = rng.usize_below(n + 1);
            let ctx = AssignCtx {
                workloads: &workloads,
                resident: &resident,
                tiers: None,
                host_wait: None,
                cost: &cm,
                gpu_free_slots: slots,
                layer: 0,
                layers: 4,
                devices: None,
            };
            let a = OptimalAssigner::new().assign(&ctx);
            assert!(a.satisfies_constraints(&ctx), "trial {trial}");
            let (opt, _) = brute_force(&ctx);
            assert_eq!(a.makespan_estimate(&ctx), opt, "trial {trial}");
        }
    }

    #[test]
    fn never_worse_than_greedy() {
        let cm = cost("qwen-sim");
        let mut rng = DetRng::new(77);
        for _ in 0..30 {
            let n = 16;
            let workloads: Vec<u32> = (0..n).map(|_| rng.usize_below(20) as u32).collect();
            let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.3)).collect();
            let ctx = AssignCtx {
                workloads: &workloads,
                resident: &resident,
                tiers: None,
                host_wait: None,
                cost: &cm,
                gpu_free_slots: n,
                layer: 0,
                layers: 4,
                devices: None,
            };
            let g = GreedyAssigner::new().assign(&ctx).makespan_estimate(&ctx);
            let o = OptimalAssigner::new().assign(&ctx).makespan_estimate(&ctx);
            assert!(o <= g);
        }
    }

    #[test]
    fn handles_all_inactive() {
        let cm = cost("mixtral-sim");
        let workloads = vec![0; 8];
        let resident = vec![false; 8];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = OptimalAssigner::new().assign(&ctx);
        assert_eq!(a, Assignment::none(8));
    }
}
