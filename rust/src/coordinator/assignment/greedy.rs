//! The paper's Greedy Assignment strategy (Algorithm 1), generalized to N
//! GPU device tiers.
//!
//! Experts are visited in descending `|t_gpu - t_cpu|` order — place the
//! experts whose device choice matters most first — and each is put on
//! whichever device yields the lower cumulative finish time: greedy over
//! (expert, device) pairs, where the GPU side of the comparison is the
//! least-loaded eligible device and the CPU side is the shared CPU queue.
//! On a single-GPU context this is exactly the paper's Algorithm 1
//! (near-optimal, ≥ ~92 % of Opt_plan in Table 4) at a tiny solve cost.

use super::{solve_model, AssignCtx, Assigner, Assignment};
use crate::hw::Ns;
use crate::store::MAX_DEVICES;

/// The scratch vectors make repeated solves allocation-free — this is the
/// solver on the simulator's per-layer hot path. `t_gpu` is device-major
/// (`n_devices × n_experts`); the per-device running totals and slot
/// counters are fixed-size stack arrays, so multi-device solves allocate
/// nothing either.
#[derive(Debug, Default, Clone)]
pub struct GreedyAssigner {
    t_gpu: Vec<u64>,
    t_cpu: Vec<u64>,
    best: Vec<u64>,
    order: Vec<usize>,
}

impl GreedyAssigner {
    pub fn new() -> Self {
        GreedyAssigner::default()
    }
}

impl Assigner for GreedyAssigner {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        let n = ctx.workloads.len();
        let nd = ctx.n_devices();
        out.reset(n);
        let GreedyAssigner { t_gpu, t_cpu, best, order } = self;
        // Alg. 1 lines 1-4: per-(device, expert) and per-expert costs.
        t_gpu.clear();
        for d in 0..nd {
            t_gpu.extend((0..n).map(|e| ctx.t_gpu_dev(e, d)));
        }
        t_cpu.clear();
        t_cpu.extend((0..n).map(|e| ctx.t_cpu(e)));
        // the sort sees each expert at its best-device cost — on one device
        // this is t_gpu(e) verbatim
        best.clear();
        best.extend((0..n).map(|e| (0..nd).map(|d| t_gpu[d * n + e]).min().unwrap_or(0)));
        // line 5: sort by |t_gpu - t_cpu| descending (index tiebreak keeps
        // the order — and hence the metrics — fully deterministic).
        order.clear();
        order.extend(0..n);
        order.sort_unstable_by_key(|&e| (std::cmp::Reverse(best[e].abs_diff(t_cpu[e])), e));
        let mut total_dev = [0u64; MAX_DEVICES];
        let mut total_cpu: u64 = 0;
        let mut free_slots = [0usize; MAX_DEVICES];
        for (d, slot) in free_slots.iter_mut().enumerate().take(nd) {
            *slot = ctx.free_slots_on(d);
        }
        for &e in order.iter() {
            // lines 9-10: skip inactive experts.
            if ctx.workloads[e] == 0 {
                continue;
            }
            // Eq. 9 memory guard per device: a device not holding the
            // expert needs a staging slot there. Among eligible devices,
            // the lowest cumulative finish wins (lowest index on ties —
            // determinism).
            let mut pick: Option<(u64, usize)> = None;
            for d in 0..nd {
                if !ctx.resident_on(e, d) && free_slots[d] == 0 {
                    continue;
                }
                let finish = total_dev[d] + t_gpu[d * n + e];
                if pick.map_or(true, |(f, _)| finish < f) {
                    pick = Some((finish, d));
                }
            }
            // lines 12-17: lower cumulative finish time wins.
            match pick {
                Some((finish, d)) if finish <= total_cpu + t_cpu[e] => {
                    out.to_gpu[e] = true;
                    out.device[e] = d as u8;
                    total_dev[d] += t_gpu[d * n + e];
                    if !ctx.resident_on(e, d) {
                        free_slots[d] -= 1;
                    }
                }
                _ => {
                    out.to_cpu[e] = true;
                    total_cpu += t_cpu[e];
                }
            }
        }
    }

    fn modeled_solve_ns(&self, ctx: &AssignCtx) -> Ns {
        // cost tables (one per device) + one sort + one placement pass
        solve_model::nlogn(ctx.active_count(), 28 * ctx.n_devices() as u64)
    }

    fn device_aware(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::{brute_force, cost};
    use super::*;
    use crate::util::DetRng;

    #[test]
    fn respects_constraints() {
        let cm = cost("mixtral-sim");
        let workloads = vec![4, 0, 1, 9, 2, 0, 7, 3];
        let resident = vec![true, false, false, false, true, false, false, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert!(a.satisfies_constraints(&ctx));
        // inactive experts untouched
        assert!(!a.to_gpu[1] && !a.to_cpu[1]);
        assert!(!a.to_gpu[5] && !a.to_cpu[5]);
    }

    #[test]
    fn resident_high_workload_expert_goes_to_gpu() {
        let cm = cost("mixtral-sim");
        let workloads = vec![64, 1];
        let resident = vec![true, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert!(a.to_gpu[0], "cached 64-token expert must run on GPU");
        assert!(a.to_cpu[1], "1-token uncached expert must stay on CPU");
    }

    #[test]
    fn within_8pct_of_bruteforce_on_random_instances() {
        // Paper Table 4: greedy ≥ ~85-92 % of optimal. Verify on many
        // random instances that greedy stays within 2x (makespan ratio) and
        // on average within 15 %.
        let cm = cost("deepseek-sim");
        let mut rng = DetRng::new(99);
        let mut ratios = vec![];
        for _ in 0..60 {
            let n = 12;
            let workloads: Vec<u32> =
                (0..n).map(|_| if rng.chance(0.3) { 0 } else { rng.usize_below(30) as u32 }).collect();
            let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.3)).collect();
            let ctx = AssignCtx {
                workloads: &workloads,
                resident: &resident,
                tiers: None,
                host_wait: None,
                cost: &cm,
                gpu_free_slots: n,
                layer: 0,
                layers: 4,
                devices: None,
            };
            let a = GreedyAssigner::new().assign(&ctx);
            assert!(a.satisfies_constraints(&ctx));
            let (opt, _) = brute_force(&ctx);
            if opt > 0 {
                let r = a.makespan_estimate(&ctx) as f64 / opt as f64;
                assert!(r < 2.0, "greedy ratio {r}");
                ratios.push(r);
            }
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(avg < 1.15, "avg greedy/opt ratio {avg}");
    }

    #[test]
    fn memory_constraint_forces_cpu() {
        let cm = cost("mixtral-sim");
        let workloads = vec![60, 60, 60];
        let resident = vec![false, false, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 1,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        let staged = (0..3).filter(|&e| a.to_gpu[e]).count();
        assert!(staged <= 1);
        assert!(a.satisfies_constraints(&ctx));
    }

    #[test]
    fn multi_device_greedy_balances_load_across_devices() {
        use super::super::DeviceView;
        let cm = cost("mixtral-sim");
        // four heavy uncached experts, plenty of slots on two devices: the
        // cumulative-finish rule must spread them instead of piling on one
        let workloads = vec![64u32, 64, 64, 64];
        let resident = vec![false; 4];
        let dev_resident = vec![false; 8];
        let free = vec![4usize, 4];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: Some(DeviceView { n: 2, resident: &dev_resident, free_slots: &free }),
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert!(a.satisfies_constraints(&ctx));
        let gpu_assigned: Vec<usize> = (0..4).filter(|&e| a.to_gpu[e]).collect();
        assert!(gpu_assigned.len() >= 2, "64-token experts belong on the GPUs");
        let on0 = gpu_assigned.iter().filter(|&&e| a.device_of(e) == 0).count();
        let on1 = gpu_assigned.iter().filter(|&&e| a.device_of(e) == 1).count();
        assert!(on0 > 0 && on1 > 0, "load must spread: {on0} vs {on1}");
        assert!(on0.abs_diff(on1) <= 1, "near-even split: {on0} vs {on1}");
    }

    #[test]
    fn multi_device_greedy_prefers_the_caching_device() {
        use super::super::DeviceView;
        let cm = cost("mixtral-sim");
        let workloads = vec![32u32];
        let resident = vec![false];
        // cached on device 1 only: running there is free of transfer
        let dev_resident = vec![false, true];
        let free = vec![4usize, 4];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: Some(DeviceView { n: 2, resident: &dev_resident, free_slots: &free }),
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert!(a.to_gpu[0]);
        assert_eq!(a.device_of(0), 1, "the caching device wins the tie");
    }

    #[test]
    fn per_device_slot_exhaustion_redirects_not_rejects() {
        use super::super::DeviceView;
        let cm = cost("mixtral-sim");
        let workloads = vec![60u32, 60, 60];
        let resident = vec![false; 3];
        let dev_resident = vec![false; 6];
        // device 0 has no slots at all: everything GPU-bound lands on 1
        let free = vec![0usize, 2];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
            devices: Some(DeviceView { n: 2, resident: &dev_resident, free_slots: &free }),
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert!(a.satisfies_constraints(&ctx));
        for e in 0..3 {
            if a.to_gpu[e] {
                assert_eq!(a.device_of(e), 1, "slotless device 0 must get nothing");
            }
        }
        assert!((0..3).filter(|&e| a.to_gpu[e]).count() <= 2);
    }

    #[test]
    fn single_device_view_reproduces_the_scalar_solve() {
        // A DeviceView with n = 1 must produce the bit-identical assignment
        // the scalar context does — the digest-backcompat anchor for the
        // solver layer.
        use super::super::DeviceView;
        let cm = cost("deepseek-sim");
        let mut rng = DetRng::new(7);
        for _ in 0..40 {
            let n = 10;
            let workloads: Vec<u32> =
                (0..n).map(|_| if rng.chance(0.3) { 0 } else { rng.usize_below(40) as u32 }).collect();
            let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.4)).collect();
            let slots = rng.usize_below(n + 1);
            let free = vec![slots];
            let base = AssignCtx {
                workloads: &workloads,
                resident: &resident,
                tiers: None,
                host_wait: None,
                cost: &cm,
                gpu_free_slots: slots,
                layer: 0,
                layers: 4,
                devices: None,
            };
            let viewed = AssignCtx {
                devices: Some(DeviceView { n: 1, resident: &resident, free_slots: &free }),
                ..base
            };
            let a = GreedyAssigner::new().assign(&base);
            let b = GreedyAssigner::new().assign(&viewed);
            assert_eq!(a, b, "n=1 view must not perturb the solve");
        }
    }

    #[test]
    fn empty_layer_assigns_nothing() {
        let cm = cost("qwen-sim");
        let workloads = vec![0; 8];
        let resident = vec![false; 8];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert_eq!(a, Assignment::none(8));
    }
}
