//! The paper's Greedy Assignment strategy (Algorithm 1).
//!
//! Experts are visited in descending `|t_gpu - t_cpu|` order — place the
//! experts whose device choice matters most first — and each is put on
//! whichever device yields the lower cumulative finish time. Near-optimal
//! (≥ ~92 % of Opt_plan in the paper's Table 4) at a tiny solve cost.

use super::{solve_model, AssignCtx, Assigner, Assignment};
use crate::hw::Ns;

/// The scratch vectors make repeated solves allocation-free — this is the
/// solver on the simulator's per-layer hot path.
#[derive(Debug, Default, Clone)]
pub struct GreedyAssigner {
    t_gpu: Vec<u64>,
    t_cpu: Vec<u64>,
    order: Vec<usize>,
}

impl GreedyAssigner {
    pub fn new() -> Self {
        GreedyAssigner::default()
    }
}

impl Assigner for GreedyAssigner {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        let n = ctx.workloads.len();
        out.reset(n);
        let GreedyAssigner { t_gpu, t_cpu, order } = self;
        // Alg. 1 lines 1-4: per-expert device costs.
        t_gpu.clear();
        t_gpu.extend((0..n).map(|e| ctx.t_gpu(e)));
        t_cpu.clear();
        t_cpu.extend((0..n).map(|e| ctx.t_cpu(e)));
        // line 5: sort by |t_gpu - t_cpu| descending (index tiebreak keeps
        // the order — and hence the metrics — fully deterministic).
        order.clear();
        order.extend(0..n);
        order.sort_unstable_by_key(|&e| (std::cmp::Reverse(t_gpu[e].abs_diff(t_cpu[e])), e));
        let mut total_gpu: u64 = 0;
        let mut total_cpu: u64 = 0;
        let mut free_slots = ctx.gpu_free_slots;
        for &e in order.iter() {
            // lines 9-10: skip inactive experts.
            if ctx.workloads[e] == 0 {
                continue;
            }
            // Eq. 9 memory guard: a non-resident expert needs a staging slot.
            let needs_slot = !ctx.resident[e];
            let gpu_ok = !needs_slot || free_slots > 0;
            // lines 12-17: lower cumulative finish time wins.
            if gpu_ok && total_gpu + t_gpu[e] <= total_cpu + t_cpu[e] {
                out.to_gpu[e] = true;
                total_gpu += t_gpu[e];
                if needs_slot {
                    free_slots -= 1;
                }
            } else {
                out.to_cpu[e] = true;
                total_cpu += t_cpu[e];
            }
        }
    }

    fn modeled_solve_ns(&self, ctx: &AssignCtx) -> Ns {
        // cost tables + one sort + one linear placement pass
        solve_model::nlogn(ctx.active_count(), 28)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::{brute_force, cost};
    use super::*;
    use crate::util::DetRng;

    #[test]
    fn respects_constraints() {
        let cm = cost("mixtral-sim");
        let workloads = vec![4, 0, 1, 9, 2, 0, 7, 3];
        let resident = vec![true, false, false, false, true, false, false, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert!(a.satisfies_constraints(&ctx));
        // inactive experts untouched
        assert!(!a.to_gpu[1] && !a.to_cpu[1]);
        assert!(!a.to_gpu[5] && !a.to_cpu[5]);
    }

    #[test]
    fn resident_high_workload_expert_goes_to_gpu() {
        let cm = cost("mixtral-sim");
        let workloads = vec![64, 1];
        let resident = vec![true, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert!(a.to_gpu[0], "cached 64-token expert must run on GPU");
        assert!(a.to_cpu[1], "1-token uncached expert must stay on CPU");
    }

    #[test]
    fn within_8pct_of_bruteforce_on_random_instances() {
        // Paper Table 4: greedy ≥ ~85-92 % of optimal. Verify on many
        // random instances that greedy stays within 2x (makespan ratio) and
        // on average within 15 %.
        let cm = cost("deepseek-sim");
        let mut rng = DetRng::new(99);
        let mut ratios = vec![];
        for _ in 0..60 {
            let n = 12;
            let workloads: Vec<u32> =
                (0..n).map(|_| if rng.chance(0.3) { 0 } else { rng.usize_below(30) as u32 }).collect();
            let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.3)).collect();
            let ctx = AssignCtx {
                workloads: &workloads,
                resident: &resident,
                tiers: None,
                host_wait: None,
                cost: &cm,
                gpu_free_slots: n,
                layer: 0,
                layers: 4,
            };
            let a = GreedyAssigner::new().assign(&ctx);
            assert!(a.satisfies_constraints(&ctx));
            let (opt, _) = brute_force(&ctx);
            if opt > 0 {
                let r = a.makespan_estimate(&ctx) as f64 / opt as f64;
                assert!(r < 2.0, "greedy ratio {r}");
                ratios.push(r);
            }
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(avg < 1.15, "avg greedy/opt ratio {avg}");
    }

    #[test]
    fn memory_constraint_forces_cpu() {
        let cm = cost("mixtral-sim");
        let workloads = vec![60, 60, 60];
        let resident = vec![false, false, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 1,
            layer: 0,
            layers: 4,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        let staged = (0..3).filter(|&e| a.to_gpu[e]).count();
        assert!(staged <= 1);
        assert!(a.satisfies_constraints(&ctx));
    }

    #[test]
    fn empty_layer_assigns_nothing() {
        let cm = cost("qwen-sim");
        let workloads = vec![0; 8];
        let resident = vec![false; 8];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
        };
        let a = GreedyAssigner::new().assign(&ctx);
        assert_eq!(a, Assignment::none(8));
    }
}
