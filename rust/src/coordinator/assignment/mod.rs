//! Expert assignment: who runs where (paper §4.1).
//!
//! The optimization problem (Eqs. 3–9): minimize `max(T_gpu, T_cpu)` where
//! `T_cpu = Σ t_cpu(w_i)·C_i`, `T_gpu = Σ t_gpu(w_i)·G_i`, subject to every
//! activated expert being assigned exactly once and the GPU memory budget.
//!
//! Implementations:
//! * [`GreedyAssigner`] — the paper's Alg. 1 (DALI's contribution);
//! * [`OptimalAssigner`] — exact branch & bound ("Opt_plan");
//! * [`BeamAssigner`] — beam-search approximation (Appendix A.2);
//! * [`StaticThresholdAssigner`] — Fiddler/HybriMoE per-expert rule;
//! * [`AllCpuAssigner`] — the "Naive" baseline;
//! * [`ResidentOnlyAssigner`] — MoE-Lightning-style fixed placement;
//! * [`LayerWiseAssigner`] — llama.cpp/KTransformers layer split.

mod all_cpu;
mod beam;
mod enumerate;
mod greedy;
mod layerwise;
mod optimal;
mod resident_only;
mod static_threshold;

pub use all_cpu::AllCpuAssigner;
pub use beam::BeamAssigner;
pub use enumerate::EnumerateAssigner;
pub use greedy::GreedyAssigner;
pub use layerwise::LayerWiseAssigner;
pub use optimal::OptimalAssigner;
pub use resident_only::ResidentOnlyAssigner;
pub use static_threshold::StaticThresholdAssigner;

use crate::hw::{CostModel, Ns};

/// Everything an assigner may look at for one MoE layer step.
pub struct AssignCtx<'a> {
    /// True workload (routed tokens) per routed expert.
    pub workloads: &'a [u32],
    /// Whether each expert's weights are already on the GPU (cache hit or
    /// arrived prefetch) — resident experts transfer for free (§4.3).
    pub resident: &'a [bool],
    pub cost: &'a CostModel,
    /// Eq. 9: how many *non-resident* experts may be staged on the GPU this
    /// layer (free VRAM / expert size).
    pub gpu_free_slots: usize,
    /// MoE layer index (used by layer-wise baselines).
    pub layer: usize,
    /// Total MoE layers.
    pub layers: usize,
}

impl AssignCtx<'_> {
    /// Eq. 5 estimate used by all solvers: `t_gpu(w)` with residency.
    pub fn t_gpu(&self, e: usize) -> Ns {
        self.cost.t_gpu(self.workloads[e] as usize, self.resident[e])
    }

    pub fn t_cpu(&self, e: usize) -> Ns {
        self.cost.t_cpu(self.workloads[e] as usize)
    }
}

/// Result: the C/G indicator vectors of the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub to_gpu: Vec<bool>,
    pub to_cpu: Vec<bool>,
}

impl Assignment {
    pub fn none(n: usize) -> Self {
        Assignment { to_gpu: vec![false; n], to_cpu: vec![false; n] }
    }

    /// Eq. 4/5 objective value of this assignment under `ctx`'s estimates.
    pub fn makespan_estimate(&self, ctx: &AssignCtx) -> Ns {
        let mut t_cpu = 0;
        let mut t_gpu = 0;
        for e in 0..self.to_gpu.len() {
            if self.to_gpu[e] {
                t_gpu += ctx.t_gpu(e);
            } else if self.to_cpu[e] {
                t_cpu += ctx.t_cpu(e);
            }
        }
        t_cpu.max(t_gpu)
    }

    /// Check Eqs. 7–9 (activation, mutual exclusion, memory).
    pub fn satisfies_constraints(&self, ctx: &AssignCtx) -> bool {
        let mut staged = 0;
        for e in 0..self.to_gpu.len() {
            let active = ctx.workloads[e] > 0;
            if active != (self.to_gpu[e] ^ self.to_cpu[e]) {
                // activated ⇔ exactly one device; inactive ⇔ neither
                if active || self.to_gpu[e] || self.to_cpu[e] {
                    return false;
                }
            }
            if self.to_gpu[e] && self.to_cpu[e] {
                return false;
            }
            if self.to_gpu[e] && !ctx.resident[e] {
                staged += 1;
            }
        }
        staged <= ctx.gpu_free_slots
    }
}

/// Trait implemented by every assignment policy.
pub trait Assigner: Send {
    fn name(&self) -> &'static str;
    fn assign(&mut self, ctx: &AssignCtx) -> Assignment;
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;
    use crate::config::Presets;

    pub fn cost(model: &str) -> CostModel {
        let p = Presets::load_default().unwrap();
        CostModel::new(p.model(model).unwrap(), p.hw("local-pc").unwrap())
    }

    /// Exhaustive optimum for small instances (test oracle).
    pub fn brute_force(ctx: &AssignCtx) -> (Ns, Assignment) {
        let n = ctx.workloads.len();
        let active: Vec<usize> = (0..n).filter(|&e| ctx.workloads[e] > 0).collect();
        assert!(active.len() <= 20, "brute force only for small instances");
        let mut best = (Ns::MAX, Assignment::none(n));
        for mask in 0u32..(1 << active.len()) {
            let mut a = Assignment::none(n);
            let mut staged = 0;
            for (i, &e) in active.iter().enumerate() {
                if mask & (1 << i) != 0 {
                    a.to_gpu[e] = true;
                    if !ctx.resident[e] {
                        staged += 1;
                    }
                } else {
                    a.to_cpu[e] = true;
                }
            }
            if staged > ctx.gpu_free_slots {
                continue;
            }
            let m = a.makespan_estimate(ctx);
            if m < best.0 {
                best = (m, a);
            }
        }
        best
    }
}
