//! Expert assignment: who runs where (paper §4.1).
//!
//! The optimization problem (Eqs. 3–9): minimize `max(T_gpu, T_cpu)` where
//! `T_cpu = Σ t_cpu(w_i)·C_i`, `T_gpu = Σ t_gpu(w_i)·G_i`, subject to every
//! activated expert being assigned exactly once and the GPU memory budget.
//!
//! Implementations:
//! * [`GreedyAssigner`] — the paper's Alg. 1 (DALI's contribution);
//! * [`OptimalAssigner`] — exact branch & bound ("Opt_plan");
//! * [`BeamAssigner`] — beam-search approximation (Appendix A.2);
//! * [`StaticThresholdAssigner`] — Fiddler/HybriMoE per-expert rule;
//! * [`AllCpuAssigner`] — the "Naive" baseline;
//! * [`ResidentOnlyAssigner`] — MoE-Lightning-style fixed placement;
//! * [`LayerWiseAssigner`] — llama.cpp/KTransformers layer split.

mod all_cpu;
mod beam;
mod enumerate;
mod greedy;
mod layerwise;
mod optimal;
mod resident_only;
mod static_threshold;

pub use all_cpu::AllCpuAssigner;
pub use beam::BeamAssigner;
pub use enumerate::EnumerateAssigner;
pub use greedy::GreedyAssigner;
pub use layerwise::LayerWiseAssigner;
pub use optimal::OptimalAssigner;
pub use resident_only::ResidentOnlyAssigner;
pub use static_threshold::StaticThresholdAssigner;

use crate::hw::{CostModel, Ns};
use crate::store::{Tier, MAX_DEVICES};

/// Per-device residency and capacity view for multi-GPU assignment. Absent
/// (`AssignCtx::devices == None`) the context is single-device: the plain
/// `resident` / `gpu_free_slots` fields describe device 0, exactly the
/// pre-multi-GPU behaviour every baseline solver was written against.
#[derive(Debug, Clone, Copy)]
pub struct DeviceView<'a> {
    /// Device count (1..=[`MAX_DEVICES`]).
    pub n: usize,
    /// Device-major residency: `resident[d * n_experts + e]` — whether
    /// expert `e` is cached on device `d`. Single-copy sharding means at
    /// most one device holds any expert, but the view does not assume it.
    pub resident: &'a [bool],
    /// Eq. 9 staging slots per device (free VRAM / expert size on `d`).
    pub free_slots: &'a [usize],
}

/// Everything an assigner may look at for one MoE layer step.
pub struct AssignCtx<'a> {
    /// True workload (routed tokens) per routed expert.
    pub workloads: &'a [u32],
    /// Whether each expert's weights are already on the GPU (cache hit or
    /// arrived prefetch) — resident experts transfer for free (§4.3).
    pub resident: &'a [bool],
    /// Storage-tier residency per expert from the tiered store. `None` =
    /// the paper's two-tier assumption (everything host-resident); with a
    /// memory-limited store, a disk-resident expert pays the NVMe fetch on
    /// *either* device (the CPU cannot execute from disk any more than the
    /// GPU can), which every solver sees through [`Self::t_gpu`] /
    /// [`Self::t_cpu`].
    pub tiers: Option<&'a [Tier]>,
    /// Per-expert extra wait before the weights are available in host RAM
    /// (tiered store with placement tracking): the NVMe-fetch estimate for
    /// disk residents, or the remaining in-flight predictive-promotion
    /// time. `None` falls back to the tier-based estimate, so solvers see
    /// identical costs whether or not the store reports arrivals.
    pub host_wait: Option<&'a [Ns]>,
    pub cost: &'a CostModel,
    /// Eq. 9: how many *non-resident* experts may be staged on the GPU this
    /// layer (free VRAM / expert size).
    pub gpu_free_slots: usize,
    /// MoE layer index (used by layer-wise baselines).
    pub layer: usize,
    /// Total MoE layers.
    pub layers: usize,
    /// Per-device residency/capacity for multi-GPU boxes. `None` = one
    /// device, described by `resident` / `gpu_free_slots` (the pre-refactor
    /// view — every existing construction site keeps its semantics).
    pub devices: Option<DeviceView<'a>>,
}

impl AssignCtx<'_> {
    /// Storage tier of an expert (Host when no store is attached).
    pub fn tier(&self, e: usize) -> Tier {
        self.tiers.map(|t| t[e]).unwrap_or(Tier::Host)
    }

    /// Number of activated experts this layer step (the `n` every solve-cost
    /// model scales with).
    pub fn active_count(&self) -> usize {
        self.workloads.iter().filter(|&&w| w > 0).count()
    }

    /// Extra ns before expert `e`'s weights are *usable* in host RAM: the
    /// store's reported arrival wait when available, else the tier-based
    /// NVMe-fetch estimate — the on-disk read plus, for quantized on-disk
    /// formats, the CPU transcode stage (identical for disk residents,
    /// zero otherwise).
    pub fn host_wait_ns(&self, e: usize) -> Ns {
        match self.host_wait {
            Some(w) => w[e],
            None => {
                if self.tier(e) == Tier::Disk {
                    self.cost.nvme_fetch_time()
                } else {
                    0
                }
            }
        }
    }

    /// Number of GPU device tiers this context prices (1 without a
    /// [`DeviceView`]).
    pub fn n_devices(&self) -> usize {
        self.devices.map(|d| d.n).unwrap_or(1)
    }

    /// Whether expert `e` is cached on device `d`. Without a device view
    /// the plain `resident` slice describes device 0 and no other device
    /// exists.
    pub fn resident_on(&self, e: usize, d: usize) -> bool {
        match self.devices {
            Some(v) => v.resident[d * self.workloads.len() + e],
            None => d == 0 && self.resident[e],
        }
    }

    /// Eq. 9 staging slots on device `d`.
    pub fn free_slots_on(&self, d: usize) -> usize {
        match self.devices {
            Some(v) => v.free_slots[d],
            None => {
                if d == 0 {
                    self.gpu_free_slots
                } else {
                    0
                }
            }
        }
    }

    /// Eq. 5 estimate used by all solvers: `t_gpu(w)` with residency,
    /// extended tier-aware — a disk-resident (or still-in-flight) expert's
    /// transfer chains NVMe-read → transcode → PCIe before compute can
    /// overlap it. Multi-device contexts price the expert on its *best*
    /// device (min over device tiers), so every single-choice solver sees
    /// the cheapest-device cost for free; [`Self::t_gpu_dev`] prices one
    /// specific device.
    pub fn t_gpu(&self, e: usize) -> Ns {
        let w = self.workloads[e] as usize;
        if w == 0 {
            return 0;
        }
        match self.devices {
            None => self.t_gpu_fallback(e, w),
            Some(v) => (0..v.n).map(|d| self.t_gpu_dev(e, d)).min().unwrap_or(0),
        }
    }

    /// Eq. 5 priced on one explicit device: residency on `d` makes the
    /// transfer free; residency on a *peer* device costs a P2P hop; no GPU
    /// residency pays the full host→device PCIe chain.
    pub fn t_gpu_dev(&self, e: usize, d: usize) -> Ns {
        let w = self.workloads[e] as usize;
        if w == 0 {
            return 0;
        }
        if self.devices.is_none() {
            debug_assert_eq!(d, 0);
            return self.t_gpu_fallback(e, w);
        }
        if self.resident_on(e, d) {
            return self.cost.t_gpu_compute(w);
        }
        let n = self.n_devices();
        let on_peer = (0..n).any(|p| p != d && self.resident_on(e, p));
        let trans = if on_peer {
            self.cost.p2p_time()
        } else {
            self.cost.trans_time() + self.host_wait_ns(e)
        };
        self.cost.t_gpu_compute(w).max(trans)
    }

    /// The pre-multi-GPU single-device estimate — the `devices == None`
    /// path, kept verbatim so store-less contexts price bit-identically.
    fn t_gpu_fallback(&self, e: usize, w: usize) -> Ns {
        if self.resident[e] {
            return self.cost.t_gpu_compute(w);
        }
        let trans = self.cost.trans_time() + self.host_wait_ns(e);
        self.cost.t_gpu_compute(w).max(trans)
    }

    /// Eq. 4 estimate, tier-aware: a CPU-assigned disk-resident (or
    /// still-in-flight) expert pays the host-RAM wait before the CPU can
    /// stream it.
    pub fn t_cpu(&self, e: usize) -> Ns {
        let w = self.workloads[e] as usize;
        if w == 0 {
            return 0;
        }
        self.cost.t_cpu(w) + self.host_wait_ns(e)
    }
}

/// How the simulator charges assignment-solve time into virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolveCost {
    /// Deterministic analytic model of each solver's wall cost (default):
    /// identical seeds produce bit-identical `RunMetrics` across runs and
    /// machines. See [`solve_model`].
    #[default]
    Modeled,
    /// Measure the actual solve wall-clock with `std::time::Instant` (the
    /// seed behaviour). Nondeterministic run-to-run; kept behind this flag
    /// for calibrating the modeled constants against real hardware.
    Measured,
}

/// Deterministic stand-ins for each solver's wall-clock solve time,
/// calibrated once against `bench_assignment` on the reference dev box.
/// All costs are pure functions of the number of activated experts, so
/// virtual time never depends on host load or machine speed.
pub mod solve_model {
    use crate::hw::Ns;

    /// Fixed dispatch overhead of any solve call (trait dispatch, context
    /// setup) — charged even for an empty layer.
    pub const DISPATCH_NS: Ns = 150;

    fn log2_ceil(n: usize) -> u64 {
        (usize::BITS - n.max(2).saturating_sub(1).leading_zeros()) as u64
    }

    /// One linear pass over the experts (threshold rules, fixed placements).
    pub fn linear(active: usize, per_expert_ns: Ns) -> Ns {
        DISPATCH_NS + per_expert_ns * active as u64
    }

    /// Sort-dominated solvers (greedy's `O(n log n)` ordering pass).
    pub fn nlogn(active: usize, per_expert_ns: Ns) -> Ns {
        DISPATCH_NS + per_expert_ns * active as u64 * log2_ceil(active)
    }

    /// Exhaustive / branching solvers: `per_node_ns · n · 2^min(n, cap)`,
    /// saturating — the modeled analogue of Opt_plan's "prohibitively high"
    /// runtime solving cost (paper §6.3-1).
    pub fn exponential(active: usize, per_node_ns: Ns, exp_cap: u32) -> Ns {
        let nodes = 1u64 << (active as u32).min(exp_cap);
        DISPATCH_NS
            + per_node_ns
                .saturating_mul(active as u64)
                .saturating_mul(nodes)
    }
}

/// Result: the C/G indicator vectors of the paper, plus the chosen device
/// per GPU-assigned expert.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Assignment {
    pub to_gpu: Vec<bool>,
    pub to_cpu: Vec<bool>,
    /// GPU device index per expert — meaningful only where `to_gpu[e]`
    /// holds; 0 everywhere else (and everywhere on single-GPU contexts, so
    /// baseline solvers that never write it keep today's behaviour on
    /// device 0).
    pub device: Vec<u8>,
}

impl Assignment {
    pub fn none(n: usize) -> Self {
        Assignment { to_gpu: vec![false; n], to_cpu: vec![false; n], device: vec![0; n] }
    }

    /// Clear to an all-unassigned state of width `n`, reusing capacity.
    pub fn reset(&mut self, n: usize) {
        self.to_gpu.clear();
        self.to_gpu.resize(n, false);
        self.to_cpu.clear();
        self.to_cpu.resize(n, false);
        self.device.clear();
        self.device.resize(n, 0);
    }

    /// Copy `src` into `self` without allocating (capacity permitting).
    pub fn copy_from(&mut self, src: &Assignment) {
        self.to_gpu.clear();
        self.to_gpu.extend_from_slice(&src.to_gpu);
        self.to_cpu.clear();
        self.to_cpu.extend_from_slice(&src.to_cpu);
        self.device.clear();
        self.device.extend_from_slice(&src.device);
    }

    /// The device expert `e` runs on (0 unless a multi-device solver or
    /// [`Self::align_devices`] chose otherwise).
    pub fn device_of(&self, e: usize) -> u8 {
        self.device.get(e).copied().unwrap_or(0)
    }

    /// Post-pass for single-device solvers on a multi-device context: pin
    /// each GPU-assigned expert to the device that already caches it (the
    /// transfer the solver priced as free is only free *there*), else to
    /// its round-robin home device `e % n` — the same striping the store
    /// and caches shard by, so staged uploads spread across every PCIe
    /// link deterministically. No-op without a device view.
    pub fn align_devices(&mut self, ctx: &AssignCtx) {
        let n = ctx.n_devices();
        if ctx.devices.is_none() || n <= 1 {
            return;
        }
        for e in 0..self.to_gpu.len() {
            if !self.to_gpu[e] {
                self.device[e] = 0;
                continue;
            }
            self.device[e] = match (0..n).find(|&d| ctx.resident_on(e, d)) {
                Some(d) => d as u8,
                None => (e % n) as u8,
            };
        }
    }

    /// Eq. 4/5 objective value of this assignment under `ctx`'s estimates:
    /// the slowest device finishes last — CPU or any GPU tier (per-device
    /// sums; a single-device context reduces to the paper's two-term max).
    pub fn makespan_estimate(&self, ctx: &AssignCtx) -> Ns {
        let mut t_cpu = 0;
        let mut t_dev = [0 as Ns; MAX_DEVICES];
        for e in 0..self.to_gpu.len() {
            if self.to_gpu[e] {
                let d = (self.device_of(e) as usize).min(ctx.n_devices() - 1);
                t_dev[d] += ctx.t_gpu_dev(e, d);
            } else if self.to_cpu[e] {
                t_cpu += ctx.t_cpu(e);
            }
        }
        t_cpu.max(t_dev.into_iter().max().unwrap_or(0))
    }

    /// Check Eqs. 7–9 (activation, mutual exclusion, memory — the memory
    /// budget per device tier).
    pub fn satisfies_constraints(&self, ctx: &AssignCtx) -> bool {
        let n = ctx.n_devices();
        let mut staged = [0usize; MAX_DEVICES];
        for e in 0..self.to_gpu.len() {
            let active = ctx.workloads[e] > 0;
            if active != (self.to_gpu[e] ^ self.to_cpu[e]) {
                // activated ⇔ exactly one device; inactive ⇔ neither
                if active || self.to_gpu[e] || self.to_cpu[e] {
                    return false;
                }
            }
            if self.to_gpu[e] && self.to_cpu[e] {
                return false;
            }
            if self.to_gpu[e] {
                let d = self.device_of(e) as usize;
                if d >= n {
                    return false;
                }
                if !ctx.resident_on(e, d) {
                    staged[d] += 1;
                }
            }
        }
        (0..n).all(|d| staged[d] <= ctx.free_slots_on(d))
    }
}

/// Trait implemented by every assignment policy.
pub trait Assigner: Send {
    fn name(&self) -> &'static str;

    /// Write the assignment for `ctx` into `out` (reset first). This is the
    /// hot-path entry point: the solvers on the measured replay paths
    /// (greedy, the static/fixed baselines) keep it allocation-free in
    /// steady state via internal scratch; the exhaustive solvers
    /// (beam/optimal/enumerate) may allocate — their whole point is that
    /// solving is expensive.
    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment);

    /// Allocating convenience wrapper (tests, one-off callers).
    fn assign(&mut self, ctx: &AssignCtx) -> Assignment {
        let mut out = Assignment::none(ctx.workloads.len());
        self.assign_into(ctx, &mut out);
        out
    }

    /// Deterministic modeled solve cost for this context ([`SolveCost`]
    /// `Modeled`). Default: one linear pass — cheap static policies.
    fn modeled_solve_ns(&self, ctx: &AssignCtx) -> Ns {
        solve_model::linear(ctx.active_count(), 10)
    }

    /// True when the solver fills [`Assignment::device`] itself on
    /// multi-device contexts. Single-GPU baselines keep the default: the
    /// simulator runs [`Assignment::align_devices`] after the solve to pin
    /// their GPU picks onto concrete devices.
    fn device_aware(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tier_tests {
    use super::test_util::cost;
    use super::*;

    #[test]
    fn disk_residency_raises_both_device_costs() {
        let cm = cost("mixtral-sim");
        let workloads = vec![4u32, 4];
        let resident = vec![false, false];
        let tiers = vec![Tier::Host, Tier::Disk];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: Some(&tiers),
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
            devices: None,
        };
        // host expert matches the two-tier estimates exactly
        assert_eq!(ctx.t_gpu(0), cm.t_gpu(4, false));
        assert_eq!(ctx.t_cpu(0), cm.t_cpu(4));
        // disk expert pays the NVMe fetch on either device
        assert_eq!(ctx.t_cpu(1), cm.t_cpu(4) + cm.nvme_read_time());
        assert!(ctx.t_gpu(1) >= cm.trans_time() + cm.nvme_read_time());
        // GPU residency overrides the storage tier (weights already up)
        let res2 = vec![false, true];
        let ctx2 = AssignCtx { resident: &res2, ..ctx };
        assert_eq!(ctx2.t_gpu(1), cm.t_gpu_compute(4));
    }

    #[test]
    fn host_wait_snapshot_overrides_tier_estimate() {
        // With a store-reported arrival snapshot, an in-flight (host-tier)
        // expert carries its remaining promotion wait in both device costs.
        let cm = cost("mixtral-sim");
        let workloads = vec![4u32, 4];
        let resident = vec![false, false];
        let tiers = vec![Tier::Host, Tier::Host];
        let wait: Vec<Ns> = vec![0, 77_000];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: Some(&tiers),
            host_wait: Some(&wait),
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
            devices: None,
        };
        assert_eq!(ctx.host_wait_ns(0), 0);
        assert_eq!(ctx.host_wait_ns(1), 77_000);
        assert_eq!(ctx.t_cpu(0), cm.t_cpu(4));
        assert_eq!(ctx.t_cpu(1), cm.t_cpu(4) + 77_000);
        assert_eq!(ctx.t_gpu(1), cm.t_gpu_compute(4).max(cm.trans_time() + 77_000));
        // a disk expert's snapshot wait equals the tier-based fallback, so
        // store-reported and store-less costs agree for disk residents
        let tiers2 = vec![Tier::Host, Tier::Disk];
        let wait2: Vec<Ns> = vec![0, cm.nvme_read_time()];
        let ctx2 = AssignCtx { tiers: Some(&tiers2), host_wait: Some(&wait2), ..ctx };
        assert_eq!(ctx2.t_cpu(1), cm.t_cpu(4) + cm.nvme_read_time());
        assert_eq!(
            ctx2.t_gpu(1),
            cm.t_gpu_compute(4).max(cm.trans_time() + cm.nvme_read_time())
        );
    }

    #[test]
    fn quantized_disk_fallback_prices_read_plus_transcode() {
        // With a quantized on-disk format and no store-reported snapshot,
        // a disk-resident expert's wait is the full fetch: the (smaller)
        // NVMe read plus the CPU transcode stage — on either device.
        let fp16 = cost("mixtral-sim");
        let q4 = cost("mixtral-sim").with_quant_ratio(0.28);
        let workloads = vec![4u32, 4];
        let resident = vec![false, false];
        let tiers = vec![Tier::Host, Tier::Disk];
        let mk = |cm: &CostModel| AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: Some(&tiers),
            host_wait: None,
            cost: cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let (cq, cf) = (mk(&q4), mk(&fp16));
        assert_eq!(cq.host_wait_ns(1), q4.nvme_fetch_time());
        assert_eq!(cq.host_wait_ns(0), 0, "host residents wait for nothing");
        assert_eq!(cq.t_cpu(1), q4.t_cpu(4) + q4.nvme_read_time() + q4.transcode_time());
        // the asymmetric format makes the disk expert cheaper to reach on
        // both devices than fp16-on-disk would
        assert!(cq.t_cpu(1) < cf.t_cpu(1));
        assert!(cq.t_gpu(1) <= cf.t_gpu(1));
        // host-resident costs are format-independent
        assert_eq!(cq.t_cpu(0), cf.t_cpu(0));
    }

    #[test]
    fn no_tiers_means_host() {
        let cm = cost("deepseek-sim");
        let workloads = vec![7u32];
        let resident = vec![false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 1,
            layer: 0,
            layers: 1,
            devices: None,
        };
        assert_eq!(ctx.tier(0), Tier::Host);
        assert_eq!(ctx.t_gpu(0), cm.t_gpu(7, false));
        assert_eq!(ctx.t_cpu(0), cm.t_cpu(7));
    }
}

#[cfg(test)]
mod device_tests {
    use super::test_util::cost;
    use super::*;

    #[test]
    fn device_view_prices_each_expert_on_every_device() {
        let cm = cost("mixtral-sim");
        let workloads = vec![4u32, 4, 4];
        let resident = vec![false, true, false];
        // device-major: e1 cached on device 0, e2 cached on device 1
        let dev_resident = vec![false, true, false, false, false, true];
        let free = vec![1usize, 1];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
            devices: Some(DeviceView { n: 2, resident: &dev_resident, free_slots: &free }),
        };
        assert_eq!(ctx.n_devices(), 2);
        assert!(ctx.resident_on(1, 0) && !ctx.resident_on(1, 1));
        assert!(ctx.resident_on(2, 1) && !ctx.resident_on(2, 0));
        assert_eq!(ctx.free_slots_on(0), 1);
        // residency on the priced device: compute only
        assert_eq!(ctx.t_gpu_dev(1, 0), cm.t_gpu_compute(4));
        // residency on a peer: a P2P hop, cheaper than the PCIe chain
        assert_eq!(ctx.t_gpu_dev(1, 1), cm.t_gpu_compute(4).max(cm.p2p_time()));
        assert!(ctx.t_gpu_dev(1, 1) <= ctx.t_gpu_dev(0, 1), "peer hop beats host staging");
        // no residency anywhere: the full host→device transfer
        assert_eq!(ctx.t_gpu_dev(0, 0), cm.t_gpu_compute(4).max(cm.trans_time()));
        // the single-choice view is the best device
        assert_eq!(ctx.t_gpu(1), ctx.t_gpu_dev(1, 0).min(ctx.t_gpu_dev(1, 1)));
    }

    #[test]
    fn single_device_view_matches_the_fallback_exactly() {
        // devices: Some(n=1) and devices: None must price identically —
        // the num_gpus = 1 digest lock rides on this
        let cm = cost("deepseek-sim");
        let workloads = vec![3u32, 5, 0, 2];
        let resident = vec![true, false, false, false];
        let free = vec![2usize];
        let base = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let viewed = AssignCtx {
            devices: Some(DeviceView { n: 1, resident: &resident, free_slots: &free }),
            ..base
        };
        for e in 0..4 {
            assert_eq!(base.t_gpu(e), viewed.t_gpu(e));
            assert_eq!(base.t_gpu_dev(e, 0), viewed.t_gpu_dev(e, 0));
            assert_eq!(base.t_cpu(e), viewed.t_cpu(e));
        }
        assert_eq!(viewed.n_devices(), 1);
        assert_eq!(base.free_slots_on(0), viewed.free_slots_on(0));
    }

    #[test]
    fn align_devices_pins_residents_and_stripes_the_rest() {
        let cm = cost("mixtral-sim");
        let workloads = vec![4u32, 4, 4, 4];
        let resident = vec![false; 4];
        // e1 cached on device 1 (off-home: 1 % 2 == 1, so also home here);
        // e3 cached on device 0 (off its home device 1)
        let dev_resident = vec![false, false, false, true, false, true, false, false];
        let free = vec![4usize, 4];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: Some(DeviceView { n: 2, resident: &dev_resident, free_slots: &free }),
        };
        let mut a = Assignment::none(4);
        a.to_gpu = vec![true, true, true, true];
        a.device = vec![9, 9, 9, 9]; // garbage the pass must overwrite
        a.align_devices(&ctx);
        assert_eq!(a.device, vec![0, 1, 0, 0], "residents pinned, rest striped by home");
        // constraint check is per-device: e0 and e2 both stage on device 0,
        // overflowing a 1-slot budget there even though 3 total slots exist
        let tight = vec![1usize, 2];
        let ctx2 = AssignCtx {
            devices: Some(DeviceView { n: 2, resident: &dev_resident, free_slots: &tight }),
            ..ctx
        };
        assert!(!a.satisfies_constraints(&ctx2), "per-device staging budget binds");
        // makespan is the max over per-device sums, not the global sum
        let per_dev_max = a.makespan_estimate(&ctx);
        let sum: Ns = (0..4).map(|e| ctx.t_gpu_dev(e, a.device_of(e) as usize)).sum();
        assert!(per_dev_max < sum, "two devices overlap their work");
    }

    #[test]
    fn align_devices_is_a_no_op_on_single_device_contexts() {
        let cm = cost("deepseek-sim");
        let workloads = vec![2u32, 2];
        let resident = vec![false, false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 1,
            devices: None,
        };
        let mut a = Assignment::none(2);
        a.to_gpu = vec![true, true];
        a.align_devices(&ctx);
        assert_eq!(a.device, vec![0, 0]);
    }
}

#[cfg(test)]
mod solve_cost_tests {
    use super::test_util::cost;
    use super::*;

    fn ctx<'a>(workloads: &'a [u32], resident: &'a [bool], cm: &'a CostModel) -> AssignCtx<'a> {
        AssignCtx {
            workloads,
            resident,
            tiers: None,
            host_wait: None,
            cost: cm,
            gpu_free_slots: workloads.len(),
            layer: 0,
            layers: 4,
            devices: None,
        }
    }

    #[test]
    fn modeled_costs_are_deterministic_and_ordered() {
        let cm = cost("deepseek-sim");
        let workloads: Vec<u32> = (0..12).map(|i| (i % 5 + 1) as u32).collect();
        let resident = vec![false; 12];
        let c = ctx(&workloads, &resident, &cm);
        let greedy = GreedyAssigner::new().modeled_solve_ns(&c);
        let greedy2 = GreedyAssigner::new().modeled_solve_ns(&c);
        assert_eq!(greedy, greedy2, "modeled cost must be a pure function");
        let opt = EnumerateAssigner::new().modeled_solve_ns(&c);
        let naive = AllCpuAssigner::new().modeled_solve_ns(&c);
        assert!(naive > 0 && greedy > naive);
        assert!(
            opt > 20 * greedy,
            "exhaustive solving must dwarf greedy (paper Fig. 15): {opt} vs {greedy}"
        );
        let beam = BeamAssigner::new(2).modeled_solve_ns(&c);
        assert!(beam > greedy, "beam search costs more than one greedy pass");
    }

    #[test]
    fn modeled_cost_scales_with_active_experts() {
        let cm = cost("mixtral-sim");
        let small: Vec<u32> = vec![1, 1, 0, 0, 0, 0, 0, 0];
        let large: Vec<u32> = vec![3; 8];
        let resident = vec![false; 8];
        let g = GreedyAssigner::new();
        assert!(
            g.modeled_solve_ns(&ctx(&small, &resident, &cm))
                < g.modeled_solve_ns(&ctx(&large, &resident, &cm))
        );
        assert_eq!(ctx(&small, &resident, &cm).active_count(), 2);
        assert_eq!(ctx(&large, &resident, &cm).active_count(), 8);
    }

    #[test]
    fn assign_into_matches_assign_and_reuses_buffers() {
        let cm = cost("mixtral-sim");
        let workloads = vec![4, 0, 1, 9, 2, 0, 7, 3];
        let resident = vec![true, false, false, false, true, false, false, false];
        let c = ctx(&workloads, &resident, &cm);
        let mut g = GreedyAssigner::new();
        let fresh = g.assign(&c);
        let mut reused = Assignment::none(8);
        for _ in 0..3 {
            g.assign_into(&c, &mut reused);
        }
        assert_eq!(fresh, reused, "buffered solve must be bit-identical");
        let mut copy = Assignment::default();
        copy.copy_from(&fresh);
        assert_eq!(copy, fresh);
        copy.reset(4);
        assert_eq!(copy, Assignment::none(4));
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;
    use crate::config::Presets;

    pub fn cost(model: &str) -> CostModel {
        let p = Presets::load_default().unwrap();
        CostModel::new(p.model(model).unwrap(), p.hw("local-pc").unwrap())
    }

    /// Exhaustive optimum for small instances (test oracle).
    pub fn brute_force(ctx: &AssignCtx) -> (Ns, Assignment) {
        let n = ctx.workloads.len();
        let active: Vec<usize> = (0..n).filter(|&e| ctx.workloads[e] > 0).collect();
        assert!(active.len() <= 20, "brute force only for small instances");
        let mut best = (Ns::MAX, Assignment::none(n));
        for mask in 0u32..(1 << active.len()) {
            let mut a = Assignment::none(n);
            let mut staged = 0;
            for (i, &e) in active.iter().enumerate() {
                if mask & (1 << i) != 0 {
                    a.to_gpu[e] = true;
                    if !ctx.resident[e] {
                        staged += 1;
                    }
                } else {
                    a.to_cpu[e] = true;
                }
            }
            if staged > ctx.gpu_free_slots {
                continue;
            }
            let m = a.makespan_estimate(ctx);
            if m < best.0 {
                best = (m, a);
            }
        }
        best
    }
}
