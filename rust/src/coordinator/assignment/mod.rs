//! Expert assignment: who runs where (paper §4.1).
//!
//! The optimization problem (Eqs. 3–9): minimize `max(T_gpu, T_cpu)` where
//! `T_cpu = Σ t_cpu(w_i)·C_i`, `T_gpu = Σ t_gpu(w_i)·G_i`, subject to every
//! activated expert being assigned exactly once and the GPU memory budget.
//!
//! Implementations:
//! * [`GreedyAssigner`] — the paper's Alg. 1 (DALI's contribution);
//! * [`OptimalAssigner`] — exact branch & bound ("Opt_plan");
//! * [`BeamAssigner`] — beam-search approximation (Appendix A.2);
//! * [`StaticThresholdAssigner`] — Fiddler/HybriMoE per-expert rule;
//! * [`AllCpuAssigner`] — the "Naive" baseline;
//! * [`ResidentOnlyAssigner`] — MoE-Lightning-style fixed placement;
//! * [`LayerWiseAssigner`] — llama.cpp/KTransformers layer split.

mod all_cpu;
mod beam;
mod enumerate;
mod greedy;
mod layerwise;
mod optimal;
mod resident_only;
mod static_threshold;

pub use all_cpu::AllCpuAssigner;
pub use beam::BeamAssigner;
pub use enumerate::EnumerateAssigner;
pub use greedy::GreedyAssigner;
pub use layerwise::LayerWiseAssigner;
pub use optimal::OptimalAssigner;
pub use resident_only::ResidentOnlyAssigner;
pub use static_threshold::StaticThresholdAssigner;

use crate::hw::{CostModel, Ns};
use crate::store::Tier;

/// Everything an assigner may look at for one MoE layer step.
pub struct AssignCtx<'a> {
    /// True workload (routed tokens) per routed expert.
    pub workloads: &'a [u32],
    /// Whether each expert's weights are already on the GPU (cache hit or
    /// arrived prefetch) — resident experts transfer for free (§4.3).
    pub resident: &'a [bool],
    /// Storage-tier residency per expert from the tiered store. `None` =
    /// the paper's two-tier assumption (everything host-resident); with a
    /// memory-limited store, a disk-resident expert pays the NVMe fetch on
    /// *either* device (the CPU cannot execute from disk any more than the
    /// GPU can), which every solver sees through [`Self::t_gpu`] /
    /// [`Self::t_cpu`].
    pub tiers: Option<&'a [Tier]>,
    /// Per-expert extra wait before the weights are available in host RAM
    /// (tiered store with placement tracking): the NVMe-fetch estimate for
    /// disk residents, or the remaining in-flight predictive-promotion
    /// time. `None` falls back to the tier-based estimate, so solvers see
    /// identical costs whether or not the store reports arrivals.
    pub host_wait: Option<&'a [Ns]>,
    pub cost: &'a CostModel,
    /// Eq. 9: how many *non-resident* experts may be staged on the GPU this
    /// layer (free VRAM / expert size).
    pub gpu_free_slots: usize,
    /// MoE layer index (used by layer-wise baselines).
    pub layer: usize,
    /// Total MoE layers.
    pub layers: usize,
}

impl AssignCtx<'_> {
    /// Storage tier of an expert (Host when no store is attached).
    pub fn tier(&self, e: usize) -> Tier {
        self.tiers.map(|t| t[e]).unwrap_or(Tier::Host)
    }

    /// Number of activated experts this layer step (the `n` every solve-cost
    /// model scales with).
    pub fn active_count(&self) -> usize {
        self.workloads.iter().filter(|&&w| w > 0).count()
    }

    /// Extra ns before expert `e`'s weights are *usable* in host RAM: the
    /// store's reported arrival wait when available, else the tier-based
    /// NVMe-fetch estimate — the on-disk read plus, for quantized on-disk
    /// formats, the CPU transcode stage (identical for disk residents,
    /// zero otherwise).
    pub fn host_wait_ns(&self, e: usize) -> Ns {
        match self.host_wait {
            Some(w) => w[e],
            None => {
                if self.tier(e) == Tier::Disk {
                    self.cost.nvme_fetch_time()
                } else {
                    0
                }
            }
        }
    }

    /// Eq. 5 estimate used by all solvers: `t_gpu(w)` with residency,
    /// extended tier-aware — a disk-resident (or still-in-flight) expert's
    /// transfer chains NVMe-read → transcode → PCIe before compute can
    /// overlap it.
    pub fn t_gpu(&self, e: usize) -> Ns {
        let w = self.workloads[e] as usize;
        if w == 0 {
            return 0;
        }
        if self.resident[e] {
            return self.cost.t_gpu_compute(w);
        }
        let trans = self.cost.trans_time() + self.host_wait_ns(e);
        self.cost.t_gpu_compute(w).max(trans)
    }

    /// Eq. 4 estimate, tier-aware: a CPU-assigned disk-resident (or
    /// still-in-flight) expert pays the host-RAM wait before the CPU can
    /// stream it.
    pub fn t_cpu(&self, e: usize) -> Ns {
        let w = self.workloads[e] as usize;
        if w == 0 {
            return 0;
        }
        self.cost.t_cpu(w) + self.host_wait_ns(e)
    }
}

/// How the simulator charges assignment-solve time into virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolveCost {
    /// Deterministic analytic model of each solver's wall cost (default):
    /// identical seeds produce bit-identical `RunMetrics` across runs and
    /// machines. See [`solve_model`].
    #[default]
    Modeled,
    /// Measure the actual solve wall-clock with `std::time::Instant` (the
    /// seed behaviour). Nondeterministic run-to-run; kept behind this flag
    /// for calibrating the modeled constants against real hardware.
    Measured,
}

/// Deterministic stand-ins for each solver's wall-clock solve time,
/// calibrated once against `bench_assignment` on the reference dev box.
/// All costs are pure functions of the number of activated experts, so
/// virtual time never depends on host load or machine speed.
pub mod solve_model {
    use crate::hw::Ns;

    /// Fixed dispatch overhead of any solve call (trait dispatch, context
    /// setup) — charged even for an empty layer.
    pub const DISPATCH_NS: Ns = 150;

    fn log2_ceil(n: usize) -> u64 {
        (usize::BITS - n.max(2).saturating_sub(1).leading_zeros()) as u64
    }

    /// One linear pass over the experts (threshold rules, fixed placements).
    pub fn linear(active: usize, per_expert_ns: Ns) -> Ns {
        DISPATCH_NS + per_expert_ns * active as u64
    }

    /// Sort-dominated solvers (greedy's `O(n log n)` ordering pass).
    pub fn nlogn(active: usize, per_expert_ns: Ns) -> Ns {
        DISPATCH_NS + per_expert_ns * active as u64 * log2_ceil(active)
    }

    /// Exhaustive / branching solvers: `per_node_ns · n · 2^min(n, cap)`,
    /// saturating — the modeled analogue of Opt_plan's "prohibitively high"
    /// runtime solving cost (paper §6.3-1).
    pub fn exponential(active: usize, per_node_ns: Ns, exp_cap: u32) -> Ns {
        let nodes = 1u64 << (active as u32).min(exp_cap);
        DISPATCH_NS
            + per_node_ns
                .saturating_mul(active as u64)
                .saturating_mul(nodes)
    }
}

/// Result: the C/G indicator vectors of the paper.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Assignment {
    pub to_gpu: Vec<bool>,
    pub to_cpu: Vec<bool>,
}

impl Assignment {
    pub fn none(n: usize) -> Self {
        Assignment { to_gpu: vec![false; n], to_cpu: vec![false; n] }
    }

    /// Clear to an all-unassigned state of width `n`, reusing capacity.
    pub fn reset(&mut self, n: usize) {
        self.to_gpu.clear();
        self.to_gpu.resize(n, false);
        self.to_cpu.clear();
        self.to_cpu.resize(n, false);
    }

    /// Copy `src` into `self` without allocating (capacity permitting).
    pub fn copy_from(&mut self, src: &Assignment) {
        self.to_gpu.clear();
        self.to_gpu.extend_from_slice(&src.to_gpu);
        self.to_cpu.clear();
        self.to_cpu.extend_from_slice(&src.to_cpu);
    }

    /// Eq. 4/5 objective value of this assignment under `ctx`'s estimates.
    pub fn makespan_estimate(&self, ctx: &AssignCtx) -> Ns {
        let mut t_cpu = 0;
        let mut t_gpu = 0;
        for e in 0..self.to_gpu.len() {
            if self.to_gpu[e] {
                t_gpu += ctx.t_gpu(e);
            } else if self.to_cpu[e] {
                t_cpu += ctx.t_cpu(e);
            }
        }
        t_cpu.max(t_gpu)
    }

    /// Check Eqs. 7–9 (activation, mutual exclusion, memory).
    pub fn satisfies_constraints(&self, ctx: &AssignCtx) -> bool {
        let mut staged = 0;
        for e in 0..self.to_gpu.len() {
            let active = ctx.workloads[e] > 0;
            if active != (self.to_gpu[e] ^ self.to_cpu[e]) {
                // activated ⇔ exactly one device; inactive ⇔ neither
                if active || self.to_gpu[e] || self.to_cpu[e] {
                    return false;
                }
            }
            if self.to_gpu[e] && self.to_cpu[e] {
                return false;
            }
            if self.to_gpu[e] && !ctx.resident[e] {
                staged += 1;
            }
        }
        staged <= ctx.gpu_free_slots
    }
}

/// Trait implemented by every assignment policy.
pub trait Assigner: Send {
    fn name(&self) -> &'static str;

    /// Write the assignment for `ctx` into `out` (reset first). This is the
    /// hot-path entry point: the solvers on the measured replay paths
    /// (greedy, the static/fixed baselines) keep it allocation-free in
    /// steady state via internal scratch; the exhaustive solvers
    /// (beam/optimal/enumerate) may allocate — their whole point is that
    /// solving is expensive.
    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment);

    /// Allocating convenience wrapper (tests, one-off callers).
    fn assign(&mut self, ctx: &AssignCtx) -> Assignment {
        let mut out = Assignment::none(ctx.workloads.len());
        self.assign_into(ctx, &mut out);
        out
    }

    /// Deterministic modeled solve cost for this context ([`SolveCost`]
    /// `Modeled`). Default: one linear pass — cheap static policies.
    fn modeled_solve_ns(&self, ctx: &AssignCtx) -> Ns {
        solve_model::linear(ctx.active_count(), 10)
    }
}

#[cfg(test)]
mod tier_tests {
    use super::test_util::cost;
    use super::*;

    #[test]
    fn disk_residency_raises_both_device_costs() {
        let cm = cost("mixtral-sim");
        let workloads = vec![4u32, 4];
        let resident = vec![false, false];
        let tiers = vec![Tier::Host, Tier::Disk];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: Some(&tiers),
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
        };
        // host expert matches the two-tier estimates exactly
        assert_eq!(ctx.t_gpu(0), cm.t_gpu(4, false));
        assert_eq!(ctx.t_cpu(0), cm.t_cpu(4));
        // disk expert pays the NVMe fetch on either device
        assert_eq!(ctx.t_cpu(1), cm.t_cpu(4) + cm.nvme_read_time());
        assert!(ctx.t_gpu(1) >= cm.trans_time() + cm.nvme_read_time());
        // GPU residency overrides the storage tier (weights already up)
        let res2 = vec![false, true];
        let ctx2 = AssignCtx { resident: &res2, ..ctx };
        assert_eq!(ctx2.t_gpu(1), cm.t_gpu_compute(4));
    }

    #[test]
    fn host_wait_snapshot_overrides_tier_estimate() {
        // With a store-reported arrival snapshot, an in-flight (host-tier)
        // expert carries its remaining promotion wait in both device costs.
        let cm = cost("mixtral-sim");
        let workloads = vec![4u32, 4];
        let resident = vec![false, false];
        let tiers = vec![Tier::Host, Tier::Host];
        let wait: Vec<Ns> = vec![0, 77_000];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: Some(&tiers),
            host_wait: Some(&wait),
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
        };
        assert_eq!(ctx.host_wait_ns(0), 0);
        assert_eq!(ctx.host_wait_ns(1), 77_000);
        assert_eq!(ctx.t_cpu(0), cm.t_cpu(4));
        assert_eq!(ctx.t_cpu(1), cm.t_cpu(4) + 77_000);
        assert_eq!(ctx.t_gpu(1), cm.t_gpu_compute(4).max(cm.trans_time() + 77_000));
        // a disk expert's snapshot wait equals the tier-based fallback, so
        // store-reported and store-less costs agree for disk residents
        let tiers2 = vec![Tier::Host, Tier::Disk];
        let wait2: Vec<Ns> = vec![0, cm.nvme_read_time()];
        let ctx2 = AssignCtx { tiers: Some(&tiers2), host_wait: Some(&wait2), ..ctx };
        assert_eq!(ctx2.t_cpu(1), cm.t_cpu(4) + cm.nvme_read_time());
        assert_eq!(
            ctx2.t_gpu(1),
            cm.t_gpu_compute(4).max(cm.trans_time() + cm.nvme_read_time())
        );
    }

    #[test]
    fn quantized_disk_fallback_prices_read_plus_transcode() {
        // With a quantized on-disk format and no store-reported snapshot,
        // a disk-resident expert's wait is the full fetch: the (smaller)
        // NVMe read plus the CPU transcode stage — on either device.
        let fp16 = cost("mixtral-sim");
        let q4 = cost("mixtral-sim").with_quant_ratio(0.28);
        let workloads = vec![4u32, 4];
        let resident = vec![false, false];
        let tiers = vec![Tier::Host, Tier::Disk];
        let mk = |cm: &CostModel| AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: Some(&tiers),
            host_wait: None,
            cost: cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
        };
        let (cq, cf) = (mk(&q4), mk(&fp16));
        assert_eq!(cq.host_wait_ns(1), q4.nvme_fetch_time());
        assert_eq!(cq.host_wait_ns(0), 0, "host residents wait for nothing");
        assert_eq!(cq.t_cpu(1), q4.t_cpu(4) + q4.nvme_read_time() + q4.transcode_time());
        // the asymmetric format makes the disk expert cheaper to reach on
        // both devices than fp16-on-disk would
        assert!(cq.t_cpu(1) < cf.t_cpu(1));
        assert!(cq.t_gpu(1) <= cf.t_gpu(1));
        // host-resident costs are format-independent
        assert_eq!(cq.t_cpu(0), cf.t_cpu(0));
    }

    #[test]
    fn no_tiers_means_host() {
        let cm = cost("deepseek-sim");
        let workloads = vec![7u32];
        let resident = vec![false];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 1,
            layer: 0,
            layers: 1,
        };
        assert_eq!(ctx.tier(0), Tier::Host);
        assert_eq!(ctx.t_gpu(0), cm.t_gpu(7, false));
        assert_eq!(ctx.t_cpu(0), cm.t_cpu(7));
    }
}

#[cfg(test)]
mod solve_cost_tests {
    use super::test_util::cost;
    use super::*;

    fn ctx<'a>(workloads: &'a [u32], resident: &'a [bool], cm: &'a CostModel) -> AssignCtx<'a> {
        AssignCtx {
            workloads,
            resident,
            tiers: None,
            host_wait: None,
            cost: cm,
            gpu_free_slots: workloads.len(),
            layer: 0,
            layers: 4,
        }
    }

    #[test]
    fn modeled_costs_are_deterministic_and_ordered() {
        let cm = cost("deepseek-sim");
        let workloads: Vec<u32> = (0..12).map(|i| (i % 5 + 1) as u32).collect();
        let resident = vec![false; 12];
        let c = ctx(&workloads, &resident, &cm);
        let greedy = GreedyAssigner::new().modeled_solve_ns(&c);
        let greedy2 = GreedyAssigner::new().modeled_solve_ns(&c);
        assert_eq!(greedy, greedy2, "modeled cost must be a pure function");
        let opt = EnumerateAssigner::new().modeled_solve_ns(&c);
        let naive = AllCpuAssigner::new().modeled_solve_ns(&c);
        assert!(naive > 0 && greedy > naive);
        assert!(
            opt > 20 * greedy,
            "exhaustive solving must dwarf greedy (paper Fig. 15): {opt} vs {greedy}"
        );
        let beam = BeamAssigner::new(2).modeled_solve_ns(&c);
        assert!(beam > greedy, "beam search costs more than one greedy pass");
    }

    #[test]
    fn modeled_cost_scales_with_active_experts() {
        let cm = cost("mixtral-sim");
        let small: Vec<u32> = vec![1, 1, 0, 0, 0, 0, 0, 0];
        let large: Vec<u32> = vec![3; 8];
        let resident = vec![false; 8];
        let g = GreedyAssigner::new();
        assert!(
            g.modeled_solve_ns(&ctx(&small, &resident, &cm))
                < g.modeled_solve_ns(&ctx(&large, &resident, &cm))
        );
        assert_eq!(ctx(&small, &resident, &cm).active_count(), 2);
        assert_eq!(ctx(&large, &resident, &cm).active_count(), 8);
    }

    #[test]
    fn assign_into_matches_assign_and_reuses_buffers() {
        let cm = cost("mixtral-sim");
        let workloads = vec![4, 0, 1, 9, 2, 0, 7, 3];
        let resident = vec![true, false, false, false, true, false, false, false];
        let c = ctx(&workloads, &resident, &cm);
        let mut g = GreedyAssigner::new();
        let fresh = g.assign(&c);
        let mut reused = Assignment::none(8);
        for _ in 0..3 {
            g.assign_into(&c, &mut reused);
        }
        assert_eq!(fresh, reused, "buffered solve must be bit-identical");
        let mut copy = Assignment::default();
        copy.copy_from(&fresh);
        assert_eq!(copy, fresh);
        copy.reset(4);
        assert_eq!(copy, Assignment::none(4));
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;
    use crate::config::Presets;

    pub fn cost(model: &str) -> CostModel {
        let p = Presets::load_default().unwrap();
        CostModel::new(p.model(model).unwrap(), p.hw("local-pc").unwrap())
    }

    /// Exhaustive optimum for small instances (test oracle).
    pub fn brute_force(ctx: &AssignCtx) -> (Ns, Assignment) {
        let n = ctx.workloads.len();
        let active: Vec<usize> = (0..n).filter(|&e| ctx.workloads[e] > 0).collect();
        assert!(active.len() <= 20, "brute force only for small instances");
        let mut best = (Ns::MAX, Assignment::none(n));
        for mask in 0u32..(1 << active.len()) {
            let mut a = Assignment::none(n);
            let mut staged = 0;
            for (i, &e) in active.iter().enumerate() {
                if mask & (1 << i) != 0 {
                    a.to_gpu[e] = true;
                    if !ctx.resident[e] {
                        staged += 1;
                    }
                } else {
                    a.to_cpu[e] = true;
                }
            }
            if staged > ctx.gpu_free_slots {
                continue;
            }
            let m = a.makespan_estimate(ctx);
            if m < best.0 {
                best = (m, a);
            }
        }
        best
    }
}
