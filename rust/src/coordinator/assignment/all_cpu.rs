//! "Naive" baseline: every activated expert runs on the CPU (paper §6.3-1's
//! comparison anchor — KTransformers with all experts offloaded).

use super::{AssignCtx, Assigner, Assignment};

pub struct AllCpuAssigner;

impl Default for AllCpuAssigner {
    fn default() -> Self {
        Self::new()
    }
}

impl AllCpuAssigner {
    pub fn new() -> Self {
        AllCpuAssigner
    }
}

impl Assigner for AllCpuAssigner {
    fn name(&self) -> &'static str {
        "all_cpu"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        let n = ctx.workloads.len();
        out.reset(n);
        for e in 0..n {
            if ctx.workloads[e] > 0 {
                out.to_cpu[e] = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::cost;
    use super::*;

    #[test]
    fn everything_on_cpu() {
        let cm = cost("mixtral-sim");
        let workloads = vec![5, 0, 100];
        let resident = vec![true, true, true];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = AllCpuAssigner::new().assign(&ctx);
        assert_eq!(a.to_cpu, vec![true, false, true]);
        assert!(a.to_gpu.iter().all(|&g| !g));
        assert!(a.satisfies_constraints(&ctx));
    }
}
