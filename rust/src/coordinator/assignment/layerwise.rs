//! Layer-wise split (llama.cpp / KTransformers, paper Fig. 1a & Fig. 3a):
//! the first `cpu_layers` MoE layers execute every expert on the CPU, the
//! remaining layers execute every expert on the GPU (their weights are
//! resident there — the GPU memory budget decides `cpu_layers`, computed in
//! `frameworks.rs`). No CPU/GPU parallelism is possible because whole
//! layers serialize.

use super::{AssignCtx, Assigner, Assignment};

pub struct LayerWiseAssigner {
    /// MoE layers `0..cpu_layers` run on CPU; the rest on GPU.
    pub cpu_layers: usize,
}

impl LayerWiseAssigner {
    pub fn new(cpu_layers: usize) -> Self {
        LayerWiseAssigner { cpu_layers }
    }
}

impl Assigner for LayerWiseAssigner {
    fn name(&self) -> &'static str {
        "layerwise"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        let n = ctx.workloads.len();
        out.reset(n);
        let on_gpu = ctx.layer >= self.cpu_layers;
        for e in 0..n {
            if ctx.workloads[e] == 0 {
                continue;
            }
            if on_gpu {
                out.to_gpu[e] = true;
            } else {
                out.to_cpu[e] = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::cost;
    use super::*;

    #[test]
    fn splits_by_layer_index() {
        let cm = cost("mixtral-sim");
        let workloads = vec![3, 4];
        let resident = vec![true, true];
        let mk = |layer| AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 8,
            layer,
            layers: 4,
            devices: None,
        };
        let mut a = LayerWiseAssigner::new(2);
        assert!(a.assign(&mk(0)).to_cpu.iter().all(|&c| c));
        assert!(a.assign(&mk(1)).to_cpu.iter().all(|&c| c));
        assert!(a.assign(&mk(2)).to_gpu.iter().all(|&g| g));
        assert!(a.assign(&mk(3)).to_gpu.iter().all(|&g| g));
    }
}
