//! Beam-search assignment (paper Appendix A.2).
//!
//! Same expert order as greedy, but keeps the `beam_width` best partial
//! states (scored by partial makespan) at every step. Slightly better
//! schedules than greedy in some cases, at a materially higher solve cost —
//! the paper's reason for rejecting it.

use super::{solve_model, AssignCtx, Assigner, Assignment};
use crate::hw::Ns;

pub struct BeamAssigner {
    pub beam_width: usize,
}

#[derive(Clone)]
struct BeamState {
    t_cpu: u64,
    t_gpu: u64,
    slots: usize,
    choices: Vec<bool>, // true = GPU, indexed by visit order
}

impl BeamAssigner {
    pub fn new(beam_width: usize) -> Self {
        assert!(beam_width >= 1);
        BeamAssigner { beam_width }
    }
}

impl Assigner for BeamAssigner {
    fn name(&self) -> &'static str {
        "beam"
    }

    fn assign_into(&mut self, ctx: &AssignCtx, out: &mut Assignment) {
        let n = ctx.workloads.len();
        let mut order: Vec<usize> = (0..n).filter(|&e| ctx.workloads[e] > 0).collect();
        order.sort_by_key(|&e| std::cmp::Reverse(ctx.t_gpu(e).abs_diff(ctx.t_cpu(e))));

        let mut beam = vec![BeamState {
            t_cpu: 0,
            t_gpu: 0,
            slots: ctx.gpu_free_slots,
            choices: Vec::with_capacity(order.len()),
        }];
        for &e in &order {
            let (c, g) = (ctx.t_cpu(e), ctx.t_gpu(e));
            let needs_slot = !ctx.resident[e];
            let mut next = Vec::with_capacity(beam.len() * 2);
            for st in &beam {
                // CPU branch (always feasible)
                let mut cpu = st.clone();
                cpu.t_cpu += c;
                cpu.choices.push(false);
                next.push(cpu);
                // GPU branch (memory permitting)
                if !needs_slot || st.slots > 0 {
                    let mut gpu = st.clone();
                    gpu.t_gpu += g;
                    if needs_slot {
                        gpu.slots -= 1;
                    }
                    gpu.choices.push(true);
                    next.push(gpu);
                }
            }
            next.sort_by_key(|s| s.t_cpu.max(s.t_gpu));
            next.truncate(self.beam_width);
            beam = next;
        }
        let best = &beam[0];
        out.reset(n);
        for (i, &e) in order.iter().enumerate() {
            if best.choices[i] {
                out.to_gpu[e] = true;
            } else {
                out.to_cpu[e] = true;
            }
        }
    }

    fn modeled_solve_ns(&self, ctx: &AssignCtx) -> Ns {
        // per expert: expand + sort + truncate 2·width states, each carrying
        // an O(n) choice vector clone.
        let a = ctx.active_count();
        solve_model::nlogn(a, 90).saturating_mul(self.beam_width as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::cost;
    use super::super::{GreedyAssigner, OptimalAssigner};
    use super::*;
    use crate::util::DetRng;

    fn random_ctx_makespans(seed: u64, n: usize) -> Vec<(u64, u64, u64)> {
        let cm = cost("deepseek-sim");
        let mut rng = DetRng::new(seed);
        let mut out = vec![];
        for _ in 0..25 {
            let workloads: Vec<u32> = (0..n).map(|_| rng.usize_below(25) as u32).collect();
            let resident: Vec<bool> = (0..n).map(|_| rng.chance(0.3)).collect();
            let ctx = AssignCtx {
                workloads: &workloads,
                resident: &resident,
                tiers: None,
                host_wait: None,
                cost: &cm,
                gpu_free_slots: n,
                layer: 0,
                layers: 4,
                devices: None,
            };
            let b = BeamAssigner::new(2).assign(&ctx);
            assert!(b.satisfies_constraints(&ctx));
            out.push((
                GreedyAssigner::new().assign(&ctx).makespan_estimate(&ctx),
                b.makespan_estimate(&ctx),
                OptimalAssigner::new().assign(&ctx).makespan_estimate(&ctx),
            ));
        }
        out
    }

    #[test]
    fn beam_between_greedy_and_optimal_on_average() {
        let ms = random_ctx_makespans(11, 12);
        let (mut sg, mut sb, mut so) = (0u64, 0u64, 0u64);
        for (g, b, o) in ms {
            assert!(o <= b, "beam can't beat optimal");
            sg += g;
            sb += b;
            so += o;
        }
        assert!(sb <= sg, "beam(2) should not be worse than greedy in aggregate");
        assert!(so <= sb);
    }

    #[test]
    fn beam_width_one_reasonable() {
        let cm = cost("mixtral-sim");
        let workloads = vec![10, 20, 30];
        let resident = vec![false; 3];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 3,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = BeamAssigner::new(1).assign(&ctx);
        assert!(a.satisfies_constraints(&ctx));
    }

    #[test]
    fn respects_memory_slots() {
        let cm = cost("mixtral-sim");
        let workloads = vec![50, 50, 50, 50];
        let resident = vec![false; 4];
        let ctx = AssignCtx {
            workloads: &workloads,
            resident: &resident,
            tiers: None,
            host_wait: None,
            cost: &cm,
            gpu_free_slots: 2,
            layer: 0,
            layers: 4,
            devices: None,
        };
        let a = BeamAssigner::new(3).assign(&ctx);
        assert!(a.satisfies_constraints(&ctx));
        assert!(a.to_gpu.iter().filter(|&&g| g).count() <= 2);
    }
}
