//! Live inference engine: real PJRT numerics + virtual-time scheduling.
//!
//! Composes the AOT artifacts into full prefill/decode inference exactly as
//! `python/compile/model.py`'s reference does (verified against
//! `golden.json`), while feeding routing data to a [`StepSimulator`] so
//! every run also yields the paper's virtual-time metrics. Also produces
//! the calibration data (residual vectors, Eq. 11; activation frequencies)
//! and the routing [`Trace`]s that the policy-sweep experiments replay.

use anyhow::{bail, Context, Result};

use crate::config::ModelDims;
use crate::runtime::PjrtEngine;
use crate::workload::calib::{CalibAccum, CalibData};
use crate::workload::trace::{
    BatchStep, LayerStepRecord, PrefillLayerRecord, SeqTrace, Trace,
};

/// Per-token top-k selection matching `jax.lax.top_k` (ties → lower index).
///
/// Runs per token per layer on the decode hot path, so it uses partial
/// selection (`select_nth_unstable_by`, O(n) expected) and only sorts the
/// k-element prefix — instead of sorting all n gate probabilities.
pub fn top_k(probs: &[f32], k: usize) -> Vec<(usize, f32)> {
    let n = probs.len();
    let k = k.min(n);
    if k == 0 {
        return vec![];
    }
    let by_prob_desc =
        |a: &usize, b: &usize| probs[*b].total_cmp(&probs[*a]).then(a.cmp(b));
    let mut idx: Vec<usize> = (0..n).collect();
    if k < n {
        idx.select_nth_unstable_by(k - 1, by_prob_desc);
        idx.truncate(k);
    }
    idx.sort_unstable_by(by_prob_desc);
    idx.into_iter().map(|e| (e, probs[e])).collect()
}

fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
    let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        (dot / (na * nb)) as f32
    }
}

/// Routing + outputs of one live run (golden-test and trace material).
#[derive(Debug, Default)]
pub struct LiveRunOutput {
    /// Greedy-argmax generated tokens per sequence.
    pub generated: Vec<Vec<i32>>,
    /// Per sequence, per decode step: full logits row.
    pub decode_logits: Vec<Vec<Vec<f32>>>,
    /// Per sequence, per decode step, per layer: routed expert ids.
    pub decode_routes: Vec<Vec<Vec<Vec<usize>>>>,
    /// Per sequence, per prompt token, per layer: routed expert ids.
    pub prefill_routes: Vec<Vec<Vec<Vec<usize>>>>,
    /// Last prompt token logits per sequence.
    pub prefill_last_logits: Vec<Vec<f32>>,
    /// Recorded trace (when requested).
    pub trace: Option<Trace>,
}

/// The live engine for one preset.
pub struct InferenceEngine {
    pub rt: PjrtEngine,
    pub dims: ModelDims,
    /// Calibration data; required for residual predictions in traces.
    pub calib: Option<CalibData>,
}

struct SeqState {
    /// Per layer: (max_seq, H, hd) row-major K/V cache for this sequence.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    pos: usize,
    last_hidden: Vec<f32>,
    tokens: Vec<i32>,
}

impl InferenceEngine {
    pub fn new(preset: &str) -> Result<Self> {
        let rt = PjrtEngine::load(preset)?;
        let dims = rt.manifest().dims.clone();
        let calib = CalibData::load(&CalibData::path_for(preset)).ok();
        Ok(InferenceEngine { rt, dims, calib })
    }

    fn cache_row(&self) -> usize {
        self.dims.max_seq * self.dims.heads * self.dims.head_dim
    }

    /// Split `probs` (t × N) into per-token top-k routings.
    fn route(&self, probs: &[f32], t: usize) -> Vec<Vec<(usize, f32)>> {
        let n = self.dims.n_routed;
        (0..t).map(|i| top_k(&probs[i * n..(i + 1) * n], self.dims.top_k)).collect()
    }

    /// One full MoE layer on `t` rows: returns `h + Σ G_i·E_i(xn) + shared`.
    fn moe_combine(
        &self,
        layer: usize,
        h: &[f32],
        xn: &[f32],
        routes: &[Vec<(usize, f32)>],
        t: usize,
    ) -> Result<Vec<f32>> {
        let d = self.dims.hidden;
        let mut out = h.to_vec();
        // group rows by expert
        for e in 0..self.dims.n_routed {
            let rows: Vec<(usize, f32)> = routes
                .iter()
                .enumerate()
                .filter_map(|(i, r)| r.iter().find(|(ex, _)| *ex == e).map(|(_, s)| (i, *s)))
                .collect();
            if rows.is_empty() {
                continue;
            }
            let mut gathered = Vec::with_capacity(rows.len() * d);
            for &(i, _) in &rows {
                gathered.extend_from_slice(&xn[i * d..(i + 1) * d]);
            }
            let y = self.rt.expert_routed(layer, e, &gathered, rows.len())?;
            for (j, &(i, score)) in rows.iter().enumerate() {
                for c in 0..d {
                    out[i * d + c] += score * y[j * d + c];
                }
            }
        }
        for s in 0..self.dims.n_shared {
            let y = self.rt.expert_shared(layer, s, xn, t)?;
            for i in 0..t * d {
                out[i] += y[i];
            }
        }
        Ok(out)
    }

    /// Predicted next-layer routings from features `h` (t rows): runs the
    /// *next* layer's gate artifact on `h` (HybriMoE) and on `h + res_vec`
    /// (DALI Eq. 10). Returns (pred_raw routes, pred_res routes, h_res).
    fn predict_next(
        &self,
        layer: usize,
        h: &[f32],
        t: usize,
    ) -> Result<(Vec<Vec<(usize, f32)>>, Vec<Vec<(usize, f32)>>, Vec<f32>)> {
        let d = self.dims.hidden;
        let (praw, _) = self.rt.gate(layer + 1, h, t)?;
        let raw = self.route(&praw, t);
        let mut h_res = h.to_vec();
        if let Some(calib) = &self.calib {
            let rv = &calib.res_vec[layer];
            for i in 0..t {
                for c in 0..d {
                    h_res[i * d + c] += rv[c];
                }
            }
        }
        let (pres, _) = self.rt.gate(layer + 1, &h_res, t)?;
        let res = self.route(&pres, t);
        Ok((raw, res, h_res))
    }

    /// Run prefill for one sequence; fills `st` and returns per-layer
    /// records + routing log + final hidden rows.
    fn prefill_seq(
        &self,
        st: &mut SeqState,
        record: bool,
    ) -> Result<(Vec<PrefillLayerRecord>, Vec<Vec<Vec<usize>>>, Vec<f32>)> {
        let d = self.dims.hidden;
        let n = self.dims.n_routed;
        let s = st.tokens.len();
        let pos: Vec<i32> = (0..s as i32).collect();
        let mut x = self.rt.embed(&st.tokens, &pos)?;
        let mut prefill_recs = Vec::new();
        let mut route_log: Vec<Vec<Vec<usize>>> = vec![vec![]; s];
        for l in 0..self.dims.layers {
            let (h, k, v) = self.rt.attn_prefill(l, &x, s)?;
            // seed the per-seq cache
            let hw = self.dims.heads * self.dims.head_dim;
            st.k[l][..s * hw].copy_from_slice(&k);
            st.v[l][..s * hw].copy_from_slice(&v);
            let (probs, xn) = self.rt.gate(l, &h, s)?;
            let routes = self.route(&probs, s);
            for (i, r) in routes.iter().enumerate() {
                route_log[i].push(r.iter().map(|(e, _)| *e).collect());
            }
            if record {
                let mut counts = vec![0u32; n];
                let mut scores = vec![0f32; n];
                for r in &routes {
                    for &(e, sc) in r {
                        counts[e] += 1;
                        scores[e] += sc;
                    }
                }
                let (mut praw, mut pres) = (vec![0u32; n], vec![0u32; n]);
                if l + 1 < self.dims.layers {
                    let (raw, res, _) = self.predict_next(l, &h, s)?;
                    for r in &raw {
                        for &(e, _) in r {
                            praw[e] += 1;
                        }
                    }
                    for r in &res {
                        for &(e, _) in r {
                            pres[e] += 1;
                        }
                    }
                }
                prefill_recs.push(PrefillLayerRecord {
                    counts,
                    gate_scores: scores,
                    pred_raw: praw,
                    pred_res: pres,
                });
            }
            x = self.moe_combine(l, &h, &xn, &routes, s)?;
        }
        st.pos = s;
        st.last_hidden = x[(s - 1) * d..s * d].to_vec();
        Ok((prefill_recs, route_log, x))
    }

    /// Run a live batch: prefill all prompts (equal lengths), then
    /// `decode_steps` greedy decode steps. Optionally records a [`Trace`]
    /// (requires calibration data for residual predictions).
    pub fn run_batch(
        &self,
        prompts: &[Vec<i32>],
        decode_steps: usize,
        record_trace: bool,
    ) -> Result<LiveRunOutput> {
        if prompts.is_empty() {
            bail!("empty batch");
        }
        let s0 = prompts[0].len();
        if prompts.iter().any(|p| p.len() != s0) {
            bail!("live batches require equal prompt lengths (serve layer buckets by length)");
        }
        if record_trace && self.calib.is_none() {
            bail!("trace recording requires calibration data — run calibrate() first");
        }
        let d = self.dims.hidden;
        let nb = prompts.len();
        let cache_row = self.cache_row();
        let mut states: Vec<SeqState> = prompts
            .iter()
            .map(|p| SeqState {
                k: vec![vec![0f32; cache_row]; self.dims.layers],
                v: vec![vec![0f32; cache_row]; self.dims.layers],
                pos: 0,
                last_hidden: vec![],
                tokens: p.clone(),
            })
            .collect();

        let mut out = LiveRunOutput::default();
        let mut seq_traces: Vec<SeqTrace> = Vec::new();
        // --- prefill (per sequence: the prefill artifacts are per-seq) ------
        let mut next_tokens = Vec::with_capacity(nb);
        for st in states.iter_mut() {
            let (recs, route_log, x) = self.prefill_seq(st, record_trace)?;
            let logits = self.rt.head(&st.last_hidden, 1)?;
            let am = argmax(&logits);
            next_tokens.push(am as i32);
            out.prefill_routes.push(route_log);
            out.prefill_last_logits.push(logits);
            if record_trace {
                seq_traces.push(SeqTrace { prompt_len: st.tokens.len(), prefill: recs, steps: vec![] });
            }
            let _ = x;
        }

        out.generated = vec![vec![]; nb];
        out.decode_logits = vec![vec![]; nb];
        out.decode_routes = vec![vec![]; nb];

        // --- decode (batched) -------------------------------------------------
        for _step in 0..decode_steps {
            let pos: Vec<i32> = states.iter().map(|s| s.pos as i32).collect();
            if states.iter().any(|s| s.pos + 1 > self.dims.max_seq) {
                bail!("sequence exceeds max_seq {}", self.dims.max_seq);
            }
            let mut x = self.rt.embed(&next_tokens, &pos)?;
            let mut step_recs: Vec<Vec<LayerStepRecord>> = vec![vec![]; nb];
            let mut step_routes: Vec<Vec<Vec<usize>>> = vec![vec![]; nb];
            for l in 0..self.dims.layers {
                // batched caches
                let mut kc = vec![0f32; nb * cache_row];
                let mut vc = vec![0f32; nb * cache_row];
                for (i, st) in states.iter().enumerate() {
                    kc[i * cache_row..(i + 1) * cache_row].copy_from_slice(&st.k[l]);
                    vc[i * cache_row..(i + 1) * cache_row].copy_from_slice(&st.v[l]);
                }
                let (h, kc2, vc2) = self.rt.attn_decode(l, &x, &kc, &vc, &pos, nb)?;
                for (i, st) in states.iter_mut().enumerate() {
                    st.k[l].copy_from_slice(&kc2[i * cache_row..(i + 1) * cache_row]);
                    st.v[l].copy_from_slice(&vc2[i * cache_row..(i + 1) * cache_row]);
                }
                let (probs, xn) = self.rt.gate(l, &h, nb)?;
                let routes = self.route(&probs, nb);
                for (i, r) in routes.iter().enumerate() {
                    step_routes[i].push(r.iter().map(|(e, _)| *e).collect());
                }
                // predictions (for traces): per-token predicted next routes
                let pred = if record_trace && l + 1 < self.dims.layers {
                    Some(self.predict_next(l, &h, nb)?)
                } else {
                    None
                };
                let x_next = self.moe_combine(l, &h, &xn, &routes, nb)?;
                if record_trace {
                    // cosine similarity vs true next gate input = x_next
                    for i in 0..nb {
                        let hi = &h[i * d..(i + 1) * d];
                        let ti = &x_next[i * d..(i + 1) * d];
                        let (praw, pres, cr, cs) = match &pred {
                            Some((raw, res, h_res)) => (
                                raw[i].iter().map(|(e, _)| *e as u16).collect(),
                                res[i].iter().map(|(e, _)| *e as u16).collect(),
                                cosine(hi, ti),
                                cosine(&h_res[i * d..(i + 1) * d], ti),
                            ),
                            None => (vec![], vec![], 0.0, 0.0),
                        };
                        step_recs[i].push(LayerStepRecord {
                            topk: routes[i].iter().map(|(e, _)| *e as u16).collect(),
                            topk_scores: routes[i].iter().map(|(_, s)| *s).collect(),
                            pred_raw: praw,
                            pred_res: pres,
                            cos_raw: cr,
                            cos_res: cs,
                        });
                    }
                }
                x = x_next;
            }
            let logits = self.rt.head(&x, nb)?;
            let v = self.dims.vocab;
            for (i, st) in states.iter_mut().enumerate() {
                let row = logits[i * v..(i + 1) * v].to_vec();
                let am = argmax(&row) as i32;
                out.generated[i].push(am);
                out.decode_logits[i].push(row);
                out.decode_routes[i].push(step_routes[i].clone());
                st.pos += 1;
                next_tokens[i] = am;
                if record_trace {
                    seq_traces[i].steps.push(step_recs[i].clone());
                }
            }
        }
        if record_trace {
            out.trace = Some(Trace {
                preset: self.rt.manifest().preset.clone(),
                task: String::new(),
                n_routed: self.dims.n_routed,
                top_k: self.dims.top_k,
                layers: self.dims.layers,
                seqs: seq_traces,
            });
        }
        Ok(out)
    }

    /// Offline calibration (paper §4.2 Eq. 11 + EdgeMoE statistics): run
    /// prefill over calibration sequences, averaging adjacent-layer gate
    /// input differences and counting activations. Saves to
    /// `artifacts/calib/<preset>.json` and installs on `self`.
    pub fn calibrate(&mut self, seqs: &[Vec<i32>]) -> Result<CalibData> {
        let d = self.dims.hidden;
        let mut acc = CalibAccum::new(self.dims.layers, d, self.dims.n_routed);
        for tokens in seqs {
            let s = tokens.len();
            let pos: Vec<i32> = (0..s as i32).collect();
            let mut x = self.rt.embed(tokens, &pos)?;
            let mut prev_gate_input: Option<Vec<f32>> = None;
            for l in 0..self.dims.layers {
                let (h, _k, _v) = self.rt.attn_prefill(l, &x, s)?;
                if let Some(prev) = &prev_gate_input {
                    for i in 0..s {
                        acc.observe_pair(l - 1, &prev[i * d..(i + 1) * d], &h[i * d..(i + 1) * d]);
                    }
                }
                let (probs, xn) = self.rt.gate(l, &h, s)?;
                let routes = self.route(&probs, s);
                for r in &routes {
                    let ids: Vec<usize> = r.iter().map(|(e, _)| *e).collect();
                    acc.observe_routing(l, &ids);
                }
                prev_gate_input = Some(h.clone());
                x = self.moe_combine(l, &h, &xn, &routes, s)?;
            }
            acc.add_tokens(s);
        }
        let preset = self.rt.manifest().preset.clone();
        let calib = acc.finish(&preset);
        calib.save(&CalibData::path_for(&preset)).context("saving calibration")?;
        self.calib = Some(calib.clone());
        Ok(calib)
    }
}

/// Index of the maximum element (ties → lower index, matching numpy argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Convert a recorded live batch step into the simulator's [`BatchStep`]s —
/// used when running live inference with timing (the engine records the
/// trace, then the caller replays it through a `StepSimulator`, which is
/// bit-identical to having timed it inline).
pub fn trace_to_batch_steps(trace: &Trace, seq_ids: &[usize]) -> (BatchStep, Vec<BatchStep>) {
    let prefill = trace.compose_prefill(seq_ids);
    let steps = (0..trace.min_steps()).map(|s| trace.compose_decode(seq_ids, s)).collect();
    (prefill, steps)
}

/// Mean of a slice of f32 (helper for experiments).
pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_matches_jax_semantics() {
        let probs = [0.1, 0.5, 0.5, 0.3];
        let r = top_k(&probs, 2);
        assert_eq!(r[0].0, 1, "tie broken by lower index");
        assert_eq!(r[1].0, 2);
    }

    #[test]
    fn top_k_partial_selection_matches_full_sort() {
        // the select_nth fast path must agree with the reference full sort
        // on every k, including ties and the k >= n / k == 0 edges
        let mut rng = crate::util::DetRng::new(5);
        for _ in 0..200 {
            let n = 1 + rng.usize_below(64);
            let probs: Vec<f32> =
                (0..n).map(|_| (rng.usize_below(16) as f32) / 16.0).collect();
            for k in [0, 1, 2, n / 2, n, n + 3] {
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]).then(a.cmp(&b)));
                let want: Vec<(usize, f32)> =
                    idx.into_iter().take(k).map(|e| (e, probs[e])).collect();
                assert_eq!(top_k(&probs, k), want, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn argmax_ties_low_index() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }
}
