//! Next-layer high-workload expert prediction (paper §4.2).
//!
//! A predictor ranks the next layer's experts by *predicted workload*; the
//! top `prefetch_size` non-resident experts are transferred on the copy
//! stream, overlapping the current layer's compute. Implementations:
//!
//! * [`ResidualPrefetcher`] — DALI: gate_{l+1}(h_l + res_vec_l) (Eq. 10),
//!   counting predicted top-k hits per token;
//! * [`FeaturePrefetcher`] — HybriMoE: gate_{l+1}(h_l) on raw features;
//! * [`StatisticalPrefetcher`] — EdgeMoE: calibration-set activation
//!   frequency (input-independent);
//! * [`RandomPrefetcher`] and [`NoPrefetcher`].
//!
//! The expensive part (the extra gate execution) happens in the engine /
//! trace: `pred_raw` and `pred_res` arrive as per-token predicted top-k
//! counts. Predictors that need a gating pass report it via
//! [`Prefetcher::needs_gate_pass`] so the simulator charges the GPU time
//! and stream-switch overhead the paper measures (§6.3-4).

mod simple;

pub use simple::{
    FeaturePrefetcher, NoPrefetcher, OraclePrefetcher, RandomPrefetcher, ResidualPrefetcher,
    StatisticalPrefetcher,
};

use crate::util::DetRng;

/// Everything a predictor may look at when layer `layer` finishes.
pub struct PrefetchCtx<'a> {
    /// Predicted next-layer workload counts from raw features (HybriMoE).
    pub pred_raw: &'a [u32],
    /// Predicted next-layer workload counts from residual-corrected features.
    pub pred_res: &'a [u32],
    /// This layer's true workloads (some heuristics reuse them).
    pub cur_workloads: &'a [u32],
    /// True next-layer workloads — for the oracle upper bound only.
    pub true_next: Option<&'a [u32]>,
    /// Calibration activation frequency of the *next* layer (EdgeMoE).
    pub calib_freq_next: &'a [f64],
    pub rng: &'a mut DetRng,
}

/// Ranks next-layer experts by predicted workload (higher = fetch first).
pub trait Prefetcher: Send {
    fn name(&self) -> &'static str;
    /// Whether prediction requires an extra gating pass on the GPU.
    fn needs_gate_pass(&self) -> bool;
    /// Write the predicted workload score per next-layer expert into `out`
    /// (cleared first; left empty = no prediction). Hot-path entry point:
    /// implementations must not allocate in steady state.
    fn predict_into(&mut self, ctx: &mut PrefetchCtx, out: &mut Vec<f64>);
    /// Allocating convenience wrapper (tests, one-off callers).
    fn predict(&mut self, ctx: &mut PrefetchCtx) -> Vec<f64> {
        let mut out = Vec::new();
        self.predict_into(ctx, &mut out);
        out
    }
}

/// Write the indices of the top-`n` experts by score into `idx` (ties
/// broken by lower index) — the reusable-buffer core of [`top_n`].
pub fn top_n_into(scores: &[f64], n: usize, idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..scores.len());
    idx.sort_unstable_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    idx.truncate(n);
}

/// Top-`n` experts by predicted score (ties broken by lower index).
pub fn top_n(scores: &[f64], n: usize) -> Vec<usize> {
    let mut idx = Vec::with_capacity(scores.len());
    top_n_into(scores, n, &mut idx);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_n_orders_and_truncates() {
        let s = vec![0.1, 0.9, 0.5, 0.9];
        assert_eq!(top_n(&s, 2), vec![1, 3]);
        assert_eq!(top_n(&s, 10), vec![1, 3, 2, 0]);
        assert_eq!(top_n(&s, 0), Vec::<usize>::new());
    }
}
