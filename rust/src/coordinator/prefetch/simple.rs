//! The five compared predictors.

use super::{PrefetchCtx, Prefetcher};

/// DALI §4.2: residual-corrected feature prediction. The heavy lifting
/// (gate_{l+1}(h_l + res_vec_l), Eq. 10) was done by the engine with the
/// real gate artifact; `pred_res` carries per-token predicted top-k counts.
pub struct ResidualPrefetcher;

impl Prefetcher for ResidualPrefetcher {
    fn name(&self) -> &'static str {
        "residual"
    }

    fn needs_gate_pass(&self) -> bool {
        true
    }

    fn predict_into(&mut self, ctx: &mut PrefetchCtx, out: &mut Vec<f64>) {
        out.clear();
        out.extend(ctx.pred_res.iter().map(|&c| c as f64));
    }
}

/// HybriMoE-style raw-feature prediction: gate_{l+1}(h_l).
pub struct FeaturePrefetcher;

impl Prefetcher for FeaturePrefetcher {
    fn name(&self) -> &'static str {
        "feature"
    }

    fn needs_gate_pass(&self) -> bool {
        true
    }

    fn predict_into(&mut self, ctx: &mut PrefetchCtx, out: &mut Vec<f64>) {
        out.clear();
        out.extend(ctx.pred_raw.iter().map(|&c| c as f64));
    }
}

/// EdgeMoE-style statistics: input-independent calibration frequency.
pub struct StatisticalPrefetcher;

impl Prefetcher for StatisticalPrefetcher {
    fn name(&self) -> &'static str {
        "statistical"
    }

    fn needs_gate_pass(&self) -> bool {
        false
    }

    fn predict_into(&mut self, ctx: &mut PrefetchCtx, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(ctx.calib_freq_next);
    }
}

/// Uniform random ranking (paper Fig. 16a's "Random").
pub struct RandomPrefetcher;

impl Prefetcher for RandomPrefetcher {
    fn name(&self) -> &'static str {
        "random"
    }

    fn needs_gate_pass(&self) -> bool {
        false
    }

    fn predict_into(&mut self, ctx: &mut PrefetchCtx, out: &mut Vec<f64>) {
        out.clear();
        for _ in 0..ctx.pred_raw.len() {
            out.push(ctx.rng.f64());
        }
    }
}

/// Perfect prediction — upper bound for ablations (not in the paper's
/// comparison set, used by our sensitivity analyses).
pub struct OraclePrefetcher;

impl Prefetcher for OraclePrefetcher {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn needs_gate_pass(&self) -> bool {
        false
    }

    fn predict_into(&mut self, ctx: &mut PrefetchCtx, out: &mut Vec<f64>) {
        out.clear();
        match ctx.true_next {
            Some(t) => out.extend(t.iter().map(|&c| c as f64)),
            None => out.resize(ctx.pred_raw.len(), 0.0),
        }
    }
}

/// No prefetching.
pub struct NoPrefetcher;

impl Prefetcher for NoPrefetcher {
    fn name(&self) -> &'static str {
        "none"
    }

    fn needs_gate_pass(&self) -> bool {
        false
    }

    fn predict_into(&mut self, _ctx: &mut PrefetchCtx, out: &mut Vec<f64>) {
        out.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::top_n;
    use super::*;
    use crate::util::DetRng;

    fn ctx<'a>(
        pred_raw: &'a [u32],
        pred_res: &'a [u32],
        true_next: Option<&'a [u32]>,
        freq: &'a [f64],
        rng: &'a mut DetRng,
    ) -> PrefetchCtx<'a> {
        PrefetchCtx {
            pred_raw,
            pred_res,
            cur_workloads: pred_raw,
            true_next,
            calib_freq_next: freq,
            rng,
        }
    }

    #[test]
    fn residual_uses_res_counts() {
        let mut rng = DetRng::new(0);
        let raw = [5, 0, 0, 0];
        let res = [0, 0, 7, 0];
        let freq = [0.0; 4];
        let mut c = ctx(&raw, &res, None, &freq, &mut rng);
        assert_eq!(top_n(&ResidualPrefetcher.predict(&mut c), 1), vec![2]);
        assert_eq!(top_n(&FeaturePrefetcher.predict(&mut c), 1), vec![0]);
    }

    #[test]
    fn statistical_ignores_input() {
        let mut rng = DetRng::new(0);
        let raw = [9, 9, 9, 9];
        let freq = [0.1, 0.2, 0.9, 0.3];
        let mut c = ctx(&raw, &raw, None, &freq, &mut rng);
        assert_eq!(top_n(&StatisticalPrefetcher.predict(&mut c), 1), vec![2]);
        assert!(!StatisticalPrefetcher.needs_gate_pass());
    }

    #[test]
    fn oracle_matches_truth() {
        let mut rng = DetRng::new(0);
        let raw = [1, 0, 0, 0];
        let truth = [0, 0, 0, 8];
        let freq = [0.0; 4];
        let mut c = ctx(&raw, &raw, Some(&truth), &freq, &mut rng);
        assert_eq!(top_n(&OraclePrefetcher.predict(&mut c), 1), vec![3]);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let raw = [0u32; 8];
        let freq = [0.0; 8];
        let mut r1 = DetRng::new(4);
        let mut r2 = DetRng::new(4);
        let a = RandomPrefetcher.predict(&mut ctx(&raw, &raw, None, &freq, &mut r1));
        let b = RandomPrefetcher.predict(&mut ctx(&raw, &raw, None, &freq, &mut r2));
        assert_eq!(a, b);
    }

    #[test]
    fn none_predicts_nothing() {
        let mut rng = DetRng::new(0);
        let raw = [1u32; 4];
        let freq = [0.0; 4];
        let mut c = ctx(&raw, &raw, None, &freq, &mut rng);
        assert!(NoPrefetcher.predict(&mut c).is_empty());
    }
}
