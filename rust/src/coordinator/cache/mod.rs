//! GPU expert cache (paper §4.3).
//!
//! Each MoE layer owns `cache_size` GPU slots for expert weights; a resident
//! expert's PCIe transfer cost is zero during assignment (the cooperation
//! rule at the end of §4.3). Replacement policies:
//!
//! * [`WorkloadAwareCache`] — DALI's Alg. 2: sliding token window of
//!   `w_size`, accumulate per-expert workload scores, every window swap the
//!   `u_size` highest-scored CPU experts against the `u_size` lowest-scored
//!   GPU experts.
//! * [`LruCache`] — FastMoE-style least-recently-used.
//! * [`ScoreCache`] — HybriMoE-style activation-score replacement.
//! * [`PinnedCache`] — fixed resident set (layer-wise frameworks,
//!   MoE-Lightning); never replaces.
//! * [`NoCache`] — no expert cache at all (Fiddler).

mod lru;
mod pinned;
mod score;
mod workload_aware;

pub use lru::LruCache;
pub use pinned::{NoCache, PinnedCache};
pub use score::ScoreCache;
pub use workload_aware::WorkloadAwareCache;

/// One replacement decision: evict `out`, load `in_` (PCIe traffic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Swap {
    pub evict: usize,
    pub load: usize,
}

/// Trait implemented by every cache policy. All methods take the MoE layer
/// index; policies keep independent per-layer state (the paper replaces
/// per-layer independently).
pub trait ExpertCache: Send {
    fn name(&self) -> &'static str;

    /// Cache capacity per layer (experts).
    fn capacity(&self) -> usize;

    fn is_resident(&self, layer: usize, expert: usize) -> bool;

    /// Write the residency bitmap for assignment into `out` (resized and
    /// overwritten). Hot-path entry point: no steady-state allocation.
    fn resident_mask_into(&self, layer: usize, out: &mut Vec<bool>);

    /// Allocating convenience wrapper around [`Self::resident_mask_into`].
    fn resident_mask(&self, layer: usize) -> Vec<bool> {
        let mut out = Vec::new();
        self.resident_mask_into(layer, &mut out);
        out
    }

    /// Observe a batch step's true workloads + routed gate scores at a layer
    /// (called once per layer per step, before replacement decisions).
    fn observe(&mut self, layer: usize, workloads: &[u32], gate_scores: &[f32]);

    /// An expert was executed on the GPU; `fetched` = it was demand-fetched
    /// this step (i.e. it is now physically on the GPU and the policy may
    /// choose to admit it). Returns an eviction if the policy admits it.
    fn on_gpu_use(&mut self, layer: usize, expert: usize, fetched: bool) -> Option<usize>;

    /// Token-window boundary at a layer: appends swaps to perform to `out`
    /// (each costs one expert upload over PCIe). Called once per decode
    /// step per layer with the current step index. Hot-path entry point:
    /// no steady-state allocation.
    fn window_tick_into(&mut self, layer: usize, step: usize, out: &mut Vec<Swap>);

    /// Allocating convenience wrapper around [`Self::window_tick_into`].
    fn window_tick(&mut self, layer: usize, step: usize) -> Vec<Swap> {
        let mut out = Vec::new();
        self.window_tick_into(layer, step, &mut out);
        out
    }
}

/// Shared helper: fixed-capacity per-layer resident sets.
#[derive(Debug, Clone)]
pub(crate) struct ResidentSets {
    pub sets: Vec<Vec<usize>>, // per layer, sorted small vecs
    pub capacity: usize,
}

impl ResidentSets {
    pub fn new(layers: usize, n_experts: usize, capacity: usize, seed: u64) -> Self {
        // Paper §4: "for each MoE layer, we randomly select a fixed number of
        // experts to be cached in GPU memory" initially.
        let mut rng = crate::util::DetRng::new(seed ^ 0x5ca1ab1e);
        let sets = (0..layers)
            .map(|_| {
                let mut ids: Vec<usize> = (0..n_experts).collect();
                rng.shuffle(&mut ids);
                let mut s: Vec<usize> = ids.into_iter().take(capacity.min(n_experts)).collect();
                s.sort_unstable();
                s
            })
            .collect();
        ResidentSets { sets, capacity }
    }

    pub fn contains(&self, layer: usize, e: usize) -> bool {
        self.sets[layer].binary_search(&e).is_ok()
    }

    pub fn mask(&self, layer: usize, n: usize) -> Vec<bool> {
        let mut m = Vec::with_capacity(n);
        self.mask_into(layer, n, &mut m);
        m
    }

    /// Buffer-reusing form of [`Self::mask`].
    pub fn mask_into(&self, layer: usize, n: usize, out: &mut Vec<bool>) {
        out.clear();
        out.resize(n, false);
        for &e in &self.sets[layer] {
            out[e] = true;
        }
    }

    pub fn replace(&mut self, layer: usize, evict: usize, load: usize) {
        let set = &mut self.sets[layer];
        if let Ok(i) = set.binary_search(&evict) {
            set.remove(i);
        }
        if let Err(i) = set.binary_search(&load) {
            set.insert(i, load);
        }
        debug_assert!(set.len() <= self.capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_sets_respect_capacity() {
        let r = ResidentSets::new(4, 16, 3, 1);
        for l in 0..4 {
            assert_eq!(r.sets[l].len(), 3);
            for &e in &r.sets[l] {
                assert!(e < 16);
            }
        }
    }

    #[test]
    fn capacity_clamped_to_expert_count() {
        let r = ResidentSets::new(2, 4, 10, 1);
        assert_eq!(r.sets[0].len(), 4);
    }

    #[test]
    fn replace_swaps_membership() {
        let mut r = ResidentSets::new(1, 8, 2, 2);
        let evict = r.sets[0][0];
        let load = (0..8).find(|e| !r.contains(0, *e)).unwrap();
        r.replace(0, evict, load);
        assert!(!r.contains(0, evict));
        assert!(r.contains(0, load));
        assert_eq!(r.sets[0].len(), 2);
    }

    #[test]
    fn initial_sets_differ_across_layers() {
        let r = ResidentSets::new(8, 64, 8, 3);
        let all_same = (1..8).all(|l| r.sets[l] == r.sets[0]);
        assert!(!all_same);
    }
}
