//! DALI's Workload-Aware Cache Replacement (paper Algorithm 2 / Fig. 11).
//!
//! Per layer: accumulate per-expert workload scores `s_k = Σ_window w_k`
//! (Eq. 12) over a sliding window of `w_size` tokens; at every window
//! boundary, take the `u_size` highest-scored experts currently on CPU and
//! the `u_size` lowest-scored experts currently on GPU and swap them, then
//! reset the scores.
//!
//! One deliberate refinement over the literal Alg. 2: a swap is skipped when
//! the incoming expert's score does not exceed the outgoing expert's score
//! (swapping equal-or-lower-scored experts costs PCIe traffic and cannot
//! improve hit rate). This matches the intent ("to maximize cache utility")
//! and the measured behaviour that replacement traffic must pay for itself
//! (Appendix A.6).

use super::{ExpertCache, ResidentSets, Swap};

pub struct WorkloadAwareCache {
    res: ResidentSets,
    scores: Vec<Vec<u64>>, // per layer, per expert accumulated workload
    pub w_size: usize,
    pub u_size: usize,
    n_experts: usize,
    /// Reused window-boundary ranking buffers (allocation-free hot path).
    cpu_buf: Vec<usize>,
    gpu_buf: Vec<usize>,
}

impl WorkloadAwareCache {
    pub fn new(
        layers: usize,
        n_experts: usize,
        capacity: usize,
        w_size: usize,
        u_size: usize,
        seed: u64,
    ) -> Self {
        assert!(w_size >= 1);
        WorkloadAwareCache {
            res: ResidentSets::new(layers, n_experts, capacity, seed),
            scores: vec![vec![0; n_experts]; layers],
            w_size,
            u_size,
            n_experts,
            cpu_buf: Vec::with_capacity(n_experts),
            gpu_buf: Vec::with_capacity(n_experts),
        }
    }
}

impl ExpertCache for WorkloadAwareCache {
    fn name(&self) -> &'static str {
        "workload_aware"
    }

    fn capacity(&self) -> usize {
        self.res.capacity
    }

    fn is_resident(&self, layer: usize, expert: usize) -> bool {
        self.res.contains(layer, expert)
    }

    fn resident_mask_into(&self, layer: usize, out: &mut Vec<bool>) {
        self.res.mask_into(layer, self.n_experts, out)
    }

    fn observe(&mut self, layer: usize, workloads: &[u32], _gate_scores: &[f32]) {
        // Alg. 2 lines 5-6: s += workload_i
        for (e, &w) in workloads.iter().enumerate() {
            self.scores[layer][e] += w as u64;
        }
    }

    fn on_gpu_use(&mut self, _layer: usize, _expert: usize, _fetched: bool) -> Option<usize> {
        // Workload-aware replacement happens only at window boundaries;
        // demand-fetched experts are staged transiently, not admitted.
        None
    }

    fn window_tick_into(&mut self, layer: usize, step: usize, out: &mut Vec<Swap>) {
        // Alg. 2 line 9: i mod w_size == 0
        if step == 0 || step % self.w_size != 0 {
            return;
        }
        let scores = &self.scores[layer];
        // top-u CPU-side experts by score (Alg. 2 line 10); the index
        // tiebreaks reproduce the old stable-sort ordering exactly.
        let cpu_side = &mut self.cpu_buf;
        cpu_side.clear();
        cpu_side.extend((0..self.n_experts).filter(|&e| !self.res.contains(layer, e)));
        cpu_side.sort_unstable_by_key(|&e| (std::cmp::Reverse(scores[e]), e));
        // bottom-u GPU-side experts by score (line 11)
        let gpu_side = &mut self.gpu_buf;
        gpu_side.clear();
        gpu_side.extend_from_slice(&self.res.sets[layer]);
        gpu_side.sort_unstable_by_key(|&e| (scores[e], e));

        let start = out.len();
        for i in 0..self.u_size.min(cpu_side.len()).min(gpu_side.len()) {
            let load = cpu_side[i];
            let evict = gpu_side[i];
            // utility guard: only swap strictly-better experts in
            if scores[load] > scores[evict] {
                out.push(Swap { evict, load });
            }
        }
        for s in &out[start..] {
            self.res.replace(layer, s.evict, s.load);
        }
        // line 15: reset scores for the next window
        self.scores[layer].iter_mut().for_each(|s| *s = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wa(cap: usize, w: usize, u: usize) -> WorkloadAwareCache {
        WorkloadAwareCache::new(1, 8, cap, w, u, 7)
    }

    #[test]
    fn hot_expert_enters_cache_after_window() {
        let mut c = wa(2, 4, 2);
        // find an expert not initially resident and hammer it
        let hot = (0..8).find(|&e| !c.is_resident(0, e)).unwrap();
        let mut w = vec![0u32; 8];
        w[hot] = 10;
        for step in 1..=4 {
            c.observe(0, &w, &[0.0; 8]);
            let swaps = c.window_tick(0, step);
            if step % 4 == 0 {
                assert!(swaps.iter().any(|s| s.load == hot), "hot expert must load");
            } else {
                assert!(swaps.is_empty(), "no replacement mid-window");
            }
        }
        assert!(c.is_resident(0, hot));
    }

    #[test]
    fn capacity_invariant_held() {
        let mut c = wa(3, 2, 2);
        let mut rng = crate::util::DetRng::new(3);
        for step in 1..100 {
            let w: Vec<u32> = (0..8).map(|_| rng.usize_below(5) as u32).collect();
            c.observe(0, &w, &[0.0; 8]);
            c.window_tick(0, step);
            assert_eq!(c.resident_mask(0).iter().filter(|&&b| b).count(), 3);
        }
    }

    #[test]
    fn scores_reset_each_window() {
        let mut c = wa(2, 2, 1);
        let cold = (0..8).find(|&e| !c.is_resident(0, e)).unwrap();
        let mut w = vec![0u32; 8];
        w[cold] = 100;
        c.observe(0, &w, &[0.0; 8]);
        c.window_tick(0, 2); // cold loads, scores reset
        assert!(c.is_resident(0, cold));
        // next window: no observations → no swaps (all scores 0)
        let swaps = c.window_tick(0, 4);
        assert!(swaps.is_empty(), "equal zero scores must not swap");
    }

    #[test]
    fn u_size_bounds_swaps_per_window() {
        let mut c = wa(4, 1, 2);
        let mut w = vec![0u32; 8];
        for e in 0..8 {
            w[e] = if c.is_resident(0, e) { 0 } else { 50 };
        }
        c.observe(0, &w, &[0.0; 8]);
        let swaps = c.window_tick(0, 1);
        assert!(swaps.len() <= 2);
    }

    #[test]
    fn per_layer_state_independent() {
        let mut c = WorkloadAwareCache::new(2, 8, 2, 1, 1, 5);
        let hot0 = (0..8).find(|&e| !c.is_resident(0, e)).unwrap();
        let mut w = vec![0u32; 8];
        w[hot0] = 9;
        c.observe(0, &w, &[0.0; 8]);
        let before_l1 = c.resident_mask(1);
        c.window_tick(0, 1);
        assert_eq!(c.resident_mask(1), before_l1, "layer 1 untouched");
    }
}
