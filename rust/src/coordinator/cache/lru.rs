//! LRU expert cache (FastMoE-style, the paper's Fig. 7 baseline).
//!
//! On every GPU execution of an expert, it is touched; a demand-fetched
//! expert is admitted, evicting the least-recently-used resident. Workload
//! magnitudes are ignored entirely — the deficiency Fig. 7 measures.

use super::{ExpertCache, ResidentSets, Swap};

pub struct LruCache {
    res: ResidentSets,
    /// Monotone use counter per layer per expert (0 = never used).
    stamp: Vec<Vec<u64>>,
    clock: u64,
    n_experts: usize,
}

impl LruCache {
    pub fn new(layers: usize, n_experts: usize, capacity: usize, seed: u64) -> Self {
        LruCache {
            res: ResidentSets::new(layers, n_experts, capacity, seed),
            stamp: vec![vec![0; n_experts]; layers],
            clock: 0,
            n_experts,
        }
    }
}

impl ExpertCache for LruCache {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn capacity(&self) -> usize {
        self.res.capacity
    }

    fn is_resident(&self, layer: usize, expert: usize) -> bool {
        self.res.contains(layer, expert)
    }

    fn resident_mask_into(&self, layer: usize, out: &mut Vec<bool>) {
        self.res.mask_into(layer, self.n_experts, out)
    }

    fn observe(&mut self, _layer: usize, _workloads: &[u32], _gate_scores: &[f32]) {}

    fn on_gpu_use(&mut self, layer: usize, expert: usize, fetched: bool) -> Option<usize> {
        self.clock += 1;
        self.stamp[layer][expert] = self.clock;
        if !fetched || self.res.contains(layer, expert) {
            return None;
        }
        // admit, evicting the LRU resident
        let victim = *self.res.sets[layer]
            .iter()
            .min_by_key(|&&e| self.stamp[layer][e])?;
        self.res.replace(layer, victim, expert);
        Some(victim)
    }

    fn window_tick_into(&mut self, _layer: usize, _step: usize, _out: &mut Vec<Swap>) {
        // LRU replaces on use, not on windows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fetched_expert_admitted_evicting_lru() {
        let mut c = LruCache::new(1, 8, 2, 1);
        let residents: Vec<usize> = (0..8).filter(|&e| c.is_resident(0, e)).collect();
        // touch residents in order; residents[0] becomes LRU
        c.on_gpu_use(0, residents[0], false);
        c.on_gpu_use(0, residents[1], false);
        let newcomer = (0..8).find(|&e| !c.is_resident(0, e)).unwrap();
        let evicted = c.on_gpu_use(0, newcomer, true);
        assert_eq!(evicted, Some(residents[0]));
        assert!(c.is_resident(0, newcomer));
        assert!(!c.is_resident(0, residents[0]));
    }

    #[test]
    fn resident_use_does_not_evict() {
        let mut c = LruCache::new(1, 8, 2, 2);
        let r = (0..8).find(|&e| c.is_resident(0, e)).unwrap();
        assert_eq!(c.on_gpu_use(0, r, false), None);
        assert_eq!(c.on_gpu_use(0, r, true), None); // already resident
    }

    #[test]
    fn capacity_stable_under_churn() {
        let mut c = LruCache::new(2, 16, 4, 3);
        let mut rng = crate::util::DetRng::new(1);
        for _ in 0..200 {
            let e = rng.usize_below(16);
            let l = rng.usize_below(2);
            let fetched = !c.is_resident(l, e);
            c.on_gpu_use(l, e, fetched);
            assert_eq!(c.resident_mask(l).iter().filter(|&&b| b).count(), 4);
        }
    }
}
