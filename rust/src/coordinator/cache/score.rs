//! HybriMoE-style activation-score cache.
//!
//! Maintains an exponentially-decayed per-expert *gate score* (the router's
//! softmax probability mass routed to each expert) and keeps the
//! highest-scored experts resident. Replacement happens on use: a fetched
//! expert is admitted iff its score exceeds the lowest resident score.
//! Workload (token counts) is not consulted — the gap the paper's Fig. 7
//! exploits, since score mass and token workload diverge under top-k
//! routing.

use super::{ExpertCache, ResidentSets, Swap};

pub struct ScoreCache {
    res: ResidentSets,
    score: Vec<Vec<f64>>,
    pub decay: f64,
    n_experts: usize,
}

impl ScoreCache {
    pub fn new(layers: usize, n_experts: usize, capacity: usize, seed: u64) -> Self {
        ScoreCache {
            res: ResidentSets::new(layers, n_experts, capacity, seed),
            score: vec![vec![0.0; n_experts]; layers],
            decay: 0.8,
            n_experts,
        }
    }
}

impl ExpertCache for ScoreCache {
    fn name(&self) -> &'static str {
        "score"
    }

    fn capacity(&self) -> usize {
        self.res.capacity
    }

    fn is_resident(&self, layer: usize, expert: usize) -> bool {
        self.res.contains(layer, expert)
    }

    fn resident_mask_into(&self, layer: usize, out: &mut Vec<bool>) {
        self.res.mask_into(layer, self.n_experts, out)
    }

    fn observe(&mut self, layer: usize, _workloads: &[u32], gate_scores: &[f32]) {
        for (e, &g) in gate_scores.iter().enumerate() {
            let s = &mut self.score[layer][e];
            *s = *s * self.decay + g as f64;
        }
    }

    fn on_gpu_use(&mut self, layer: usize, expert: usize, fetched: bool) -> Option<usize> {
        if !fetched || self.res.contains(layer, expert) {
            return None;
        }
        let victim = *self.res.sets[layer]
            .iter()
            .min_by(|&&a, &&b| self.score[layer][a].total_cmp(&self.score[layer][b]))?;
        if self.score[layer][expert] > self.score[layer][victim] {
            self.res.replace(layer, victim, expert);
            Some(victim)
        } else {
            None
        }
    }

    fn window_tick_into(&mut self, _layer: usize, _step: usize, _out: &mut Vec<Swap>) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_score_expert_displaces_low() {
        let mut c = ScoreCache::new(1, 8, 2, 4);
        let hot = (0..8).find(|&e| !c.is_resident(0, e)).unwrap();
        let mut g = vec![0.0f32; 8];
        g[hot] = 5.0;
        c.observe(0, &[0; 8], &g);
        let evicted = c.on_gpu_use(0, hot, true);
        assert!(evicted.is_some());
        assert!(c.is_resident(0, hot));
    }

    #[test]
    fn low_score_expert_not_admitted() {
        let mut c = ScoreCache::new(1, 8, 2, 4);
        // give residents solid scores
        let mut g = vec![0.0f32; 8];
        for e in 0..8 {
            if c.is_resident(0, e) {
                g[e] = 3.0;
            }
        }
        c.observe(0, &[0; 8], &g);
        let cold = (0..8).find(|&e| !c.is_resident(0, e)).unwrap();
        assert_eq!(c.on_gpu_use(0, cold, true), None);
        assert!(!c.is_resident(0, cold));
    }

    #[test]
    fn scores_decay() {
        let mut c = ScoreCache::new(1, 4, 1, 1);
        let mut g = vec![0.0f32; 4];
        g[0] = 1.0;
        c.observe(0, &[0; 4], &g);
        let s0 = c.score[0][0];
        c.observe(0, &[0; 4], &[0.0; 4]);
        assert!(c.score[0][0] < s0);
    }
}
