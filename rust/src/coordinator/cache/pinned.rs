//! Fixed-residency caches: [`PinnedCache`] (a chosen set, never replaced —
//! layer-wise frameworks and MoE-Lightning's offline placement) and
//! [`NoCache`] (nothing resident — Fiddler).

use super::{ExpertCache, Swap};

/// Fixed resident sets decided before inference.
pub struct PinnedCache {
    mask: Vec<Vec<bool>>, // per layer
    capacity: usize,
}

impl PinnedCache {
    /// Pin an explicit per-layer set.
    pub fn new(mask: Vec<Vec<bool>>) -> Self {
        let capacity = mask.iter().map(|m| m.iter().filter(|&&b| b).count()).max().unwrap_or(0);
        PinnedCache { mask, capacity }
    }

    /// Pin every expert of layers `cpu_layers..layers` (layer-wise split).
    pub fn whole_layers(layers: usize, n_experts: usize, cpu_layers: usize) -> Self {
        let mask = (0..layers)
            .map(|l| vec![l >= cpu_layers; n_experts])
            .collect();
        Self::new(mask)
    }

    /// Pin the top-`per_layer` experts per layer ranked by calibration
    /// activation frequency (MoE-Lightning's offline placement search).
    pub fn by_frequency(freq: &[Vec<f64>], per_layer: usize) -> Self {
        let mask = freq
            .iter()
            .map(|f| {
                let mut idx: Vec<usize> = (0..f.len()).collect();
                idx.sort_by(|&a, &b| f[b].total_cmp(&f[a]));
                let mut m = vec![false; f.len()];
                for &e in idx.iter().take(per_layer) {
                    m[e] = true;
                }
                m
            })
            .collect();
        Self::new(mask)
    }
}

impl ExpertCache for PinnedCache {
    fn name(&self) -> &'static str {
        "pinned"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn is_resident(&self, layer: usize, expert: usize) -> bool {
        self.mask[layer][expert]
    }

    fn resident_mask_into(&self, layer: usize, out: &mut Vec<bool>) {
        out.clear();
        out.extend_from_slice(&self.mask[layer]);
    }

    fn observe(&mut self, _layer: usize, _workloads: &[u32], _gate_scores: &[f32]) {}

    fn on_gpu_use(&mut self, _layer: usize, _expert: usize, _fetched: bool) -> Option<usize> {
        None
    }

    fn window_tick_into(&mut self, _layer: usize, _step: usize, _out: &mut Vec<Swap>) {}
}

/// No expert cache at all.
pub struct NoCache {
    layers: usize,
    n_experts: usize,
}

impl NoCache {
    pub fn new(layers: usize, n_experts: usize) -> Self {
        NoCache { layers, n_experts }
    }
}

impl ExpertCache for NoCache {
    fn name(&self) -> &'static str {
        "none"
    }

    fn capacity(&self) -> usize {
        0
    }

    fn is_resident(&self, layer: usize, _expert: usize) -> bool {
        debug_assert!(layer < self.layers);
        false
    }

    fn resident_mask_into(&self, _layer: usize, out: &mut Vec<bool>) {
        out.clear();
        out.resize(self.n_experts, false);
    }

    fn observe(&mut self, _layer: usize, _workloads: &[u32], _gate_scores: &[f32]) {}

    fn on_gpu_use(&mut self, _layer: usize, _expert: usize, _fetched: bool) -> Option<usize> {
        None
    }

    fn window_tick_into(&mut self, _layer: usize, _step: usize, _out: &mut Vec<Swap>) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_layers_split() {
        let c = PinnedCache::whole_layers(4, 8, 2);
        assert!(!c.is_resident(0, 3));
        assert!(!c.is_resident(1, 3));
        assert!(c.is_resident(2, 3));
        assert!(c.is_resident(3, 0));
    }

    #[test]
    fn by_frequency_picks_top() {
        let freq = vec![vec![0.1, 0.9, 0.5, 0.2]];
        let c = PinnedCache::by_frequency(&freq, 2);
        assert!(c.is_resident(0, 1));
        assert!(c.is_resident(0, 2));
        assert!(!c.is_resident(0, 0));
        assert!(!c.is_resident(0, 3));
    }

    #[test]
    fn pinned_never_replaces() {
        let mut c = PinnedCache::by_frequency(&vec![vec![1.0, 0.0]], 1);
        assert_eq!(c.on_gpu_use(0, 1, true), None);
        assert!(c.window_tick(0, 10).is_empty());
        assert!(!c.is_resident(0, 1));
    }

    #[test]
    fn no_cache_is_empty() {
        let mut c = NoCache::new(2, 4);
        assert_eq!(c.capacity(), 0);
        assert!(!c.is_resident(0, 0));
        assert_eq!(c.on_gpu_use(0, 0, true), None);
        assert_eq!(c.resident_mask(1), vec![false; 4]);
    }
}
