//! Per-layer orchestration over the simulated heterogeneous platform —
//! the paper's Fig. 9 runtime loop:
//!
//! 1. gate → true expert workloads;
//! 2. **assignment** (Greedy/optimal/static/...) with a deterministic
//!    modeled solve cost charged into virtual time (wall-clock measurement
//!    kept behind [`SolveCost::Measured`]);
//! 3. parallel execution: CPU side `Σ t_cpu(w_i)`, GPU side on the
//!    copy/compute pipeline (demand fetches for non-resident experts);
//! 4. **prefetch** stream for layer l+1 (prediction gate pass + transfers);
//! 5. **cache** observation + window replacement.
//!
//! The same loop serves live inference (the engine computes real
//! activations alongside) and trace replay (policy sweeps without PJRT) —
//! both produce identical virtual-time metrics for identical routing.
//!
//! **Hot-path discipline:** `run_step` performs no heap allocation in
//! steady state. All per-step temporaries live in a reusable
//! [`StepScratch`]; in-flight prefetches are tracked in a flat
//! `layer × expert` arrival table instead of a `HashMap`; policies write
//! into caller buffers via the `*_into` APIs. `tests/alloc_audit.rs`
//! enforces this with a counting global allocator.
//!
//! **Tracing:** the simulator is generic over a [`TraceSink`] and emits a
//! typed [`Event`] at every scheduling decision (assignment devices,
//! prefetch issue/hit/wasted, cache swaps, per-lane busy intervals, step
//! boundaries). With the default [`NullSink`] every emission site —
//! guarded `if S::ENABLED` — monomorphizes away, so the hot path stays
//! zero-alloc and bit-identical; the `alloc_audit` and `determinism`
//! suites run against this default. Attach a sink with
//! [`StepSimulator::with_sink`] or [`replay_decode_traced`].

use crate::coordinator::assignment::{AssignCtx, Assigner, Assignment, DeviceView, SolveCost};
use crate::coordinator::cache::{ExpertCache, Swap};
use crate::coordinator::prefetch::{top_n_into, PrefetchCtx, Prefetcher};
use crate::fault::FaultPlan;
use crate::hw::{CostModel, GpuPipeline, Ns, TransferKind};
use crate::metrics::RunMetrics;
use crate::store::{placement, PlacementCfg, Tier, TieredStore, MAX_DEVICES};
use crate::trace::{Event, Lane, NullSink, TraceSink};
use crate::util::DetRng;
use crate::workload::trace::BatchStep;
use crate::workload::Trace;

/// A framework's policy bundle: assignment × prefetch × cache + execution
/// quirks. The six compared systems are bundles of these (frameworks.rs).
pub struct PolicyBundle {
    pub assigner: Box<dyn Assigner>,
    pub prefetcher: Box<dyn Prefetcher>,
    pub cache: Box<dyn ExpertCache>,
    /// Experts to prefetch per layer (paper's "prefetch size"; 0 = off).
    pub prefetch_size: usize,
    /// CPU GEMM efficiency multiplier (llama.cpp's slower CPU kernels < 1).
    pub cpu_eff: f64,
    /// Extra per-layer overhead (MoE-Lightning's stream-switch cost etc.).
    pub layer_overhead_ns: Ns,
    /// Eq. 9: staging slots for non-resident experts per layer.
    pub gpu_free_slots: usize,
    /// How assignment-solve time is charged into virtual time. The default
    /// [`SolveCost::Modeled`] makes identical seeds produce bit-identical
    /// [`RunMetrics`] across runs and machines.
    pub solve_cost: SolveCost,
    /// Tiered-store placement policy for this framework: predictive
    /// (promote-ahead + score demotion, the DALI bundles) or reactive
    /// (LRU spill, the baselines). Applied to the store on
    /// [`StepSimulator::with_store`]; inert without a memory-limited store.
    pub placement: PlacementCfg,
}

/// Which inference phase a step belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// Sentinel in the flat prefetch-arrival table: no transfer in flight.
const NO_ARRIVAL: Ns = Ns::MAX;

/// Reusable per-step buffers — the reason `run_step` allocates nothing in
/// steady state. Taken out of the simulator at the top of each step
/// (`mem::take`) so field borrows never fight the rest of `self`.
#[derive(Default)]
struct StepScratch {
    /// Cache residency bitmap of the current layer.
    cache_resident: Vec<bool>,
    /// cache ∪ arrived-or-in-flight prefetches (assignment input).
    resident: Vec<bool>,
    /// Storage-tier snapshot of the current layer (tiered store only).
    tiers: Vec<Tier>,
    /// Per-expert host-RAM arrival wait of the current layer (tiered store
    /// only) — prices in-flight predictive promotions into assignment.
    host_wait: Vec<Ns>,
    /// The solver's output for the current layer.
    assignment: Assignment,
    /// CPU-side (arrival, duration) pairs, sorted by arrival.
    cpu_timeline: Vec<(Ns, Ns)>,
    /// GPU-assigned experts in execution order.
    gpu_experts: Vec<usize>,
    /// Prefetcher score output.
    scores: Vec<f64>,
    /// Expert indices ranked by prefetch score.
    ranked: Vec<usize>,
    /// Cache window-tick swap list.
    swaps: Vec<Swap>,
    /// Device-major per-device residency (`d * n_routed + e`) for the
    /// multi-device assignment view. Empty on single-GPU runs.
    dev_resident: Vec<bool>,
    /// Per-device staging budgets for the view. Empty on single-GPU runs.
    dev_free: Vec<usize>,
}

impl StepScratch {
    /// Pre-size every buffer for `n_routed`-expert layers so the hot loop
    /// never reallocates, regardless of which branches early steps hit.
    fn with_dims(n_routed: usize) -> Self {
        StepScratch {
            cache_resident: Vec::with_capacity(n_routed),
            resident: Vec::with_capacity(n_routed),
            tiers: Vec::with_capacity(n_routed),
            host_wait: Vec::with_capacity(n_routed),
            assignment: Assignment::none(n_routed),
            cpu_timeline: Vec::with_capacity(n_routed),
            gpu_experts: Vec::with_capacity(n_routed),
            scores: Vec::with_capacity(n_routed),
            ranked: Vec::with_capacity(n_routed),
            swaps: Vec::with_capacity(n_routed),
            dev_resident: Vec::with_capacity(MAX_DEVICES * n_routed),
            dev_free: Vec::with_capacity(MAX_DEVICES),
        }
    }
}

/// The virtual-time step simulator, generic over a trace sink. The
/// default [`NullSink`] is statically disabled, so untraced users (every
/// pre-existing call site) pay nothing and compile unchanged.
pub struct StepSimulator<'a, S: TraceSink = NullSink> {
    cost: &'a CostModel,
    pub policy: PolicyBundle,
    /// Calibration activation frequencies per layer (EdgeMoE predictor) —
    /// borrowed, so sweeps replay thousands of times without cloning it.
    calib_freq: &'a [Vec<f64>],
    /// One copy/compute pipeline per GPU device tier (`gpus[0]` is the
    /// primary device that also runs attention, gating, shared experts and
    /// the head). Length == `n_devices`.
    gpus: Vec<GpuPipeline>,
    n_devices: usize,
    /// Inter-GPU P2P fabric: one FIFO lane shared by all device pairs.
    /// `p2p_run` is the start of the transfer occupying the lane at
    /// `p2p_free` (the rebase-residual anchor, mirroring the NVMe lanes).
    p2p_free: Ns,
    p2p_run: Ns,
    p2p_busy: Ns,
    p2p_bytes: u64,
    p2p_copies: u64,
    /// Demand uploads re-homed to their shard over the fabric (the
    /// simulator-side share of [`RunMetrics::p2p_migrations`]).
    p2p_rehomes: u64,
    now: Ns,
    pub metrics: RunMetrics,
    rng: DetRng,
    /// In-flight / arrived prefetch arrival times, flat `layer * n_routed
    /// + e` ([`NO_ARRIVAL`] = none) — replaces the seed's per-step
    /// `HashMap<(usize, usize), Ns>` churn.
    prefetch_arrival: Vec<Ns>,
    /// Device each in-flight prefetch targets (parallel to
    /// `prefetch_arrival`; meaningful only where that slot is set).
    prefetch_dev: Vec<u8>,
    decode_steps_done: usize,
    layers: usize,
    n_routed: usize,
    n_shared: usize,
    /// Last assignment per layer (exposed for breakdown experiments).
    pub last_assignments: Vec<Option<Assignment>>,
    /// Tiered GPU/host/NVMe expert store. `None` (and equivalently an
    /// unlimited store) reproduces the paper's two-tier behaviour exactly;
    /// a memory-limited store makes assignment tier-aware, turns cache
    /// evictions into demotions, and charges NVMe promotions.
    store: Option<TieredStore>,
    scratch: StepScratch,
    /// Steps retired so far (both phases) — the `StepEnd` event index and
    /// the step index every fault process is keyed on.
    steps_done: u64,
    /// Deterministic fault plan (`None` = healthy machine). Installed with
    /// [`StepSimulator::with_faults`]; a clean plan is bit-transparent.
    faults: Option<FaultPlan>,
    /// Pre-built degraded cost-model views, indexed
    /// `(gpu throttled) | (pcie degraded) << 1`. Built once when the plan
    /// is installed (`CostModel::degraded` clones, and the step loop must
    /// stay allocation-free), empty without an active non-clean plan.
    fault_costs: Vec<CostModel>,
    /// Pre-built CPU-shifted cost view for the overload ladder's top rung
    /// (`None` until [`Self::install_degraded_assign_view`]). Like
    /// `fault_costs`, built once so toggling it per step never allocates.
    degrade_cost: Option<Box<CostModel>>,
    /// Overload rung 3: price *assignment* through `degrade_cost` so
    /// Greedy sheds marginal experts CPU-ward; execution keeps true costs.
    degrade_assign: bool,
    /// Overload rung >= 2: skip predictive NVMe→host promote-ahead.
    promote_paused: bool,
    sink: S,
}

impl<'a> StepSimulator<'a> {
    pub fn new(
        cost: &'a CostModel,
        policy: PolicyBundle,
        calib_freq: &'a [Vec<f64>],
        layers: usize,
        n_routed: usize,
        n_shared: usize,
        seed: u64,
    ) -> Self {
        StepSimulator {
            cost,
            policy,
            calib_freq,
            gpus: vec![GpuPipeline::new()],
            n_devices: 1,
            p2p_free: 0,
            p2p_run: 0,
            p2p_busy: 0,
            p2p_bytes: 0,
            p2p_copies: 0,
            p2p_rehomes: 0,
            now: 0,
            metrics: RunMetrics::default(),
            rng: DetRng::new(seed ^ 0xda11),
            prefetch_arrival: vec![NO_ARRIVAL; layers * n_routed],
            prefetch_dev: vec![0; layers * n_routed],
            decode_steps_done: 0,
            layers,
            n_routed,
            n_shared,
            last_assignments: vec![None; layers],
            store: None,
            scratch: StepScratch::with_dims(n_routed),
            steps_done: 0,
            faults: None,
            fault_costs: Vec::new(),
            degrade_cost: None,
            degrade_assign: false,
            promote_paused: false,
            sink: NullSink,
        }
    }
}

impl<'a, S: TraceSink> StepSimulator<'a, S> {
    /// Replace the trace sink (typically on a freshly built simulator).
    /// Consumes `self` because the sink type is part of the simulator's
    /// type; all accumulated state carries over.
    pub fn with_sink<T: TraceSink>(self, sink: T) -> StepSimulator<'a, T> {
        StepSimulator {
            cost: self.cost,
            policy: self.policy,
            calib_freq: self.calib_freq,
            gpus: self.gpus,
            n_devices: self.n_devices,
            p2p_free: self.p2p_free,
            p2p_run: self.p2p_run,
            p2p_busy: self.p2p_busy,
            p2p_bytes: self.p2p_bytes,
            p2p_copies: self.p2p_copies,
            p2p_rehomes: self.p2p_rehomes,
            now: self.now,
            metrics: self.metrics,
            rng: self.rng,
            prefetch_arrival: self.prefetch_arrival,
            prefetch_dev: self.prefetch_dev,
            decode_steps_done: self.decode_steps_done,
            layers: self.layers,
            n_routed: self.n_routed,
            n_shared: self.n_shared,
            last_assignments: self.last_assignments,
            store: self.store,
            scratch: self.scratch,
            steps_done: self.steps_done,
            faults: self.faults,
            fault_costs: self.fault_costs,
            degrade_cost: self.degrade_cost,
            degrade_assign: self.degrade_assign,
            promote_paused: self.promote_paused,
            sink,
        }
    }

    /// Install a deterministic fault plan. Pre-builds the degraded
    /// cost-model views for the four `(GPU throttled) × (PCIe degraded)`
    /// combinations up front, so per-step selection in `run_step` is a
    /// slice index with no allocation, and propagates the plan to an
    /// already-attached store; [`Self::with_store`] propagates it the
    /// other way, so either installation order works. A clean plan is
    /// fully transparent: no views are built and every fault process is
    /// a no-op.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.fault_costs.clear();
        if !plan.is_clean() {
            let p = *plan.profile();
            for idx in 0..4usize {
                let gpu = if idx & 1 != 0 { p.gpu_mult } else { 1.0 };
                let pcie = if idx & 2 != 0 { p.pcie_mult } else { 1.0 };
                self.fault_costs.push(self.cost.degraded(gpu, pcie));
            }
        }
        if let Some(st) = self.store.as_mut() {
            st.set_faults(Some(plan));
        }
        self.faults = Some(plan);
        self
    }

    /// Attach a tiered expert store. The store's host floor is raised to
    /// the cache's total pinned capacity (GPU-resident experts keep a host
    /// staging copy), so the slot invariant holds for any cache policy, and
    /// the bundle's placement policy is installed on the store.
    pub fn with_store(mut self, mut store: TieredStore) -> Self {
        store.ensure_min_slots(self.policy.cache.capacity() * self.layers + 1);
        store.set_placement(self.policy.placement);
        store.set_n_devices(self.n_devices);
        if let Some(plan) = self.faults {
            store.set_faults(Some(plan));
        }
        self.store = Some(store);
        self
    }

    /// Shard the GPU tier across `n` expert-parallel devices
    /// (1..=[`MAX_DEVICES`]). Each routed expert `e` gets a *home* device
    /// `e % n` holding its cached copy; executing it elsewhere pays one
    /// P2P-fabric hop. `n = 1` is bit-identical to the pre-sharding
    /// simulator — every device formula degenerates to device 0.
    /// Propagates the device count to an attached store (and
    /// [`Self::with_store`] propagates the other way), so either
    /// installation order works.
    pub fn with_gpus(mut self, n: usize) -> Self {
        assert!(
            (1..=MAX_DEVICES).contains(&n),
            "device count {n} outside 1..={MAX_DEVICES}"
        );
        self.gpus.clear();
        self.gpus.resize_with(n, GpuPipeline::new);
        self.n_devices = n;
        if let Some(st) = self.store.as_mut() {
            st.set_n_devices(n);
        }
        self
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Home device of routed expert `e`: the shard whose cache holds its
    /// resident copy. Round-robin keeps every device's cached population
    /// within one expert of even, with no per-expert table to maintain.
    #[inline]
    fn home(&self, e: usize) -> usize {
        e % self.n_devices
    }

    /// Occupy the inter-GPU P2P fabric FIFO from `at` for `dur`; returns
    /// the transfer's end. Mirrors the store lanes' residual-carry
    /// bookkeeping so [`Self::reset_metrics`] can rebase it.
    fn schedule_p2p(&mut self, at: Ns, dur: Ns, bytes: u64) -> Ns {
        let start = at.max(self.p2p_free);
        self.p2p_run = start;
        self.p2p_free = start + dur;
        self.p2p_busy += dur;
        self.p2p_bytes += bytes;
        self.p2p_copies += 1;
        self.p2p_free
    }

    pub fn store(&self) -> Option<&TieredStore> {
        self.store.as_ref()
    }

    pub fn now(&self) -> Ns {
        self.now
    }

    /// Advance the virtual clock to `t` without doing work. No-op when `t`
    /// is in the past. The serving simulator uses this to idle across the
    /// gap to the next request arrival when the running batch is empty —
    /// `run_step` itself never moves the clock for an empty step.
    pub fn advance_to(&mut self, t: Ns) {
        self.now = self.now.max(t);
    }

    /// Emit a caller-composed event through the run's sink, so layers
    /// above the step loop (the serving simulator's request lifecycle) can
    /// join the same digest/JSONL stream as the scheduling events.
    pub fn note_event(&mut self, ev: Event) {
        if S::ENABLED {
            self.sink.emit(&ev);
        }
    }

    /// Pre-build the degraded (CPU-shifted) assignment cost view the
    /// overload ladder's top rung toggles. One clone up front — the same
    /// allocate-at-install discipline as the fault views — so
    /// [`Self::set_degraded_assign`] is free inside the tick loop.
    pub fn install_degraded_assign_view(&mut self, gpu_mult: f64, pcie_mult: f64) {
        self.degrade_cost = Some(Box::new(self.cost.degraded(gpu_mult, pcie_mult)));
    }

    /// Toggle overload rung 3: price assignment through the degraded view
    /// (no-op until [`Self::install_degraded_assign_view`]). Execution
    /// still runs at true costs — the view only biases the GPU-vs-CPU
    /// choice, never the modeled time of the chosen side.
    pub fn set_degraded_assign(&mut self, on: bool) {
        self.degrade_assign = on;
    }

    /// Toggle overload rung 2: pause predictive promote-ahead so the NVMe
    /// read lane serves demand traffic only.
    pub fn set_promote_paused(&mut self, paused: bool) {
        self.promote_paused = paused;
    }

    /// Host-RAM arrival for an execution-path access of (layer, e):
    /// counts the tier hit/miss and waits for (or issues) the promotion.
    /// Shared by the CPU-execution and GPU-demand-fetch paths so the tier
    /// counters can never diverge between them.
    fn exec_arrival(&mut self, l: usize, e: usize) -> Ns {
        let now = self.now;
        let cost = self.cost;
        match self.store.as_mut() {
            Some(st) => {
                if st.tier(l, e) == Tier::Disk {
                    self.metrics.tier_disk_misses += 1;
                } else {
                    self.metrics.tier_host_hits += 1;
                }
                st.host_arrival_t(l, e, now, cost, &mut self.sink)
            }
            None => {
                self.metrics.tier_host_hits += 1;
                now
            }
        }
    }

    /// Reset metrics but keep cache/prefetch state — used to measure the
    /// decode phase separately after a warm-up prefill, as the paper does.
    pub fn reset_metrics(&mut self) {
        let base = self.now;
        self.now = 0;
        for g in self.gpus.iter_mut() {
            *g = GpuPipeline::new();
        }
        // Rebase the simulator's P2P fabric lane like the store lanes:
        // the busy integral restarts at the residual of any copy still in
        // flight past the reset instant.
        self.p2p_busy = self.p2p_free.saturating_sub(self.p2p_run.max(base));
        self.p2p_free = self.p2p_free.saturating_sub(base);
        self.p2p_run = self.p2p_run.saturating_sub(base);
        self.p2p_bytes = 0;
        self.p2p_copies = 0;
        self.p2p_rehomes = 0;
        // re-base in-flight prefetch arrivals
        for v in self.prefetch_arrival.iter_mut() {
            if *v != NO_ARRIVAL {
                *v = v.saturating_sub(base);
            }
        }
        if let Some(st) = self.store.as_mut() {
            st.rebase_and_clear(base);
        }
        if S::ENABLED {
            self.sink.emit(&Event::Reset { at: base });
            // Carry events: re-seed each NVMe/transcode lane with the
            // residual of work still in flight at the reset (the store's
            // busy integrals were just rebased to exactly that residual),
            // so post-reset per-lane interval sums reconstruct the final
            // busy counters exactly — residual + every later duration is
            // precisely the integral `fold_pipeline` reports. The GPU
            // pipelines are recreated from scratch at reset, so their
            // lanes need no carry; the P2P fabric (simulator + store
            // halves) does, like the NVMe lanes.
            if self.p2p_busy > 0 {
                self.sink.emit(&Event::LaneBusy {
                    lane: Lane::P2p,
                    device: 0,
                    start: self.p2p_free - self.p2p_busy,
                    end: self.p2p_free,
                });
            }
            if let Some(st) = self.store.as_ref() {
                for (lane, busy, free) in [
                    (Lane::NvmeRead, st.xfer.read_busy, st.xfer.read_free_at()),
                    (Lane::NvmeWrite, st.xfer.write_busy, st.xfer.write_free_at()),
                    (Lane::Transcode, st.xfer.transcode_busy, st.xfer.transcode_free_at()),
                    (Lane::P2p, st.xfer.p2p_busy, st.xfer.p2p_free_at()),
                ] {
                    if busy > 0 {
                        self.sink.emit(&Event::LaneBusy {
                            lane,
                            device: 0,
                            start: free - busy,
                            end: free,
                        });
                    }
                }
            }
        }
        self.metrics = RunMetrics::default();
    }

    /// Advance one batch step (all MoE layers + attention + head).
    ///
    /// `kv_len` — average KV length during this step (attention cost).
    pub fn run_step(&mut self, step: &BatchStep, kv_len: usize, phase: Phase) {
        if step.tokens == 0 {
            return;
        }
        debug_assert_eq!(step.layers.len(), self.layers);
        // --- fault processes for this step ------------------------------
        // Pure functions of (plan seed, step index): select the degraded
        // cost-model view for any GPU-throttle / PCIe-degradation window
        // covering this step, and apply the RAM-pressure budget to the
        // store. The views were pre-built in `with_faults`, so selection
        // never allocates; the vec is taken out of `self` (like the
        // scratch) so the `cost` borrow can't fight `&mut self` below.
        let fault_costs = std::mem::take(&mut self.fault_costs);
        let (gpu_hot, pcie_hot) = match &self.faults {
            Some(plan) if !plan.is_clean() => (
                plan.gpu_mult(self.steps_done) > 1.0,
                plan.pcie_mult(self.steps_done) > 1.0,
            ),
            _ => (false, false),
        };
        let cost: &CostModel = if (gpu_hot || pcie_hot) && !fault_costs.is_empty() {
            &fault_costs[(gpu_hot as usize) | ((pcie_hot as usize) << 1)]
        } else {
            self.cost
        };
        // Per-device fault views: each GPU tier draws its throttle / PCIe
        // windows from a device-salted fault domain, so a 2-GPU box can
        // have one hot and one healthy device. Device 0's domain is the
        // base domain, so `dev_cost[0] == cost` and the single-GPU replay
        // is untouched. `any_*_hot` widens the step's degraded-time
        // attribution to "any device hot" (identical at one device).
        let mut dev_cost: [&CostModel; MAX_DEVICES] = [cost; MAX_DEVICES];
        let (mut any_gpu_hot, mut any_pcie_hot) = (gpu_hot, pcie_hot);
        if self.n_devices > 1 && !fault_costs.is_empty() {
            if let Some(plan) = &self.faults {
                for (d, slot) in dev_cost.iter_mut().enumerate().take(self.n_devices).skip(1) {
                    let g = plan.gpu_mult_dev(self.steps_done, d as u8) > 1.0;
                    let p = plan.pcie_mult_dev(self.steps_done, d as u8) > 1.0;
                    if g || p {
                        *slot = &fault_costs[(g as usize) | ((p as usize) << 1)];
                        any_gpu_hot |= g;
                        any_pcie_hot |= p;
                    }
                }
            }
        }
        // Overload rung 3 prices *assignment only* through the degraded
        // view (execution keeps `cost`): the GPU/PCIe sides look slower to
        // the solver, so Greedy sheds marginal experts CPU-ward without
        // the modeled time of any chosen side ever getting worse. A live
        // fault window takes precedence — its view is already CPU-shifted.
        let degrade_cost = std::mem::take(&mut self.degrade_cost);
        let assign_cost: &CostModel = match degrade_cost.as_deref() {
            Some(view) if self.degrade_assign && !(gpu_hot || pcie_hot) => view,
            _ => cost,
        };
        if self.faults.is_some() {
            if let Some(st) = self.store.as_mut() {
                st.apply_fault_step(self.steps_done, self.now, cost, &mut self.sink);
            }
        }
        let step_start = self.now;
        // Everything below prices through the selected view: degraded PCIe
        // stretches `trans` (demand, prefetch, and cache-update transfers
        // plus the spec-lane backlog gate), a throttled GPU stretches
        // attention, gating, expert kernels, and the head — and both feed
        // the assignment ctx, so Greedy reroutes marginal experts to the
        // CPU for exactly the steps a window covers. NVMe and CPU times
        // are identical in every view, so store promotions and the
        // `exec_arrival` path are unaffected by construction.
        let trans = cost.trans_time();
        let bytes = cost.expert_bytes() as u64;
        let n = self.n_routed;
        let nd = self.n_devices;
        let calib_freq = self.calib_freq;
        let mut scratch = std::mem::take(&mut self.scratch);
        let StepScratch {
            cache_resident,
            resident,
            tiers,
            host_wait,
            assignment,
            cpu_timeline,
            gpu_experts,
            scores,
            ranked,
            swaps,
            dev_resident,
            dev_free,
        } = &mut scratch;
        // Predictive placement is active only with a memory-limited store:
        // with unlimited host RAM there is nothing to promote or demote, and
        // gating here keeps the two-tier replay bit-identical to the seed.
        let placement_on = self.policy.placement.predictive
            && self.store.as_ref().map(|st| !st.is_unlimited()).unwrap_or(false);
        for l in 0..self.layers {
            let data = &step.layers[l];
            let layer_base = l * n;
            // --- attention + fixed overheads -------------------------------
            let attn = cost.attn_time(step.tokens, kv_len)
                + cost.layer_fixed()
                + self.policy.layer_overhead_ns;
            self.now += attn;
            self.metrics.attn_ns += attn;
            // --- gate -------------------------------------------------------
            let gate = cost.gate_time(step.tokens);
            self.now += gate;
            self.metrics.gate_ns += gate;

            // --- residency: cache ∪ prefetched ------------------------------
            // A prefetched expert counts as resident for assignment even if
            // its transfer is still in flight — the copy is already paid for
            // and overlapped; execution below waits for the actual arrival.
            self.policy.cache.resident_mask_into(l, cache_resident);
            // Reconcile the store with the cache's (seeded) initial resident
            // set once per layer — load-time placement, free of traffic.
            if let Some(st) = self.store.as_mut() {
                st.sync_layer(l, cache_resident);
            }
            resident.clone_from(cache_resident);
            for e in 0..n {
                if self.prefetch_arrival[layer_base + e] != NO_ARRIVAL {
                    resident[e] = true;
                }
            }

            // Wrong prefetches are not free: their weights occupy GPU
            // staging buffers until the layer retires, shrinking the Eq. 9
            // budget for demand fetches (the paper's "costly inaccurate
            // prefetches").
            let wasted_staging = (0..n)
                .filter(|&e| {
                    self.prefetch_arrival[layer_base + e] != NO_ARRIVAL
                        && data.workloads[e] == 0
                })
                .count();

            // Multi-device view: device-major residency (home-sharded cache
            // copies plus in-flight prefetches on their target device) and
            // per-device Eq. 9 staging budgets, each shrunk by that
            // device's own wasted prefetches. Built only when sharding is
            // on, so the single-GPU solve path stays byte-for-byte the
            // pre-refactor one.
            if nd > 1 {
                dev_resident.clear();
                dev_resident.resize(nd * n, false);
                dev_free.clear();
                dev_free.resize(nd, self.policy.gpu_free_slots);
                for e in 0..n {
                    let slot = layer_base + e;
                    if cache_resident[e] {
                        dev_resident[(e % nd) * n + e] = true;
                    }
                    if self.prefetch_arrival[slot] != NO_ARRIVAL {
                        let d = (self.prefetch_dev[slot] as usize).min(nd - 1);
                        dev_resident[d * n + e] = true;
                        if data.workloads[e] == 0 {
                            dev_free[d] = dev_free[d].saturating_sub(1);
                        }
                    }
                }
            }

            // --- assignment (modeled solve cost charged 1:1) ----------------
            let (tiers_snapshot, wait_snapshot): (Option<&[Tier]>, Option<&[Ns]>) =
                match self.store.as_ref() {
                    Some(st) => {
                        st.layer_tiers_into(l, tiers);
                        st.layer_host_wait_into(l, self.now, cost, host_wait);
                        (Some(tiers.as_slice()), Some(host_wait.as_slice()))
                    }
                    None => (None, None),
                };
            let ctx = AssignCtx {
                workloads: &data.workloads,
                resident,
                tiers: tiers_snapshot,
                host_wait: wait_snapshot,
                cost: assign_cost,
                gpu_free_slots: self.policy.gpu_free_slots.saturating_sub(wasted_staging),
                layer: l,
                layers: self.layers,
                devices: if nd > 1 {
                    Some(DeviceView {
                        n: nd,
                        resident: dev_resident.as_slice(),
                        free_slots: dev_free.as_slice(),
                    })
                } else {
                    None
                },
            };
            let solve = match self.policy.solve_cost {
                SolveCost::Modeled => {
                    self.policy.assigner.assign_into(&ctx, assignment);
                    self.policy.assigner.modeled_solve_ns(&ctx)
                }
                SolveCost::Measured => {
                    let wall = std::time::Instant::now();
                    self.policy.assigner.assign_into(&ctx, assignment);
                    wall.elapsed().as_nanos() as Ns
                }
            };
            // Single-device baselines leave `assignment.device` untouched;
            // pin their GPU picks onto the device lattice (caching device,
            // else round-robin home) so execution below is device-complete.
            if nd > 1 && !self.policy.assigner.device_aware() {
                assignment.align_devices(&ctx);
            }
            self.now += solve;
            self.metrics.sched_ns += solve;
            if S::ENABLED {
                // one Assign per non-idle expert, with the priced cost of
                // the chosen side (what the solver compared)
                for e in 0..n {
                    let w = data.workloads[e];
                    if w == 0 || (!assignment.to_gpu[e] && !assignment.to_cpu[e]) {
                        continue;
                    }
                    let gpu = assignment.to_gpu[e];
                    let cost_ns = if gpu {
                        assign_cost.t_gpu_compute(w as usize)
                    } else {
                        (assign_cost.t_cpu(w as usize) as f64 / self.policy.cpu_eff) as Ns
                    };
                    self.sink.emit(&Event::Assign {
                        layer: l as u32,
                        expert: e as u32,
                        gpu,
                        device: if gpu { assignment.device_of(e) } else { 0 },
                        workload: w,
                        cost_ns,
                    });
                }
            }

            // --- cache observation ------------------------------------------
            self.policy.cache.observe(l, &data.workloads, &data.gate_scores);
            // placement observation: decay + accumulate the EWMA workload
            // scores that rank host-tier demotion victims
            if let Some(st) = self.store.as_mut() {
                st.observe_workloads(l, &data.workloads);
            }

            // --- CPU side: Eq. 4 (tier-aware) -------------------------------
            // Disk-resident CPU experts stream in over the NVMe read stream
            // first; the CPU executes sequentially in arrival order, so
            // host-resident work overlaps in-flight promotions.
            let mut cpu_total: Ns = 0;
            cpu_timeline.clear();
            for e in 0..n {
                if !assignment.to_cpu[e] {
                    continue;
                }
                let t = cost.t_cpu(data.workloads[e] as usize);
                let dur = (t as f64 / self.policy.cpu_eff) as Ns;
                // waits for in-flight predictive promotions and promotes
                // on demand from disk
                let arrival = self.exec_arrival(l, e);
                cpu_timeline.push((arrival, dur));
                cpu_total += dur;
            }
            // equal-arrival order cannot change the fold below, so the
            // unstable sort stays deterministic
            cpu_timeline.sort_unstable_by_key(|&(a, _)| a);
            let mut cpu_end = self.now;
            for &(arrival, dur) in cpu_timeline.iter() {
                let start = cpu_end.max(arrival);
                cpu_end = start + dur;
                if S::ENABLED {
                    self.sink.emit(&Event::LaneBusy {
                        lane: Lane::Cpu,
                        device: 0,
                        start,
                        end: cpu_end,
                    });
                }
            }
            self.metrics.moe_cpu_busy_ns += cpu_total;

            // --- GPU side: copy/compute pipeline per device tier ------------
            let gpu_busy0: Ns = self.gpus.iter().map(|g| g.compute_busy).sum();
            // resident experts first (no copy), then by descending workload
            // (index tiebreak keeps the order deterministic)
            gpu_experts.clear();
            gpu_experts.extend((0..n).filter(|&e| assignment.to_gpu[e]));
            gpu_experts.sort_unstable_by_key(|&e| {
                (if resident[e] { 0 } else { 1 }, std::cmp::Reverse(data.workloads[e]), e)
            });
            for &e in gpu_experts.iter() {
                let w = data.workloads[e] as usize;
                let d = (assignment.device_of(e) as usize).min(nd - 1);
                let compute = dev_cost[d].t_gpu_compute(w);
                self.metrics.cache_lookups += 1;
                let arr = self.prefetch_arrival[layer_base + e];
                if cache_resident[e] {
                    let hd = e % nd;
                    self.metrics.cache_hits += 1;
                    self.metrics.tier_gpu_hits += 1;
                    self.metrics.dev_cache_hits[hd] += 1;
                    // off-home execution reads the cached copy over the P2P
                    // fabric first; the home copy stays put
                    let mut start = self.now;
                    if d != hd {
                        let p2p = cost.p2p_time();
                        let p_end = self.schedule_p2p(start, p2p, bytes);
                        if S::ENABLED {
                            self.sink.emit(&Event::P2pCopy {
                                layer: l as u32,
                                expert: e as u32,
                                from: hd as u8,
                                to: d as u8,
                                start: p_end - p2p,
                                end: p_end,
                            });
                            self.sink.emit(&Event::LaneBusy {
                                lane: Lane::P2p,
                                device: 0,
                                start: p_end - p2p,
                                end: p_end,
                            });
                        }
                        start = p_end;
                    }
                    let out = self.gpus[d].schedule_expert(start, 0, 0, compute);
                    if S::ENABLED {
                        self.sink.emit(&Event::LaneBusy {
                            lane: Lane::GpuCompute,
                            device: d as u8,
                            start: out.compute_end - compute,
                            end: out.compute_end,
                        });
                    }
                    let evicted = self.policy.cache.on_gpu_use(l, e, false);
                    if S::ENABLED {
                        if let Some(v) = evicted {
                            self.sink.emit(&Event::CacheEvict {
                                layer: l as u32,
                                expert: v as u32,
                                device: (v % nd) as u8,
                            });
                        }
                    }
                    if let Some(st) = self.store.as_mut() {
                        st.touch(l, e);
                        if let Some(v) = evicted {
                            st.demote_gpu(l, v);
                        }
                    }
                } else if arr != NO_ARRIVAL {
                    // prefetched: wait for arrival if still in flight, no
                    // new PCIe transfer; a cross-device pick adds a P2P hop
                    self.metrics.tier_gpu_hits += 1;
                    let pd = (self.prefetch_dev[layer_base + e] as usize).min(nd - 1);
                    let mut start = arr.max(self.now);
                    if d != pd {
                        let p2p = cost.p2p_time();
                        let p_end = self.schedule_p2p(start, p2p, bytes);
                        if S::ENABLED {
                            self.sink.emit(&Event::P2pCopy {
                                layer: l as u32,
                                expert: e as u32,
                                from: pd as u8,
                                to: d as u8,
                                start: p_end - p2p,
                                end: p_end,
                            });
                            self.sink.emit(&Event::LaneBusy {
                                lane: Lane::P2p,
                                device: 0,
                                start: p_end - p2p,
                                end: p_end,
                            });
                        }
                        start = p_end;
                    }
                    let out = self.gpus[d].schedule_expert(start, 0, 0, compute);
                    if S::ENABLED {
                        self.sink.emit(&Event::LaneBusy {
                            lane: Lane::GpuCompute,
                            device: d as u8,
                            start: out.compute_end - compute,
                            end: out.compute_end,
                        });
                    }
                    if let Some(st) = self.store.as_mut() {
                        st.touch(l, e);
                    }
                } else {
                    // demand fetch: disk-resident experts promote over NVMe
                    // first (or join an in-flight predictive promotion),
                    // then the PCIe upload starts at arrival — on the
                    // executing device's own PCIe lane and fault view.
                    let ready = self.exec_arrival(l, e);
                    let trans_d = dev_cost[d].trans_time();
                    let out = self.gpus[d].schedule_expert(ready, trans_d, bytes, compute);
                    if S::ENABLED {
                        if trans_d > 0 {
                            self.sink.emit(&Event::LaneBusy {
                                lane: Lane::PcieDemand,
                                device: d as u8,
                                start: out.copy_end - trans_d,
                                end: out.copy_end,
                            });
                        }
                        self.sink.emit(&Event::LaneBusy {
                            lane: Lane::GpuCompute,
                            device: d as u8,
                            start: out.compute_end - compute,
                            end: out.compute_end,
                        });
                    }
                    let evicted = self.policy.cache.on_gpu_use(l, e, true);
                    if S::ENABLED {
                        if let Some(v) = evicted {
                            self.sink.emit(&Event::CacheEvict {
                                layer: l as u32,
                                expert: v as u32,
                                device: (v % nd) as u8,
                            });
                            self.sink.emit(&Event::CacheAdmit {
                                layer: l as u32,
                                expert: e as u32,
                                device: (e % nd) as u8,
                            });
                        }
                    }
                    if let Some(st) = self.store.as_mut() {
                        if let Some(v) = evicted {
                            // the cache admitted the fetched expert: fold the
                            // replacement into the store (evict → demotion).
                            st.demote_gpu(l, v);
                            st.admit_to_gpu(l, e);
                        }
                    }
                    // The upload landed on the executing device; an admitted
                    // expert's cached copy belongs on its home shard, so
                    // re-home it over the fabric off the critical path (the
                    // kernel already runs from the landed copy).
                    if nd > 1 && evicted.is_some() && d != e % nd {
                        let p2p = cost.p2p_time();
                        let p_end = self.schedule_p2p(out.copy_end, p2p, bytes);
                        self.p2p_rehomes += 1;
                        if S::ENABLED {
                            self.sink.emit(&Event::P2pCopy {
                                layer: l as u32,
                                expert: e as u32,
                                from: d as u8,
                                to: (e % nd) as u8,
                                start: p_end - p2p,
                                end: p_end,
                            });
                            self.sink.emit(&Event::LaneBusy {
                                lane: Lane::P2p,
                                device: 0,
                                start: p_end - p2p,
                                end: p_end,
                            });
                        }
                    }
                }
            }
            // shared experts always run on GPU on the full token batch —
            // replicated on the primary device, which also owns attention
            for _s in 0..self.n_shared {
                let compute = cost.t_gpu_compute(step.tokens);
                let out = self.gpus[0].schedule_expert(self.now, 0, 0, compute);
                if S::ENABLED {
                    self.sink.emit(&Event::LaneBusy {
                        lane: Lane::GpuCompute,
                        device: 0,
                        start: out.compute_end - compute,
                        end: out.compute_end,
                    });
                }
            }

            // --- prefetch accounting for this layer's arrivals --------------
            for e in 0..n {
                let slot = &mut self.prefetch_arrival[layer_base + e];
                if *slot != NO_ARRIVAL {
                    *slot = NO_ARRIVAL;
                    if assignment.to_gpu[e] && data.workloads[e] > 0 {
                        self.metrics.prefetch_useful += 1;
                        if S::ENABLED {
                            self.sink
                                .emit(&Event::PrefetchHit { layer: l as u32, expert: e as u32 });
                        }
                    } else if S::ENABLED && data.workloads[e] == 0 {
                        // staged for nothing: the wrong-prediction case the
                        // paper calls "costly inaccurate prefetches"
                        self.sink
                            .emit(&Event::PrefetchWasted { layer: l as u32, expert: e as u32 });
                    }
                }
            }

            // The layer barrier waits only for this layer's expert kernels
            // (on every device); the prefetch work below runs on a separate
            // CUDA work stream (paper Fig. 9) and overlaps the *next* layer.
            let gpu_end_experts = self
                .gpus
                .iter()
                .map(|g| g.compute_free_at())
                .max()
                .unwrap_or(0)
                .max(self.now);

            // --- issue prefetches + placement for layer l+1 ------------------
            if l + 1 < self.layers && (self.policy.prefetch_size > 0 || placement_on) {
                let mut ready = self.now;
                if self.policy.prefetcher.needs_gate_pass() {
                    // prediction gating runs on the GPU work stream: costs a
                    // gate pass + a stream switch (paper §6.3-4). It contends
                    // for SMs (scheduled on the compute stream, delaying the
                    // *next* layer's kernels) but is not part of this layer's
                    // barrier.
                    let pred_cost = cost.gate_time(step.tokens) + cost.layer_fixed();
                    let out = self.gpus[0].schedule_expert(self.now, 0, 0, pred_cost);
                    self.metrics.prefetch_gate_ns += pred_cost;
                    if S::ENABLED {
                        self.sink.emit(&Event::LaneBusy {
                            lane: Lane::GpuCompute,
                            device: 0,
                            start: out.compute_end - pred_cost,
                            end: out.compute_end,
                        });
                    }
                    ready = out.compute_end;
                }
                let true_next = step.layers.get(l + 1).map(|d| d.workloads.as_slice());
                self.policy.prefetcher.predict_into(
                    &mut PrefetchCtx {
                        pred_raw: &data.pred_raw,
                        pred_res: &data.pred_res,
                        cur_workloads: &data.workloads,
                        true_next,
                        calib_freq_next: &calib_freq[l + 1],
                        rng: &mut self.rng,
                    },
                    scores,
                );
                top_n_into(scores, n, ranked);
                // feed the fresh predictions into the placement demotion
                // score table before any spill decision this layer
                if placement_on {
                    if let Some(st) = self.store.as_mut() {
                        st.note_predictions(l + 1, scores);
                    }
                }
                let next_base = (l + 1) * n;
                let mut issued = 0;
                for &e in ranked.iter() {
                    if issued == self.policy.prefetch_size {
                        break;
                    }
                    if scores[e] <= 0.0 {
                        break; // nothing predicted there
                    }
                    // Each prefetch lands on the least-backlogged spec lane
                    // (lowest index wins ties — device 0 at one device, so
                    // the single-GPU stream order is untouched). Transfers
                    // price through the target device's own fault view.
                    let mut dstar = 0usize;
                    for dd in 1..nd {
                        if self.gpus[dd].spec_free_at() < self.gpus[dstar].spec_free_at() {
                            dstar = dd;
                        }
                    }
                    let trans_p = dev_cost[dstar].trans_time();
                    // Speculative transfers are issued only while they can
                    // still plausibly arrive in time to matter: cap the
                    // low-priority lane's backlog at a few transfers.
                    if self.gpus[dstar].spec_free_at() > ready + 4 * trans_p {
                        break;
                    }
                    if self.policy.cache.is_resident(l + 1, e)
                        || self.prefetch_arrival[next_base + e] != NO_ARRIVAL
                    {
                        continue;
                    }
                    // a disk-resident (or still-arriving) prefetch target
                    // chains its host arrival → PCIe; the read is
                    // speculative, not demand-path
                    let mut pcie_ready = ready;
                    if let Some(st) = self.store.as_mut() {
                        if st.tier(l + 1, e) == Tier::Disk || st.pending(l + 1, e, ready) {
                            pcie_ready = st
                                .host_arrival_spec_t(l + 1, e, ready, cost, &mut self.sink)
                                .max(ready);
                        }
                    }
                    let arr = self.gpus[dstar].schedule_transfer(
                        pcie_ready,
                        trans_p,
                        bytes,
                        TransferKind::Prefetch,
                    );
                    self.prefetch_arrival[next_base + e] = arr;
                    self.prefetch_dev[next_base + e] = dstar as u8;
                    self.metrics.prefetch_issued += 1;
                    if S::ENABLED {
                        self.sink.emit(&Event::PrefetchIssue {
                            layer: (l + 1) as u32,
                            expert: e as u32,
                            device: dstar as u8,
                            arrival: arr,
                        });
                        if trans_p > 0 {
                            self.sink.emit(&Event::LaneBusy {
                                lane: Lane::PcieSpec,
                                device: dstar as u8,
                                start: arr - trans_p,
                                end: arr,
                            });
                        }
                    }
                    issued += 1;
                }
                // Predictive placement: NVMe→host promotions for layer l+1
                // on the dedicated read stream, decoupled from the PCIe
                // spec lane — issued AFTER the prefetch loop, so the budget
                // goes to experts beyond the prefetch window (targets the
                // lane just fetched are host-resident by now and skipped)
                // and a promotion can only be consumed in a later instant,
                // with genuinely hidden NVMe time.
                if placement_on && !self.promote_paused {
                    if let Some(st) = self.store.as_mut() {
                        placement::promote_ahead_layer_t(
                            st,
                            l + 1,
                            ranked,
                            scores,
                            ready,
                            cost,
                            &mut self.sink,
                        );
                    }
                }
            }

            // --- layer barrier: CPU and GPU compute must finish --------------
            let gpu_end = gpu_end_experts;
            let end = cpu_end.max(gpu_end);
            self.metrics.moe_ns += end - self.now;
            let gpu_busy1: Ns = self.gpus.iter().map(|g| g.compute_busy).sum();
            self.metrics.moe_gpu_busy_ns += gpu_busy1 - gpu_busy0;
            self.now = end;

            // --- cache window replacement (decode only) ----------------------
            // With a tiered store, the eviction is a demotion into the store
            // (not a drop), and loading a disk-resident expert chains an
            // NVMe promotion before its PCIe upload.
            if phase == Phase::Decode {
                swaps.clear();
                self.policy.cache.window_tick_into(l, self.decode_steps_done + 1, swaps);
                for swap in swaps.iter() {
                    let mut ready = self.now;
                    let now = self.now;
                    // the replacement uploads straight to the loaded
                    // expert's home shard, over that device's PCIe lane
                    let hd = swap.load % nd;
                    let trans_h = dev_cost[hd].trans_time();
                    if S::ENABLED {
                        self.sink.emit(&Event::CacheEvict {
                            layer: l as u32,
                            expert: swap.evict as u32,
                            device: (swap.evict % nd) as u8,
                        });
                        self.sink.emit(&Event::CacheAdmit {
                            layer: l as u32,
                            expert: swap.load as u32,
                            device: hd as u8,
                        });
                    }
                    if let Some(st) = self.store.as_mut() {
                        st.demote_gpu(l, swap.evict);
                        if st.tier(l, swap.load) == Tier::Disk || st.pending(l, swap.load, now) {
                            // cache-update traffic: speculative, not demand
                            ready = st.host_arrival_spec_t(l, swap.load, now, cost, &mut self.sink);
                        }
                        st.admit_to_gpu(l, swap.load);
                    }
                    let arr = self.gpus[hd].schedule_transfer(
                        ready,
                        trans_h,
                        bytes,
                        TransferKind::CacheUpdate,
                    );
                    if S::ENABLED && trans_h > 0 {
                        self.sink.emit(&Event::LaneBusy {
                            lane: Lane::PcieSpec,
                            device: hd as u8,
                            start: arr - trans_h,
                            end: arr,
                        });
                    }
                }
            }
            match &mut self.last_assignments[l] {
                Some(a) => a.copy_from(assignment),
                slot => *slot = Some(assignment.clone()),
            }
        }
        self.scratch = scratch;
        // --- LM head ----------------------------------------------------------
        let head = cost.head_time(step.tokens);
        self.now += head;
        self.metrics.attn_ns += head;

        // attribute the step's span to any fault window that covered it on
        // any device (== the base-domain window at one device)
        if any_gpu_hot {
            self.metrics.degraded_gpu_ns += self.now - step_start;
        }
        if any_pcie_hot {
            self.metrics.degraded_pcie_ns += self.now - step_start;
        }
        self.fault_costs = fault_costs;
        self.degrade_cost = degrade_cost;

        match phase {
            Phase::Prefill => self.metrics.tokens_in += step.tokens as u64,
            Phase::Decode => {
                self.metrics.tokens_out += step.tokens as u64;
                self.decode_steps_done += 1;
            }
        }
        self.metrics.layer_steps += self.layers as u64;
        if S::ENABLED {
            self.sink.emit(&Event::StepEnd {
                step: self.steps_done,
                decode: phase == Phase::Decode,
                end_ns: self.now,
                tokens: step.tokens as u32,
            });
        }
        self.steps_done += 1;
    }

    /// Fold pipeline counters and close out.
    pub fn finish(mut self) -> RunMetrics {
        self.fold_pipeline();
        self.metrics
    }

    /// [`Self::finish`], also handing the sink back (to flush a JSON sink
    /// or read a digest's event count).
    pub fn finish_with_sink(mut self) -> (RunMetrics, S) {
        self.fold_pipeline();
        (self.metrics, self.sink)
    }

    /// Fold pipeline counters without consuming (for phase-split metrics).
    pub fn fold_pipeline(&mut self) {
        self.metrics.total_ns = self.now;
        self.metrics.stall_ns = self.gpus.iter().map(|g| g.stall).sum();
        // Fig. 5 metric: transfer time on the demand (critical) path,
        // summed over every device's own PCIe lane.
        self.metrics.pcie_busy_ns = self.gpus.iter().map(|g| g.copy_busy_demand).sum();
        self.metrics.pcie_demand_bytes = self.gpus.iter().map(|g| g.bytes_demand).sum();
        self.metrics.pcie_prefetch_bytes = self.gpus.iter().map(|g| g.bytes_prefetch).sum();
        self.metrics.pcie_cache_bytes = self.gpus.iter().map(|g| g.bytes_cache).sum();
        for (d, g) in self.gpus.iter().enumerate() {
            self.metrics.dev_compute_busy_ns[d] = g.compute_busy;
            self.metrics.dev_copy_busy_ns[d] = g.copy_busy;
        }
        // P2P fabric: the simulator's execution-path hops plus the store's
        // placement migrations share one lane but keep separate schedulers
        // (the store's is rebased with its NVMe lanes).
        self.metrics.p2p_busy_ns = self.p2p_busy;
        self.metrics.p2p_bytes = self.p2p_bytes;
        self.metrics.p2p_copies = self.p2p_copies;
        self.metrics.p2p_migrations = self.p2p_rehomes;
        if let Some(st) = &self.store {
            self.metrics.p2p_busy_ns = self.p2p_busy + st.xfer.p2p_busy;
            self.metrics.p2p_bytes = self.p2p_bytes + st.xfer.p2p_bytes;
            self.metrics.p2p_copies = self.p2p_copies + st.xfer.p2p_copies;
            self.metrics.p2p_migrations = self.p2p_rehomes + st.p2p_migrations;
            self.metrics.nvme_read_ns = st.xfer.read_busy;
            self.metrics.nvme_write_ns = st.xfer.write_busy;
            self.metrics.nvme_read_bytes = st.xfer.read_bytes;
            self.metrics.nvme_write_bytes = st.xfer.write_bytes;
            self.metrics.store_promotions = st.promotions;
            self.metrics.store_spills = st.spills;
            self.metrics.store_gpu_demotions = st.gpu_demotions;
            self.metrics.store_promote_ahead = st.ahead_issued;
            self.metrics.promote_ahead_hits = st.ahead_hits;
            self.metrics.promote_ahead_misses = st.ahead_misses;
            self.metrics.nvme_demand_ns = st.demand_read_ns;
            self.metrics.nvme_overlap_hidden_ns = st.overlap_hidden_ns;
            self.metrics.transcode_ns = st.xfer.transcode_busy;
            self.metrics.disk_bytes_saved = st.bytes_saved;
            self.metrics.fault_retries = st.fault_retries;
            self.metrics.fault_aborts = st.fault_aborts;
            self.metrics.fault_stall_ns = st.fault_stall_ns;
            self.metrics.ram_pressure_events = st.ram_pressure_events;
            self.metrics.ram_pressure_spills = st.ram_pressure_spills;
        }
        // None under the default NullSink — keeps untraced metric equality
        // (e.g. the unlimited-store transparency tests) exactly as before.
        self.metrics.trace_digest = self.sink.digest();
    }
}

/// Replay a composed decode run over a trace: warm-up prefill (state only),
/// then `steps` decode steps with metrics. Returns the decode-phase metrics.
#[allow(clippy::too_many_arguments)]
pub fn replay_decode(
    trace: &Trace,
    seq_ids: &[usize],
    steps: usize,
    cost: &CostModel,
    policy: PolicyBundle,
    calib_freq: &[Vec<f64>],
    n_shared: usize,
    seed: u64,
) -> RunMetrics {
    replay_decode_store(trace, seq_ids, steps, cost, policy, calib_freq, n_shared, seed, None)
}

/// [`replay_decode`] with an optional tiered expert store attached — the
/// memory-limited presets route through this.
#[allow(clippy::too_many_arguments)]
pub fn replay_decode_store(
    trace: &Trace,
    seq_ids: &[usize],
    steps: usize,
    cost: &CostModel,
    policy: PolicyBundle,
    calib_freq: &[Vec<f64>],
    n_shared: usize,
    seed: u64,
    store: Option<TieredStore>,
) -> RunMetrics {
    replay_decode_traced(
        trace, seq_ids, steps, cost, policy, calib_freq, n_shared, seed, store, NullSink,
    )
    .0
}

/// [`replay_decode_store`] with a trace sink attached: every scheduling
/// decision of the decode phase (plus the warm-up reset boundary) streams
/// into `sink`, which is returned alongside the metrics so callers can
/// flush a JSON sink or read a digest. With [`NullSink`] this is exactly
/// `replay_decode_store`.
#[allow(clippy::too_many_arguments)]
pub fn replay_decode_traced<S: TraceSink>(
    trace: &Trace,
    seq_ids: &[usize],
    steps: usize,
    cost: &CostModel,
    policy: PolicyBundle,
    calib_freq: &[Vec<f64>],
    n_shared: usize,
    seed: u64,
    store: Option<TieredStore>,
    sink: S,
) -> (RunMetrics, S) {
    replay_decode_faulted(
        trace, seq_ids, steps, cost, policy, calib_freq, n_shared, seed, None, store, sink,
    )
}

/// [`replay_decode_traced`] with a deterministic fault plan installed:
/// NVMe retry storms, PCIe/GPU degradation windows, and mid-run
/// RAM-pressure budget shrinks all replay bit-identically for a fixed
/// `(plan seed, profile)` — `dali run --faults`, the bench faulted tier,
/// and the chaos suite route through here. `faults: None` (or a clean
/// plan) is exactly `replay_decode_traced`. Fault step indices count
/// both phases, so the warm-up prefill consumes step 0 and decode step
/// `s` sees fault step `s + 1`.
#[allow(clippy::too_many_arguments)]
pub fn replay_decode_faulted<S: TraceSink>(
    trace: &Trace,
    seq_ids: &[usize],
    steps: usize,
    cost: &CostModel,
    policy: PolicyBundle,
    calib_freq: &[Vec<f64>],
    n_shared: usize,
    seed: u64,
    faults: Option<FaultPlan>,
    store: Option<TieredStore>,
    sink: S,
) -> (RunMetrics, S) {
    replay_decode_gpus(
        trace, seq_ids, steps, cost, policy, calib_freq, n_shared, seed, 1, faults, store, sink,
    )
}

/// [`replay_decode_faulted`] generalized to `n_gpus` expert-parallel
/// device tiers (1..=[`MAX_DEVICES`]). Experts shard round-robin across
/// devices (`home(e) = e % n_gpus`); each device has its own PCIe lanes,
/// compute pipeline, staging budget, and fault domains, joined by one
/// inter-GPU P2P fabric lane. `n_gpus = 1` is exactly
/// [`replay_decode_faulted`] — bit-identical metrics and trace digest.
#[allow(clippy::too_many_arguments)]
pub fn replay_decode_gpus<S: TraceSink>(
    trace: &Trace,
    seq_ids: &[usize],
    steps: usize,
    cost: &CostModel,
    policy: PolicyBundle,
    calib_freq: &[Vec<f64>],
    n_shared: usize,
    seed: u64,
    n_gpus: usize,
    faults: Option<FaultPlan>,
    store: Option<TieredStore>,
    sink: S,
) -> (RunMetrics, S) {
    let mut sim = StepSimulator::new(
        cost,
        policy,
        calib_freq,
        trace.layers,
        trace.n_routed,
        n_shared,
        seed,
    )
    .with_gpus(n_gpus)
    .with_sink(sink);
    if let Some(plan) = faults {
        sim = sim.with_faults(plan);
    }
    if let Some(st) = store {
        sim = sim.with_store(st);
    }
    let prompt_len = trace.seqs[seq_ids[0] % trace.seqs.len()].prompt_len;
    let mut step = BatchStep::default();
    trace.compose_prefill_into(seq_ids, &mut step);
    sim.run_step(&step, prompt_len / 2, Phase::Prefill);
    sim.reset_metrics();
    let max_steps = steps.min(trace.min_steps());
    for s in 0..max_steps {
        trace.compose_decode_into(seq_ids, s, &mut step);
        sim.run_step(&step, prompt_len + s, Phase::Decode);
    }
    sim.finish_with_sink()
}

/// Replay the prefill phase only.
#[allow(clippy::too_many_arguments)]
pub fn replay_prefill(
    trace: &Trace,
    seq_ids: &[usize],
    cost: &CostModel,
    policy: PolicyBundle,
    calib_freq: &[Vec<f64>],
    n_shared: usize,
    seed: u64,
) -> RunMetrics {
    let mut sim = StepSimulator::new(
        cost,
        policy,
        calib_freq,
        trace.layers,
        trace.n_routed,
        n_shared,
        seed,
    );
    let prompt_len = trace.seqs[seq_ids[0] % trace.seqs.len()].prompt_len;
    let prefill = trace.compose_prefill(seq_ids);
    sim.run_step(&prefill, prompt_len / 2, Phase::Prefill);
    let mut m = sim.finish();
    // prefill "speed" counts prompt tokens processed
    m.tokens_out = m.tokens_in;
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Presets;
    use crate::coordinator::assignment::{AllCpuAssigner, GreedyAssigner};
    use crate::coordinator::cache::{NoCache, WorkloadAwareCache};
    use crate::coordinator::prefetch::{NoPrefetcher, ResidualPrefetcher};
    use crate::workload::trace::{LayerStepData, Trace};

    fn cost() -> CostModel {
        let p = Presets::load_default().unwrap();
        CostModel::new(p.model("mixtral-sim").unwrap(), p.hw("local-pc").unwrap())
    }

    fn freq(layers: usize, n: usize) -> Vec<Vec<f64>> {
        vec![vec![0.0; n]; layers]
    }

    fn mk_step(layers: usize, n: usize, w: &[u32]) -> BatchStep {
        BatchStep {
            tokens: w.iter().sum::<u32>() as usize / 2,
            layers: (0..layers)
                .map(|_| LayerStepData {
                    workloads: w.to_vec(),
                    gate_scores: w.iter().map(|&x| x as f32 * 0.4).collect(),
                    pred_raw: w.to_vec(),
                    pred_res: w.to_vec(),
                })
                .collect(),
        }
        .tap(|s| debug_assert_eq!(s.layers[0].workloads.len(), n))
    }

    trait Tap: Sized {
        fn tap(self, f: impl FnOnce(&Self)) -> Self {
            f(&self);
            self
        }
    }
    impl<T> Tap for T {}

    fn bundle(prefetch: bool, cache: bool) -> PolicyBundle {
        PolicyBundle {
            assigner: Box::new(GreedyAssigner::new()),
            prefetcher: if prefetch {
                Box::new(ResidualPrefetcher)
            } else {
                Box::new(NoPrefetcher)
            },
            cache: if cache {
                Box::new(WorkloadAwareCache::new(4, 8, 2, 4, 1, 1))
            } else {
                Box::new(NoCache::new(4, 8))
            },
            prefetch_size: if prefetch { 1 } else { 0 },
            cpu_eff: 1.0,
            layer_overhead_ns: 0,
            gpu_free_slots: 8,
            solve_cost: SolveCost::Modeled,
            placement: PlacementCfg::default(),
        }
    }

    #[test]
    fn time_advances_and_tokens_counted() {
        let c = cost();
        let f = freq(4, 8);
        let mut sim = StepSimulator::new(&c, bundle(false, false), &f, 4, 8, 0, 1);
        let step = mk_step(4, 8, &[2, 0, 1, 3, 0, 0, 1, 1]);
        sim.run_step(&step, 16, Phase::Decode);
        let m = sim.finish();
        assert!(m.total_ns > 0);
        assert_eq!(m.tokens_out, 4);
        assert_eq!(m.layer_steps, 4);
        assert!(m.moe_ns > 0);
        assert!(m.sched_ns > 0);
    }

    #[test]
    fn empty_step_is_noop() {
        let c = cost();
        let f = freq(4, 8);
        let mut sim = StepSimulator::new(&c, bundle(false, false), &f, 4, 8, 0, 1);
        sim.run_step(&BatchStep { tokens: 0, layers: vec![] }, 4, Phase::Decode);
        assert_eq!(sim.finish().total_ns, 0);
    }

    #[test]
    fn modeled_solve_cost_is_bit_deterministic() {
        // The acceptance criterion: identical seeds → bit-identical
        // RunMetrics, which the seed's wall-clock `Instant` charging broke.
        let c = cost();
        let f = freq(4, 8);
        let run = || {
            let mut sim = StepSimulator::new(&c, bundle(true, true), &f, 4, 8, 1, 9);
            for i in 0..24 {
                let w = [8u32, (i % 3) as u32, 8, 0, 2, 0, 1, i as u32 % 5];
                sim.run_step(&mk_step(4, 8, &w), 16 + i, Phase::Decode);
            }
            sim.finish()
        };
        assert_eq!(run(), run(), "identical seeds must give identical metrics");
    }

    #[test]
    fn measured_solve_cost_still_charges_time() {
        let c = cost();
        let f = freq(4, 8);
        let mut policy = bundle(false, false);
        policy.solve_cost = SolveCost::Measured;
        let mut sim = StepSimulator::new(&c, policy, &f, 4, 8, 0, 1);
        for _ in 0..8 {
            sim.run_step(&mk_step(4, 8, &[4, 4, 4, 4, 0, 0, 0, 0]), 8, Phase::Decode);
        }
        let m = sim.finish();
        assert!(m.sched_ns > 0, "wall-clock mode must charge some solve time");
    }

    #[test]
    fn cache_reduces_demand_traffic() {
        let c = cost();
        let f = freq(4, 8);
        let w = [8u32, 8, 8, 8, 0, 0, 0, 0];
        let run = |cache| {
            let mut sim = StepSimulator::new(&c, bundle(false, cache), &f, 4, 8, 0, 1);
            for _ in 0..16 {
                sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
            }
            sim.finish()
        };
        let without = run(false);
        let with = run(true);
        assert!(with.cache_hits > 0, "stable hot set must produce hits");
        assert!(
            with.pcie_demand_bytes < without.pcie_demand_bytes,
            "cache must cut demand transfers: {} vs {}",
            with.pcie_demand_bytes,
            without.pcie_demand_bytes
        );
        assert!(with.total_ns < without.total_ns);
    }

    #[test]
    fn perfect_prefetch_counts_useful() {
        let c = cost();
        let f = freq(4, 8);
        // workloads identical across layers, so pred == truth → useful
        let mut sim = StepSimulator::new(&c, bundle(true, false), &f, 4, 8, 0, 1);
        for _ in 0..8 {
            sim.run_step(&mk_step(4, 8, &[16, 0, 0, 0, 0, 0, 0, 0]), 16, Phase::Decode);
        }
        let m = sim.finish();
        assert!(m.prefetch_issued > 0);
        assert!(m.prefetch_useful > 0);
        assert!(m.prefetch_gate_ns > 0, "residual prediction costs gate passes");
        assert!(m.pcie_prefetch_bytes > 0);
    }

    #[test]
    fn all_cpu_never_touches_pcie() {
        let c = cost();
        let f = freq(4, 8);
        let policy = PolicyBundle {
            assigner: Box::new(AllCpuAssigner::new()),
            prefetcher: Box::new(NoPrefetcher),
            cache: Box::new(NoCache::new(4, 8)),
            prefetch_size: 0,
            cpu_eff: 1.0,
            layer_overhead_ns: 0,
            gpu_free_slots: 8,
            solve_cost: SolveCost::Modeled,
            placement: PlacementCfg::default(),
        };
        let mut sim = StepSimulator::new(&c, policy, &f, 4, 8, 0, 1);
        for _ in 0..4 {
            sim.run_step(&mk_step(4, 8, &[4, 4, 4, 4, 0, 0, 0, 0]), 8, Phase::Decode);
        }
        let m = sim.finish();
        assert_eq!(m.pcie_demand_bytes, 0);
        assert_eq!(m.cache_lookups, 0);
        assert!(m.moe_cpu_busy_ns > 0);
        assert_eq!(m.moe_gpu_busy_ns, 0);
    }

    #[test]
    fn greedy_beats_all_cpu_on_heavy_workloads() {
        let c = cost();
        let f = freq(4, 8);
        let w = [32u32, 32, 32, 32, 32, 32, 32, 32];
        let run = |all_cpu: bool| {
            let policy = PolicyBundle {
                assigner: if all_cpu {
                    Box::new(AllCpuAssigner::new()) as Box<dyn Assigner>
                } else {
                    Box::new(GreedyAssigner::new())
                },
                prefetcher: Box::new(NoPrefetcher),
                cache: Box::new(NoCache::new(4, 8)),
                prefetch_size: 0,
                cpu_eff: 1.0,
                layer_overhead_ns: 0,
                gpu_free_slots: 8,
                solve_cost: SolveCost::Modeled,
                placement: PlacementCfg::default(),
            };
            let mut sim = StepSimulator::new(&c, policy, &f, 4, 8, 0, 1);
            for _ in 0..4 {
                sim.run_step(&mk_step(4, 8, &w), 32, Phase::Decode);
            }
            sim.finish().total_ns
        };
        assert!(run(false) < run(true), "hybrid must beat CPU-only at heavy load");
    }

    fn tiny_trace(layers: usize, n: usize, steps: usize) -> Trace {
        use crate::workload::trace::{LayerStepRecord, PrefillLayerRecord, SeqTrace};
        let rec = LayerStepRecord {
            topk: vec![0, 1],
            topk_scores: vec![0.6, 0.3],
            pred_raw: vec![0, 1],
            pred_res: vec![0, 1],
            cos_raw: 0.8,
            cos_res: 0.9,
        };
        let pre = PrefillLayerRecord {
            counts: {
                let mut v = vec![0; n];
                v[0] = 4;
                v[1] = 4;
                v
            },
            gate_scores: vec![0.5; n],
            pred_raw: vec![1; n],
            pred_res: vec![1; n],
        };
        Trace {
            preset: "t".into(),
            task: "t".into(),
            n_routed: n,
            top_k: 2,
            layers,
            seqs: vec![SeqTrace {
                prompt_len: 8,
                prefill: vec![pre; layers],
                steps: vec![vec![rec; layers]; steps],
            }],
        }
    }

    #[test]
    fn unlimited_store_reproduces_two_tier_run_exactly() {
        // Acceptance criterion: with an unlimited host-RAM budget the
        // tiered store must be timing-transparent — bit-identical metrics
        // to the seed two-tier path (store bookkeeping counters aside).
        let c = cost();
        let f = freq(4, 8);
        let w = [8u32, 8, 0, 8, 2, 0, 1, 0];
        let run = |store: Option<crate::store::TieredStore>| {
            let mut sim = StepSimulator::new(&c, bundle(true, true), &f, 4, 8, 1, 1);
            if let Some(st) = store {
                sim = sim.with_store(st);
            }
            for _ in 0..12 {
                sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
            }
            sim.finish()
        };
        let two_tier = run(None);
        let mut tiered = run(Some(crate::store::TieredStore::unlimited(4, 8)));
        assert_eq!(tiered.nvme_read_bytes, 0, "unlimited store must never touch NVMe");
        assert_eq!(tiered.store_promotions, 0);
        // store bookkeeping (free demotions) is the only permitted delta
        tiered.store_gpu_demotions = two_tier.store_gpu_demotions;
        assert_eq!(tiered, two_tier);
    }

    #[test]
    fn memory_limited_store_charges_nvme_and_slows_decode() {
        let c = cost();
        let f = freq(4, 8);
        let w = [8u32, 8, 8, 8, 8, 8, 8, 8];
        let run = |store: Option<crate::store::TieredStore>| {
            let mut sim = StepSimulator::new(&c, bundle(false, true), &f, 4, 8, 0, 1);
            if let Some(st) = store {
                sim = sim.with_store(st);
            }
            for _ in 0..12 {
                sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
            }
            sim.finish()
        };
        let fast = run(Some(crate::store::TieredStore::unlimited(4, 8)));
        let slow = run(Some(crate::store::TieredStore::new(
            4,
            8,
            crate::store::StoreCfg { host_slots: 10, ..Default::default() },
        )));
        assert!(slow.tier_disk_misses > 0, "disk tier must be exercised");
        assert!(slow.nvme_read_ns > 0 && slow.nvme_read_bytes > 0);
        assert!(slow.store_promotions > 0);
        assert!(
            slow.total_ns > fast.total_ns,
            "NVMe promotions must cost virtual time: {} vs {}",
            slow.total_ns,
            fast.total_ns
        );
        assert_eq!(fast.tier_disk_misses, 0);
    }

    #[test]
    fn quantized_disk_format_shrinks_demand_nvme_time() {
        // Same policy, trace, and host budget — only the on-disk format
        // differs. The q4 tier's smaller reads must strictly cut
        // demand-path NVMe time and bytes, pay a real (separately
        // reported) transcode stage, and save NVMe traffic.
        let f = freq(4, 8);
        let w = [8u32, 8, 8, 8, 8, 8, 8, 8];
        let run = |ratio: f64| {
            let c = cost().with_quant_ratio(ratio);
            let mut sim = StepSimulator::new(&c, bundle(false, true), &f, 4, 8, 0, 1)
                .with_store(crate::store::TieredStore::new(
                    4,
                    8,
                    crate::store::StoreCfg { host_slots: 10, ..Default::default() },
                ));
            for _ in 0..12 {
                sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
            }
            sim.finish()
        };
        let fp16 = run(1.0);
        let q4 = run(0.28);
        assert_eq!(fp16.transcode_ns, 0, "fp16 on disk never transcodes");
        assert_eq!(fp16.disk_bytes_saved, 0);
        assert!(fp16.nvme_demand_ns > 0, "the budget must force demand reads");
        assert!(q4.transcode_ns > 0, "q4 promotions pass the transcode lane");
        assert!(q4.disk_bytes_saved > 0);
        assert!(
            q4.nvme_demand_ns < fp16.nvme_demand_ns,
            "quantized reads must cut demand NVMe time: {} vs {}",
            q4.nvme_demand_ns,
            fp16.nvme_demand_ns
        );
        assert!(q4.nvme_read_bytes < fp16.nvme_read_bytes);
    }

    #[test]
    fn transcode_rides_demand_arrivals_not_gpu_streams() {
        // All-CPU execution over a memory-limited q4 store: every demand
        // arrival includes the transcode completion (CPU work waits for
        // it), yet the GPU compute and PCIe streams stay untouched — the
        // transcode lane is not GPU time.
        let f = freq(4, 8);
        let w = [4u32, 4, 4, 4, 4, 4, 4, 4];
        let run = |ratio: f64| {
            let c = cost().with_quant_ratio(ratio);
            let policy = PolicyBundle {
                assigner: Box::new(AllCpuAssigner::new()),
                prefetcher: Box::new(NoPrefetcher),
                cache: Box::new(NoCache::new(4, 8)),
                prefetch_size: 0,
                cpu_eff: 1.0,
                layer_overhead_ns: 0,
                gpu_free_slots: 8,
                solve_cost: SolveCost::Modeled,
                placement: PlacementCfg::default(),
            };
            let mut sim = StepSimulator::new(&c, policy, &f, 4, 8, 0, 1).with_store(
                crate::store::TieredStore::new(
                    4,
                    8,
                    crate::store::StoreCfg { host_slots: 10, ..Default::default() },
                ),
            );
            for _ in 0..6 {
                sim.run_step(&mk_step(4, 8, &w), 8, Phase::Decode);
            }
            sim.finish()
        };
        let q4 = run(0.28);
        assert!(q4.transcode_ns > 0, "CPU demand arrivals pass the transcode lane");
        assert_eq!(q4.moe_gpu_busy_ns, 0, "transcode never lands on the GPU stream");
        assert_eq!(q4.pcie_demand_bytes, 0);
        assert!(q4.moe_cpu_busy_ns > 0);
        // and the asymmetric format wins end-to-end: small read + CPU
        // transcode arrives sooner than the big fp16 read
        let fp16 = run(1.0);
        assert!(
            q4.total_ns < fp16.total_ns,
            "q4 fetches must be faster end-to-end: {} vs {}",
            q4.total_ns,
            fp16.total_ns
        );
    }

    #[test]
    fn predictive_placement_on_unlimited_store_stays_transparent() {
        // Placement can be enabled on every DALI bundle unconditionally:
        // with an unlimited host budget it must be inert (nothing to
        // promote or demote), preserving the two-tier regression.
        let c = cost();
        let f = freq(4, 8);
        let w = [8u32, 8, 0, 8, 2, 0, 1, 0];
        let run = |store: Option<crate::store::TieredStore>, predictive: bool| {
            let mut policy = bundle(true, true);
            if predictive {
                policy.placement = PlacementCfg::predictive(1);
            }
            let mut sim = StepSimulator::new(&c, policy, &f, 4, 8, 1, 1);
            if let Some(st) = store {
                sim = sim.with_store(st);
            }
            for _ in 0..12 {
                sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
            }
            sim.finish()
        };
        let two_tier = run(None, false);
        let mut tiered = run(Some(crate::store::TieredStore::unlimited(4, 8)), true);
        assert_eq!(tiered.store_promote_ahead, 0);
        assert_eq!(tiered.nvme_read_bytes, 0);
        tiered.store_gpu_demotions = two_tier.store_gpu_demotions;
        assert_eq!(tiered, two_tier, "placement must be inert without memory pressure");
    }

    #[test]
    fn predictive_placement_reduces_demand_nvme_on_locality_trace() {
        use crate::workload::trace::synthetic_locality_trace;
        let c = cost();
        let f = freq(4, 8);
        let trace = synthetic_locality_trace(4, 8, 2, 8, 32, 0x7157);
        let ids: Vec<usize> = (0..8).collect();
        let run = |predictive: bool| {
            let mut policy = bundle(true, true);
            if predictive {
                policy.placement = PlacementCfg::predictive(1);
            }
            let store = crate::store::TieredStore::new(
                4,
                8,
                crate::store::StoreCfg { host_slots: 12, ..Default::default() },
            );
            replay_decode_store(&trace, &ids, 32, &c, policy, &f, 0, 7, Some(store))
        };
        let reactive = run(false);
        let predictive = run(true);
        assert_eq!(reactive.store_promote_ahead, 0);
        assert!(predictive.store_promote_ahead > 0, "ahead promotions must fire");
        assert!(predictive.promote_ahead_hits > 0, "and get consumed");
        assert!(predictive.nvme_overlap_hidden_ns > 0, "hiding NVMe latency");
        assert!(
            predictive.tier_disk_misses < reactive.tier_disk_misses,
            "promote-ahead must convert disk misses into host hits: {} vs {}",
            predictive.tier_disk_misses,
            reactive.tier_disk_misses
        );
        assert!(
            predictive.nvme_demand_ns < reactive.nvme_demand_ns,
            "demand-path NVMe time must shrink: {} vs {}",
            predictive.nvme_demand_ns,
            reactive.nvme_demand_ns
        );
    }

    #[test]
    fn clean_fault_plan_is_bit_transparent() {
        // Acceptance criterion: installing a clean plan must not move a
        // single bit of any metric — same arithmetic, same branches.
        use crate::fault::{FaultPlan, FaultProfile};
        let c = cost();
        let f = freq(4, 8);
        let run = |faulted: bool| {
            let mut sim = StepSimulator::new(&c, bundle(true, true), &f, 4, 8, 1, 9).with_store(
                crate::store::TieredStore::new(
                    4,
                    8,
                    crate::store::StoreCfg { host_slots: 12, ..Default::default() },
                ),
            );
            if faulted {
                sim = sim.with_faults(FaultPlan::new(FaultProfile::clean(), 0xfa));
            }
            for i in 0..16 {
                let w = [8u32, (i % 3) as u32, 8, 0, 2, 0, 1, i as u32 % 5];
                sim.run_step(&mk_step(4, 8, &w), 16 + i as usize, Phase::Decode);
            }
            sim.finish()
        };
        let clean = run(false);
        let planned = run(true);
        assert_eq!(planned, clean, "a clean fault plan must be bit-transparent");
        assert_eq!(planned.fault_retries, 0);
        assert_eq!(planned.degraded_gpu_ns, 0);
        assert_eq!(planned.ram_pressure_events, 0);
    }

    #[test]
    fn flaky_nvme_plan_is_deterministic_and_charges_retry_stalls() {
        use crate::fault::{FaultPlan, FaultProfile};
        let c = cost();
        let f = freq(4, 8);
        let w = [8u32, 8, 8, 8, 8, 8, 8, 8];
        let run = |plan: Option<FaultPlan>| {
            let mut sim = StepSimulator::new(&c, bundle(false, true), &f, 4, 8, 0, 1);
            if let Some(p) = plan {
                sim = sim.with_faults(p);
            }
            sim = sim.with_store(crate::store::TieredStore::new(
                4,
                8,
                crate::store::StoreCfg { host_slots: 10, ..Default::default() },
            ));
            for _ in 0..12 {
                sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
            }
            sim.finish()
        };
        let mut profile = FaultProfile::named("flaky-nvme").unwrap();
        profile.nvme_fail_prob = 0.5; // make retries certain over 12 steps
        let a = run(Some(FaultPlan::new(profile, 0x51)));
        let b = run(Some(FaultPlan::new(profile, 0x51)));
        assert_eq!(a, b, "same (seed, profile) must replay bit-identically");
        assert!(a.fault_retries > 0, "half the reads failing must retry");
        assert!(a.fault_stall_ns > 0, "failed attempts hold the read lane");
        let clean = run(None);
        assert!(
            a.total_ns > clean.total_ns,
            "retry storms must cost virtual time: {} vs {}",
            a.total_ns,
            clean.total_ns
        );
        // no speculative traffic in this bundle — only abortable reads abort
        assert_eq!(a.fault_aborts, 0);
        assert_eq!(a.store_promotions, clean.store_promotions, "demand reads always land");
    }

    #[test]
    fn gpu_throttle_windows_reroute_work_to_cpu() {
        use crate::fault::{FaultPlan, FaultProfile};
        let c = cost();
        let f = freq(4, 8);
        let w = [32u32, 32, 32, 32, 32, 32, 32, 32];
        let run = |plan: Option<FaultPlan>| {
            let mut sim = StepSimulator::new(&c, bundle(false, false), &f, 4, 8, 0, 1);
            if let Some(p) = plan {
                sim = sim.with_faults(p);
            }
            for _ in 0..8 {
                sim.run_step(&mk_step(4, 8, &w), 32, Phase::Decode);
            }
            sim.finish()
        };
        // window covers every step (len == period), 8x slower GPU
        let profile = FaultProfile {
            gpu_period: 8,
            gpu_len: 8,
            gpu_mult: 8.0,
            ..FaultProfile::clean()
        };
        let clean = run(None);
        let hot = run(Some(FaultPlan::new(profile, 3)));
        assert_eq!(hot.degraded_gpu_ns, hot.total_ns, "every step falls in the window");
        assert_eq!(hot.degraded_pcie_ns, 0);
        assert!(hot.total_ns > clean.total_ns, "a throttled GPU must cost time");
        assert!(
            hot.moe_cpu_busy_ns > clean.moe_cpu_busy_ns,
            "assignment must reroute marginal experts to the CPU: {} vs {}",
            hot.moe_cpu_busy_ns,
            clean.moe_cpu_busy_ns
        );
    }

    #[test]
    fn thermal_profile_accumulates_both_degradation_windows() {
        use crate::fault::{FaultPlan, FaultProfile};
        let c = cost();
        let f = freq(4, 8);
        let w = [16u32, 16, 16, 16, 0, 0, 0, 0];
        let run = |plan: Option<FaultPlan>| {
            let mut sim = StepSimulator::new(&c, bundle(false, false), &f, 4, 8, 0, 1);
            if let Some(p) = plan {
                sim = sim.with_faults(p);
            }
            for _ in 0..72 {
                sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
            }
            sim.finish()
        };
        let profile = FaultProfile::named("thermal").unwrap();
        let clean = run(None);
        let hot = run(Some(FaultPlan::new(profile, 0x7e)));
        // 72 steps cover three GPU periods and two PCIe periods: both
        // windows must have been live for whole steps at a time
        assert!(hot.degraded_gpu_ns > 0, "GPU throttle windows must land");
        assert!(hot.degraded_pcie_ns > 0, "PCIe degradation windows must land");
        assert!(hot.total_ns > clean.total_ns);
        assert_eq!(clean.degraded_gpu_ns, 0);
    }

    #[test]
    fn replay_decode_faulted_matches_traced_when_clean() {
        use crate::fault::{FaultPlan, FaultProfile};
        let c = cost();
        let f = freq(4, 8);
        let t = tiny_trace(4, 8, 16);
        let store = || {
            crate::store::TieredStore::new(
                4,
                8,
                crate::store::StoreCfg { host_slots: 12, ..Default::default() },
            )
        };
        let base = replay_decode_traced(
            &t,
            &[0, 0],
            16,
            &c,
            bundle(true, true),
            &f,
            0,
            5,
            Some(store()),
            NullSink,
        )
        .0;
        let clean = replay_decode_faulted(
            &t,
            &[0, 0],
            16,
            &c,
            bundle(true, true),
            &f,
            0,
            5,
            Some(FaultPlan::new(FaultProfile::clean(), 9)),
            Some(store()),
            NullSink,
        )
        .0;
        assert_eq!(clean, base, "clean plan through the replay entry must be exact");
    }

    #[test]
    fn replay_decode_produces_speed() {
        let c = cost();
        let f = freq(4, 8);
        let t = tiny_trace(4, 8, 16);
        let m = replay_decode(&t, &[0, 0, 0, 0], 16, &c, bundle(false, true), &f, 0, 1);
        assert_eq!(m.tokens_out, 64);
        assert!(m.tokens_per_s() > 0.0);
    }

    #[test]
    fn replay_prefill_counts_prompt_tokens() {
        let c = cost();
        let f = freq(4, 8);
        let t = tiny_trace(4, 8, 2);
        let m = replay_prefill(&t, &[0, 0], &c, bundle(false, false), &f, 0, 1);
        assert_eq!(m.tokens_out, 16);
    }

    #[test]
    fn one_gpu_entry_point_is_exactly_the_single_device_replay() {
        // The backcompat contract at the API level: `n_gpus = 1` through
        // the sharded entry point replays bit-identically — metrics AND
        // trace digest — to the pre-sharding path, store attached or not.
        use crate::trace::DigestSink;
        let c = cost();
        let f = freq(4, 8);
        let t = tiny_trace(4, 8, 16);
        let store = || {
            crate::store::TieredStore::new(
                4,
                8,
                crate::store::StoreCfg { host_slots: 12, ..Default::default() },
            )
        };
        for st in [false, true] {
            let mk = || if st { Some(store()) } else { None };
            let (base, bsink) = replay_decode_traced(
                &t,
                &[0, 0],
                16,
                &c,
                bundle(true, true),
                &f,
                1,
                5,
                mk(),
                DigestSink::new(),
            );
            let (one, osink) = replay_decode_gpus(
                &t,
                &[0, 0],
                16,
                &c,
                bundle(true, true),
                &f,
                1,
                5,
                1,
                None,
                mk(),
                DigestSink::new(),
            );
            assert_eq!(one, base, "store={st}: one-device metrics must be exact");
            assert_eq!(osink.value(), bsink.value(), "store={st}: digests must match");
        }
    }

    #[test]
    fn two_devices_balance_demand_work_and_beat_one() {
        // A GPU-bound all-demand workload: two device tiers must each do
        // real compute, and the extra PCIe lane + pipeline must strictly
        // shorten the modeled decode.
        let c = cost();
        let f = freq(4, 8);
        let w = [32u32; 8];
        let run = |n_gpus: usize| {
            let mut sim = StepSimulator::new(&c, bundle(false, false), &f, 4, 8, 0, 1)
                .with_gpus(n_gpus);
            for _ in 0..12 {
                sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
            }
            sim.finish()
        };
        let one = run(1);
        let two = run(2);
        assert!(two.dev_compute_busy_ns[0] > 0, "device 0 must compute");
        assert!(two.dev_compute_busy_ns[1] > 0, "device 1 must compute");
        assert_eq!(one.dev_compute_busy_ns[1], 0, "one-device runs never touch device 1");
        assert!(
            two.total_ns < one.total_ns,
            "2 GPUs must beat 1 on a GPU-bound workload: {} vs {}",
            two.total_ns,
            one.total_ns
        );
        assert_eq!(one.tokens_out, two.tokens_out);
    }

    #[test]
    fn off_home_admissions_travel_the_p2p_fabric() {
        // An LRU cache admits every demand-fetched expert; a rotating,
        // load-asymmetric hot set makes Greedy balance some of those
        // fetches onto the device that is NOT the expert's round-robin
        // home shard — each such admission re-homes over the P2P fabric,
        // and the byte accounting must stay exact.
        use crate::coordinator::cache::LruCache;
        let c = cost();
        let f = freq(4, 8);
        let mut policy = bundle(false, false);
        policy.cache = Box::new(LruCache::new(4, 8, 2, 3));
        let mut sim = StepSimulator::new(&c, policy, &f, 4, 8, 0, 3).with_gpus(2);
        for i in 0..24 {
            let w: [u32; 8] = if i % 2 == 0 {
                [0, 0, 0, 0, 16, 8, 8, 0]
            } else {
                [16, 8, 8, 0, 0, 0, 0, 0]
            };
            sim.run_step(&mk_step(4, 8, &w), 16, Phase::Decode);
        }
        let m = sim.finish();
        assert!(m.p2p_copies > 0, "off-home placements must cross the P2P fabric");
        assert!(
            m.p2p_migrations <= m.p2p_copies,
            "re-homes are a subset of fabric copies (the rest are off-home reads)"
        );
        assert_eq!(
            m.p2p_bytes,
            m.p2p_copies * c.expert_bytes() as u64,
            "P2P moves whole experts"
        );
        assert!(m.p2p_busy_ns > 0);
    }

    #[test]
    fn multi_device_replay_is_bit_deterministic() {
        use crate::trace::DigestSink;
        let c = cost();
        let f = freq(4, 8);
        let t = tiny_trace(4, 8, 16);
        let run = || {
            replay_decode_gpus(
                &t,
                &[0, 1, 0],
                16,
                &c,
                bundle(true, true),
                &f,
                1,
                7,
                2,
                None,
                Some(crate::store::TieredStore::new(
                    4,
                    8,
                    crate::store::StoreCfg { host_slots: 12, ..Default::default() },
                )),
                DigestSink::new(),
            )
        };
        let (m1, s1) = run();
        let (m2, s2) = run();
        assert_eq!(m1, m2, "identical seeds must give identical 2-GPU metrics");
        assert_eq!(s1.value(), s2.value(), "and identical 2-GPU digests");
    }
}
