//! Layer-3 coordinator: the paper's system contribution.
//!
//! * [`assignment`] — CPU/GPU expert placement (§4.1): the 0-1 program, the
//!   Greedy Assignment heuristic (Alg. 1), exact branch-and-bound, beam
//!   search, and the baselines' static policies.
//! * [`prefetch`] — next-layer high-workload expert prediction (§4.2):
//!   residual-based plus the compared feature/statistical/random predictors.
//! * [`cache`] — GPU expert cache replacement (§4.3): Workload-Aware
//!   (Alg. 2), LRU, score-based, pinned.
//! * [`simrun`] — the per-layer orchestration loop over the simulated
//!   platform (assign → parallel CPU/GPU execution → prefetch stream →
//!   cache update), shared by live inference and trace replay.
//! * [`engine`] — the live inference engine: real PJRT numerics + the same
//!   orchestration for timing; also produces traces and calibration data.
//! * [`frameworks`] — the six compared systems as policy bundles.

pub mod assignment;
pub mod cache;
pub mod engine;
pub mod frameworks;
pub mod prefetch;
pub mod simrun;

pub use assignment::{AssignCtx, Assigner, Assignment};
pub use cache::ExpertCache;
pub use frameworks::Framework;
pub use prefetch::Prefetcher;
pub use simrun::{PolicyBundle, StepSimulator};
