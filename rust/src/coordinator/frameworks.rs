//! The compared systems (paper §6.1) as policy bundles.
//!
//! Each framework is its published *scheduling policy* (assignment ×
//! prefetch × cache × execution quirks) running inside the shared engine on
//! the shared simulated platform — the cleanest apples-to-apples form of
//! the paper's comparison (DESIGN.md §1).

use crate::config::ModelDims;
use crate::coordinator::assignment::*;
use crate::coordinator::cache::*;
use crate::coordinator::prefetch::*;
use crate::coordinator::simrun::PolicyBundle;
use crate::hw::{ns, CostModel, GpuMemModel};
use crate::store::PlacementCfg;

/// The frameworks of the paper's evaluation plus DALI ablation variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    /// All experts on CPU (paper Fig. 14/19 "Naive" anchor).
    Naive,
    /// llama.cpp: layer-wise split, slow CPU GEMM path.
    LlamaCpp,
    /// KTransformers: layer-wise split, fast (AMX-like) CPU kernels.
    KTransformers,
    /// Fiddler: static expert-wise threshold, no prefetch, no cache.
    Fiddler,
    /// MoE-Lightning: offline frequency-based placement + paging overheads.
    MoELightning,
    /// HybriMoE: static expert-wise + feature prefetch + score cache.
    HybriMoE,
    /// DALI: greedy assignment + residual prefetch + workload-aware cache.
    Dali,
    /// DALI with the exact 0-1 solver ("Opt_plan").
    DaliOpt,
    /// DALI with beam-search assignment (Appendix A.2).
    DaliBeam,
}

/// Tunables shared across frameworks for a fair comparison (paper §6.1-3:
/// same cached-expert count, same CPU cores, comparable GPU memory).
#[derive(Debug, Clone)]
pub struct FrameworkCfg {
    /// Experts cached on GPU per layer (HybriMoE + DALI).
    pub cache_size: usize,
    /// DALI cache window / update sizes (paper defaults (4,8) or (4,1)).
    pub w_size: usize,
    pub u_size: usize,
    /// Experts prefetched per layer.
    pub prefetch_size: usize,
    /// Eq. 9 staging slots.
    pub gpu_free_slots: usize,
    pub seed: u64,
}

impl FrameworkCfg {
    /// The paper's per-model defaults (§6.2 Fig. 12 caption):
    /// Mixtral (u=1, ps=1), DeepSeek/Qwen (u=8, ps=4), cache ratio 50 %.
    pub fn paper_default(dims: &ModelDims) -> Self {
        let mixtral_like = dims.n_routed <= 8;
        FrameworkCfg {
            cache_size: (dims.n_routed / 2).max(1),
            w_size: 4,
            u_size: if mixtral_like { 1 } else { 8 },
            prefetch_size: if mixtral_like { 1 } else { 4 },
            gpu_free_slots: dims.n_routed,
            seed: 17,
        }
    }
}

impl Framework {
    pub fn name(&self) -> &'static str {
        match self {
            Framework::Naive => "naive",
            Framework::LlamaCpp => "llama.cpp",
            Framework::KTransformers => "ktransformers",
            Framework::Fiddler => "fiddler",
            Framework::MoELightning => "moe-lightning",
            Framework::HybriMoE => "hybrimoe",
            Framework::Dali => "dali",
            Framework::DaliOpt => "dali-opt",
            Framework::DaliBeam => "dali-beam",
        }
    }

    /// The five systems of Fig. 12 plus DALI.
    pub fn comparison_set() -> Vec<Framework> {
        vec![
            Framework::LlamaCpp,
            Framework::KTransformers,
            Framework::MoELightning,
            Framework::HybriMoE,
            Framework::Dali,
        ]
    }

    /// Layer-wise frameworks put whole MoE layers on the GPU; to keep GPU
    /// memory comparable (paper §6.1-3), the number of GPU layers matches
    /// DALI's total cached-expert budget.
    fn gpu_layers(dims: &ModelDims, cache_size: usize) -> usize {
        ((cache_size * dims.layers) / dims.n_routed).min(dims.layers)
    }

    /// Build this framework's policy bundle.
    ///
    /// `calib_freq` — per-layer expert activation frequency (MoE-Lightning's
    /// offline placement input); pass zeros when unavailable.
    pub fn bundle(
        &self,
        dims: &ModelDims,
        cost: &CostModel,
        calib_freq: &[Vec<f64>],
        cfg: &FrameworkCfg,
    ) -> PolicyBundle {
        let l = dims.layers;
        let n = dims.n_routed;
        let base = PolicyBundle {
            assigner: Box::new(GreedyAssigner::new()),
            prefetcher: Box::new(NoPrefetcher),
            cache: Box::new(NoCache::new(l, n)),
            prefetch_size: 0,
            cpu_eff: 1.0,
            layer_overhead_ns: 0,
            gpu_free_slots: cfg.gpu_free_slots,
            solve_cost: SolveCost::default(),
            // Reactive (LRU-spill, demand-only) placement for the baselines:
            // none of the compared systems anticipates NVMe residency, so
            // giving them DALI's placement would misattribute its gains.
            placement: PlacementCfg::default(),
        };
        let _ = cost;
        match self {
            Framework::Naive => PolicyBundle {
                assigner: Box::new(AllCpuAssigner::new()),
                ..base
            },
            Framework::LlamaCpp => {
                let cpu_layers = l - Self::gpu_layers(dims, cfg.cache_size);
                PolicyBundle {
                    assigner: Box::new(LayerWiseAssigner::new(cpu_layers)),
                    cache: Box::new(PinnedCache::whole_layers(l, n, cpu_layers)),
                    // llama.cpp's CPU MoE GEMMs are markedly slower than
                    // KTransformers' AMX/AVX-512 path (paper §6.2 gap).
                    cpu_eff: 0.5,
                    ..base
                }
            }
            Framework::KTransformers => {
                let cpu_layers = l - Self::gpu_layers(dims, cfg.cache_size);
                PolicyBundle {
                    assigner: Box::new(LayerWiseAssigner::new(cpu_layers)),
                    cache: Box::new(PinnedCache::whole_layers(l, n, cpu_layers)),
                    ..base
                }
            }
            Framework::Fiddler => PolicyBundle {
                assigner: Box::new(StaticThresholdAssigner::new()),
                // Fiddler's python-level expert dispatch adds large per-layer
                // overhead (paper reports it 14.3x slower than DALI).
                layer_overhead_ns: ns(900e-6),
                cpu_eff: 0.6,
                ..base
            },
            Framework::MoELightning => PolicyBundle {
                assigner: Box::new(ResidentOnlyAssigner::new()),
                cache: Box::new(PinnedCache::by_frequency(calib_freq, cfg.cache_size)),
                // asynchronous paging + frequent stream switches (§6.2).
                layer_overhead_ns: ns(60e-6),
                ..base
            },
            Framework::HybriMoE => PolicyBundle {
                assigner: Box::new(StaticThresholdAssigner::new()),
                prefetcher: Box::new(FeaturePrefetcher),
                cache: Box::new(ScoreCache::new(l, n, cfg.cache_size, cfg.seed)),
                prefetch_size: cfg.prefetch_size,
                ..base
            },
            // The DALI variants drive tiered-store placement from the same
            // residual workload predictions that drive their prefetching.
            Framework::Dali => PolicyBundle {
                assigner: Box::new(GreedyAssigner::new()),
                prefetcher: Box::new(ResidualPrefetcher),
                cache: Box::new(WorkloadAwareCache::new(
                    l,
                    n,
                    cfg.cache_size,
                    cfg.w_size,
                    cfg.u_size,
                    cfg.seed,
                )),
                prefetch_size: cfg.prefetch_size,
                placement: PlacementCfg::predictive(cfg.prefetch_size),
                ..base
            },
            Framework::DaliOpt => PolicyBundle {
                assigner: Box::new(EnumerateAssigner::new()),
                prefetcher: Box::new(ResidualPrefetcher),
                cache: Box::new(WorkloadAwareCache::new(
                    l,
                    n,
                    cfg.cache_size,
                    cfg.w_size,
                    cfg.u_size,
                    cfg.seed,
                )),
                prefetch_size: cfg.prefetch_size,
                placement: PlacementCfg::predictive(cfg.prefetch_size),
                ..base
            },
            Framework::DaliBeam => PolicyBundle {
                assigner: Box::new(BeamAssigner::new(2)),
                prefetcher: Box::new(ResidualPrefetcher),
                cache: Box::new(WorkloadAwareCache::new(
                    l,
                    n,
                    cfg.cache_size,
                    cfg.w_size,
                    cfg.u_size,
                    cfg.seed,
                )),
                prefetch_size: cfg.prefetch_size,
                placement: PlacementCfg::predictive(cfg.prefetch_size),
                ..base
            },
        }
    }

    /// Default staging-slot budget from the memory model (Eq. 9): what is
    /// left of VRAM after resident weights + cache + a nominal KV budget.
    pub fn default_slots(mem: &GpuMemModel, hw_mem: f64, cache_size: usize) -> usize {
        let free = hw_mem - mem.resident_base() - mem.cache_bytes(cache_size) - mem.kv_bytes(64, 256);
        let per = mem.cache_bytes(1).max(1.0) / 1.0;
        // per-layer staging: distribute free bytes over layers
        ((free / per).floor() as isize).clamp(1, 16) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Presets;

    fn setup() -> (ModelDims, CostModel) {
        let p = Presets::load_default().unwrap();
        let m = p.model("mixtral-sim").unwrap();
        (m.sim.clone(), CostModel::new(m, p.hw("local-pc").unwrap()))
    }

    #[test]
    fn all_frameworks_build() {
        let (dims, cost) = setup();
        let cfg = FrameworkCfg::paper_default(&dims);
        let freq = vec![vec![1.0 / dims.n_routed as f64; dims.n_routed]; dims.layers];
        for f in [
            Framework::Naive,
            Framework::LlamaCpp,
            Framework::KTransformers,
            Framework::Fiddler,
            Framework::MoELightning,
            Framework::HybriMoE,
            Framework::Dali,
            Framework::DaliOpt,
            Framework::DaliBeam,
        ] {
            let b = f.bundle(&dims, &cost, &freq, &cfg);
            assert!(!f.name().is_empty());
            assert!(b.cpu_eff > 0.0 && b.cpu_eff <= 1.0);
        }
    }

    #[test]
    fn paper_defaults_follow_caption() {
        let p = Presets::load_default().unwrap();
        let mixtral = FrameworkCfg::paper_default(&p.model("mixtral-sim").unwrap().sim);
        assert_eq!(mixtral.u_size, 1);
        assert_eq!(mixtral.prefetch_size, 1);
        assert_eq!(mixtral.cache_size, 4); // 50% of 8
        let qwen = FrameworkCfg::paper_default(&p.model("qwen-sim").unwrap().sim);
        assert_eq!(qwen.u_size, 8);
        assert_eq!(qwen.prefetch_size, 4);
    }

    #[test]
    fn gpu_layers_memory_matched() {
        let (dims, _) = setup();
        // cache 4/8 experts × 4 layers = 16 experts ≈ 2 full layers of 8
        assert_eq!(Framework::gpu_layers(&dims, 4), 2);
        assert_eq!(Framework::gpu_layers(&dims, 8), 4);
        assert_eq!(Framework::gpu_layers(&dims, 0), 0);
    }

    #[test]
    fn only_dali_bundles_get_predictive_placement() {
        let (dims, cost) = setup();
        let cfg = FrameworkCfg::paper_default(&dims);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        for f in [Framework::Dali, Framework::DaliOpt, Framework::DaliBeam] {
            let b = f.bundle(&dims, &cost, &freq, &cfg);
            assert!(b.placement.predictive, "{} drives placement", f.name());
            assert!(b.placement.ahead >= 2);
        }
        for f in [
            Framework::Naive,
            Framework::LlamaCpp,
            Framework::KTransformers,
            Framework::Fiddler,
            Framework::MoELightning,
            Framework::HybriMoE,
        ] {
            assert!(
                !f.bundle(&dims, &cost, &freq, &cfg).placement.predictive,
                "{} must keep reactive LRU-spill placement",
                f.name()
            );
        }
    }

    #[test]
    fn comparison_set_matches_fig12() {
        let names: Vec<&str> =
            Framework::comparison_set().iter().map(|f| f.name()).collect();
        assert_eq!(
            names,
            vec!["llama.cpp", "ktransformers", "moe-lightning", "hybrimoe", "dali"]
        );
    }
}
