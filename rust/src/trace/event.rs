//! Typed per-step trace events.
//!
//! Every scheduling decision the simulator takes — assignment devices,
//! prefetch issue/consumption, predictive promotions, demand fetches,
//! spills, cache swaps — plus every lane's busy intervals, expressed as
//! plain-old-data variants (`Copy`, no heap) so emitting one costs a few
//! register moves and hashing one needs no buffer.
//!
//! Two serial forms exist side by side:
//! * [`Event::fold_words`] — the canonical `u64`-word encoding the digest
//!   sink hashes (variant tag first, then every field in declaration
//!   order);
//! * [`Event::to_value`] / [`Event::from_value`] — a JSON object per event
//!   (`{"ev": "...", ...}`) for the JSON-lines sink, round-trippable
//!   through [`crate::util::json`].

use anyhow::{bail, Result};

use crate::hw::Ns;
use crate::util::json::Value;

/// A virtual-time execution lane. Busy intervals are reported per lane so
/// utilization and overlap can be reconstructed from the trace alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// NVMe read stream (disk → host promotions).
    NvmeRead,
    /// NVMe write stream (write-back spills).
    NvmeWrite,
    /// CPU transcode lane (de/re-quantize of the on-disk format).
    Transcode,
    /// High-priority PCIe lane (demand fetches — the critical path).
    PcieDemand,
    /// Low-priority PCIe lane (prefetch + cache-update traffic).
    PcieSpec,
    /// GPU compute stream (expert kernels, gate passes).
    GpuCompute,
    /// CPU expert execution.
    Cpu,
    /// Inter-GPU P2P/NVLink fabric (cross-device expert copies; never
    /// busy on single-GPU runs).
    P2p,
}

impl Lane {
    pub const COUNT: usize = 8;
    pub const ALL: [Lane; Lane::COUNT] = [
        Lane::NvmeRead,
        Lane::NvmeWrite,
        Lane::Transcode,
        Lane::PcieDemand,
        Lane::PcieSpec,
        Lane::GpuCompute,
        Lane::Cpu,
        Lane::P2p,
    ];

    /// Stable dense index (array slot + digest word).
    pub fn idx(self) -> usize {
        match self {
            Lane::NvmeRead => 0,
            Lane::NvmeWrite => 1,
            Lane::Transcode => 2,
            Lane::PcieDemand => 3,
            Lane::PcieSpec => 4,
            Lane::GpuCompute => 5,
            Lane::Cpu => 6,
            Lane::P2p => 7,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Lane::NvmeRead => "nvme_read",
            Lane::NvmeWrite => "nvme_write",
            Lane::Transcode => "transcode",
            Lane::PcieDemand => "pcie_demand",
            Lane::PcieSpec => "pcie_spec",
            Lane::GpuCompute => "gpu_compute",
            Lane::Cpu => "cpu",
            Lane::P2p => "p2p",
        }
    }

    pub fn from_name(s: &str) -> Result<Lane> {
        for l in Lane::ALL {
            if l.name() == s {
                return Ok(l);
            }
        }
        bail!("unknown lane '{s}'")
    }
}

/// One trace event. Times are virtual ns on the run's clock; `layer` /
/// `expert` address the sim-scale expert grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Assignment chose a device for one non-idle expert; `cost_ns` is the
    /// priced execution cost of the chosen side (GPU kernel estimate, or
    /// the CPU GEMM time after the bundle's efficiency factor). `device`
    /// is the GPU tier index the expert landed on (0 when `gpu` is false).
    Assign { layer: u32, expert: u32, gpu: bool, device: u8, workload: u32, cost_ns: Ns },
    /// A speculative PCIe prefetch was issued for the next layer onto GPU
    /// `device`; `arrival` is its scheduled GPU arrival instant.
    PrefetchIssue { layer: u32, expert: u32, device: u8, arrival: Ns },
    /// A prefetched expert was consumed by a GPU assignment with real
    /// workload (counts 1:1 with `RunMetrics::prefetch_useful`).
    PrefetchHit { layer: u32, expert: u32 },
    /// A prefetched expert retired with zero workload — staging budget and
    /// PCIe time wasted on a wrong prediction.
    PrefetchWasted { layer: u32, expert: u32 },
    /// Predictive NVMe→host promotion issued ahead of need.
    AheadIssue { layer: u32, expert: u32, arrival: Ns },
    /// An ahead promotion was consumed; `hidden_ns` is the portion of its
    /// NVMe fetch already hidden behind compute by consumption time.
    AheadHit { layer: u32, expert: u32, hidden_ns: Ns },
    /// An unconsumed ahead promotion was spilled back out (wasted read).
    AheadMiss { layer: u32, expert: u32 },
    /// Disk→host promotion at access time. `demand` marks execution-path
    /// fetches (counts 1:1 with `RunMetrics::tier_disk_misses`); false is
    /// speculative chaining (prefetch / cache-update consumers).
    Fetch { layer: u32, expert: u32, demand: bool, arrival: Ns },
    /// Host→disk spill; `writeback` when an NVMe write was charged.
    Spill { layer: u32, expert: u32, writeback: bool },
    /// Cache admitted an expert to GPU `device`'s resident set.
    CacheAdmit { layer: u32, expert: u32, device: u8 },
    /// Cache evicted an expert from GPU `device`'s resident set (a
    /// demotion when a tiered store is attached).
    CacheEvict { layer: u32, expert: u32, device: u8 },
    /// One busy interval `[start, end)` on a lane of `device` (always 0
    /// for the host-side NVMe/transcode/CPU lanes and the P2P fabric).
    /// Sums per lane reconstruct the corresponding `RunMetrics` busy
    /// integrals exactly (see the carry rule on [`Event::Reset`]).
    LaneBusy { lane: Lane, device: u8, start: Ns, end: Ns },
    /// Metrics reset (warmup boundary): the clock rebased to 0 at `at`.
    /// Followed immediately by carry `LaneBusy` events re-seeding each
    /// NVMe/transcode lane with the residual of work still in flight, so
    /// post-reset interval sums still equal the busy counters exactly.
    Reset { at: Ns },
    /// One batch step retired. `end_ns` is the clock after the step (the
    /// final step's `end_ns` equals `RunMetrics::total_ns`).
    StepEnd { step: u64, decode: bool, end_ns: Ns, tokens: u32 },
    /// An injected-fault transfer attempt failed and was retried:
    /// `attempt` (1-based) timed out on `lane` at `at`, backing off before
    /// the next try (fault-injection runs only).
    FaultRetry { lane: Lane, layer: u32, expert: u32, attempt: u32, at: Ns },
    /// A speculative transfer exhausted its retries and was abandoned
    /// after `attempts` failed tries; the expert stays in its source tier.
    FaultAbort { lane: Lane, layer: u32, expert: u32, attempts: u32, at: Ns },
    /// Host-RAM budget pressure transition at `at`: `reserved` slots are
    /// currently confiscated (0 = restored), `spilled` experts were demoted
    /// under the workload-aware score to satisfy the shrink.
    RamPressure { at: Ns, reserved: u32, spilled: u32 },
    /// A serving-simulation request entered the arrival queue at `at`
    /// (virtual time; serving runs only).
    RequestArrive { req: u32, at: Ns, prompt_len: u32, max_tokens: u32 },
    /// The continuous batcher admitted a queued request into the running
    /// batch; `queue_ns` is its time spent waiting in the arrival queue.
    RequestAdmit { req: u32, at: Ns, queue_ns: Ns },
    /// A request produced its first decoded token; `ttft_ns` is the
    /// arrival→first-token latency (the TTFT sample this request reports).
    RequestFirstToken { req: u32, at: Ns, ttft_ns: Ns },
    /// A request finished and left the batch after generating `tokens`
    /// decode tokens.
    RequestFinish { req: u32, at: Ns, tokens: u32 },
    /// Admission control turned a request away at `at`. `reason`:
    /// 0 = deadline already blown at admit time, 1 = pending queue at
    /// capacity on arrival, 2 = predicted TTFT exceeds the deadline.
    RequestReject { req: u32, at: Ns, reason: u32 },
    /// Load shedding evicted a running request whose completion deadline
    /// was blown by `overdue_ns`, freeing its slot after `generated`
    /// decode tokens.
    RequestEvict { req: u32, at: Ns, generated: u32, overdue_ns: Ns },
    /// The overload controller escalated the degradation ladder
    /// (`from` → `to`, one rung) with `queue_depth` requests pending.
    DegradeEnter { at: Ns, from: u32, to: u32, queue_depth: u32 },
    /// The overload controller de-escalated the degradation ladder
    /// (`from` → `to`, one rung) with `queue_depth` requests pending.
    DegradeExit { at: Ns, from: u32, to: u32, queue_depth: u32 },
    /// An expert's weights were copied GPU `from` → GPU `to` over the P2P
    /// fabric (execution placed it off its caching device, or a demand
    /// fetch is being re-homed). Multi-GPU runs only.
    P2pCopy { layer: u32, expert: u32, from: u8, to: u8, start: Ns, end: Ns },
}

impl Event {
    /// Short stable name of the variant (the JSON `"ev"` field).
    pub fn name(&self) -> &'static str {
        match self {
            Event::Assign { .. } => "assign",
            Event::PrefetchIssue { .. } => "prefetch_issue",
            Event::PrefetchHit { .. } => "prefetch_hit",
            Event::PrefetchWasted { .. } => "prefetch_wasted",
            Event::AheadIssue { .. } => "ahead_issue",
            Event::AheadHit { .. } => "ahead_hit",
            Event::AheadMiss { .. } => "ahead_miss",
            Event::Fetch { .. } => "fetch",
            Event::Spill { .. } => "spill",
            Event::CacheAdmit { .. } => "cache_admit",
            Event::CacheEvict { .. } => "cache_evict",
            Event::LaneBusy { .. } => "lane",
            Event::Reset { .. } => "reset",
            Event::StepEnd { .. } => "step",
            Event::FaultRetry { .. } => "fault_retry",
            Event::FaultAbort { .. } => "fault_abort",
            Event::RamPressure { .. } => "ram_pressure",
            Event::RequestArrive { .. } => "request_arrive",
            Event::RequestAdmit { .. } => "request_admit",
            Event::RequestFirstToken { .. } => "request_first_token",
            Event::RequestFinish { .. } => "request_finish",
            Event::RequestReject { .. } => "request_reject",
            Event::RequestEvict { .. } => "request_evict",
            Event::DegradeEnter { .. } => "degrade_enter",
            Event::DegradeExit { .. } => "degrade_exit",
            Event::P2pCopy { .. } => "p2p_copy",
        }
    }

    /// Fold the event into `u64` words: variant tag, then every field in
    /// declaration order. This is the digest sink's canonical encoding —
    /// allocation-free and stable across platforms.
    pub fn fold_words(&self, f: &mut impl FnMut(u64)) {
        match *self {
            Event::Assign { layer, expert, gpu, device, workload, cost_ns } => {
                f(1);
                f(layer as u64);
                f(expert as u64);
                // placement word: 0 = CPU, 1 + d = GPU device d. Device 0
                // folds exactly like the old `gpu as u64`, so 1-GPU digests
                // are unchanged by the device tag.
                f(if gpu { 1 + device as u64 } else { 0 });
                f(workload as u64);
                f(cost_ns);
            }
            Event::PrefetchIssue { layer, expert, device, arrival } => {
                f(2);
                f(layer as u64);
                // device rides the high 32 bits of the expert word (zero —
                // i.e. the pre-multi-GPU word — on device 0)
                f(expert as u64 | (device as u64) << 32);
                f(arrival);
            }
            Event::PrefetchHit { layer, expert } => {
                f(3);
                f(layer as u64);
                f(expert as u64);
            }
            Event::PrefetchWasted { layer, expert } => {
                f(4);
                f(layer as u64);
                f(expert as u64);
            }
            Event::AheadIssue { layer, expert, arrival } => {
                f(5);
                f(layer as u64);
                f(expert as u64);
                f(arrival);
            }
            Event::AheadHit { layer, expert, hidden_ns } => {
                f(6);
                f(layer as u64);
                f(expert as u64);
                f(hidden_ns);
            }
            Event::AheadMiss { layer, expert } => {
                f(7);
                f(layer as u64);
                f(expert as u64);
            }
            Event::Fetch { layer, expert, demand, arrival } => {
                f(8);
                f(layer as u64);
                f(expert as u64);
                f(demand as u64);
                f(arrival);
            }
            Event::Spill { layer, expert, writeback } => {
                f(9);
                f(layer as u64);
                f(expert as u64);
                f(writeback as u64);
            }
            Event::CacheAdmit { layer, expert, device } => {
                f(10);
                f(layer as u64);
                f(expert as u64 | (device as u64) << 32);
            }
            Event::CacheEvict { layer, expert, device } => {
                f(11);
                f(layer as u64);
                f(expert as u64 | (device as u64) << 32);
            }
            Event::LaneBusy { lane, device, start, end } => {
                f(12);
                f(lane.idx() as u64 | (device as u64) << 32);
                f(start);
                f(end);
            }
            Event::Reset { at } => {
                f(13);
                f(at);
            }
            Event::StepEnd { step, decode, end_ns, tokens } => {
                f(14);
                f(step);
                f(decode as u64);
                f(end_ns);
                f(tokens as u64);
            }
            Event::FaultRetry { lane, layer, expert, attempt, at } => {
                f(15);
                f(lane.idx() as u64);
                f(layer as u64);
                f(expert as u64);
                f(attempt as u64);
                f(at);
            }
            Event::FaultAbort { lane, layer, expert, attempts, at } => {
                f(16);
                f(lane.idx() as u64);
                f(layer as u64);
                f(expert as u64);
                f(attempts as u64);
                f(at);
            }
            Event::RamPressure { at, reserved, spilled } => {
                f(17);
                f(at);
                f(reserved as u64);
                f(spilled as u64);
            }
            Event::RequestArrive { req, at, prompt_len, max_tokens } => {
                f(18);
                f(req as u64);
                f(at);
                f(prompt_len as u64);
                f(max_tokens as u64);
            }
            Event::RequestAdmit { req, at, queue_ns } => {
                f(19);
                f(req as u64);
                f(at);
                f(queue_ns);
            }
            Event::RequestFirstToken { req, at, ttft_ns } => {
                f(20);
                f(req as u64);
                f(at);
                f(ttft_ns);
            }
            Event::RequestFinish { req, at, tokens } => {
                f(21);
                f(req as u64);
                f(at);
                f(tokens as u64);
            }
            Event::RequestReject { req, at, reason } => {
                f(22);
                f(req as u64);
                f(at);
                f(reason as u64);
            }
            Event::RequestEvict { req, at, generated, overdue_ns } => {
                f(23);
                f(req as u64);
                f(at);
                f(generated as u64);
                f(overdue_ns);
            }
            Event::DegradeEnter { at, from, to, queue_depth } => {
                f(24);
                f(at);
                f(from as u64);
                f(to as u64);
                f(queue_depth as u64);
            }
            Event::DegradeExit { at, from, to, queue_depth } => {
                f(25);
                f(at);
                f(from as u64);
                f(to as u64);
                f(queue_depth as u64);
            }
            Event::P2pCopy { layer, expert, from, to, start, end } => {
                f(26);
                f(layer as u64);
                f(expert as u64);
                f(from as u64);
                f(to as u64);
                f(start);
                f(end);
            }
        }
    }

    /// JSON form (one object; the JSON-lines sink writes one per line).
    /// Virtual-time fields stay well inside f64's 53-bit integer range
    /// (runs are seconds of ns), so numbers round-trip exactly.
    pub fn to_value(&self) -> Value {
        let ev = Value::str(self.name());
        match *self {
            Event::Assign { layer, expert, gpu, device, workload, cost_ns } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("gpu", Value::Bool(gpu)),
                ("device", Value::num(device as f64)),
                ("workload", Value::num(workload as f64)),
                ("cost_ns", Value::num(cost_ns as f64)),
            ]),
            Event::PrefetchIssue { layer, expert, device, arrival } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("device", Value::num(device as f64)),
                ("arrival", Value::num(arrival as f64)),
            ]),
            Event::AheadIssue { layer, expert, arrival } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("arrival", Value::num(arrival as f64)),
            ]),
            Event::PrefetchHit { layer, expert }
            | Event::PrefetchWasted { layer, expert }
            | Event::AheadMiss { layer, expert } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
            ]),
            Event::CacheAdmit { layer, expert, device }
            | Event::CacheEvict { layer, expert, device } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("device", Value::num(device as f64)),
            ]),
            Event::AheadHit { layer, expert, hidden_ns } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("hidden_ns", Value::num(hidden_ns as f64)),
            ]),
            Event::Fetch { layer, expert, demand, arrival } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("demand", Value::Bool(demand)),
                ("arrival", Value::num(arrival as f64)),
            ]),
            Event::Spill { layer, expert, writeback } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("writeback", Value::Bool(writeback)),
            ]),
            Event::LaneBusy { lane, device, start, end } => Value::obj(vec![
                ("ev", ev),
                ("lane", Value::str(lane.name())),
                ("device", Value::num(device as f64)),
                ("start", Value::num(start as f64)),
                ("end", Value::num(end as f64)),
            ]),
            Event::Reset { at } => {
                Value::obj(vec![("ev", ev), ("at", Value::num(at as f64))])
            }
            Event::StepEnd { step, decode, end_ns, tokens } => Value::obj(vec![
                ("ev", ev),
                ("step", Value::num(step as f64)),
                ("decode", Value::Bool(decode)),
                ("end_ns", Value::num(end_ns as f64)),
                ("tokens", Value::num(tokens as f64)),
            ]),
            Event::FaultRetry { lane, layer, expert, attempt, at } => Value::obj(vec![
                ("ev", ev),
                ("lane", Value::str(lane.name())),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("attempt", Value::num(attempt as f64)),
                ("at", Value::num(at as f64)),
            ]),
            Event::FaultAbort { lane, layer, expert, attempts, at } => Value::obj(vec![
                ("ev", ev),
                ("lane", Value::str(lane.name())),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("attempts", Value::num(attempts as f64)),
                ("at", Value::num(at as f64)),
            ]),
            Event::RamPressure { at, reserved, spilled } => Value::obj(vec![
                ("ev", ev),
                ("at", Value::num(at as f64)),
                ("reserved", Value::num(reserved as f64)),
                ("spilled", Value::num(spilled as f64)),
            ]),
            Event::RequestArrive { req, at, prompt_len, max_tokens } => Value::obj(vec![
                ("ev", ev),
                ("req", Value::num(req as f64)),
                ("at", Value::num(at as f64)),
                ("prompt_len", Value::num(prompt_len as f64)),
                ("max_tokens", Value::num(max_tokens as f64)),
            ]),
            Event::RequestAdmit { req, at, queue_ns } => Value::obj(vec![
                ("ev", ev),
                ("req", Value::num(req as f64)),
                ("at", Value::num(at as f64)),
                ("queue_ns", Value::num(queue_ns as f64)),
            ]),
            Event::RequestFirstToken { req, at, ttft_ns } => Value::obj(vec![
                ("ev", ev),
                ("req", Value::num(req as f64)),
                ("at", Value::num(at as f64)),
                ("ttft_ns", Value::num(ttft_ns as f64)),
            ]),
            Event::RequestFinish { req, at, tokens } => Value::obj(vec![
                ("ev", ev),
                ("req", Value::num(req as f64)),
                ("at", Value::num(at as f64)),
                ("tokens", Value::num(tokens as f64)),
            ]),
            Event::RequestReject { req, at, reason } => Value::obj(vec![
                ("ev", ev),
                ("req", Value::num(req as f64)),
                ("at", Value::num(at as f64)),
                ("reason", Value::num(reason as f64)),
            ]),
            Event::RequestEvict { req, at, generated, overdue_ns } => Value::obj(vec![
                ("ev", ev),
                ("req", Value::num(req as f64)),
                ("at", Value::num(at as f64)),
                ("generated", Value::num(generated as f64)),
                ("overdue_ns", Value::num(overdue_ns as f64)),
            ]),
            Event::DegradeEnter { at, from, to, queue_depth }
            | Event::DegradeExit { at, from, to, queue_depth } => Value::obj(vec![
                ("ev", ev),
                ("at", Value::num(at as f64)),
                ("from", Value::num(from as f64)),
                ("to", Value::num(to as f64)),
                ("queue_depth", Value::num(queue_depth as f64)),
            ]),
            Event::P2pCopy { layer, expert, from, to, start, end } => Value::obj(vec![
                ("ev", ev),
                ("layer", Value::num(layer as f64)),
                ("expert", Value::num(expert as f64)),
                ("from", Value::num(from as f64)),
                ("to", Value::num(to as f64)),
                ("start", Value::num(start as f64)),
                ("end", Value::num(end as f64)),
            ]),
        }
    }

    /// Parse the JSON form back (the schema round-trip the sink tests
    /// lock: `from_value(to_value(e)) == e` for every variant).
    pub fn from_value(v: &Value) -> Result<Event> {
        let le = |k: &str| -> Result<u32> { Ok(v.get(k)?.as_u64()? as u32) };
        let ns = |k: &str| -> Result<Ns> { v.get(k)?.as_u64() };
        // absent on pre-multi-GPU trace files: default to device 0
        let dev = || -> u8 { v.get("device").and_then(|x| x.as_u64()).unwrap_or(0) as u8 };
        Ok(match v.get("ev")?.as_str()? {
            "assign" => Event::Assign {
                layer: le("layer")?,
                expert: le("expert")?,
                gpu: v.get("gpu")?.as_bool()?,
                device: dev(),
                workload: le("workload")?,
                cost_ns: ns("cost_ns")?,
            },
            "prefetch_issue" => Event::PrefetchIssue {
                layer: le("layer")?,
                expert: le("expert")?,
                device: dev(),
                arrival: ns("arrival")?,
            },
            "prefetch_hit" => {
                Event::PrefetchHit { layer: le("layer")?, expert: le("expert")? }
            }
            "prefetch_wasted" => {
                Event::PrefetchWasted { layer: le("layer")?, expert: le("expert")? }
            }
            "ahead_issue" => Event::AheadIssue {
                layer: le("layer")?,
                expert: le("expert")?,
                arrival: ns("arrival")?,
            },
            "ahead_hit" => Event::AheadHit {
                layer: le("layer")?,
                expert: le("expert")?,
                hidden_ns: ns("hidden_ns")?,
            },
            "ahead_miss" => {
                Event::AheadMiss { layer: le("layer")?, expert: le("expert")? }
            }
            "fetch" => Event::Fetch {
                layer: le("layer")?,
                expert: le("expert")?,
                demand: v.get("demand")?.as_bool()?,
                arrival: ns("arrival")?,
            },
            "spill" => Event::Spill {
                layer: le("layer")?,
                expert: le("expert")?,
                writeback: v.get("writeback")?.as_bool()?,
            },
            "cache_admit" => {
                Event::CacheAdmit { layer: le("layer")?, expert: le("expert")?, device: dev() }
            }
            "cache_evict" => {
                Event::CacheEvict { layer: le("layer")?, expert: le("expert")?, device: dev() }
            }
            "lane" => Event::LaneBusy {
                lane: Lane::from_name(v.get("lane")?.as_str()?)?,
                device: dev(),
                start: ns("start")?,
                end: ns("end")?,
            },
            "reset" => Event::Reset { at: ns("at")? },
            "step" => Event::StepEnd {
                step: ns("step")?,
                decode: v.get("decode")?.as_bool()?,
                end_ns: ns("end_ns")?,
                tokens: le("tokens")?,
            },
            "fault_retry" => Event::FaultRetry {
                lane: Lane::from_name(v.get("lane")?.as_str()?)?,
                layer: le("layer")?,
                expert: le("expert")?,
                attempt: le("attempt")?,
                at: ns("at")?,
            },
            "fault_abort" => Event::FaultAbort {
                lane: Lane::from_name(v.get("lane")?.as_str()?)?,
                layer: le("layer")?,
                expert: le("expert")?,
                attempts: le("attempts")?,
                at: ns("at")?,
            },
            "ram_pressure" => Event::RamPressure {
                at: ns("at")?,
                reserved: le("reserved")?,
                spilled: le("spilled")?,
            },
            "request_arrive" => Event::RequestArrive {
                req: le("req")?,
                at: ns("at")?,
                prompt_len: le("prompt_len")?,
                max_tokens: le("max_tokens")?,
            },
            "request_admit" => Event::RequestAdmit {
                req: le("req")?,
                at: ns("at")?,
                queue_ns: ns("queue_ns")?,
            },
            "request_first_token" => Event::RequestFirstToken {
                req: le("req")?,
                at: ns("at")?,
                ttft_ns: ns("ttft_ns")?,
            },
            "request_finish" => Event::RequestFinish {
                req: le("req")?,
                at: ns("at")?,
                tokens: le("tokens")?,
            },
            "request_reject" => Event::RequestReject {
                req: le("req")?,
                at: ns("at")?,
                reason: le("reason")?,
            },
            "request_evict" => Event::RequestEvict {
                req: le("req")?,
                at: ns("at")?,
                generated: le("generated")?,
                overdue_ns: ns("overdue_ns")?,
            },
            "degrade_enter" => Event::DegradeEnter {
                at: ns("at")?,
                from: le("from")?,
                to: le("to")?,
                queue_depth: le("queue_depth")?,
            },
            "degrade_exit" => Event::DegradeExit {
                at: ns("at")?,
                from: le("from")?,
                to: le("to")?,
                queue_depth: le("queue_depth")?,
            },
            "p2p_copy" => Event::P2pCopy {
                layer: le("layer")?,
                expert: le("expert")?,
                from: le("from")? as u8,
                to: le("to")? as u8,
                start: ns("start")?,
                end: ns("end")?,
            },
            other => bail!("unknown trace event '{other}'"),
        })
    }

    /// One exemplar of every variant — keeps round-trip and digest tests
    /// exhaustive by construction (a new variant must be added here, or
    /// the match in `fold_words`/`to_value` fails to compile first).
    pub fn examples() -> Vec<Event> {
        vec![
            Event::Assign { layer: 3, expert: 7, gpu: true, device: 0, workload: 12, cost_ns: 4096 },
            Event::Assign { layer: 3, expert: 2, gpu: false, device: 0, workload: 1, cost_ns: 900 },
            Event::Assign { layer: 3, expert: 4, gpu: true, device: 1, workload: 6, cost_ns: 2048 },
            Event::PrefetchIssue { layer: 4, expert: 1, device: 0, arrival: 77_000 },
            Event::PrefetchIssue { layer: 4, expert: 2, device: 3, arrival: 78_000 },
            Event::PrefetchHit { layer: 4, expert: 1 },
            Event::PrefetchWasted { layer: 4, expert: 6 },
            Event::AheadIssue { layer: 5, expert: 0, arrival: 123_456 },
            Event::AheadHit { layer: 5, expert: 0, hidden_ns: 98_765 },
            Event::AheadMiss { layer: 5, expert: 3 },
            Event::Fetch { layer: 2, expert: 4, demand: true, arrival: 55_555 },
            Event::Fetch { layer: 2, expert: 5, demand: false, arrival: 66_666 },
            Event::Spill { layer: 1, expert: 2, writeback: false },
            Event::Spill { layer: 1, expert: 3, writeback: true },
            Event::CacheAdmit { layer: 0, expert: 5, device: 0 },
            Event::CacheAdmit { layer: 0, expert: 6, device: 2 },
            Event::CacheEvict { layer: 0, expert: 2, device: 0 },
            Event::CacheEvict { layer: 0, expert: 3, device: 1 },
            Event::LaneBusy { lane: Lane::NvmeRead, device: 0, start: 100, end: 350 },
            Event::LaneBusy { lane: Lane::Transcode, device: 0, start: 350, end: 400 },
            Event::LaneBusy { lane: Lane::Cpu, device: 0, start: 0, end: 10 },
            Event::LaneBusy { lane: Lane::GpuCompute, device: 1, start: 20, end: 44 },
            Event::LaneBusy { lane: Lane::P2p, device: 0, start: 44, end: 60 },
            Event::Reset { at: 1_000_000 },
            Event::StepEnd { step: 9, decode: true, end_ns: 2_000_000, tokens: 8 },
            Event::FaultRetry { lane: Lane::NvmeRead, layer: 2, expert: 6, attempt: 1, at: 500 },
            Event::FaultAbort { lane: Lane::NvmeRead, layer: 2, expert: 6, attempts: 4, at: 900 },
            Event::RamPressure { at: 1_500, reserved: 12, spilled: 5 },
            Event::RequestArrive { req: 0, at: 2_000, prompt_len: 8, max_tokens: 16 },
            Event::RequestAdmit { req: 0, at: 2_500, queue_ns: 500 },
            Event::RequestFirstToken { req: 0, at: 3_000, ttft_ns: 1_000 },
            Event::RequestFinish { req: 0, at: 9_000, tokens: 16 },
            Event::RequestReject { req: 1, at: 2_100, reason: 2 },
            Event::RequestEvict { req: 2, at: 8_000, generated: 5, overdue_ns: 3_000 },
            Event::DegradeEnter { at: 4_000, from: 0, to: 1, queue_depth: 9 },
            Event::DegradeExit { at: 7_000, from: 1, to: 0, queue_depth: 1 },
            Event::P2pCopy { layer: 2, expert: 9, from: 0, to: 1, start: 60, end: 90 },
        ]
    }
}
