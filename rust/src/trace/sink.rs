//! Trace sinks: where events go, at what cost.
//!
//! The simulator is generic over `S: TraceSink` and guards every emission
//! site with `if S::ENABLED { ... }`. With the default [`NullSink`]
//! (`ENABLED = false`) the guard is a compile-time constant and the whole
//! instrumentation monomorphizes to nothing — the hot path stays
//! zero-alloc and bit-identical, which `alloc_audit` / `determinism`
//! continue to prove.
//!
//! Shipped sinks:
//! * [`NullSink`] — the zero-cost default;
//! * [`DigestSink`] — allocation-free FNV-1a over every event's canonical
//!   word encoding; its single `u64` locks whole runs in golden tests and
//!   surfaces in `RunMetrics::trace_digest`;
//! * [`JsonSink`] — buffered JSON-lines writer for `dali run --trace` and
//!   offline `dali trace summarize`.
//!
//! Sinks compose: `(DigestSink, JsonSink)` hashes and records in one pass.

use std::io::{self, Write};

use super::event::Event;

/// Receiver for trace events. `ENABLED` is an associated constant so the
/// disabled case is decided at monomorphization time, not at runtime.
pub trait TraceSink {
    /// Whether this sink wants events. Emission sites are guarded with
    /// `if S::ENABLED`, so a `false` here deletes the instrumentation
    /// (including the argument computation inside the guard) entirely.
    const ENABLED: bool = true;

    fn emit(&mut self, ev: &Event);

    /// The run digest, if this sink (or a composed member) computes one.
    fn digest(&self) -> Option<u64> {
        None
    }
}

/// The default sink: statically disabled, every emission compiles out.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TraceSink for NullSink {
    const ENABLED: bool = false;

    #[inline(always)]
    fn emit(&mut self, _ev: &Event) {}
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Allocation-free FNV-1a 64 over the canonical word encoding of every
/// event ([`Event::fold_words`], each word hashed as 8 little-endian
/// bytes). Two runs emit the same digest iff they emitted the same event
/// sequence — a whole-run equality lock in one `u64`.
#[derive(Debug, Clone, Copy)]
pub struct DigestSink {
    h: u64,
    /// Total events folded in (handy for sanity checks; not part of the
    /// digest itself — the event stream already determines it).
    pub events: u64,
}

impl DigestSink {
    pub fn new() -> Self {
        DigestSink { h: FNV_OFFSET, events: 0 }
    }

    /// Current digest value.
    pub fn value(&self) -> u64 {
        self.h
    }
}

impl Default for DigestSink {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceSink for DigestSink {
    #[inline]
    fn emit(&mut self, ev: &Event) {
        let mut h = self.h;
        ev.fold_words(&mut |w: u64| {
            for b in w.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        });
        self.h = h;
        self.events += 1;
    }

    fn digest(&self) -> Option<u64> {
        Some(self.h)
    }
}

/// Buffered JSON-lines sink: one `Event::to_value()` object per line.
/// Buffers into a `String` and flushes to the writer in 64 KiB chunks so
/// tracing a run costs large sequential writes, not a syscall per event.
/// I/O errors are deferred to [`JsonSink::finish`] (the simulator's
/// emission path stays infallible).
pub struct JsonSink<W: Write> {
    w: W,
    buf: String,
    err: Option<io::Error>,
    /// Events written (including any dropped after a deferred error).
    pub events: u64,
}

const JSON_FLUSH_BYTES: usize = 1 << 16;

impl<W: Write> JsonSink<W> {
    pub fn new(w: W) -> Self {
        JsonSink { w, buf: String::with_capacity(JSON_FLUSH_BYTES + 1024), err: None, events: 0 }
    }

    fn flush_buf(&mut self) {
        if self.err.is_some() || self.buf.is_empty() {
            return;
        }
        if let Err(e) = self.w.write_all(self.buf.as_bytes()) {
            self.err = Some(e);
        }
        self.buf.clear();
    }

    /// Flush remaining buffered lines and hand back the writer, or the
    /// first I/O error encountered while streaming.
    pub fn finish(mut self) -> io::Result<W> {
        self.flush_buf();
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        self.w.flush()?;
        Ok(self.w)
    }
}

impl<W: Write> TraceSink for JsonSink<W> {
    fn emit(&mut self, ev: &Event) {
        self.buf.push_str(&ev.to_value().to_json());
        self.buf.push('\n');
        self.events += 1;
        if self.buf.len() >= JSON_FLUSH_BYTES {
            self.flush_buf();
        }
    }
}

/// Composition: both members see every event. Enabled if either is, and
/// the digest comes from the first member that computes one.
impl<A: TraceSink, B: TraceSink> TraceSink for (A, B) {
    const ENABLED: bool = A::ENABLED || B::ENABLED;

    #[inline]
    fn emit(&mut self, ev: &Event) {
        if A::ENABLED {
            self.0.emit(ev);
        }
        if B::ENABLED {
            self.1.emit(ev);
        }
    }

    fn digest(&self) -> Option<u64> {
        self.0.digest().or(self.1.digest())
    }
}
