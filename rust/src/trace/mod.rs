//! Structured step-trace subsystem: typed per-step events, zero-cost
//! sinks, and digest-locked replay audits.
//!
//! The simulator's determinism guarantee (same scenario + bundle + seed →
//! bit-identical `RunMetrics`) is asserted in tests but was never
//! *exported*: runs could not be diffed across machines or inspected at
//! the timeline level. This module makes every scheduling decision
//! observable without touching the hot path's costs:
//!
//! * [`Event`] — typed, `Copy`, per-step events: assignment devices with
//!   priced costs, prefetch issue/hit/wasted, promote-ahead
//!   issue/hit/miss, demand fetches, spills, cache admit/evict, per-lane
//!   busy intervals in virtual time, resets, and step boundaries.
//! * [`TraceSink`] — the receiver trait. Statically zero-cost when
//!   disabled: [`NullSink`] sets `ENABLED = false` and every emission
//!   site (guarded `if S::ENABLED`) compiles out, proven by the
//!   `alloc_audit` and `determinism` suites running against the default.
//! * [`DigestSink`] — allocation-free FNV-1a over the canonical event
//!   words; one `u64` per run, surfaced in `RunMetrics::trace_digest`,
//!   printed by `dali run`, recorded per tier by `dali bench`, and
//!   equality-locked in golden tests.
//! * [`JsonSink`] — buffered JSON-lines for `dali run --trace out.jsonl`;
//!   [`TraceSummary`] reduces the file for `dali trace summarize`
//!   (per-lane utilization, overlap-hidden time, top-N wasted
//!   prefetches) and reproduces the run's busy counters exactly.

pub mod event;
pub mod sink;
pub mod summary;

pub use event::{Event, Lane};
pub use sink::{DigestSink, JsonSink, NullSink, TraceSink};
pub use summary::TraceSummary;
