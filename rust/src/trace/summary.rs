//! Offline reduction of a JSON-lines trace: per-lane utilization,
//! overlap-hidden time, prefetch/promotion outcomes, top-N wasted
//! prefetches. Backs `dali trace summarize`.
//!
//! The accumulators mirror the simulator's own bookkeeping: a `reset`
//! event zeroes them (warmup boundary) exactly like `reset_metrics`
//! zeroes `RunMetrics`, and the carry `LaneBusy` events emitted right
//! after a reset re-seed in-flight lane work — so the summary's lane
//! totals equal the final `RunMetrics` busy counters *exactly*, which the
//! sink tests assert.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::hw::Ns;
use crate::util::json::Value;

use super::event::{Event, Lane};

/// Aggregates computed from an event stream (file or in-memory).
#[derive(Debug, Default, Clone)]
pub struct TraceSummary {
    /// Total events observed, including pre-reset ones.
    pub events: u64,
    /// Number of `reset` events (metrics rebaselines).
    pub resets: u64,
    /// Steps retired since the last reset.
    pub steps: u64,
    /// Decode steps among them.
    pub decode_steps: u64,
    /// Tokens across retired steps.
    pub tokens: u64,
    /// Clock at the last `step` event == `RunMetrics::total_ns`.
    pub end_ns: Ns,
    /// Busy time per lane (indexed by `Lane::idx`), since the last reset.
    pub lane_busy: [Ns; Lane::COUNT],
    /// Interval count per lane, since the last reset.
    pub lane_ops: [u64; Lane::COUNT],
    pub assignments_gpu: u64,
    pub assignments_cpu: u64,
    pub prefetch_issued: u64,
    pub prefetch_hits: u64,
    pub prefetch_wasted: u64,
    pub ahead_issued: u64,
    pub ahead_hits: u64,
    pub ahead_misses: u64,
    /// Sum of `hidden_ns` over ahead hits == `nvme_overlap_hidden_ns`.
    pub overlap_hidden_ns: Ns,
    /// Demand-path disk fetches == `tier_disk_misses`.
    pub demand_fetches: u64,
    /// Speculative disk fetches (prefetch / cache-update chains).
    pub spec_fetches: u64,
    pub spills: u64,
    pub writeback_spills: u64,
    pub cache_admits: u64,
    pub cache_evicts: u64,
    /// Injected-fault retries (timed-out transfer attempts re-issued).
    pub fault_retries: u64,
    /// Speculative transfers abandoned after exhausting their retries.
    pub fault_aborts: u64,
    /// RAM-pressure transitions observed (shrink or restore edges).
    pub ram_pressure_events: u64,
    /// Experts demoted to satisfy RAM-pressure shrinks.
    pub ram_pressure_spills: u64,
    /// Serving-simulation request arrivals observed.
    pub request_arrivals: u64,
    /// Requests admitted into the running batch.
    pub request_admits: u64,
    /// Requests that produced at least one decode token.
    pub request_first_tokens: u64,
    /// Requests that finished and left the batch.
    pub request_finishes: u64,
    /// Tokens generated across finished requests.
    pub request_tokens: u64,
    /// Requests turned away by admission control.
    pub request_rejects: u64,
    /// Running requests evicted by deadline load-shedding.
    pub request_evicts: u64,
    /// Degradation-ladder escalations (rung went up).
    pub degrade_enters: u64,
    /// Degradation-ladder de-escalations (rung went down).
    pub degrade_exits: u64,
    /// Inter-GPU P2P expert copies (multi-GPU runs only).
    pub p2p_copies: u64,
    /// Total fabric time across those copies.
    pub p2p_busy_ns: Ns,
    /// Wasted-prefetch count per (layer, expert), since the last reset.
    pub wasted_by_expert: BTreeMap<(u32, u32), u64>,
}

impl TraceSummary {
    /// Fold one event in. Order matters only the way it does for the
    /// emitting run: a `reset` zeroes the post-warmup accumulators.
    pub fn observe(&mut self, ev: &Event) {
        self.events += 1;
        match *ev {
            Event::Reset { .. } => {
                let events = self.events;
                let resets = self.resets + 1;
                *self = TraceSummary::default();
                self.events = events;
                self.resets = resets;
            }
            Event::Assign { gpu, .. } => {
                if gpu {
                    self.assignments_gpu += 1;
                } else {
                    self.assignments_cpu += 1;
                }
            }
            Event::PrefetchIssue { .. } => self.prefetch_issued += 1,
            Event::PrefetchHit { .. } => self.prefetch_hits += 1,
            Event::PrefetchWasted { layer, expert } => {
                self.prefetch_wasted += 1;
                *self.wasted_by_expert.entry((layer, expert)).or_insert(0) += 1;
            }
            Event::AheadIssue { .. } => self.ahead_issued += 1,
            Event::AheadHit { hidden_ns, .. } => {
                self.ahead_hits += 1;
                self.overlap_hidden_ns += hidden_ns;
            }
            Event::AheadMiss { .. } => self.ahead_misses += 1,
            Event::Fetch { demand, .. } => {
                if demand {
                    self.demand_fetches += 1;
                } else {
                    self.spec_fetches += 1;
                }
            }
            Event::Spill { writeback, .. } => {
                self.spills += 1;
                if writeback {
                    self.writeback_spills += 1;
                }
            }
            Event::CacheAdmit { .. } => self.cache_admits += 1,
            Event::CacheEvict { .. } => self.cache_evicts += 1,
            Event::LaneBusy { lane, start, end, .. } => {
                // device-merged: a lane's total busy sums every device's
                // intervals (per-device splits live in RunMetrics)
                self.lane_busy[lane.idx()] += end.saturating_sub(start);
                self.lane_ops[lane.idx()] += 1;
            }
            Event::StepEnd { decode, end_ns, tokens, .. } => {
                self.steps += 1;
                if decode {
                    self.decode_steps += 1;
                }
                self.tokens += tokens as u64;
                self.end_ns = end_ns;
            }
            Event::FaultRetry { .. } => self.fault_retries += 1,
            Event::FaultAbort { .. } => self.fault_aborts += 1,
            Event::RamPressure { spilled, .. } => {
                self.ram_pressure_events += 1;
                self.ram_pressure_spills += spilled as u64;
            }
            Event::RequestArrive { .. } => self.request_arrivals += 1,
            Event::RequestAdmit { .. } => self.request_admits += 1,
            Event::RequestFirstToken { .. } => self.request_first_tokens += 1,
            Event::RequestFinish { tokens, .. } => {
                self.request_finishes += 1;
                self.request_tokens += tokens as u64;
            }
            Event::RequestReject { .. } => self.request_rejects += 1,
            Event::RequestEvict { .. } => self.request_evicts += 1,
            Event::DegradeEnter { .. } => self.degrade_enters += 1,
            Event::DegradeExit { .. } => self.degrade_exits += 1,
            Event::P2pCopy { start, end, .. } => {
                self.p2p_copies += 1;
                self.p2p_busy_ns += end.saturating_sub(start);
            }
        }
    }

    /// Parse a JSON-lines trace (blank lines ignored) and fold every
    /// event. Fails on the first malformed line, with its line number.
    pub fn from_json_lines(text: &str) -> Result<TraceSummary> {
        let mut s = TraceSummary::default();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let v = Value::parse(line).with_context(|| format!("trace line {}", i + 1))?;
            let ev = Event::from_value(&v).with_context(|| format!("trace line {}", i + 1))?;
            s.observe(&ev);
        }
        Ok(s)
    }

    /// The `n` (layer, expert) pairs with the most wasted prefetches,
    /// most-wasted first (ties broken by grid order).
    pub fn top_wasted(&self, n: usize) -> Vec<((u32, u32), u64)> {
        let mut v: Vec<((u32, u32), u64)> =
            self.wasted_by_expert.iter().map(|(k, c)| (*k, *c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }

    /// Human-readable report for `dali trace summarize`.
    pub fn render(&self, top_n: usize) -> String {
        let mut out = String::new();
        let pct = |busy: Ns| -> f64 {
            if self.end_ns == 0 {
                0.0
            } else {
                100.0 * busy as f64 / self.end_ns as f64
            }
        };
        out.push_str(&format!(
            "events {}  resets {}  steps {} ({} decode)  tokens {}  span {:.3} ms\n",
            self.events,
            self.resets,
            self.steps,
            self.decode_steps,
            self.tokens,
            self.end_ns as f64 / 1e6
        ));
        out.push_str("lane utilization (since last reset):\n");
        for lane in Lane::ALL {
            let i = lane.idx();
            out.push_str(&format!(
                "  {:<12} busy {:>12} ns  ({:>5.1}%)  intervals {}\n",
                lane.name(),
                self.lane_busy[i],
                pct(self.lane_busy[i]),
                self.lane_ops[i]
            ));
        }
        out.push_str(&format!(
            "assignments: gpu {}  cpu {}\n",
            self.assignments_gpu, self.assignments_cpu
        ));
        out.push_str(&format!(
            "prefetch: issued {}  hits {}  wasted {}\n",
            self.prefetch_issued, self.prefetch_hits, self.prefetch_wasted
        ));
        out.push_str(&format!(
            "promote-ahead: issued {}  hits {}  misses {}  overlap-hidden {} ns\n",
            self.ahead_issued, self.ahead_hits, self.ahead_misses, self.overlap_hidden_ns
        ));
        out.push_str(&format!(
            "store: demand fetches {}  spec fetches {}  spills {} ({} writeback)\n",
            self.demand_fetches, self.spec_fetches, self.spills, self.writeback_spills
        ));
        out.push_str(&format!(
            "cache: admits {}  evicts {}\n",
            self.cache_admits, self.cache_evicts
        ));
        if self.p2p_copies > 0 {
            out.push_str(&format!(
                "p2p fabric: copies {}  busy {} ns\n",
                self.p2p_copies, self.p2p_busy_ns
            ));
        }
        if self.fault_retries + self.fault_aborts + self.ram_pressure_events > 0 {
            out.push_str(&format!(
                "faults: retries {}  aborts {}  ram-pressure events {} ({} spills)\n",
                self.fault_retries,
                self.fault_aborts,
                self.ram_pressure_events,
                self.ram_pressure_spills
            ));
        }
        if self.request_arrivals > 0 {
            out.push_str(&format!(
                "serving: arrivals {}  admits {}  first-tokens {}  finished {} ({} tokens)\n",
                self.request_arrivals,
                self.request_admits,
                self.request_first_tokens,
                self.request_finishes,
                self.request_tokens
            ));
        }
        if self.request_rejects + self.request_evicts + self.degrade_enters + self.degrade_exits
            > 0
        {
            out.push_str(&format!(
                "overload: rejected {}  evicted {}  degrade enters {}  exits {}\n",
                self.request_rejects,
                self.request_evicts,
                self.degrade_enters,
                self.degrade_exits
            ));
        }
        let top = self.top_wasted(top_n);
        if !top.is_empty() {
            out.push_str(&format!("top-{} wasted prefetches (layer, expert, count):\n", top.len()));
            for ((l, e), c) in top {
                out.push_str(&format!("  L{l:<3} E{e:<3} x{c}\n"));
            }
        }
        out
    }
}
