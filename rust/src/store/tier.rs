//! The three-level residency lattice.

/// Where an expert's weights primarily live. Ordered coldest-first so
/// `Tier::Disk < Tier::Host < Tier::Gpu` reads as "promotion moves up".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    /// NVMe-resident: must be read into host RAM before any device can
    /// execute it (CPU included).
    Disk,
    /// Host-DRAM-resident: the paper's baseline assumption for all experts.
    Host,
    /// GPU-cache-resident (the host keeps the pinned staging copy).
    Gpu,
}

impl Tier {
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Disk => "disk",
            Tier::Host => "host",
            Tier::Gpu => "gpu",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lattice_orders_coldest_first() {
        assert!(Tier::Disk < Tier::Host);
        assert!(Tier::Host < Tier::Gpu);
        assert_eq!(Tier::Gpu.name(), "gpu");
    }
}
