//! The three-level residency lattice, device-indexed at the top.

/// Hard cap on the number of GPU device tiers one run may address. Keeps
/// the per-device metrics arrays fixed-size (`Copy`, exhaustively
/// destructurable) and bounds the `u8` device index with room to spare;
/// `HwConfig::validate` rejects presets asking for more.
pub const MAX_DEVICES: usize = 8;

/// Where an expert's weights primarily live. Ordered coldest-first so
/// `Tier::Disk < Tier::Host < Tier::Gpu(d)` reads as "promotion moves up".
///
/// The GPU tier is device-indexed: an N-GPU box has N distinct top tiers.
/// The derived ordering ranks `Gpu(0) < Gpu(1) < …` — that cross-device
/// order carries **no thermal meaning** (no device is "hotter" than
/// another); it exists only so sorts and victim tiebreaks over mixed tiers
/// stay fully deterministic. Use [`Tier::is_gpu`] / [`Tier::device`] when
/// the question is "on a GPU at all" vs "on which GPU".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    /// NVMe-resident: must be read into host RAM before any device can
    /// execute it (CPU included).
    Disk,
    /// Host-DRAM-resident: the paper's baseline assumption for all experts.
    Host,
    /// GPU-cache-resident on device `d` (the host keeps the pinned staging
    /// copy). Single-GPU runs use `Gpu(0)` everywhere.
    Gpu(u8),
}

impl Tier {
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Disk => "disk",
            Tier::Host => "host",
            Tier::Gpu(_) => "gpu",
        }
    }

    /// Whether the expert is on any GPU device.
    pub fn is_gpu(&self) -> bool {
        matches!(self, Tier::Gpu(_))
    }

    /// The GPU device index, if on a GPU tier.
    pub fn device(&self) -> Option<u8> {
        match self {
            Tier::Gpu(d) => Some(*d),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lattice_orders_coldest_first() {
        assert!(Tier::Disk < Tier::Host);
        assert!(Tier::Host < Tier::Gpu(0));
        assert_eq!(Tier::Gpu(0).name(), "gpu");
    }

    #[test]
    fn device_tiers_order_deterministically_above_host() {
        // every device tier sits above Host/Disk; the cross-device order is
        // a documented determinism tiebreak, not a thermal ranking
        for d in 0..MAX_DEVICES as u8 {
            assert!(Tier::Host < Tier::Gpu(d));
            assert!(Tier::Disk < Tier::Gpu(d));
        }
        assert!(Tier::Gpu(0) < Tier::Gpu(1));
        assert!(Tier::Gpu(1) < Tier::Gpu(7));
    }

    #[test]
    fn device_accessors() {
        assert!(Tier::Gpu(3).is_gpu());
        assert!(!Tier::Host.is_gpu() && !Tier::Disk.is_gpu());
        assert_eq!(Tier::Gpu(3).device(), Some(3));
        assert_eq!(Tier::Host.device(), None);
        assert_eq!(Tier::Disk.device(), None);
    }
}
