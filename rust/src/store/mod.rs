//! Tiered expert store: GPU HBM / host RAM / NVMe residency with an async
//! transfer scheduler.
//!
//! The paper assumes every expert lives in host DRAM and models a two-tier
//! GPU↔host hierarchy. Local-PC deployments of DeepSeek-V3-class models
//! break that assumption: cold expert storage exceeds both VRAM *and* RAM,
//! so residency placement across three tiers — not just GPU caching —
//! dominates latency once RAM is constrained. This subsystem adds that
//! axis:
//!
//! * [`Tier`] — the residency lattice `Gpu > Host > Disk`. Every expert has
//!   exactly one *primary* tier (the conservation invariant the property
//!   tests assert).
//! * [`TransferScheduler`] — distinct virtual-time NVMe read/write streams
//!   (disk↔host) plus a CPU *transcode lane*: scenarios with a quantized
//!   on-disk format (`quant_ratio` < 1 in `configs/presets.json`) read
//!   fewer bytes off NVMe but must dequantize before host RAM holds
//!   usable fp16 weights — the transcode runs on its own lane, so it
//!   overlaps subsequent reads and all GPU work. Host↔GPU traffic stays
//!   on the existing [`crate::hw::GpuPipeline`] PCIe lanes; promotions
//!   from disk chain NVMe-read → transcode → PCIe.
//! * [`TieredStore`] — per-expert residency state plus a slot allocator for
//!   the host tier. Promotions (disk→host→GPU) are charged to the streams;
//!   GPU cache evictions *demote into the store* instead of dropping.
//!
//! Semantics: host↔GPU is **inclusive** (promoting an expert to the GPU
//! cache keeps its pinned host staging copy, so eviction back to host is
//! free bookkeeping — exactly the seed's two-tier behaviour), while
//! disk↔host is **exclusive** (a disk-resident expert consumes no host
//! slot). With an unlimited host budget every expert starts host-resident,
//! no NVMe traffic ever occurs, and the simulator reproduces the two-tier
//! virtual-time results bit-for-bit (regression-tested in
//! `rust/tests/store_property.rs`).
//!
//! * [`PlacementCfg`] (module [`placement`]) — workload-predictive
//!   placement: NVMe→host promotions issued from the prefetcher's workload
//!   predictions one layer ahead of need (cross-layer overlap on the
//!   dedicated read stream) and predicted-workload-score demotion instead
//!   of LRU spill. Off by default; the DALI bundles enable it, the
//!   baseline frameworks keep the reactive PR 1 behaviour
//!   (invariant-tested in `rust/tests/placement_property.rs`).

pub mod placement;
mod scheduler;
mod tier;
mod tiered;

pub use placement::PlacementCfg;
pub use scheduler::TransferScheduler;
pub use tier::{Tier, MAX_DEVICES};
pub use tiered::{StoreCfg, TieredStore};
