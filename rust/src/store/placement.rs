//! Workload-predictive tier placement (the DALI thesis applied to the
//! storage hierarchy).
//!
//! PR 1's store placed experts *reactively*: NVMe promotions happened at
//! access time (demand) or chained onto the same layer's speculative PCIe
//! lane (prefetch), and host-tier spills picked the LRU victim — exactly
//! the static-policy mismatch the paper argues against for GPU caching,
//! replayed one tier down. This module makes residency *anticipatory*:
//!
//! * **Promote ahead** — the residual prefetcher's per-layer workload
//!   predictions (paper §4.2) drive NVMe→host promotions for layer `l+1`
//!   while layer `l` computes. The reads run on the store's dedicated NVMe
//!   read stream, decoupled from the PCIe spec lane, so by the time the
//!   expert is demanded (on either device) most of the NVMe latency is
//!   hidden behind compute ([`crate::metrics::RunMetrics::nvme_overlap_hidden_ns`]).
//! * **Demote by predicted workload** — the host-tier spill victim is the
//!   expert with the lowest EWMA workload score (observed workloads decayed
//!   per step, raised by fresh predictions), not the LRU one. HybriMoE
//!   (arXiv:2504.05897) and DAOP (arXiv:2501.10375) both observe that
//!   prediction only pays when it drives placement, not just fetch.
//!
//! Quantized on-disk formats (scenario `quant_ratio` < 1) compound with
//! promote-ahead: each speculative read moves fewer bytes, so the read
//! stream's backlog gate admits more promotions per layer, and the CPU
//! transcode stage of each promotion overlaps the next expert's read on
//! its own lane (see [`crate::store::TransferScheduler`]).
//!
//! The policy is pure virtual-time bookkeeping over pre-allocated tables:
//! zero steady-state allocation (enforced by `tests/alloc_audit.rs` on the
//! `mixtral-sim-ram16` scenario, fp16 and q4 on-disk) and
//! bit-deterministic for a fixed seed.

use crate::hw::{CostModel, Ns};
use crate::trace::{NullSink, TraceSink};

use super::tiered::TieredStore;

/// Placement policy knobs, carried per framework bundle so the
/// DALI-vs-baselines comparisons stay honest (baselines keep LRU spill and
/// demand-only promotion).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlacementCfg {
    /// Master switch: predictive promote-ahead + score-based demotion.
    pub predictive: bool,
    /// Max predictive NVMe→host promotions issued per layer step.
    pub ahead: usize,
    /// Backlog gate: stop issuing speculative reads once the NVMe read
    /// stream is this many expert-reads behind `now` (wrong predictions
    /// must never starve demand promotions of stream time).
    pub max_backlog: u64,
    /// Per-step EWMA decay of the observed-workload score table.
    pub decay: f64,
}

impl Default for PlacementCfg {
    fn default() -> Self {
        PlacementCfg { predictive: false, ahead: 2, max_backlog: 2, decay: 0.5 }
    }
}

impl PlacementCfg {
    /// The predictive configuration used by the DALI bundles: promote-ahead
    /// budget scales with the framework's prefetch size (the same
    /// prediction ranking feeds both), clamped to keep the NVMe stream from
    /// running more than a few expert-reads speculative.
    pub fn predictive(prefetch_size: usize) -> Self {
        PlacementCfg {
            predictive: true,
            ahead: (2 * prefetch_size.max(1)).min(8),
            ..PlacementCfg::default()
        }
    }
}

/// Issue up to `cfg.ahead` predictive NVMe→host promotions for `layer`,
/// walking `ranked` (expert ids by descending predicted workload) and
/// skipping experts that are already host/GPU-resident or predicted idle.
/// `now` is the instant the prediction becomes available (after the gate
/// pass), i.e. while the *previous* layer's compute is still running.
/// Returns the number of promotions issued.
pub fn promote_ahead_layer(
    store: &mut TieredStore,
    layer: usize,
    ranked: &[usize],
    scores: &[f64],
    now: Ns,
    cost: &CostModel,
) -> usize {
    promote_ahead_layer_t(store, layer, ranked, scores, now, cost, &mut NullSink)
}

/// [`promote_ahead_layer`] with a trace sink: each issued promotion emits
/// an `ahead_issue` event (plus its NVMe/transcode lane intervals).
#[allow(clippy::too_many_arguments)]
pub fn promote_ahead_layer_t<S: TraceSink>(
    store: &mut TieredStore,
    layer: usize,
    ranked: &[usize],
    scores: &[f64],
    now: Ns,
    cost: &CostModel,
    sink: &mut S,
) -> usize {
    // graceful degradation: while the fault plan's RAM-pressure process
    // holds host slots confiscated, the whole speculative walk is skipped —
    // promotions would only thrash the shrunken tier (the per-expert
    // promote-ahead gate refuses too; this just short-circuits the scan).
    if store.under_pressure() {
        return 0;
    }
    let budget = store.placement().ahead;
    let mut issued = 0usize;
    for &e in ranked {
        if issued == budget {
            break;
        }
        if scores[e] <= 0.0 {
            break; // ranked is sorted: nothing predicted beyond this point
        }
        if store.promote_ahead_t(layer, e, now, cost, sink) {
            issued += 1;
        }
    }
    issued
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Presets;
    use crate::store::{StoreCfg, Tier};

    fn cost() -> CostModel {
        let p = Presets::load_default().unwrap();
        CostModel::new(p.model("mixtral-sim").unwrap(), p.hw("local-pc-ram16").unwrap())
    }

    fn predictive_store(layers: usize, n: usize, slots: usize) -> TieredStore {
        let mut st =
            TieredStore::new(layers, n, StoreCfg { host_slots: slots, ..Default::default() });
        st.set_placement(PlacementCfg::predictive(1));
        st
    }

    #[test]
    fn default_is_reactive_and_predictive_scales_with_prefetch() {
        assert!(!PlacementCfg::default().predictive);
        let p1 = PlacementCfg::predictive(1);
        assert!(p1.predictive);
        assert_eq!(p1.ahead, 2);
        assert_eq!(PlacementCfg::predictive(4).ahead, 8);
        assert_eq!(PlacementCfg::predictive(16).ahead, 8, "budget is clamped");
        assert_eq!(PlacementCfg::predictive(0).ahead, 2);
    }

    #[test]
    fn promote_ahead_layer_respects_budget_and_ranking() {
        let c = cost();
        let mut st = predictive_store(2, 8, 8);
        // expert-major fill: 8 slots cover experts 0..4 of both layers,
        // so layer 1 experts 4..8 start on disk
        assert_eq!(st.tier(1, 5), Tier::Disk);
        let scores = vec![0.0, 0.0, 0.0, 0.0, 3.0, 9.0, 1.0, 0.0];
        let ranked = vec![5usize, 4, 6, 0, 1, 2, 3, 7];
        st.note_predictions(1, &scores);
        let issued = promote_ahead_layer(&mut st, 1, &ranked, &scores, 0, &c);
        assert_eq!(issued, 2, "budget (ahead=2) bounds issuance");
        assert_eq!(st.tier(1, 5), Tier::Host);
        assert_eq!(st.tier(1, 4), Tier::Host);
        assert_eq!(st.tier(1, 6), Tier::Disk, "third candidate over budget");
        assert_eq!(st.ahead_issued, 2);
        st.check_invariants().unwrap();
    }

    #[test]
    fn zero_scores_issue_nothing() {
        let c = cost();
        let mut st = predictive_store(1, 8, 4);
        let scores = vec![0.0; 8];
        let ranked: Vec<usize> = (0..8).collect();
        assert_eq!(promote_ahead_layer(&mut st, 0, &ranked, &scores, 0, &c), 0);
        assert_eq!(st.ahead_issued, 0);
        assert_eq!(st.xfer.read_bytes, 0);
    }

    #[test]
    fn disabled_placement_never_promotes_ahead() {
        let c = cost();
        let mut st = TieredStore::new(1, 8, StoreCfg { host_slots: 2, ..Default::default() });
        assert_eq!(st.placement(), &PlacementCfg::default());
        let scores = vec![5.0; 8];
        let ranked: Vec<usize> = (0..8).collect();
        assert_eq!(promote_ahead_layer(&mut st, 0, &ranked, &scores, 0, &c), 0);
        assert_eq!(st.promotions, 0);
    }
}
