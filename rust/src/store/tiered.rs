//! Per-expert residency state + host-tier slot allocator.
//!
//! Residency is tracked over the *sim-scale* expert grid (layers ×
//! n_routed), while byte budgets are *paper-scale* (the repo's "virtual
//! time, real numerics" doctrine): a host-RAM budget is converted into a
//! slot count by taking the fraction of total paper-scale expert bytes it
//! can hold and applying that fraction to the sim grid. Timing ratios
//! (NVMe vs PCIe vs compute) therefore match the paper-scale hardware.

use crate::config::HwConfig;
use crate::hw::{CostModel, Ns};

use super::scheduler::TransferScheduler;
use super::tier::Tier;

/// Store configuration.
#[derive(Debug, Clone)]
pub struct StoreCfg {
    /// Host-tier capacity in experts (`usize::MAX` = unlimited, the
    /// paper's two-tier assumption).
    pub host_slots: usize,
    /// Charge an NVMe write when spilling host → disk. Off by default:
    /// expert weights are immutable and the disk master copy always
    /// exists, so a spill of the canonical format is a free drop. Enable
    /// for stores whose host pool holds a transcoded (e.g. dequantized)
    /// format that must be persisted to NVMe scratch.
    pub spill_writeback: bool,
}

impl Default for StoreCfg {
    fn default() -> Self {
        StoreCfg { host_slots: usize::MAX, spill_writeback: false }
    }
}

/// Three-tier expert store: residency map, host slot allocator, and the
/// NVMe transfer scheduler. See the module docs for the tier semantics.
#[derive(Debug, Clone)]
pub struct TieredStore {
    layers: usize,
    n_experts: usize,
    /// Primary tier per expert, flat `layer * n_experts + e`.
    tier: Vec<Tier>,
    /// Experts whose primary tier is Host or Gpu (inclusive host↔GPU).
    host_used: usize,
    host_slots: usize,
    spill_writeback: bool,
    /// LRU clock for host-victim selection.
    clock: u64,
    last_use: Vec<u64>,
    /// Layers whose initial GPU cache residency has been reconciled.
    synced: Vec<bool>,
    /// NVMe read/write virtual-time streams.
    pub xfer: TransferScheduler,
    /// Disk→host promotions (NVMe reads charged).
    pub promotions: u64,
    /// Host→disk spills.
    pub spills: u64,
    /// GPU→host demotions (cache evictions folded into the store).
    pub gpu_demotions: u64,
    /// Host promotions requested while every host slot was pinned by a
    /// GPU-resident expert (capacity floor violations; see
    /// `ensure_min_slots`).
    pub overcommits: u64,
}

impl TieredStore {
    /// Build a store with `host_slots` host-tier slots. Initial placement
    /// fills the host tier expert-major (expert 0 of every layer, then
    /// expert 1, ...), so every layer keeps a warm working set and cold
    /// expert ids spill to disk — deterministic and model-agnostic.
    pub fn new(layers: usize, n_experts: usize, cfg: StoreCfg) -> Self {
        let total = layers * n_experts;
        let mut tier = vec![Tier::Disk; total];
        let mut placed = 0usize;
        'fill: for e in 0..n_experts {
            for l in 0..layers {
                if placed == cfg.host_slots {
                    break 'fill;
                }
                tier[l * n_experts + e] = Tier::Host;
                placed += 1;
            }
        }
        TieredStore {
            layers,
            n_experts,
            tier,
            host_used: placed,
            host_slots: cfg.host_slots,
            spill_writeback: cfg.spill_writeback,
            clock: 0,
            last_use: vec![0; total],
            synced: vec![false; layers],
            xfer: TransferScheduler::new(),
            promotions: 0,
            spills: 0,
            gpu_demotions: 0,
            overcommits: 0,
        }
    }

    /// Two-tier store: host RAM holds every expert (seed behaviour).
    pub fn unlimited(layers: usize, n_experts: usize) -> Self {
        Self::new(layers, n_experts, StoreCfg::default())
    }

    /// Derive the store from a hardware preset: the host-RAM budget (with
    /// 10 % headroom for activations/KV staging) is converted to a
    /// sim-grid slot count via the paper-scale expert footprint.
    pub fn for_model(hw: &HwConfig, cost: &CostModel, layers: usize, n_experts: usize) -> Self {
        if hw.host_ram_bytes <= 0.0 {
            return Self::unlimited(layers, n_experts);
        }
        let total = layers * n_experts;
        let frac = (hw.host_ram_bytes * 0.9 / cost.total_expert_bytes()).clamp(0.0, 1.0);
        let slots = ((frac * total as f64).floor() as usize).max(1);
        let cfg = StoreCfg { host_slots: slots.min(total), ..StoreCfg::default() };
        Self::new(layers, n_experts, cfg)
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn host_slots(&self) -> usize {
        self.host_slots
    }

    pub fn host_used(&self) -> usize {
        self.host_used
    }

    /// Whether this store can hold every expert in host RAM.
    pub fn is_unlimited(&self) -> bool {
        self.host_slots >= self.layers * self.n_experts
    }

    fn idx(&self, layer: usize, e: usize) -> usize {
        debug_assert!(layer < self.layers && e < self.n_experts);
        layer * self.n_experts + e
    }

    pub fn tier(&self, layer: usize, e: usize) -> Tier {
        self.tier[self.idx(layer, e)]
    }

    /// Residency tiers of one whole layer (assignment input).
    pub fn layer_tiers(&self, layer: usize) -> Vec<Tier> {
        let mut out = Vec::with_capacity(self.n_experts);
        self.layer_tiers_into(layer, &mut out);
        out
    }

    /// Buffer-reusing form of [`Self::layer_tiers`] — the simulator reads
    /// this snapshot every MoE layer, so it must not allocate.
    pub fn layer_tiers_into(&self, layer: usize, out: &mut Vec<Tier>) {
        let i = layer * self.n_experts;
        out.clear();
        out.extend_from_slice(&self.tier[i..i + self.n_experts]);
    }

    /// Record a use (LRU recency) without changing residency.
    pub fn touch(&mut self, layer: usize, e: usize) {
        self.clock += 1;
        let i = self.idx(layer, e);
        self.last_use[i] = self.clock;
    }

    /// Raise the host capacity floor so it can always pin the GPU cache's
    /// staging copies (call once with the cache's total capacity).
    pub fn ensure_min_slots(&mut self, min: usize) {
        let total = self.layers * self.n_experts;
        if self.host_slots < min {
            self.host_slots = min.min(total);
        }
    }

    /// Zero the operation counters (metrics-period boundary). Residency
    /// state and stream clocks are untouched — pair with
    /// `xfer.rebase_and_clear`.
    pub fn clear_op_counters(&mut self) {
        self.promotions = 0;
        self.spills = 0;
        self.gpu_demotions = 0;
        self.overcommits = 0;
    }

    /// Make `e` of `layer` host-resident, charging an NVMe read if it was
    /// on disk (and spilling an LRU host victim if the host tier is full).
    /// Returns the virtual instant the weights are available in host RAM
    /// (`now` when already host- or GPU-resident).
    pub fn ensure_host(&mut self, layer: usize, e: usize, now: Ns, cost: &CostModel) -> Ns {
        let i = self.idx(layer, e);
        self.touch(layer, e);
        if self.tier[i] != Tier::Disk {
            return now;
        }
        if self.host_used >= self.host_slots {
            self.spill_one(now, (layer, e), cost);
        }
        if self.host_used >= self.host_slots {
            // every slot is pinned by a GPU-resident staging copy: those
            // set a hard floor below which the budget cannot shrink — grow
            // it and record the overcommit.
            self.host_slots = self.host_used + 1;
            self.overcommits += 1;
        }
        self.tier[i] = Tier::Host;
        self.host_used += 1;
        self.promotions += 1;
        let bytes = cost.expert_bytes() as u64;
        self.xfer.schedule_read(now, cost.nvme_read_time(), bytes)
    }

    /// Spill the least-recently-used host-primary expert to disk. GPU-tier
    /// experts are pinned (their host copy backs the GPU cache) and never
    /// chosen. No-op if every slot is pinned — the caller then grows the
    /// budget floor and records an overcommit.
    fn spill_one(&mut self, now: Ns, protect: (usize, usize), cost: &CostModel) {
        let pi = protect.0 * self.n_experts + protect.1;
        let mut victim: Option<usize> = None;
        for i in 0..self.tier.len() {
            if i == pi || self.tier[i] != Tier::Host {
                continue;
            }
            if victim.map(|v| self.last_use[i] < self.last_use[v]).unwrap_or(true) {
                victim = Some(i);
            }
        }
        if let Some(v) = victim {
            self.tier[v] = Tier::Disk;
            self.host_used -= 1;
            self.spills += 1;
            if self.spill_writeback {
                let bytes = cost.expert_bytes() as u64;
                self.xfer.schedule_write(now, cost.nvme_write_time(), bytes);
            }
        }
    }

    /// Mark `e` of `layer` GPU-resident (cache admission / swap load). The
    /// caller is responsible for having made it host-resident first
    /// (`ensure_host`) and for charging the PCIe upload; a disk-resident
    /// expert is tolerated only for free initial placement and claims its
    /// host slot without NVMe traffic.
    pub fn admit_to_gpu(&mut self, layer: usize, e: usize) {
        let i = self.idx(layer, e);
        self.touch(layer, e);
        if self.tier[i] == Tier::Disk {
            // initial placement path (cache seeded before the store syncs)
            self.host_used += 1;
            if self.host_used > self.host_slots {
                self.host_slots = self.host_used;
            }
        }
        self.tier[i] = Tier::Gpu;
    }

    /// Fold a GPU cache eviction into the store: the expert's primary tier
    /// drops to Host (free — the pinned host copy still exists).
    pub fn demote_gpu(&mut self, layer: usize, e: usize) {
        let i = self.idx(layer, e);
        if self.tier[i] == Tier::Gpu {
            self.tier[i] = Tier::Host;
            self.gpu_demotions += 1;
        }
    }

    /// One-time reconciliation of a layer's initial cache residency (the
    /// caches seed random resident sets before the store exists). Free:
    /// models load-time placement, not runtime traffic.
    pub fn sync_layer(&mut self, layer: usize, gpu_mask: &[bool]) {
        if self.synced[layer] {
            return;
        }
        self.synced[layer] = true;
        for e in 0..self.n_experts.min(gpu_mask.len()) {
            let i = self.idx(layer, e);
            if gpu_mask[e] && self.tier[i] != Tier::Gpu {
                self.admit_to_gpu(layer, e);
            }
        }
    }

    /// (gpu, host, disk) expert counts across the whole grid.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for t in &self.tier {
            match t {
                Tier::Gpu => c.0 += 1,
                Tier::Host => c.1 += 1,
                Tier::Disk => c.2 += 1,
            }
        }
        c
    }

    /// GPU-primary experts of one layer (memory-model consistency checks).
    pub fn gpu_count_layer(&self, layer: usize) -> usize {
        let i = layer * self.n_experts;
        self.tier[i..i + self.n_experts].iter().filter(|t| **t == Tier::Gpu).count()
    }

    /// Paper-scale bytes the host tier currently pins (slot fraction of
    /// the total expert footprint).
    pub fn host_bytes_paper(&self, cost: &CostModel) -> f64 {
        let total = (self.layers * self.n_experts).max(1);
        cost.total_expert_bytes() * self.host_used as f64 / total as f64
    }

    /// Verify the store's internal invariants; returns a description of
    /// the first violation. Used by the property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let (gpu, host, disk) = self.counts();
        if gpu + host + disk != self.layers * self.n_experts {
            return Err(format!(
                "residency not conserved: {gpu}+{host}+{disk} != {}",
                self.layers * self.n_experts
            ));
        }
        if gpu + host != self.host_used {
            return Err(format!(
                "host accounting drift: counted {} vs tracked {}",
                gpu + host,
                self.host_used
            ));
        }
        if self.host_used > self.host_slots {
            return Err(format!(
                "host over capacity: {} used > {} slots",
                self.host_used, self.host_slots
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Presets;

    fn cost() -> CostModel {
        let p = Presets::load_default().unwrap();
        CostModel::new(p.model("mixtral-sim").unwrap(), p.hw("local-pc").unwrap())
    }

    #[test]
    fn unlimited_store_is_all_host() {
        let s = TieredStore::unlimited(4, 8);
        assert!(s.is_unlimited());
        let (g, h, d) = s.counts();
        assert_eq!((g, h, d), (0, 32, 0));
        s.check_invariants().unwrap();
    }

    #[test]
    fn limited_store_spreads_host_slots_across_layers() {
        let s = TieredStore::new(4, 8, StoreCfg { host_slots: 8, ..Default::default() });
        let (_, h, d) = s.counts();
        assert_eq!(h, 8);
        assert_eq!(d, 24);
        // expert-major fill → every layer holds experts 0 and 1
        for l in 0..4 {
            assert_eq!(s.tier(l, 0), Tier::Host);
            assert_eq!(s.tier(l, 1), Tier::Host);
            assert_eq!(s.tier(l, 2), Tier::Disk);
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn ensure_host_charges_nvme_and_spills_lru() {
        let c = cost();
        let mut s = TieredStore::new(2, 4, StoreCfg { host_slots: 2, ..Default::default() });
        // initial host set: (0,0) and (1,0)
        assert_eq!(s.tier(0, 0), Tier::Host);
        assert_eq!(s.tier(1, 0), Tier::Host);
        s.touch(0, 0); // (1,0) is now LRU
        let arr = s.ensure_host(0, 3, 0, &c);
        assert_eq!(arr, c.nvme_read_time());
        assert_eq!(s.tier(0, 3), Tier::Host);
        assert_eq!(s.tier(1, 0), Tier::Disk, "LRU host expert spilled");
        assert_eq!(s.promotions, 1);
        assert_eq!(s.spills, 1);
        assert_eq!(s.xfer.write_bytes, 0, "clean spill is free by default");
        s.check_invariants().unwrap();
        // second promotion queues behind the first on the read stream
        let arr2 = s.ensure_host(1, 3, 0, &c);
        assert_eq!(arr2, 2 * c.nvme_read_time());
    }

    #[test]
    fn writeback_spills_charge_the_write_stream() {
        let c = cost();
        let mut s =
            TieredStore::new(2, 4, StoreCfg { host_slots: 1, spill_writeback: true });
        s.ensure_host(1, 3, 0, &c);
        assert_eq!(s.spills, 1);
        assert!(s.xfer.write_bytes > 0);
        assert_eq!(s.xfer.write_busy, c.nvme_write_time());
    }

    #[test]
    fn gpu_admission_pins_and_demotion_is_free() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        s.ensure_host(0, 0, 0, &c); // already host; no-op
        s.admit_to_gpu(0, 0);
        assert_eq!(s.tier(0, 0), Tier::Gpu);
        // GPU expert is pinned: promoting two more spills only expert 1
        s.ensure_host(0, 2, 0, &c);
        assert_eq!(s.tier(0, 1), Tier::Disk);
        assert_eq!(s.tier(0, 0), Tier::Gpu);
        let nvme = s.xfer.read_busy;
        s.demote_gpu(0, 0);
        assert_eq!(s.tier(0, 0), Tier::Host);
        assert_eq!(s.xfer.read_busy, nvme, "demotion moves no bytes");
        assert_eq!(s.gpu_demotions, 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn sync_layer_is_free_and_idempotent() {
        let mut s = TieredStore::new(2, 4, StoreCfg { host_slots: 2, ..Default::default() });
        s.sync_layer(0, &[false, false, true, true]);
        assert_eq!(s.tier(0, 2), Tier::Gpu);
        assert_eq!(s.tier(0, 3), Tier::Gpu);
        assert_eq!(s.xfer.read_bytes, 0, "initial placement is free");
        // second sync of the same layer does nothing
        s.sync_layer(0, &[true, false, false, false]);
        assert_eq!(s.tier(0, 0), Tier::Host);
        s.check_invariants().unwrap();
    }

    #[test]
    fn for_model_converts_ram_budget_to_slots() {
        let p = Presets::load_default().unwrap();
        let m = p.model("mixtral-sim").unwrap();
        let c = CostModel::new(m, p.hw("local-pc-ram16").unwrap());
        let s = TieredStore::for_model(p.hw("local-pc-ram16").unwrap(), &c, 4, 8);
        assert!(!s.is_unlimited());
        assert!(s.host_slots() >= 1 && s.host_slots() < 32);
        assert!(s.host_bytes_paper(&c) <= 16e9);
        // unlimited hardware → unlimited store
        let c2 = CostModel::new(m, p.hw("local-pc").unwrap());
        assert!(TieredStore::for_model(p.hw("local-pc").unwrap(), &c2, 4, 8).is_unlimited());
    }
}
