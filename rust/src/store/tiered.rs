//! Per-expert residency state + host-tier slot allocator.
//!
//! Residency is tracked over the *sim-scale* expert grid (layers ×
//! n_routed), while byte budgets are *paper-scale* (the repo's "virtual
//! time, real numerics" doctrine): a host-RAM budget is converted into a
//! slot count by taking the fraction of total paper-scale expert bytes it
//! can hold and applying that fraction to the sim grid. Timing ratios
//! (NVMe vs PCIe vs compute) therefore match the paper-scale hardware.
//!
//! Placement is either *reactive* (LRU spill, demand-only promotion — the
//! PR 1 behaviour, kept for the baseline frameworks) or *predictive* via
//! [`super::PlacementCfg`]: promotions are issued ahead of need from the
//! prefetcher's workload predictions and spills evict the lowest
//! predicted-workload expert. Every promotion records its host-arrival
//! instant in a flat table, so consumers (CPU execution, PCIe uploads)
//! wait for in-flight reads instead of pretending the weights teleported.

use crate::config::HwConfig;
use crate::fault::{FaultPlan, ReadFaults};
use crate::hw::{CostModel, Ns};
use crate::trace::{Event, Lane, NullSink, TraceSink};

use super::placement::PlacementCfg;
use super::scheduler::TransferScheduler;
use super::tier::{Tier, MAX_DEVICES};

/// Store configuration.
#[derive(Debug, Clone)]
pub struct StoreCfg {
    /// Host-tier capacity in experts (`usize::MAX` = unlimited, the
    /// paper's two-tier assumption).
    pub host_slots: usize,
    /// Charge an NVMe write when spilling host → disk. Off by default:
    /// expert weights are immutable and the on-disk master copy (in its
    /// on-disk, possibly quantized format) always exists, so a spill is a
    /// free drop — even for quantized scenarios, where re-promotion
    /// simply re-reads and re-transcodes. Enable for scratch stores
    /// without master copies; write-back then persists the *on-disk*
    /// format — charged quantized bytes, after a re-quantize pass on the
    /// CPU transcode lane.
    pub spill_writeback: bool,
}

impl Default for StoreCfg {
    fn default() -> Self {
        StoreCfg { host_slots: usize::MAX, spill_writeback: false }
    }
}

/// Three-tier expert store: residency map, host slot allocator, and the
/// NVMe transfer scheduler. See the module docs for the tier semantics.
#[derive(Debug, Clone)]
pub struct TieredStore {
    layers: usize,
    n_experts: usize,
    /// Primary tier per expert, flat `layer * n_experts + e`.
    tier: Vec<Tier>,
    /// Experts whose primary tier is Host or Gpu (inclusive host↔GPU).
    host_used: usize,
    host_slots: usize,
    /// Host slots the *initial placement* borrowed beyond the configured
    /// budget (caches seed GPU-resident sets before the store syncs, and
    /// each GPU expert pins a host staging copy). Tracked separately so
    /// the seeding can never silently widen `host_slots` itself — only
    /// `sync_layer` may grow this allowance, and each demand-pressure
    /// spill repays one borrowed slot, so the effective capacity decays
    /// back to the configured budget after warmup.
    seed_slack: usize,
    /// True only inside `sync_layer`'s initial-placement reconciliation —
    /// guards the one `admit_to_gpu` path allowed to claim a slot for a
    /// disk-resident expert.
    syncing: bool,
    /// Number of GPU device tiers admissions may target
    /// (1..=[`MAX_DEVICES`]; 1 = the pre-multi-GPU behaviour).
    n_devices: usize,
    /// Experts whose primary tier is `Gpu(d)`, per device. Each one pins a
    /// host staging slot, so this doubles as the per-device staging-pin
    /// count the host-budget floor is built from.
    gpu_used: [usize; MAX_DEVICES],
    /// Optional per-device GPU residency budgets in experts
    /// (`usize::MAX` = the cache layer is the sole capacity authority —
    /// the single-GPU behaviour). Enforced by `check_invariants`.
    gpu_slots: [usize; MAX_DEVICES],
    spill_writeback: bool,
    /// LRU clock for host-victim selection.
    clock: u64,
    last_use: Vec<u64>,
    /// Layers whose initial GPU cache residency has been reconciled.
    synced: Vec<bool>,
    /// Flat ids whose tier is exactly Host — the spill-victim candidate
    /// set, kept as an index so victim selection scans host residents
    /// only, not the whole grid (order-independent: selection tie-breaks
    /// on the flat id, so the set's internal order never matters).
    host_members: Vec<usize>,
    /// Position of each flat id in `host_members` (`usize::MAX` = absent).
    member_pos: Vec<usize>,
    /// Virtual instant each expert's host copy is (or will be) available —
    /// consumers of a still-in-flight NVMe promotion wait for this.
    host_ready: Vec<Ns>,
    /// Predictively promoted and not yet consumed by any access.
    ahead: Vec<bool>,
    /// EWMA predicted-workload score per expert (spill-victim ranking when
    /// placement is predictive).
    score: Vec<f64>,
    placement: PlacementCfg,
    /// Deterministic perturbation schedule, when fault injection is on.
    faults: Option<FaultPlan>,
    /// Step index the fault processes are evaluated at (set once per step
    /// by [`Self::apply_fault_step`]; monotonic across the whole run).
    fault_step: u64,
    /// Host slots currently confiscated by the RAM-pressure process. The
    /// effective capacity shrinks by this much; restore hands them back.
    pressure_reserved: usize,
    /// NVMe read/write virtual-time streams.
    pub xfer: TransferScheduler,
    /// Disk→host promotions (NVMe reads charged), demand + ahead.
    pub promotions: u64,
    /// Host→disk spills.
    pub spills: u64,
    /// GPU→host demotions (cache evictions folded into the store).
    pub gpu_demotions: u64,
    /// Host promotions requested while every host slot was pinned by a
    /// GPU-resident expert (capacity floor violations; see
    /// `ensure_min_slots`).
    pub overcommits: u64,
    /// Predictive promotions issued / later consumed / spilled unused.
    pub ahead_issued: u64,
    pub ahead_hits: u64,
    pub ahead_misses: u64,
    /// NVMe read busy-time charged by demand-path (access-time) promotions.
    pub demand_read_ns: Ns,
    /// NVMe read time of predictive promotions that was already spent by
    /// the time the expert was consumed — latency hidden behind earlier
    /// layers' compute.
    pub overlap_hidden_ns: Ns,
    /// NVMe bytes the quantized on-disk format kept off the link: fp16
    /// bytes minus on-disk bytes, summed over promotions and write-back
    /// spills. Zero when experts are stored fp16 on disk.
    pub bytes_saved: u64,
    /// Injected-fault bookkeeping: failed NVMe attempts re-tried, transfers
    /// abandoned after exhausting retries, lane time the failed attempts
    /// burned, and RAM-pressure transitions / forced demotions.
    pub fault_retries: u64,
    pub fault_aborts: u64,
    pub fault_stall_ns: Ns,
    pub ram_pressure_events: u64,
    pub ram_pressure_spills: u64,
    /// Inter-GPU residency migrations charged to the P2P fabric lane.
    pub p2p_migrations: u64,
}

impl TieredStore {
    /// Build a store with `host_slots` host-tier slots. Initial placement
    /// fills the host tier expert-major (expert 0 of every layer, then
    /// expert 1, ...), so every layer keeps a warm working set and cold
    /// expert ids spill to disk — deterministic and model-agnostic.
    pub fn new(layers: usize, n_experts: usize, cfg: StoreCfg) -> Self {
        let total = layers * n_experts;
        let mut tier = vec![Tier::Disk; total];
        let mut placed = 0usize;
        'fill: for e in 0..n_experts {
            for l in 0..layers {
                if placed == cfg.host_slots {
                    break 'fill;
                }
                tier[l * n_experts + e] = Tier::Host;
                placed += 1;
            }
        }
        let mut host_members = Vec::with_capacity(total);
        let mut member_pos = vec![usize::MAX; total];
        for (i, t) in tier.iter().enumerate() {
            if *t == Tier::Host {
                member_pos[i] = host_members.len();
                host_members.push(i);
            }
        }
        TieredStore {
            layers,
            n_experts,
            tier,
            host_used: placed,
            host_slots: cfg.host_slots,
            seed_slack: 0,
            syncing: false,
            n_devices: 1,
            gpu_used: [0; MAX_DEVICES],
            gpu_slots: [usize::MAX; MAX_DEVICES],
            spill_writeback: cfg.spill_writeback,
            clock: 0,
            last_use: vec![0; total],
            synced: vec![false; layers],
            host_members,
            member_pos,
            host_ready: vec![0; total],
            ahead: vec![false; total],
            score: vec![0.0; total],
            placement: PlacementCfg::default(),
            faults: None,
            fault_step: 0,
            pressure_reserved: 0,
            xfer: TransferScheduler::new(),
            promotions: 0,
            spills: 0,
            gpu_demotions: 0,
            overcommits: 0,
            ahead_issued: 0,
            ahead_hits: 0,
            ahead_misses: 0,
            demand_read_ns: 0,
            overlap_hidden_ns: 0,
            bytes_saved: 0,
            fault_retries: 0,
            fault_aborts: 0,
            fault_stall_ns: 0,
            ram_pressure_events: 0,
            ram_pressure_spills: 0,
            p2p_migrations: 0,
        }
    }

    /// Two-tier store: host RAM holds every expert (seed behaviour).
    pub fn unlimited(layers: usize, n_experts: usize) -> Self {
        Self::new(layers, n_experts, StoreCfg::default())
    }

    /// Derive the store from a hardware preset: the host-RAM budget (with
    /// 10 % headroom for activations/KV staging) is converted to a
    /// sim-grid slot count via the paper-scale expert footprint.
    pub fn for_model(hw: &HwConfig, cost: &CostModel, layers: usize, n_experts: usize) -> Self {
        if hw.host_ram_bytes <= 0.0 {
            return Self::unlimited(layers, n_experts);
        }
        let total = layers * n_experts;
        let frac = (hw.host_ram_bytes * 0.9 / cost.total_expert_bytes()).clamp(0.0, 1.0);
        let slots = ((frac * total as f64).floor() as usize).max(1);
        let cfg = StoreCfg { host_slots: slots.min(total), ..StoreCfg::default() };
        Self::new(layers, n_experts, cfg)
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    /// Number of GPU device tiers this store addresses.
    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Size the store for `n` GPU device tiers. Must be called before any
    /// GPU admission (the simulator sets it at construction); shrinking a
    /// store that already holds device residents would orphan them.
    pub fn set_n_devices(&mut self, n: usize) {
        assert!(n >= 1 && n <= MAX_DEVICES, "n_devices must be in 1..={MAX_DEVICES}, got {n}");
        assert!(
            self.gpu_used.iter().all(|&u| u == 0),
            "set_n_devices after GPU admissions would orphan residents"
        );
        self.n_devices = n;
    }

    /// The device expert `e`'s GPU-cache residency is sharded to: experts
    /// are striped round-robin across devices, so each device's cache holds
    /// a disjoint ~1/N slice of the expert grid (the expert-parallel
    /// layout). At `n_devices = 1` every expert is homed on device 0.
    pub fn home_device(&self, e: usize) -> u8 {
        (e % self.n_devices) as u8
    }

    /// Experts currently GPU-resident on device `d` — also that device's
    /// host staging-pin count (each GPU resident pins a host copy).
    pub fn gpu_used_dev(&self, d: usize) -> usize {
        self.gpu_used[d]
    }

    /// Install an expert-count residency budget for device `d`
    /// (`usize::MAX` = uncapped; the cache layer enforces its own
    /// capacity either way — this is the store-side conservation check).
    pub fn set_gpu_slots(&mut self, d: usize, slots: usize) {
        self.gpu_slots[d] = slots;
    }

    /// The configured host budget. Never inflated by initial placement —
    /// slots the cache seeding borrowed beyond it live in
    /// [`Self::seed_slack`].
    pub fn host_slots(&self) -> usize {
        self.host_slots
    }

    /// Host slots the initial placement borrowed beyond the configured
    /// budget: zero when seeding fits the budget, and repaid one slot per
    /// demand-pressure spill afterwards.
    pub fn seed_slack(&self) -> usize {
        self.seed_slack
    }

    /// Effective host capacity: the configured budget plus the seed
    /// allowance, minus whatever the RAM-pressure fault process currently
    /// confiscates.
    fn effective_slots(&self) -> usize {
        self.host_slots.saturating_add(self.seed_slack).saturating_sub(self.pressure_reserved)
    }

    /// Install (or clear) the deterministic fault plan. The simulator
    /// propagates its plan when a store is attached; a `None` or clean plan
    /// leaves every code path bit-identical to an un-faulted run.
    pub fn set_faults(&mut self, plan: Option<FaultPlan>) {
        self.faults = plan;
    }

    pub fn faults(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Host slots currently confiscated by RAM pressure (0 = no pressure).
    pub fn pressure_reserved(&self) -> usize {
        self.pressure_reserved
    }

    /// Whether the RAM-pressure process is currently shrinking the budget —
    /// predictive placement pauses promote-ahead while this holds, so
    /// speculation never fights the OS for reclaimed slots.
    pub fn under_pressure(&self) -> bool {
        self.pressure_reserved > 0
    }

    /// Evaluate the fault processes at `step` (called once per step by the
    /// simulator, before any layer work): records the step for the NVMe
    /// ledger and applies the RAM-pressure shrink/restore. A shrink demotes
    /// host residents — coldest-first under the workload-aware score —
    /// until the reduced budget holds; GPU-pinned staging copies set a hard
    /// floor the reservation is clamped to. Restores are free (the slots
    /// simply come back). Every transition emits `Event::RamPressure`.
    pub fn apply_fault_step<S: TraceSink>(
        &mut self,
        step: u64,
        now: Ns,
        cost: &CostModel,
        sink: &mut S,
    ) {
        self.fault_step = step;
        let plan = match self.faults {
            Some(p) => p,
            None => return,
        };
        let target = plan.ram_reserved(step, self.host_slots);
        if target == self.pressure_reserved {
            return;
        }
        let mut spilled = 0u32;
        if target > self.pressure_reserved {
            self.pressure_reserved = target;
            while self.host_used > self.effective_slots() {
                match self.spill_victim(usize::MAX) {
                    Some(v) => {
                        self.spill_index(v, now, cost, sink);
                        spilled += 1;
                        self.ram_pressure_spills += 1;
                    }
                    None => break,
                }
            }
            // only GPU-pinned copies remain below the target: they cannot
            // be demoted by a host-budget shrink, so clamp the reservation
            // to what the demotions actually achieved.
            let base = self.host_slots.saturating_add(self.seed_slack);
            if self.host_used > base.saturating_sub(self.pressure_reserved) {
                self.pressure_reserved = base.saturating_sub(self.host_used);
            }
        } else {
            self.pressure_reserved = target;
        }
        self.ram_pressure_events += 1;
        if S::ENABLED {
            sink.emit(&Event::RamPressure {
                at: now,
                reserved: self.pressure_reserved as u32,
                spilled,
            });
        }
    }

    pub fn host_used(&self) -> usize {
        self.host_used
    }

    /// Whether this store can hold every expert in host RAM.
    pub fn is_unlimited(&self) -> bool {
        self.host_slots >= self.layers * self.n_experts
    }

    /// Install the placement policy for this store (the simulator applies
    /// the policy bundle's config when the store is attached).
    pub fn set_placement(&mut self, cfg: PlacementCfg) {
        self.placement = cfg;
    }

    pub fn placement(&self) -> &PlacementCfg {
        &self.placement
    }

    fn idx(&self, layer: usize, e: usize) -> usize {
        debug_assert!(layer < self.layers && e < self.n_experts);
        layer * self.n_experts + e
    }

    pub fn tier(&self, layer: usize, e: usize) -> Tier {
        self.tier[self.idx(layer, e)]
    }

    /// EWMA predicted-workload score of one expert (placement ranking).
    pub fn score(&self, layer: usize, e: usize) -> f64 {
        self.score[self.idx(layer, e)]
    }

    /// Whether (layer, e) has an unconsumed predictive promotion or an
    /// NVMe read still in flight at `now`.
    pub fn pending(&self, layer: usize, e: usize, now: Ns) -> bool {
        let i = self.idx(layer, e);
        self.ahead[i] || self.host_ready[i] > now
    }

    /// Residency tiers of one whole layer (assignment input).
    pub fn layer_tiers(&self, layer: usize) -> Vec<Tier> {
        let mut out = Vec::with_capacity(self.n_experts);
        self.layer_tiers_into(layer, &mut out);
        out
    }

    /// Buffer-reusing form of [`Self::layer_tiers`] — the simulator reads
    /// this snapshot every MoE layer, so it must not allocate.
    pub fn layer_tiers_into(&self, layer: usize, out: &mut Vec<Tier>) {
        let i = layer * self.n_experts;
        out.clear();
        out.extend_from_slice(&self.tier[i..i + self.n_experts]);
    }

    /// Per-expert extra wait before weights are available in host RAM at
    /// `now`: the NVMe-fetch estimate for disk residents, or the remaining
    /// in-flight promotion time for host/GPU residents. Assignment reads
    /// this snapshot every layer (allocation-free), so solvers price
    /// in-flight predictive promotions instead of assuming host residency
    /// is instantaneous.
    pub fn layer_host_wait_into(
        &self,
        layer: usize,
        now: Ns,
        cost: &CostModel,
        out: &mut Vec<Ns>,
    ) {
        out.clear();
        let base = layer * self.n_experts;
        let nvme = cost.nvme_fetch_time();
        for e in 0..self.n_experts {
            let i = base + e;
            out.push(match self.tier[i] {
                Tier::Disk => nvme,
                _ => self.host_ready[i].saturating_sub(now),
            });
        }
    }

    /// Record a use (LRU recency) without changing residency.
    pub fn touch(&mut self, layer: usize, e: usize) {
        self.clock += 1;
        let i = self.idx(layer, e);
        self.last_use[i] = self.clock;
    }

    /// EWMA-decay one layer's scores with this step's observed workloads
    /// (predictive placement only; the baselines keep pure LRU state).
    pub fn observe_workloads(&mut self, layer: usize, workloads: &[u32]) {
        if !self.placement.predictive {
            return;
        }
        let base = layer * self.n_experts;
        for (e, &w) in workloads.iter().take(self.n_experts).enumerate() {
            let s = &mut self.score[base + e];
            *s = *s * self.placement.decay + w as f64;
        }
    }

    /// Raise one layer's scores to at least the prefetcher's freshly
    /// predicted workloads (scores in routed-token units, same as
    /// observation counts).
    pub fn note_predictions(&mut self, layer: usize, predicted: &[f64]) {
        if !self.placement.predictive {
            return;
        }
        let base = layer * self.n_experts;
        for (e, &p) in predicted.iter().take(self.n_experts).enumerate() {
            if p > self.score[base + e] {
                self.score[base + e] = p;
            }
        }
    }

    /// Raise the host capacity floor so it can always pin the GPU cache's
    /// staging copies (call once with the cache's total capacity).
    pub fn ensure_min_slots(&mut self, min: usize) {
        let total = self.layers * self.n_experts;
        if self.host_slots < min {
            self.host_slots = min.min(total);
        }
    }

    /// Zero the operation counters (metrics-period boundary). Residency
    /// state and stream clocks are untouched — pair with
    /// `xfer.rebase_and_clear` or use [`Self::rebase_and_clear`].
    pub fn clear_op_counters(&mut self) {
        self.promotions = 0;
        self.spills = 0;
        self.gpu_demotions = 0;
        self.overcommits = 0;
        self.ahead_issued = 0;
        self.ahead_hits = 0;
        self.ahead_misses = 0;
        self.demand_read_ns = 0;
        self.overlap_hidden_ns = 0;
        self.bytes_saved = 0;
        self.fault_retries = 0;
        self.fault_aborts = 0;
        self.fault_stall_ns = 0;
        self.ram_pressure_events = 0;
        self.ram_pressure_spills = 0;
        self.p2p_migrations = 0;
    }

    /// Metrics-period boundary: shift every virtual-time clock back by
    /// `base` (stream free-times and in-flight host arrivals) and clear
    /// the operation counters. Mirrors the simulator re-basing in-flight
    /// prefetch arrivals in `reset_metrics`. Unconsumed ahead flags are
    /// dropped with the counters (their reads stay in flight and consumers
    /// still wait via `host_ready`, but hit/miss accounting belongs to the
    /// period that issued them — keeping `hits + misses <= issued` exact).
    pub fn rebase_and_clear(&mut self, base: Ns) {
        self.xfer.rebase_and_clear(base);
        for r in self.host_ready.iter_mut() {
            *r = r.saturating_sub(base);
        }
        for a in self.ahead.iter_mut() {
            *a = false;
        }
        self.clear_op_counters();
    }

    /// Add / remove a flat id to the Host-tier member index (O(1), no
    /// allocation — `host_members` is pre-sized to the grid).
    fn member_add(&mut self, i: usize) {
        debug_assert_eq!(self.member_pos[i], usize::MAX);
        self.member_pos[i] = self.host_members.len();
        self.host_members.push(i);
    }

    fn member_remove(&mut self, i: usize) {
        let p = self.member_pos[i];
        debug_assert_ne!(p, usize::MAX);
        self.host_members.swap_remove(p);
        if let Some(&moved) = self.host_members.get(p) {
            self.member_pos[moved] = p;
        }
        self.member_pos[i] = usize::MAX;
    }

    /// Make `e` of `layer` host-resident, charging a demand-path NVMe
    /// read if it was on disk (and spilling a victim if the host tier is
    /// full — LRU, or lowest predicted-workload score under predictive
    /// placement). Returns the virtual instant the weights are available
    /// in host RAM (`now` when already resident and nothing in flight).
    pub fn ensure_host(&mut self, layer: usize, e: usize, now: Ns, cost: &CostModel) -> Ns {
        self.ensure_host_t(layer, e, now, cost, &mut NullSink)
    }

    /// [`Self::ensure_host`] with a trace sink (the `_t` variants thread
    /// the simulator's sink through the store; the unsuffixed names keep
    /// every existing call site compiling against a [`NullSink`]).
    pub fn ensure_host_t<S: TraceSink>(
        &mut self,
        layer: usize,
        e: usize,
        now: Ns,
        cost: &CostModel,
        sink: &mut S,
    ) -> Ns {
        self.arrival(layer, e, now, cost, true, sink)
    }

    /// On-disk bytes of one expert transfer, with the bytes-saved
    /// bookkeeping shared by both NVMe directions (promotion reads and
    /// write-back spills) — one definition, so the conservation property
    /// tests can't be broken by the two sites drifting apart.
    fn disk_bytes_accounted(&mut self, cost: &CostModel) -> u64 {
        let bytes = cost.disk_expert_bytes() as u64;
        self.bytes_saved += (cost.expert_bytes() as u64).saturating_sub(bytes);
        bytes
    }

    /// Charge one disk→host promotion on the NVMe lanes: a read of the
    /// *on-disk* (possibly quantized) bytes, chained into the CPU
    /// transcode lane when the on-disk format is not fp16. Returns the
    /// instant the fp16 host copy is usable and books the bytes the
    /// quantized format kept off the NVMe link.
    ///
    /// Under an active fault plan the transfer first walks its NVMe fault
    /// ledger (pure function of `(seed, step, layer, expert)`): each failed
    /// attempt occupies the read lane for the profile's timeout, surfaces
    /// as an `Event::FaultRetry`, and backs off exponentially in lane-idle
    /// virtual time before the next attempt. When every attempt fails, an
    /// `abortable` (promote-ahead speculative) transfer is abandoned —
    /// `Event::FaultAbort`, `None` returned, no bytes moved — while a
    /// committed transfer (demand fetch or an already-chained speculative
    /// consumer) falls back to a final raw read that always succeeds, so
    /// the execution path can never deadlock on injected failures.
    fn schedule_promotion<S: TraceSink>(
        &mut self,
        layer: usize,
        e: usize,
        now: Ns,
        cost: &CostModel,
        abortable: bool,
        sink: &mut S,
    ) -> Option<Ns> {
        let read = cost.nvme_read_time();
        let mut read_dur = read;
        let mut issue_at = now;
        let faults = match self.faults {
            Some(plan) if !plan.is_clean() => plan.read_faults(self.fault_step, layer, e),
            _ => ReadFaults::NONE,
        };
        if faults.failures > 0 {
            let plan = self.faults.expect("fault ledger without a plan");
            let mut last_end = now;
            for k in 1..=faults.failures {
                let stall = plan.timeout_ns(read);
                let end = self.xfer.schedule_read_stall(issue_at, stall);
                self.fault_retries += 1;
                self.fault_stall_ns += stall;
                if S::ENABLED {
                    sink.emit(&Event::LaneBusy {
                        lane: Lane::NvmeRead,
                        device: 0,
                        start: end - stall,
                        end,
                    });
                    sink.emit(&Event::FaultRetry {
                        lane: Lane::NvmeRead,
                        layer: layer as u32,
                        expert: e as u32,
                        attempt: k,
                        at: end,
                    });
                }
                last_end = end;
                issue_at = end.saturating_add(plan.backoff_ns(read, k));
            }
            if faults.exhausted && abortable {
                self.fault_aborts += 1;
                if S::ENABLED {
                    sink.emit(&Event::FaultAbort {
                        lane: Lane::NvmeRead,
                        layer: layer as u32,
                        expert: e as u32,
                        attempts: faults.failures,
                        at: last_end,
                    });
                }
                return None;
            }
        }
        if !faults.exhausted {
            read_dur = crate::fault::scale_ns(read, faults.slow_mult);
        }
        let bytes = self.disk_bytes_accounted(cost);
        let read_done = self.xfer.schedule_read(issue_at, read_dur, bytes);
        if S::ENABLED {
            sink.emit(&Event::LaneBusy {
                lane: Lane::NvmeRead,
                device: 0,
                start: read_done - read_dur,
                end: read_done,
            });
        }
        let transcode = cost.transcode_time();
        Some(if transcode == 0 {
            read_done
        } else {
            let t_done = self.xfer.schedule_transcode(read_done, transcode);
            if S::ENABLED {
                sink.emit(&Event::LaneBusy {
                    lane: Lane::Transcode,
                    device: 0,
                    start: t_done - transcode,
                    end: t_done,
                });
            }
            t_done
        })
    }

    /// Unified arrival: touch, promote from disk if needed. `demand`
    /// classifies a promotion's NVMe read: true for access-time fetches on
    /// the execution path (CPU exec, GPU demand fetch), false for
    /// speculative consumers (prefetch chaining, cache-update loads) —
    /// `nvme_demand_ns` must measure only the reads predictive placement
    /// exists to remove, identically across placement policies. The
    /// returned arrival is the transcode completion for quantized on-disk
    /// formats: host RAM holds usable fp16 weights only then.
    fn arrival<S: TraceSink>(
        &mut self,
        layer: usize,
        e: usize,
        now: Ns,
        cost: &CostModel,
        demand: bool,
        sink: &mut S,
    ) -> Ns {
        let i = self.idx(layer, e);
        self.touch(layer, e);
        if self.tier[i] != Tier::Disk {
            return self.host_ready[i].max(now);
        }
        if self.host_used >= self.effective_slots() {
            if let Some(v) = self.spill_victim(i) {
                self.spill_index(v, now, cost, sink);
            }
            // Repay one warmup-borrowed slot per demand-pressure event:
            // spill a second victim and shrink the seed allowance, so the
            // effective capacity decays back to the configured budget as
            // real traffic arrives instead of staying inflated at the
            // seeding peak forever.
            if self.seed_slack > 0 {
                if let Some(v) = self.spill_victim(i) {
                    self.spill_index(v, now, cost, sink);
                    self.seed_slack -= 1;
                }
            }
        }
        if self.host_used >= self.effective_slots() {
            // every remaining slot is pinned by a GPU-resident staging
            // copy: those set a hard floor below which the capacity cannot
            // shrink. Any fault-injected RAM reservation yields first
            // (pinned copies outrank the pressure process); only when the
            // configured budget itself is the shortfall does it grow, and
            // that is the overcommit the counter records.
            let need = self.host_used + 1;
            let base = self.host_slots.saturating_add(self.seed_slack);
            if base >= need {
                self.pressure_reserved = base - need;
            } else {
                self.pressure_reserved = 0;
                self.host_slots = need.saturating_sub(self.seed_slack);
                self.overcommits += 1;
            }
        }
        self.tier[i] = Tier::Host;
        self.member_add(i);
        self.host_used += 1;
        self.promotions += 1;
        if demand {
            self.demand_read_ns += cost.nvme_read_time();
        }
        let arr = self
            .schedule_promotion(layer, e, now, cost, false, sink)
            .expect("committed promotions never abort");
        self.host_ready[i] = arr;
        if S::ENABLED {
            sink.emit(&Event::Fetch {
                layer: layer as u32,
                expert: e as u32,
                demand,
                arrival: arr,
            });
        }
        arr
    }

    /// Consume (layer, e)'s predictive promotion if one is outstanding:
    /// records the hit and how much of the NVMe read was already hidden
    /// behind earlier layers' compute by the time of consumption.
    fn consume_ahead<S: TraceSink>(
        &mut self,
        layer: usize,
        e: usize,
        now: Ns,
        cost: &CostModel,
        sink: &mut S,
    ) {
        let i = self.idx(layer, e);
        if self.ahead[i] {
            self.ahead[i] = false;
            self.ahead_hits += 1;
            // the full fetch — NVMe read plus transcode — can hide
            let dur = cost.nvme_fetch_time();
            let wait = self.host_ready[i].saturating_sub(now).min(dur);
            self.overlap_hidden_ns += dur - wait;
            if S::ENABLED {
                sink.emit(&Event::AheadHit {
                    layer: layer as u32,
                    expert: e as u32,
                    hidden_ns: dur - wait,
                });
            }
        }
    }

    /// Host arrival for an execution-path access (CPU execution, GPU
    /// demand fetch) — a promotion here is a demand-path NVMe read.
    pub fn host_arrival(&mut self, layer: usize, e: usize, now: Ns, cost: &CostModel) -> Ns {
        self.host_arrival_t(layer, e, now, cost, &mut NullSink)
    }

    /// [`Self::host_arrival`] with a trace sink.
    pub fn host_arrival_t<S: TraceSink>(
        &mut self,
        layer: usize,
        e: usize,
        now: Ns,
        cost: &CostModel,
        sink: &mut S,
    ) -> Ns {
        self.consume_ahead(layer, e, now, cost, sink);
        self.arrival(layer, e, now, cost, true, sink)
    }

    /// Host arrival for a speculative consumer (prefetch-chained PCIe
    /// upload, cache-update load) — promotes if needed, but the read is
    /// not charged to the demand path.
    pub fn host_arrival_spec(&mut self, layer: usize, e: usize, now: Ns, cost: &CostModel) -> Ns {
        self.host_arrival_spec_t(layer, e, now, cost, &mut NullSink)
    }

    /// [`Self::host_arrival_spec`] with a trace sink.
    pub fn host_arrival_spec_t<S: TraceSink>(
        &mut self,
        layer: usize,
        e: usize,
        now: Ns,
        cost: &CostModel,
        sink: &mut S,
    ) -> Ns {
        self.consume_ahead(layer, e, now, cost, sink);
        self.arrival(layer, e, now, cost, false, sink)
    }

    /// Predictively promote (layer, e) NVMe→host on the dedicated read
    /// stream, ahead of any access. Refused (returns `false`) when
    /// placement is reactive, the expert is already host/GPU-resident, the
    /// read stream's speculative backlog is too deep, or the host tier is
    /// full and holds no strictly-colder victim (by predicted-workload
    /// score) — speculation must never thrash warmer residents out.
    pub fn promote_ahead(&mut self, layer: usize, e: usize, now: Ns, cost: &CostModel) -> bool {
        self.promote_ahead_t(layer, e, now, cost, &mut NullSink)
    }

    /// [`Self::promote_ahead`] with a trace sink.
    pub fn promote_ahead_t<S: TraceSink>(
        &mut self,
        layer: usize,
        e: usize,
        now: Ns,
        cost: &CostModel,
        sink: &mut S,
    ) -> bool {
        if !self.placement.predictive {
            return false;
        }
        // graceful degradation: while the RAM-pressure process holds slots
        // confiscated, speculation pauses — promote-ahead would only fight
        // the shrink for capacity and thrash the survivors out.
        if self.under_pressure() {
            return false;
        }
        let i = self.idx(layer, e);
        if self.tier[i] != Tier::Disk {
            return false;
        }
        // the backlog gate watches the read stream only: quantized
        // on-disk formats shrink each read, so the same gate admits more
        // speculative promotions per layer — exactly the asymmetry the
        // format buys (transcodes queue on their own lane).
        let dur = cost.nvme_read_time();
        if self.xfer.read_free_at() > now + self.placement.max_backlog * dur {
            return false;
        }
        if self.host_used >= self.effective_slots() {
            let v = match self.spill_victim(i) {
                Some(v) if self.score[v] < self.score[i] => v,
                _ => return false,
            };
            self.spill_index(v, now, cost, sink);
        }
        // speculative reads are abortable: when the fault ledger exhausts
        // every retry the promotion is abandoned and the expert stays on
        // disk (the victim spill above stands — the sick drive genuinely
        // wasted that work). The lane time the failed attempts burned is
        // already charged.
        let arr = match self.schedule_promotion(layer, e, now, cost, true, sink) {
            Some(arr) => arr,
            None => return false,
        };
        self.tier[i] = Tier::Host;
        self.member_add(i);
        self.host_used += 1;
        self.promotions += 1;
        self.ahead_issued += 1;
        self.ahead[i] = true;
        self.touch(layer, e);
        self.host_ready[i] = arr;
        if S::ENABLED {
            sink.emit(&Event::AheadIssue { layer: layer as u32, expert: e as u32, arrival: arr });
        }
        true
    }

    /// Pick the host-tier spill victim, never the protected index and
    /// never a pinned GPU-tier expert (the member index holds Host-tier
    /// experts only, so the scan is O(host residents), not O(grid)).
    /// Predictive placement evicts the lowest predicted-workload score;
    /// reactive placement is pure LRU. Both tie-break on recency then the
    /// flat id, so the member set's internal order never affects the
    /// choice (determinism).
    fn spill_victim(&self, protect: usize) -> Option<usize> {
        let mut victim: Option<usize> = None;
        for &i in &self.host_members {
            if i == protect {
                continue;
            }
            debug_assert_eq!(self.tier[i], Tier::Host);
            let better = match victim {
                None => true,
                Some(v) => {
                    if self.placement.predictive {
                        (self.score[i], self.last_use[i], i)
                            < (self.score[v], self.last_use[v], v)
                    } else {
                        (self.last_use[i], i) < (self.last_use[v], v)
                    }
                }
            };
            if better {
                victim = Some(i);
            }
        }
        victim
    }

    /// Spill the host-resident expert at flat index `v` to disk. An
    /// unconsumed predictive promotion spilled here was a wasted ahead
    /// read (miss).
    fn spill_index<S: TraceSink>(&mut self, v: usize, now: Ns, cost: &CostModel, sink: &mut S) {
        debug_assert_eq!(self.tier[v], Tier::Host);
        let (layer, expert) = ((v / self.n_experts) as u32, (v % self.n_experts) as u32);
        self.tier[v] = Tier::Disk;
        self.member_remove(v);
        self.host_used -= 1;
        self.spills += 1;
        if self.ahead[v] {
            self.ahead[v] = false;
            self.ahead_misses += 1;
            if S::ENABLED {
                sink.emit(&Event::AheadMiss { layer, expert });
            }
        }
        if S::ENABLED {
            sink.emit(&Event::Spill { layer, expert, writeback: self.spill_writeback });
        }
        if self.spill_writeback {
            // Write-back persists the on-disk format: quantized bytes, not
            // the fp16 host copy — which first costs the reverse transcode
            // (re-quantize) on the shared CPU transcode lane; the NVMe
            // write starts only once the encoded bytes exist. Symmetric
            // with promotion (read → dequantize), so neither direction of
            // the asymmetric format is priced as free.
            let bytes = self.disk_bytes_accounted(cost);
            let t = cost.transcode_time();
            let encoded = if t == 0 { now } else { self.xfer.schedule_transcode(now, t) };
            if S::ENABLED && t > 0 {
                sink.emit(&Event::LaneBusy {
                    lane: Lane::Transcode,
                    device: 0,
                    start: encoded - t,
                    end: encoded,
                });
            }
            let write = cost.nvme_write_time();
            let w_done = self.xfer.schedule_write(encoded, write, bytes);
            if S::ENABLED && write > 0 {
                sink.emit(&Event::LaneBusy {
                    lane: Lane::NvmeWrite,
                    device: 0,
                    start: w_done - write,
                    end: w_done,
                });
            }
        }
    }

    /// Mark `e` of `layer` GPU-resident on its home device (cache
    /// admission / swap load). The caller is responsible for having made
    /// it host-resident first (`ensure_host`) and for charging the PCIe
    /// upload; a disk-resident expert is tolerated only for free initial
    /// placement and claims its host slot without NVMe traffic.
    pub fn admit_to_gpu(&mut self, layer: usize, e: usize) {
        self.admit_to_gpu_dev(layer, e, self.home_device(e));
    }

    /// [`Self::admit_to_gpu`] targeting an explicit device tier. Admitting
    /// an expert already resident on another device *moves* it (residency
    /// stays single-copy); the caller charges the P2P copy — or uses
    /// [`Self::migrate_gpu_dev`], which does both.
    pub fn admit_to_gpu_dev(&mut self, layer: usize, e: usize, device: u8) {
        assert!(
            (device as usize) < self.n_devices,
            "admission to device {device} of {}",
            self.n_devices
        );
        let i = self.idx(layer, e);
        self.touch(layer, e);
        match self.tier[i] {
            Tier::Disk => {
                // Initial placement (cache seeded before the store synced):
                // claim a host staging slot without NVMe traffic. Slots
                // beyond the configured budget are tracked as `seed_slack`,
                // NOT folded into `host_slots` — the configured budget must
                // survive warmup, so mid-run admissions can't silently
                // inflate host RAM. Outside `sync_layer` the caller must
                // have promoted via `ensure_host` first.
                debug_assert!(
                    self.syncing,
                    "disk-tier GPU admission outside initial placement \
                     (layer {layer}, expert {e})"
                );
                self.host_used += 1;
                if self.host_used > self.effective_slots() {
                    self.seed_slack = self.host_used - self.host_slots;
                }
            }
            Tier::Host => self.member_remove(i),
            // already on a GPU: release the old device's count; the shared
            // increment below re-books it (net no-op when prev == device)
            Tier::Gpu(prev) => self.gpu_used[prev as usize] -= 1,
        }
        self.tier[i] = Tier::Gpu(device);
        self.gpu_used[device as usize] += 1;
    }

    /// Fold a GPU cache eviction into the store: the expert's primary tier
    /// drops to Host (free — the pinned host copy still exists). Works for
    /// any device tier.
    pub fn demote_gpu(&mut self, layer: usize, e: usize) {
        let i = self.idx(layer, e);
        if let Tier::Gpu(d) = self.tier[i] {
            self.gpu_used[d as usize] -= 1;
            self.tier[i] = Tier::Host;
            self.member_add(i);
            self.gpu_demotions += 1;
        }
    }

    /// Move a GPU-resident expert to device `to` over the inter-GPU P2P
    /// fabric lane, charging one expert of fp16 bytes (both ends hold the
    /// execution format — quantization never touches P2P). Returns the
    /// copy's completion instant; a same-device "move" is free and moves
    /// nothing. Residency stays single-copy: retiring the source device's
    /// cache entry is the caller's job.
    pub fn migrate_gpu_dev(
        &mut self,
        layer: usize,
        e: usize,
        to: u8,
        now: Ns,
        cost: &CostModel,
    ) -> Ns {
        assert!((to as usize) < self.n_devices, "migration to device {to} of {}", self.n_devices);
        let i = self.idx(layer, e);
        let from = match self.tier[i] {
            Tier::Gpu(d) => d,
            t => panic!("P2P migration of non-GPU-resident expert (tier {t:?})"),
        };
        if from == to {
            return now;
        }
        self.touch(layer, e);
        self.gpu_used[from as usize] -= 1;
        self.gpu_used[to as usize] += 1;
        self.tier[i] = Tier::Gpu(to);
        self.p2p_migrations += 1;
        self.xfer.schedule_p2p(now, cost.p2p_time(), cost.expert_bytes() as u64)
    }

    /// One-time reconciliation of a layer's initial cache residency (the
    /// caches seed random resident sets before the store exists). Free:
    /// models load-time placement, not runtime traffic.
    pub fn sync_layer(&mut self, layer: usize, gpu_mask: &[bool]) {
        if self.synced[layer] {
            return;
        }
        self.synced[layer] = true;
        self.syncing = true;
        for e in 0..self.n_experts.min(gpu_mask.len()) {
            let i = self.idx(layer, e);
            if gpu_mask[e] && !self.tier[i].is_gpu() {
                // seeds land on the expert's home device — the sharded
                // layout the per-device caches mirror
                self.admit_to_gpu_dev(layer, e, self.home_device(e));
            }
        }
        self.syncing = false;
    }

    /// (gpu, host, disk) expert counts across the whole grid (GPU summed
    /// over every device tier; see [`Self::gpu_used_dev`] for one device).
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for t in &self.tier {
            match t {
                Tier::Gpu(_) => c.0 += 1,
                Tier::Host => c.1 += 1,
                Tier::Disk => c.2 += 1,
            }
        }
        c
    }

    /// GPU-primary experts of one layer, any device (memory-model
    /// consistency checks).
    pub fn gpu_count_layer(&self, layer: usize) -> usize {
        let i = layer * self.n_experts;
        self.tier[i..i + self.n_experts].iter().filter(|t| t.is_gpu()).count()
    }

    /// Paper-scale bytes the host tier currently pins (slot fraction of
    /// the total expert footprint).
    pub fn host_bytes_paper(&self, cost: &CostModel) -> f64 {
        let total = (self.layers * self.n_experts).max(1);
        cost.total_expert_bytes() * self.host_used as f64 / total as f64
    }

    /// Verify the store's internal invariants; returns a description of
    /// the first violation. Used by the property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let (gpu, host, disk) = self.counts();
        if gpu + host + disk != self.layers * self.n_experts {
            return Err(format!(
                "residency not conserved: {gpu}+{host}+{disk} != {}",
                self.layers * self.n_experts
            ));
        }
        if gpu + host != self.host_used {
            return Err(format!(
                "host accounting drift: counted {} vs tracked {}",
                gpu + host,
                self.host_used
            ));
        }
        if self.host_used > self.effective_slots() {
            return Err(format!(
                "host over capacity: {} used > {} slots + {} seed slack - {} reserved",
                self.host_used, self.host_slots, self.seed_slack, self.pressure_reserved
            ));
        }
        if self.pressure_reserved > self.host_slots.saturating_add(self.seed_slack) {
            return Err(format!(
                "RAM reservation exceeds the whole budget: {} > {} + {}",
                self.pressure_reserved, self.host_slots, self.seed_slack
            ));
        }
        for (i, &a) in self.ahead.iter().enumerate() {
            if a && self.tier[i] == Tier::Disk {
                return Err(format!("expert {i} flagged ahead-promoted but disk-resident"));
            }
        }
        if self.ahead_hits + self.ahead_misses > self.ahead_issued {
            return Err(format!(
                "ahead accounting drift: {} hits + {} misses > {} issued",
                self.ahead_hits, self.ahead_misses, self.ahead_issued
            ));
        }
        if self.host_members.len() != host {
            return Err(format!(
                "member index drift: {} members vs {} host-tier experts",
                self.host_members.len(),
                host
            ));
        }
        for (p, &i) in self.host_members.iter().enumerate() {
            if self.tier[i] != Tier::Host || self.member_pos[i] != p {
                return Err(format!("member index corrupt at slot {p} (flat id {i})"));
            }
        }
        // Per-device conservation: the tracked per-device counts must match
        // a recount of the tier map (single residency is structural — one
        // tier per expert — so a drift here means double-booking), every
        // device stays within its budget, and no expert sits on a device
        // tier beyond the configured device count.
        let mut per_dev = [0usize; MAX_DEVICES];
        for t in &self.tier {
            if let Tier::Gpu(d) = t {
                per_dev[*d as usize] += 1;
            }
        }
        for d in 0..MAX_DEVICES {
            if per_dev[d] != self.gpu_used[d] {
                return Err(format!(
                    "device {d} residency drift: counted {} vs tracked {}",
                    per_dev[d], self.gpu_used[d]
                ));
            }
            if self.gpu_used[d] > self.gpu_slots[d] {
                return Err(format!(
                    "device {d} over budget: {} used > {} slots",
                    self.gpu_used[d], self.gpu_slots[d]
                ));
            }
            if d >= self.n_devices && self.gpu_used[d] > 0 {
                return Err(format!(
                    "device {d} holds {} experts but only {} devices exist",
                    self.gpu_used[d], self.n_devices
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Presets;
    use crate::fault::FaultProfile;

    fn cost() -> CostModel {
        let p = Presets::load_default().unwrap();
        CostModel::new(p.model("mixtral-sim").unwrap(), p.hw("local-pc").unwrap())
    }

    #[test]
    fn unlimited_store_is_all_host() {
        let s = TieredStore::unlimited(4, 8);
        assert!(s.is_unlimited());
        let (g, h, d) = s.counts();
        assert_eq!((g, h, d), (0, 32, 0));
        s.check_invariants().unwrap();
    }

    #[test]
    fn limited_store_spreads_host_slots_across_layers() {
        let s = TieredStore::new(4, 8, StoreCfg { host_slots: 8, ..Default::default() });
        let (_, h, d) = s.counts();
        assert_eq!(h, 8);
        assert_eq!(d, 24);
        // expert-major fill → every layer holds experts 0 and 1
        for l in 0..4 {
            assert_eq!(s.tier(l, 0), Tier::Host);
            assert_eq!(s.tier(l, 1), Tier::Host);
            assert_eq!(s.tier(l, 2), Tier::Disk);
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn ensure_host_charges_nvme_and_spills_lru() {
        let c = cost();
        let mut s = TieredStore::new(2, 4, StoreCfg { host_slots: 2, ..Default::default() });
        // initial host set: (0,0) and (1,0)
        assert_eq!(s.tier(0, 0), Tier::Host);
        assert_eq!(s.tier(1, 0), Tier::Host);
        s.touch(0, 0); // (1,0) is now LRU
        let arr = s.ensure_host(0, 3, 0, &c);
        assert_eq!(arr, c.nvme_read_time());
        assert_eq!(s.tier(0, 3), Tier::Host);
        assert_eq!(s.tier(1, 0), Tier::Disk, "LRU host expert spilled");
        assert_eq!(s.promotions, 1);
        assert_eq!(s.spills, 1);
        assert_eq!(s.demand_read_ns, c.nvme_read_time());
        assert_eq!(s.xfer.write_bytes, 0, "clean spill is free by default");
        s.check_invariants().unwrap();
        // second promotion queues behind the first on the read stream
        let arr2 = s.ensure_host(1, 3, 0, &c);
        assert_eq!(arr2, 2 * c.nvme_read_time());
    }

    #[test]
    fn ensure_host_waits_for_in_flight_promotions() {
        // A second access before the NVMe read lands must wait for the
        // recorded host arrival, not pretend the weights teleported.
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        let arr = s.ensure_host(0, 2, 0, &c);
        assert!(arr > 0);
        assert_eq!(s.ensure_host(0, 2, 0, &c), arr, "still in flight at t=0");
        assert_eq!(s.ensure_host(0, 2, arr + 5, &c), arr + 5, "landed by then");
        assert_eq!(s.promotions, 1, "no duplicate read charged");
    }

    #[test]
    fn writeback_spills_charge_the_write_stream() {
        let c = cost();
        let mut s =
            TieredStore::new(2, 4, StoreCfg { host_slots: 1, spill_writeback: true });
        s.ensure_host(1, 3, 0, &c);
        assert_eq!(s.spills, 1);
        assert!(s.xfer.write_bytes > 0);
        assert_eq!(s.xfer.write_busy, c.nvme_write_time());
    }

    #[test]
    fn gpu_admission_pins_and_demotion_is_free() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        s.ensure_host(0, 0, 0, &c); // already host; no-op
        s.admit_to_gpu(0, 0);
        assert_eq!(s.tier(0, 0), Tier::Gpu(0));
        // GPU expert is pinned: promoting two more spills only expert 1
        s.ensure_host(0, 2, 0, &c);
        assert_eq!(s.tier(0, 1), Tier::Disk);
        assert_eq!(s.tier(0, 0), Tier::Gpu(0));
        let nvme = s.xfer.read_busy;
        s.demote_gpu(0, 0);
        assert_eq!(s.tier(0, 0), Tier::Host);
        assert_eq!(s.xfer.read_busy, nvme, "demotion moves no bytes");
        assert_eq!(s.gpu_demotions, 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn sync_layer_is_free_and_idempotent() {
        let mut s = TieredStore::new(2, 4, StoreCfg { host_slots: 2, ..Default::default() });
        s.sync_layer(0, &[false, false, true, true]);
        assert_eq!(s.tier(0, 2), Tier::Gpu(0));
        assert_eq!(s.tier(0, 3), Tier::Gpu(0));
        assert_eq!(s.xfer.read_bytes, 0, "initial placement is free");
        // second sync of the same layer does nothing
        s.sync_layer(0, &[true, false, false, false]);
        assert_eq!(s.tier(0, 0), Tier::Host);
        s.check_invariants().unwrap();
    }

    #[test]
    fn for_model_converts_ram_budget_to_slots() {
        let p = Presets::load_default().unwrap();
        let m = p.model("mixtral-sim").unwrap();
        let c = CostModel::new(m, p.hw("local-pc-ram16").unwrap());
        let s = TieredStore::for_model(p.hw("local-pc-ram16").unwrap(), &c, 4, 8);
        assert!(!s.is_unlimited());
        assert!(s.host_slots() >= 1 && s.host_slots() < 32);
        assert!(s.host_bytes_paper(&c) <= 16e9);
        // unlimited hardware → unlimited store
        let c2 = CostModel::new(m, p.hw("local-pc").unwrap());
        assert!(TieredStore::for_model(p.hw("local-pc").unwrap(), &c2, 4, 8).is_unlimited());
    }

    #[test]
    fn promote_ahead_hides_nvme_latency_and_counts_hits() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 3, ..Default::default() });
        s.set_placement(PlacementCfg::predictive(1));
        assert_eq!(s.tier(0, 3), Tier::Disk);
        s.note_predictions(0, &[0.0, 0.0, 0.0, 5.0]);
        assert!(s.promote_ahead(0, 3, 0, &c));
        assert!(s.pending(0, 3, 0));
        assert_eq!(s.ahead_issued, 1);
        let dur = c.nvme_read_time();
        // consumed well after the read landed: the whole read was hidden
        let arr = s.host_arrival(0, 3, 2 * dur, &c);
        assert_eq!(arr, 2 * dur);
        assert_eq!(s.ahead_hits, 1);
        assert_eq!(s.overlap_hidden_ns, dur);
        assert_eq!(s.demand_read_ns, 0, "no demand-path read was needed");
        assert!(!s.pending(0, 3, 2 * dur));
        s.check_invariants().unwrap();
    }

    #[test]
    fn promote_ahead_partial_overlap_counts_hidden_portion() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 3, ..Default::default() });
        s.set_placement(PlacementCfg::predictive(1));
        s.note_predictions(0, &[0.0, 0.0, 0.0, 4.0]);
        assert!(s.promote_ahead(0, 3, 0, &c));
        let dur = c.nvme_read_time();
        // consumed halfway through the read: half the latency was hidden
        let arr = s.host_arrival(0, 3, dur / 2, &c);
        assert_eq!(arr, dur, "consumer waits for the in-flight read");
        assert_eq!(s.overlap_hidden_ns, dur - (dur - dur / 2));
    }

    #[test]
    fn promote_ahead_refuses_backlog_and_warmer_victims() {
        let c = cost();
        let mut s = TieredStore::new(1, 8, StoreCfg { host_slots: 2, ..Default::default() });
        s.set_placement(PlacementCfg { predictive: true, ahead: 8, max_backlog: 1, decay: 0.5 });
        // hosts 0 and 1 are warm; candidates colder than both are refused
        s.observe_workloads(0, &[9, 9, 0, 0, 0, 0, 0, 0]);
        assert!(!s.promote_ahead(0, 2, 0, &c), "no colder victim to displace");
        assert_eq!(s.spills, 0);
        // hotter candidates displace the coldest hosts, until the read
        // stream's speculative backlog gate trips
        s.note_predictions(0, &[0.0, 0.0, 0.0, 20.0, 30.0, 40.0, 0.0, 0.0]);
        assert!(s.promote_ahead(0, 3, 0, &c));
        assert_eq!(s.spills, 1);
        assert!(s.promote_ahead(0, 4, 0, &c), "one read of backlog allowed");
        assert!(!s.promote_ahead(0, 5, 0, &c), "two reads of backlog refused");
        assert_eq!(s.ahead_issued, 2);
        s.check_invariants().unwrap();
    }

    #[test]
    fn spilling_an_unused_ahead_promotion_is_a_miss() {
        let c = cost();
        let mut s = TieredStore::new(1, 8, StoreCfg { host_slots: 1, ..Default::default() });
        s.set_placement(PlacementCfg::predictive(1));
        s.note_predictions(0, &[0.0, 0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert!(s.promote_ahead(0, 2, 0, &c));
        // a demand promotion now evicts the (lowest-score) victim; with
        // only one slot the unconsumed ahead promotion itself goes
        s.note_predictions(0, &[0.0, 0.0, 0.0, 9.0, 0.0, 0.0, 0.0, 0.0]);
        s.ensure_host(0, 3, 0, &c);
        assert_eq!(s.ahead_misses, 1);
        assert_eq!(s.ahead_hits, 0);
        assert_eq!(s.tier(0, 2), Tier::Disk);
        s.check_invariants().unwrap();
    }

    #[test]
    fn predictive_spill_picks_lowest_score_not_lru() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        s.set_placement(PlacementCfg::predictive(1));
        // expert 0 is hot by score but least recently used; expert 1 cold
        s.observe_workloads(0, &[10, 1, 0, 0]);
        s.touch(0, 1); // LRU would evict 0
        s.ensure_host(0, 3, 0, &c);
        assert_eq!(s.tier(0, 0), Tier::Host, "hot expert survives");
        assert_eq!(s.tier(0, 1), Tier::Disk, "cold score evicted despite recency");
        s.check_invariants().unwrap();
    }

    #[test]
    fn observe_decays_and_predictions_raise_scores() {
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        // reactive stores keep the table idle
        s.observe_workloads(0, &[4, 0, 0, 0]);
        assert_eq!(s.score(0, 0), 0.0);
        s.set_placement(PlacementCfg::predictive(1));
        s.observe_workloads(0, &[4, 0, 0, 0]);
        assert_eq!(s.score(0, 0), 4.0);
        s.observe_workloads(0, &[0, 0, 0, 0]);
        assert_eq!(s.score(0, 0), 2.0, "decay halves an idle expert");
        s.note_predictions(0, &[1.0, 8.0, 0.0, 0.0]);
        assert_eq!(s.score(0, 0), 2.0, "lower prediction never lowers");
        assert_eq!(s.score(0, 1), 8.0);
    }

    #[test]
    fn quantized_promotion_chains_read_and_transcode() {
        let c = cost().with_quant_ratio(0.25);
        assert!(c.transcode_time() > 0);
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        let arr = s.ensure_host(0, 2, 0, &c);
        // host arrival is the transcode completion, not the read's
        assert_eq!(arr, c.nvme_read_time() + c.transcode_time());
        // NVMe moved only the on-disk (quantized) bytes
        assert_eq!(s.xfer.read_bytes, c.disk_expert_bytes() as u64);
        assert_eq!(s.xfer.transcode_busy, c.transcode_time());
        assert_eq!(s.xfer.transcodes, 1);
        // the demand charge is the read — the transcode lane is separate
        assert_eq!(s.demand_read_ns, c.nvme_read_time());
        assert_eq!(
            s.bytes_saved,
            c.expert_bytes() as u64 - c.disk_expert_bytes() as u64
        );
        // a second promotion's read overlaps the first expert's transcode
        let arr2 = s.ensure_host(0, 3, 0, &c);
        assert_eq!(
            arr2,
            2 * c.nvme_read_time() + c.transcode_time(),
            "expert 3's read runs while expert 2 transcodes"
        );
        s.check_invariants().unwrap();
    }

    #[test]
    fn quantized_writeback_spills_quantized_bytes() {
        let c = cost().with_quant_ratio(0.25);
        let mut s = TieredStore::new(2, 4, StoreCfg { host_slots: 1, spill_writeback: true });
        s.ensure_host(1, 3, 0, &c);
        assert_eq!(s.spills, 1);
        assert_eq!(s.xfer.write_bytes, c.disk_expert_bytes() as u64);
        assert_eq!(s.xfer.write_busy, c.nvme_write_time());
        assert!((s.xfer.write_bytes as f64) < c.expert_bytes(), "spill re-quantizes");
        // the spill's re-quantize and the promotion's dequantize share the
        // transcode lane, and the NVMe write waits for the encoded bytes
        assert_eq!(s.xfer.transcodes, 2);
        assert_eq!(s.xfer.write_free_at(), c.transcode_time() + c.nvme_write_time());
        s.check_invariants().unwrap();
    }

    #[test]
    fn seeded_gpu_admissions_never_widen_the_configured_budget() {
        // Bugfix regression: the initial-placement Disk path in
        // `admit_to_gpu` used to fold borrowed slots into `host_slots`,
        // permanently inflating the configured budget. The allowance now
        // lives in `seed_slack` and the budget survives warmup.
        let c = cost();
        let mut s = TieredStore::new(2, 4, StoreCfg { host_slots: 2, ..Default::default() });
        // initial fill (expert-major, 2 slots over 2 layers): e0 of both
        // layers is host-resident; the cache seeds 3 GPU residents in
        // layer 0, two of them disk-resident
        s.sync_layer(0, &[true, true, true, false]);
        assert_eq!(s.host_slots(), 2, "configured budget survives seeding");
        assert_eq!(s.seed_slack(), 2, "borrowed slots are tracked separately");
        assert_eq!(s.host_used(), 4);
        s.check_invariants().unwrap();
        // demand traffic fits the effective capacity without inflating
        // the budget any further
        s.ensure_host(1, 3, 0, &c);
        assert_eq!(s.host_slots(), 2, "mid-run promotions must not inflate the budget");
        assert_eq!(s.seed_slack(), 2, "GPU-pinned slots cannot be repaid yet");
        assert_eq!(s.spills, 1, "the promotion displaced a host victim");
        s.check_invariants().unwrap();
    }

    #[test]
    fn demand_pressure_repays_the_seed_allowance() {
        // Once seeded GPU residents demote back to host, every
        // demand-pressure spill repays one borrowed slot: the effective
        // capacity decays to the configured budget instead of staying
        // inflated at the warmup peak.
        let c = cost();
        let mut s = TieredStore::new(1, 8, StoreCfg { host_slots: 2, ..Default::default() });
        s.sync_layer(0, &[true, true, true, true, false, false, false, false]);
        assert_eq!(s.seed_slack(), 2, "e2/e3 were seeded beyond the 2-slot budget");
        assert_eq!(s.host_used(), 4);
        for e in 0..4 {
            s.demote_gpu(0, e);
        }
        assert_eq!(s.host_used(), 4, "demotion keeps the pinned host copies");
        // two pressure events each spill a victim for the promotion plus
        // one extra to repay the allowance
        s.ensure_host(0, 5, 0, &c);
        assert_eq!(s.seed_slack(), 1);
        assert_eq!(s.host_used(), 3);
        s.ensure_host(0, 6, 0, &c);
        assert_eq!(s.seed_slack(), 0, "allowance fully repaid");
        assert_eq!(s.host_used(), 2);
        assert_eq!(s.host_used(), s.host_slots(), "back at the configured budget");
        assert_eq!(s.spills, 4);
        assert_eq!(s.promotions, 2);
        s.check_invariants().unwrap();
    }

    #[test]
    fn rebase_shifts_host_arrivals_and_clears_counters() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        s.set_placement(PlacementCfg::predictive(1));
        s.note_predictions(0, &[0.0, 0.0, 3.0, 0.0]);
        assert!(s.promote_ahead(0, 2, 0, &c));
        let dur = c.nvme_read_time();
        s.rebase_and_clear(dur / 2);
        assert_eq!(s.ahead_issued, 0);
        // the in-flight read's residual survives the reset (busy time past
        // the reset instant must not be undercounted)
        assert_eq!(s.xfer.read_busy, dur - dur / 2);
        assert_eq!(s.xfer.read_bytes, 0, "bytes belong to the issuing period");
        assert!(!s.pending(0, 2, dur), "ahead flag belongs to the cleared period");
        // the in-flight arrival shifted with the clock and is still waited on
        let arr = s.host_arrival(0, 2, 0, &c);
        assert_eq!(arr, dur - dur / 2);
        assert_eq!(s.ahead_hits, 0, "hit accounting does not cross the reset");
        s.check_invariants().unwrap();
    }

    fn flaky(fail: f64, retries: u32) -> FaultPlan {
        let p = FaultProfile {
            nvme_fail_prob: fail,
            max_retries: retries,
            ..FaultProfile::default()
        };
        FaultPlan::new(p, 11)
    }

    #[test]
    fn faulted_demand_promotion_retries_then_reads_raw() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        s.set_faults(Some(flaky(1.0, 1)));
        let r = c.nvme_read_time();
        // every attempt fails and max_retries = 1: two stalled attempts
        // (3r each, timeout_mult 3) separated by exponential backoffs
        // (r, then 2r), then the raw fallback read that must succeed
        let arr = s.ensure_host(0, 2, 0, &c);
        assert_eq!(arr, 10 * r);
        assert_eq!(s.fault_retries, 2);
        assert_eq!(s.fault_stall_ns, 6 * r);
        assert_eq!(s.fault_aborts, 0, "demand fetches never abort");
        assert_eq!(s.xfer.read_stalls, 2);
        assert_eq!(s.xfer.reads, 1, "only the successful read counts");
        assert_eq!(s.xfer.read_busy, 7 * r);
        assert_eq!(s.demand_read_ns, r, "the demand charge stays the clean read");
        assert_eq!(s.tier(0, 2), Tier::Host);
        s.check_invariants().unwrap();
    }

    #[test]
    fn exhausted_speculative_promotion_aborts_and_leaves_disk() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 3, ..Default::default() });
        s.set_placement(PlacementCfg::predictive(1));
        s.set_faults(Some(flaky(1.0, 0)));
        s.note_predictions(0, &[0.0, 0.0, 0.0, 5.0]);
        assert!(!s.promote_ahead(0, 3, 0, &c), "exhausted ledger aborts the ahead read");
        assert_eq!(s.tier(0, 3), Tier::Disk);
        assert_eq!(s.fault_aborts, 1);
        assert_eq!(s.fault_retries, 1, "the one failed attempt stalled the lane");
        assert_eq!(s.xfer.reads, 0, "no bytes moved");
        assert_eq!(s.xfer.read_bytes, 0);
        assert_eq!(s.promotions, 0);
        assert_eq!(s.ahead_issued, 0);
        assert_eq!(s.spills, 1, "the victim spill stands — work the sick drive wasted");
        s.check_invariants().unwrap();
    }

    #[test]
    fn ram_pressure_shrinks_then_restores_the_host_budget() {
        let c = cost();
        let mut s = TieredStore::new(1, 8, StoreCfg { host_slots: 4, ..Default::default() });
        s.set_placement(PlacementCfg::predictive(1));
        let p = FaultProfile {
            ram_period: 8,
            ram_len: 4,
            ram_shrink_frac: 0.5,
            ..FaultProfile::default()
        };
        let plan = FaultPlan::new(p, 21);
        s.set_faults(Some(plan));
        // the window phase is seed-jittered: locate one shrunken and one
        // clear step instead of assuming which is which
        let shrunk = (0..8).find(|&t| plan.ram_reserved(t, 4) == 2).unwrap();
        let clear = (0..8).find(|&t| plan.ram_reserved(t, 4) == 0).unwrap();
        s.apply_fault_step(shrunk, 0, &c, &mut NullSink);
        assert!(s.under_pressure());
        assert_eq!(s.pressure_reserved(), 2);
        assert_eq!(s.host_used(), 2, "two residents demoted to satisfy the shrink");
        assert_eq!(s.ram_pressure_spills, 2);
        assert_eq!(s.ram_pressure_events, 1);
        s.check_invariants().unwrap();
        // speculation pauses while the budget is shrunken
        s.note_predictions(0, &[0.0, 0.0, 0.0, 0.0, 0.0, 9.0, 0.0, 0.0]);
        assert!(!s.promote_ahead(0, 5, 0, &c), "promote-ahead pauses under pressure");
        s.apply_fault_step(clear, 100, &c, &mut NullSink);
        assert!(!s.under_pressure());
        assert_eq!(s.ram_pressure_events, 2, "the restore edge is an event too");
        // restored capacity admits promotions again without overcommit
        s.ensure_host(0, 6, 100, &c);
        assert_eq!(s.overcommits, 0);
        assert_eq!(s.host_used(), 3);
        s.check_invariants().unwrap();
    }

    #[test]
    fn ram_pressure_clamps_at_the_gpu_pinned_floor() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg { host_slots: 2, ..Default::default() });
        s.sync_layer(0, &[true, true, false, false]);
        assert_eq!(s.host_used(), 2);
        let p = FaultProfile {
            ram_period: 4,
            ram_len: 4, // len == period: every step is in-window
            ram_shrink_frac: 1.0,
            ..FaultProfile::default()
        };
        let plan = FaultPlan::new(p, 3);
        s.set_faults(Some(plan));
        assert_eq!(plan.ram_reserved(0, 2), 2);
        s.apply_fault_step(0, 0, &c, &mut NullSink);
        // both residents are GPU-pinned staging copies: nothing can spill
        // and the reservation clamps down to the achievable zero
        assert_eq!(s.pressure_reserved(), 0);
        assert_eq!(s.ram_pressure_spills, 0);
        assert_eq!(s.spills, 0);
        assert_eq!(s.host_used(), 2);
        s.check_invariants().unwrap();
    }

    #[test]
    fn multi_device_seeding_shards_by_home_device() {
        let mut s = TieredStore::new(2, 8, StoreCfg::default());
        s.set_n_devices(2);
        assert_eq!(s.n_devices(), 2);
        for e in 0..8 {
            assert_eq!(s.home_device(e), (e % 2) as u8);
        }
        s.sync_layer(0, &[true; 8]);
        // round-robin striping: evens on device 0, odds on device 1
        for e in 0..8 {
            assert_eq!(s.tier(0, e), Tier::Gpu((e % 2) as u8));
        }
        assert_eq!(s.gpu_used_dev(0), 4);
        assert_eq!(s.gpu_used_dev(1), 4);
        let (g, _, _) = s.counts();
        assert_eq!(g, 8, "counts() sums over every device tier");
        s.check_invariants().unwrap();
        // demotion releases the right device's count
        s.demote_gpu(0, 3);
        assert_eq!(s.gpu_used_dev(1), 3);
        assert_eq!(s.gpu_used_dev(0), 4);
        s.check_invariants().unwrap();
        // a single-device store homes everything on device 0
        let mut one = TieredStore::new(1, 4, StoreCfg::default());
        one.sync_layer(0, &[true, true, false, false]);
        assert_eq!(one.tier(0, 1), Tier::Gpu(0));
        one.check_invariants().unwrap();
    }

    #[test]
    fn p2p_migration_charges_the_fabric_lane_once() {
        let c = cost();
        let mut s = TieredStore::new(1, 4, StoreCfg::default());
        s.set_n_devices(2);
        s.sync_layer(0, &[true, false, false, false]);
        assert_eq!(s.tier(0, 0), Tier::Gpu(0));
        // cross-device move: one expert of fp16 bytes on the P2P lane
        let done = s.migrate_gpu_dev(0, 0, 1, 0, &c);
        assert_eq!(done, c.p2p_time());
        assert_eq!(s.tier(0, 0), Tier::Gpu(1));
        assert_eq!(s.gpu_used_dev(0), 0);
        assert_eq!(s.gpu_used_dev(1), 1);
        assert_eq!(s.p2p_migrations, 1);
        assert_eq!(s.xfer.p2p_copies, 1);
        assert_eq!(s.xfer.p2p_bytes, c.expert_bytes() as u64);
        assert_eq!(s.xfer.p2p_busy, c.p2p_time());
        s.check_invariants().unwrap();
        // same-device "move" is free and moves nothing
        let same = s.migrate_gpu_dev(0, 0, 1, 99, &c);
        assert_eq!(same, 99);
        assert_eq!(s.xfer.p2p_copies, 1);
        // NVMe accounting is untouched by fabric traffic
        assert_eq!(s.xfer.read_bytes, 0);
        assert_eq!(s.xfer.write_bytes, 0);
    }

    #[test]
    fn per_device_budgets_are_enforced_by_the_invariant_check() {
        let mut s = TieredStore::new(1, 8, StoreCfg::default());
        s.set_n_devices(2);
        s.set_gpu_slots(0, 2);
        s.sync_layer(0, &[true, true, true, false, false, false, false, false]);
        // e0/e2 home on device 0 (2 used, budget 2), e1 on device 1: legal
        s.check_invariants().unwrap();
        // a third device-0 admission breaches the budget
        s.set_gpu_slots(0, 1);
        let err = s.check_invariants().unwrap_err();
        assert!(err.contains("device 0 over budget"), "{err}");
    }

    #[test]
    fn clean_fault_plan_is_transparent() {
        let c = cost();
        let mut a = TieredStore::new(2, 4, StoreCfg { host_slots: 2, ..Default::default() });
        let mut b = a.clone();
        b.set_faults(Some(FaultPlan::new(FaultProfile::clean(), 42)));
        b.apply_fault_step(5, 0, &c, &mut NullSink);
        for (l, e) in [(0, 2), (1, 3), (0, 3)] {
            assert_eq!(a.ensure_host(l, e, 0, &c), b.ensure_host(l, e, 0, &c));
        }
        assert_eq!(a.xfer.read_busy, b.xfer.read_busy);
        assert_eq!(b.fault_retries, 0);
        assert_eq!(b.xfer.read_stalls, 0);
        assert_eq!(b.ram_pressure_events, 0);
    }
}
