//! Virtual-time NVMe transfer streams (disk ↔ host).
//!
//! Mirrors [`crate::hw::GpuPipeline`]'s stream discipline for the third
//! tier: one read stream (disk → host promotions) and one write stream
//! (host → disk spills), each FIFO with its own free-time pointer, so
//! promotions and demotions overlap each other and all GPU work. A
//! promotion that feeds a PCIe upload chains: the PCIe transfer may start
//! only at the NVMe arrival instant.

use crate::hw::Ns;

/// Two independent NVMe virtual-time streams plus traffic counters.
#[derive(Debug, Clone, Default)]
pub struct TransferScheduler {
    read_free: Ns,
    write_free: Ns,
    /// Busy-time integrals per stream.
    pub read_busy: Ns,
    pub write_busy: Ns,
    /// Bytes moved per direction.
    pub read_bytes: u64,
    pub write_bytes: u64,
    /// Transfer counts per direction.
    pub reads: u64,
    pub writes: u64,
}

impl TransferScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Next instant the read stream is free.
    pub fn read_free_at(&self) -> Ns {
        self.read_free
    }

    /// Next instant the write stream is free.
    pub fn write_free_at(&self) -> Ns {
        self.write_free
    }

    /// Schedule a disk→host read at or after `now`; returns arrival time.
    pub fn schedule_read(&mut self, now: Ns, dur: Ns, bytes: u64) -> Ns {
        let start = self.read_free.max(now);
        self.read_free = start + dur;
        self.read_busy += dur;
        self.read_bytes += bytes;
        self.reads += 1;
        self.read_free
    }

    /// Schedule a host→disk write at or after `now`; returns completion.
    pub fn schedule_write(&mut self, now: Ns, dur: Ns, bytes: u64) -> Ns {
        let start = self.write_free.max(now);
        self.write_free = start + dur;
        self.write_busy += dur;
        self.write_bytes += bytes;
        self.writes += 1;
        self.write_free
    }

    /// Re-base stream clocks after a metrics reset (mirrors
    /// `StepSimulator::reset_metrics` re-basing in-flight prefetches) and
    /// clear the counters.
    pub fn rebase_and_clear(&mut self, base: Ns) {
        self.read_free = self.read_free.saturating_sub(base);
        self.write_free = self.write_free.saturating_sub(base);
        self.read_busy = 0;
        self.write_busy = 0;
        self.read_bytes = 0;
        self.write_bytes = 0;
        self.reads = 0;
        self.writes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_are_fifo_on_one_stream() {
        let mut s = TransferScheduler::new();
        assert_eq!(s.schedule_read(0, 100, 8), 100);
        assert_eq!(s.schedule_read(0, 50, 8), 150);
        assert_eq!(s.read_busy, 150);
        assert_eq!(s.read_bytes, 16);
        assert_eq!(s.reads, 2);
    }

    #[test]
    fn read_and_write_streams_overlap() {
        let mut s = TransferScheduler::new();
        let r = s.schedule_read(0, 100, 1);
        let w = s.schedule_write(0, 100, 1);
        assert_eq!(r, 100);
        assert_eq!(w, 100, "write stream does not queue behind reads");
    }

    #[test]
    fn transfers_respect_now() {
        let mut s = TransferScheduler::new();
        assert_eq!(s.schedule_read(500, 100, 1), 600);
        assert_eq!(s.schedule_read(0, 100, 1), 700, "FIFO after the backlog");
    }

    #[test]
    fn rebase_shifts_clocks_and_clears_counters() {
        let mut s = TransferScheduler::new();
        s.schedule_read(0, 1000, 4);
        s.schedule_write(0, 300, 4);
        s.rebase_and_clear(400);
        assert_eq!(s.read_free_at(), 600);
        assert_eq!(s.write_free_at(), 0);
        assert_eq!(s.read_busy, 0);
        assert_eq!(s.write_bytes, 0);
    }
}
