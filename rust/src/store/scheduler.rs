//! Virtual-time NVMe transfer streams (disk ↔ host) plus the CPU
//! transcode lane.
//!
//! Mirrors [`crate::hw::GpuPipeline`]'s stream discipline for the third
//! tier: one read stream (disk → host promotions), one write stream
//! (host → disk spills), and one CPU transcode lane (dequantizing a
//! quantized on-disk format into usable fp16 host weights), each FIFO
//! with its own free-time pointer. Promotions, demotions and transcodes
//! therefore overlap each other and all GPU work: while one expert
//! dequantizes, the next expert's (smaller, quantized) read is already in
//! flight. A promotion that feeds a PCIe upload chains: the PCIe transfer
//! may start only at the transcode-completion (host-usable) instant.

use crate::hw::Ns;

/// Four independent virtual-time lanes plus traffic counters.
#[derive(Debug, Clone, Default)]
pub struct TransferScheduler {
    read_free: Ns,
    write_free: Ns,
    transcode_free: Ns,
    p2p_free: Ns,
    /// Start of the contiguous busy run ending at each lane's free
    /// pointer — lets [`Self::rebase_and_clear`] carry the residual busy
    /// time of in-flight work across a metrics reset instead of dropping
    /// it (transfers straddling the reset used to be undercounted).
    read_run: Ns,
    write_run: Ns,
    transcode_run: Ns,
    p2p_run: Ns,
    /// Busy-time integrals per lane.
    pub read_busy: Ns,
    pub write_busy: Ns,
    /// CPU transcode (dequantize) lane busy time — a quantized disk read
    /// becomes usable host weights only after this stage.
    pub transcode_busy: Ns,
    /// Bytes moved per direction (on-disk bytes: quantized when the
    /// scenario stores experts compressed).
    pub read_bytes: u64,
    pub write_bytes: u64,
    /// Operation counts per lane. `reads` counts *successful* reads only —
    /// fault-injected attempts that time out are tracked separately so the
    /// `reads == promotions` style conservation properties stay exact.
    pub reads: u64,
    pub writes: u64,
    pub transcodes: u64,
    /// Fault injection: timed-out read attempts (lane occupied, no usable
    /// bytes moved) and the lane time they consumed. `read_stall_ns` is a
    /// subset of `read_busy` — the stream is genuinely busy while a
    /// stalled command waits for its timeout.
    pub read_stalls: u64,
    pub read_stall_ns: Ns,
    /// Inter-GPU P2P/NVLink lane: busy integral, bytes moved (fp16 — the
    /// executable format the device tiers hold), and copy count. One
    /// shared lane models the NVLink/PCIe-P2P fabric; single-GPU runs
    /// never touch it, so all three stay 0 there.
    pub p2p_busy: Ns,
    pub p2p_bytes: u64,
    pub p2p_copies: u64,
}

impl TransferScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Next instant the read stream is free.
    pub fn read_free_at(&self) -> Ns {
        self.read_free
    }

    /// Next instant the write stream is free.
    pub fn write_free_at(&self) -> Ns {
        self.write_free
    }

    /// Next instant the transcode lane is free.
    pub fn transcode_free_at(&self) -> Ns {
        self.transcode_free
    }

    /// Schedule a disk→host read at or after `now`; returns arrival time.
    pub fn schedule_read(&mut self, now: Ns, dur: Ns, bytes: u64) -> Ns {
        let start = self.read_free.max(now);
        if start > self.read_free {
            self.read_run = start;
        }
        self.read_free = start + dur;
        self.read_busy += dur;
        self.read_bytes += bytes;
        self.reads += 1;
        self.read_free
    }

    /// Schedule a *failed* read attempt at or after `now`: the stream is
    /// occupied for the per-transfer timeout `dur`, then the command is
    /// abandoned — no bytes arrive and `reads` does not advance. Returns
    /// the instant the timeout fires (the earliest a retry may be
    /// re-issued, before backoff). Fault-injection runs only.
    pub fn schedule_read_stall(&mut self, now: Ns, dur: Ns) -> Ns {
        let start = self.read_free.max(now);
        if start > self.read_free {
            self.read_run = start;
        }
        self.read_free = start + dur;
        self.read_busy += dur;
        self.read_stalls += 1;
        self.read_stall_ns += dur;
        self.read_free
    }

    /// Schedule a host→disk write at or after `now`; returns completion.
    pub fn schedule_write(&mut self, now: Ns, dur: Ns, bytes: u64) -> Ns {
        let start = self.write_free.max(now);
        if start > self.write_free {
            self.write_run = start;
        }
        self.write_free = start + dur;
        self.write_busy += dur;
        self.write_bytes += bytes;
        self.writes += 1;
        self.write_free
    }

    /// Next instant the inter-GPU P2P lane is free.
    pub fn p2p_free_at(&self) -> Ns {
        self.p2p_free
    }

    /// Schedule one inter-GPU P2P/NVLink copy at or after `now`; returns
    /// its arrival on the destination device. FIFO on the shared fabric
    /// lane — concurrent device pairs serialize, the conservative model —
    /// and fully overlapped with every NVMe/PCIe/compute lane.
    pub fn schedule_p2p(&mut self, now: Ns, dur: Ns, bytes: u64) -> Ns {
        let start = self.p2p_free.max(now);
        if start > self.p2p_free {
            self.p2p_run = start;
        }
        self.p2p_free = start + dur;
        self.p2p_busy += dur;
        self.p2p_bytes += bytes;
        self.p2p_copies += 1;
        self.p2p_free
    }

    /// Schedule the CPU transcode (dequantize) of one promoted expert at
    /// or after `after` (its NVMe read completion); returns the instant
    /// the fp16 host copy is usable. FIFO on its own lane, so transcodes
    /// overlap subsequent reads and all GPU/PCIe work.
    pub fn schedule_transcode(&mut self, after: Ns, dur: Ns) -> Ns {
        let start = self.transcode_free.max(after);
        if start > self.transcode_free {
            self.transcode_run = start;
        }
        self.transcode_free = start + dur;
        self.transcode_busy += dur;
        self.transcodes += 1;
        self.transcode_free
    }

    /// Re-base lane clocks after a metrics reset (mirrors
    /// `StepSimulator::reset_metrics` re-basing in-flight prefetches) and
    /// clear the counters. Busy integrals restart at the *residual* of
    /// work still in flight at `base` — the portion of the current busy
    /// run extending past the reset — so post-reset utilization metrics
    /// don't undercount transfers straddling the reset (they used to be
    /// zeroed outright). Bytes and operation counts are attributed to the
    /// period that issued them and drop to zero. The residual is exact
    /// whenever the lane's current busy run began at or before `base` —
    /// always true for the read stream (every read is issued at a sim
    /// instant the next layer barrier has absorbed by reset time); items
    /// chained off future completions — transcodes after their reads,
    /// quantized write-backs after their re-quantize — can start runs
    /// past `base`, where only the latest run's residual is kept (runs
    /// older than it are conservatively dropped).
    ///
    /// Attribution note: busy time is charged at *issue* time, so a
    /// straddling transfer appears in full in the issuing period's
    /// integral AND as a residual in the next period's. Per-period
    /// utilization is therefore never undercounted, but summing busy
    /// integrals across a reset double-counts the straddling portion —
    /// don't add phase-split busy numbers; every current caller resets
    /// exactly once, after a discarded warmup.
    ///
    /// Trace reconstruction: the step-trace subsystem makes these
    /// integrals auditable from the event stream. At a reset the
    /// simulator emits one carry `LaneBusy` interval per lane covering
    /// exactly the rebased residual (`[free − busy, free)` of the
    /// post-rebase state), so summing a trace's per-lane intervals after
    /// the last `reset` event reconstructs `read/write/transcode_busy`
    /// **exactly** — by construction, residual + every duration scheduled
    /// afterwards is precisely the integral. The trace is the source of
    /// truth for the *counters*; the counters themselves keep the bounded
    /// error documented above versus physical ground truth: a lane whose
    /// items chain off future completions (transcode, quantized
    /// write-back) can have several distinct busy runs past `base`, and
    /// only the latest run's residual is carried — older straddling runs
    /// are conservatively dropped from the post-reset period (each is
    /// still fully charged to the issuing period). The undercount is
    /// bounded by the backlog the issue gates allow and is pinned by
    /// `rebase_keeps_only_the_latest_future_transcode_run` below.
    pub fn rebase_and_clear(&mut self, base: Ns) {
        fn residual(free: Ns, run: Ns, base: Ns) -> Ns {
            free.saturating_sub(run.max(base))
        }
        self.read_busy = residual(self.read_free, self.read_run, base);
        self.write_busy = residual(self.write_free, self.write_run, base);
        self.transcode_busy = residual(self.transcode_free, self.transcode_run, base);
        self.p2p_busy = residual(self.p2p_free, self.p2p_run, base);
        self.read_free = self.read_free.saturating_sub(base);
        self.write_free = self.write_free.saturating_sub(base);
        self.transcode_free = self.transcode_free.saturating_sub(base);
        self.p2p_free = self.p2p_free.saturating_sub(base);
        self.read_run = self.read_run.saturating_sub(base);
        self.write_run = self.write_run.saturating_sub(base);
        self.transcode_run = self.transcode_run.saturating_sub(base);
        self.p2p_run = self.p2p_run.saturating_sub(base);
        self.read_bytes = 0;
        self.write_bytes = 0;
        self.reads = 0;
        self.writes = 0;
        self.transcodes = 0;
        self.read_stalls = 0;
        self.read_stall_ns = 0;
        self.p2p_bytes = 0;
        self.p2p_copies = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_are_fifo_on_one_stream() {
        let mut s = TransferScheduler::new();
        assert_eq!(s.schedule_read(0, 100, 8), 100);
        assert_eq!(s.schedule_read(0, 50, 8), 150);
        assert_eq!(s.read_busy, 150);
        assert_eq!(s.read_bytes, 16);
        assert_eq!(s.reads, 2);
    }

    #[test]
    fn read_and_write_streams_overlap() {
        let mut s = TransferScheduler::new();
        let r = s.schedule_read(0, 100, 1);
        let w = s.schedule_write(0, 100, 1);
        assert_eq!(r, 100);
        assert_eq!(w, 100, "write stream does not queue behind reads");
    }

    #[test]
    fn transfers_respect_now() {
        let mut s = TransferScheduler::new();
        assert_eq!(s.schedule_read(500, 100, 1), 600);
        assert_eq!(s.schedule_read(0, 100, 1), 700, "FIFO after the backlog");
    }

    #[test]
    fn transcode_lane_chains_reads_and_overlaps_the_next_read() {
        let mut s = TransferScheduler::new();
        let r1 = s.schedule_read(0, 100, 8);
        let t1 = s.schedule_transcode(r1, 30);
        assert_eq!(t1, 130, "transcode starts at read completion");
        // the second read runs while expert 1 transcodes
        let r2 = s.schedule_read(0, 100, 8);
        assert_eq!(r2, 200, "read stream never waits on the transcode lane");
        let t2 = s.schedule_transcode(r2, 30);
        assert_eq!(t2, 230, "second transcode waits for its own read, lane was idle");
        assert_eq!(s.transcode_busy, 60);
        assert_eq!(s.transcodes, 2);
        // a busy transcode lane queues FIFO
        let t3 = s.schedule_transcode(0, 50);
        assert_eq!(t3, 280);
    }

    #[test]
    fn stalled_attempts_occupy_the_lane_without_counting_as_reads() {
        let mut s = TransferScheduler::new();
        // a timed-out attempt, a backoff gap, then the successful retry
        let t = s.schedule_read_stall(0, 300);
        assert_eq!(t, 300);
        let r = s.schedule_read(t + 100, 100, 8);
        assert_eq!(r, 500, "retry honours the backoff gap (lane idle 300..400)");
        assert_eq!(s.reads, 1, "only the successful attempt is a read");
        assert_eq!(s.read_stalls, 1);
        assert_eq!(s.read_stall_ns, 300);
        assert_eq!(s.read_busy, 400, "stall time is genuine lane occupancy");
        assert_eq!(s.read_bytes, 8, "failed attempts move no usable bytes");
        // a later read queues FIFO behind the whole retry chain
        assert_eq!(s.schedule_read(0, 50, 1), 550);
        // rebase clears the stall counters with the rest
        s.rebase_and_clear(550);
        assert_eq!(s.read_stalls, 0);
        assert_eq!(s.read_stall_ns, 0);
    }

    #[test]
    fn rebase_shifts_clocks_and_keeps_residual_busy() {
        let mut s = TransferScheduler::new();
        s.schedule_read(0, 1000, 4);
        s.schedule_write(0, 300, 4);
        s.rebase_and_clear(400);
        assert_eq!(s.read_free_at(), 600);
        assert_eq!(s.write_free_at(), 0);
        // the regression the bugfix pins: the read still has 600 ns in
        // flight past the reset — busy carries the residual, not zero
        assert_eq!(s.read_busy, 600);
        assert_eq!(s.write_busy, 0, "the write finished before the reset");
        assert_eq!(s.read_bytes, 0, "bytes belong to the issuing period");
        assert_eq!(s.write_bytes, 0);
        assert_eq!(s.reads, 0);
    }

    #[test]
    fn rebase_residual_ignores_pre_gap_busy_time() {
        // Two reads separated by an idle gap: only the in-flight portion
        // of the *current* run survives the reset, not the whole backlog.
        let mut s = TransferScheduler::new();
        s.schedule_read(0, 100, 1); // done at 100
        s.schedule_read(500, 100, 1); // idle 100..500, done at 600
        s.rebase_and_clear(550);
        assert_eq!(s.read_busy, 50, "residual = portion of the run past the reset");
        assert_eq!(s.read_free_at(), 50);
        // a run starting entirely after the reset carries fully over
        let mut s2 = TransferScheduler::new();
        s2.schedule_read(0, 100, 1);
        s2.rebase_and_clear(700);
        assert_eq!(s2.read_busy, 0, "fully-landed transfers leave no residual");
        assert_eq!(s2.read_free_at(), 0);
    }

    #[test]
    fn rebase_carries_transcode_residual() {
        let mut s = TransferScheduler::new();
        let r = s.schedule_read(0, 100, 1);
        s.schedule_transcode(r, 60); // busy 100..160
        s.rebase_and_clear(120);
        assert_eq!(s.read_busy, 0);
        assert_eq!(s.transcode_busy, 40, "in-flight transcode keeps its residual");
        assert_eq!(s.transcode_free_at(), 40);
        assert_eq!(s.transcodes, 0);
    }

    #[test]
    fn p2p_lane_is_fifo_and_overlaps_every_other_lane() {
        let mut s = TransferScheduler::new();
        // P2P copies never queue behind NVMe traffic…
        s.schedule_read(0, 1000, 8);
        assert_eq!(s.schedule_p2p(0, 100, 4), 100);
        // …but serialize FIFO on the shared fabric lane
        assert_eq!(s.schedule_p2p(0, 50, 4), 150);
        assert_eq!(s.schedule_p2p(400, 50, 4), 450, "respects now after idle gap");
        assert_eq!(s.p2p_busy, 200);
        assert_eq!(s.p2p_bytes, 12);
        assert_eq!(s.p2p_copies, 3);
        assert_eq!(s.read_busy, 1000, "NVMe lane untouched by P2P traffic");
    }

    #[test]
    fn rebase_carries_p2p_residual_like_the_nvme_lanes() {
        // the same residual-busy carry rule as read/write/transcode: the
        // portion of the current run extending past the reset survives,
        // bytes and copy counts belong to the issuing period
        let mut s = TransferScheduler::new();
        s.schedule_p2p(0, 1000, 8);
        s.rebase_and_clear(400);
        assert_eq!(s.p2p_free_at(), 600);
        assert_eq!(s.p2p_busy, 600, "in-flight P2P copy keeps its residual");
        assert_eq!(s.p2p_bytes, 0);
        assert_eq!(s.p2p_copies, 0);
        // a fully-landed copy leaves no residual
        let mut s2 = TransferScheduler::new();
        s2.schedule_p2p(0, 100, 8);
        s2.rebase_and_clear(700);
        assert_eq!(s2.p2p_busy, 0);
        assert_eq!(s2.p2p_free_at(), 0);
        // pre-gap busy time is not carried — only the current run counts
        let mut s3 = TransferScheduler::new();
        s3.schedule_p2p(0, 100, 1); // run 0..100
        s3.schedule_p2p(500, 100, 1); // idle gap, run 500..600
        s3.rebase_and_clear(550);
        assert_eq!(s3.p2p_busy, 50, "residual = portion of the run past the reset");
    }

    #[test]
    fn rebase_keeps_only_the_latest_future_transcode_run() {
        // The documented bounded error of the busy counters: lanes whose
        // items chain off future completions (transcodes after reads) can
        // hold several distinct busy runs entirely past the reset instant,
        // and the run-start carry keeps only the latest one. Two reads
        // chained into two gapped transcodes: run 100..160, gap, run
        // 200..260 — a reset at 50 precedes both, but the carried residual
        // is the latest run's 60 ns, not the physical 120 ns still ahead.
        let mut s = TransferScheduler::new();
        let r1 = s.schedule_read(0, 100, 1); // read 0..100
        s.schedule_transcode(r1, 60); // transcode 100..160
        let r2 = s.schedule_read(100, 100, 1); // read 100..200
        s.schedule_transcode(r2, 60); // transcode 200..260 (lane idle 160..200)
        assert_eq!(s.transcode_busy, 120);
        s.rebase_and_clear(50);
        // the read lane's single contiguous run 0..200 carries exactly
        assert_eq!(s.read_busy, 150, "read lane: one run, exact residual");
        // the transcode lane drops the older future run (100..160)
        assert_eq!(
            s.transcode_busy, 60,
            "only the latest future transcode run survives the carry"
        );
        assert_eq!(s.transcode_free_at(), 210);
        // the trace-side carry interval [free − busy, free) = [150, 210)
        // is what post-reset interval sums rebuild — consistent with the
        // counter by construction, conservative versus ground truth.
    }
}
