//! GPU memory accounting (paper Table 7 and the Eq. 9 memory constraint).
//!
//! Tracks the paper-scale byte footprint of what the framework keeps
//! resident: attention weights (all layers, as all compared frameworks do),
//! the expert cache, KV cache, and transient expert buffers.

use crate::config::PaperDims;

#[derive(Debug, Clone)]
pub struct GpuMemModel {
    paper: PaperDims,
}

impl GpuMemModel {
    pub fn new(paper: &PaperDims) -> Self {
        GpuMemModel { paper: paper.clone() }
    }

    /// Attention + norm + gate weights for all layers (always resident).
    pub fn resident_base(&self) -> f64 {
        let d = self.paper.hidden as f64;
        let per_layer =
            (4.0 * d * d + 2.0 * d + d * self.paper.n_routed as f64) * self.paper.dtype_bytes as f64;
        per_layer * self.paper.layers as f64
    }

    /// Expert cache of `cache_size` experts per layer.
    pub fn cache_bytes(&self, cache_size: usize) -> f64 {
        self.paper.expert_bytes() * (cache_size * self.paper.layers) as f64
    }

    /// KV cache for `batch` sequences at length `seq` (fp16, MHA-equivalent).
    pub fn kv_bytes(&self, batch: usize, seq: usize) -> f64 {
        2.0 * (batch * seq) as f64 * self.paper.hidden as f64 * self.paper.dtype_bytes as f64
    }

    /// Transient buffers: staging area for in-flight expert transfers plus
    /// activations. `staging_experts` differs by framework — HybriMoE keeps
    /// buffers for every predicted/fetched expert alive across the layer,
    /// DALI disposes them as soon as the expert's kernel retires (§A.4-2).
    pub fn transient_bytes(&self, staging_experts: usize, batch: usize) -> f64 {
        let acts = 8.0 * batch as f64 * self.paper.hidden as f64 * 4.0;
        self.paper.expert_bytes() * staging_experts as f64 + acts
    }

    /// Total for Table 7.
    pub fn total(&self, cache_size: usize, batch: usize, seq: usize, staging: usize) -> f64 {
        self.resident_base()
            + self.cache_bytes(cache_size)
            + self.kv_bytes(batch, seq)
            + self.transient_bytes(staging, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Presets;

    #[test]
    fn mixtral_cache_dominates() {
        let p = Presets::load_default().unwrap();
        let m = GpuMemModel::new(&p.model("mixtral-sim").unwrap().paper);
        // 2 cached experts/layer × 32 layers × 352 MB ≈ 22 GB — the reason
        // Mixtral cache ratios stay small on a 24 GB card.
        assert!(m.cache_bytes(2) > 20e9);
        assert!(m.cache_bytes(1) < 13e9);
        assert!(m.resident_base() < 5e9);
    }

    #[test]
    fn memory_grows_with_batch() {
        let p = Presets::load_default().unwrap();
        let m = GpuMemModel::new(&p.model("qwen-sim").unwrap().paper);
        assert!(m.total(8, 64, 64, 1) > m.total(8, 8, 64, 1));
        assert!(m.kv_bytes(128, 64) > m.kv_bytes(8, 64));
    }
}
