//! GPU copy/compute two-stream pipeline simulator.
//!
//! The paper's Eq. 5 approximates each GPU expert's cost as
//! `max(trans, compute)` "due to pipeline parallelism". This module is the
//! exact discrete version of that pipeline: a copy stream (PCIe DMA) and a
//! compute stream, where an expert's kernel may start only after its weights
//! arrive. The scheduler *estimates* with Eq. 5; execution is *accounted*
//! with this pipeline, so estimation error is part of the reproduction, as
//! it is on real hardware.

use super::cost::Ns;

/// Why a transfer was issued — segregates PCIe traffic for Fig. 5 / Fig. 17.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    /// Demand fetch of an expert assigned to GPU but not resident.
    Demand,
    /// Speculative prefetch for the next layer (§4.2).
    Prefetch,
    /// Cache replacement traffic (§4.3, Alg. 2 line 13).
    CacheUpdate,
}

/// One simulated GPU with a compute stream and a **two-priority copy
/// engine**: demand fetches (an expert the scheduler just assigned to the
/// GPU) always take precedence over speculative traffic (prefetch, cache
/// updates), which waits for both lanes — the standard CUDA
/// priority-stream arrangement all compared frameworks use. Without this,
/// wrong prefetches would head-of-line-block demand fetches, which no real
/// implementation allows.
///
/// Time is absolute virtual ns; the engine advances a global clock and asks
/// the pipeline to schedule work at or after given instants.
#[derive(Debug, Clone, Default)]
pub struct GpuPipeline {
    /// High-priority lane (demand fetches).
    copy_free: Ns,
    /// Low-priority lane (prefetch / cache updates); never runs ahead of
    /// outstanding demand traffic.
    spec_free: Ns,
    compute_free: Ns,
    /// Total bytes moved over PCIe, by kind.
    pub bytes_demand: u64,
    pub bytes_prefetch: u64,
    pub bytes_cache: u64,
    /// Busy time integrals (for utilisation metrics). `copy_busy` sums both
    /// lanes; `copy_busy_demand` counts only the high-priority lane — the
    /// transfer time that sits on the critical demand path (paper Fig. 5's
    /// "PCIe transfer time" measures exactly this blocking traffic).
    pub copy_busy: Ns,
    pub copy_busy_demand: Ns,
    pub compute_busy: Ns,
    /// Compute-stream idle time attributable to waiting on transfers.
    pub stall: Ns,
}

/// Outcome of scheduling one expert (or one bare transfer).
#[derive(Debug, Clone, Copy)]
pub struct PipelineOutcome {
    pub copy_end: Ns,
    pub compute_end: Ns,
}

impl GpuPipeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Next instant the demand copy lane is free.
    pub fn copy_free_at(&self) -> Ns {
        self.copy_free
    }

    /// Next instant the speculative copy lane is free.
    pub fn spec_free_at(&self) -> Ns {
        self.spec_free.max(self.copy_free)
    }

    /// Next instant the compute stream is free.
    pub fn compute_free_at(&self) -> Ns {
        self.compute_free
    }

    /// Schedule a transfer at or after `now`. Demand transfers use the
    /// high-priority lane; speculative transfers wait for *both* lanes.
    pub fn schedule_transfer(&mut self, now: Ns, dur: Ns, bytes: u64, kind: TransferKind) -> Ns {
        let end = match kind {
            TransferKind::Demand => {
                let start = self.copy_free.max(now);
                self.copy_free = start + dur;
                self.bytes_demand += bytes;
                self.copy_busy_demand += dur;
                start + dur
            }
            TransferKind::Prefetch | TransferKind::CacheUpdate => {
                let start = self.spec_free.max(self.copy_free).max(now);
                self.spec_free = start + dur;
                if kind == TransferKind::Prefetch {
                    self.bytes_prefetch += bytes;
                } else {
                    self.bytes_cache += bytes;
                }
                start + dur
            }
        };
        self.copy_busy += dur;
        end
    }

    /// Schedule one expert: optional demand transfer then compute.
    ///
    /// `ready` — when the expert's *inputs* are ready (layer start);
    /// `trans` — transfer duration (0 if resident);
    /// `compute` — kernel duration.
    pub fn schedule_expert(
        &mut self,
        ready: Ns,
        trans: Ns,
        trans_bytes: u64,
        compute: Ns,
    ) -> PipelineOutcome {
        let copy_end = if trans > 0 {
            self.schedule_transfer(ready, trans, trans_bytes, TransferKind::Demand)
        } else {
            ready
        };
        let start = self.compute_free.max(copy_end);
        // idle gap on the compute stream caused by waiting for the copy
        let idle_from = self.compute_free.max(ready);
        if start > idle_from {
            self.stall += start - idle_from;
        }
        let end = start + compute;
        self.compute_free = end;
        self.compute_busy += compute;
        PipelineOutcome { copy_end, compute_end: end }
    }

    /// Fast-forward all streams to at least `now` (layer barrier).
    pub fn barrier(&mut self, now: Ns) {
        self.copy_free = self.copy_free.max(now);
        self.spec_free = self.spec_free.max(now);
        self.compute_free = self.compute_free.max(now);
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_demand + self.bytes_prefetch + self.bytes_cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_expert_runs_immediately() {
        let mut p = GpuPipeline::new();
        let o = p.schedule_expert(100, 0, 0, 50);
        assert_eq!(o.compute_end, 150);
        assert_eq!(p.total_bytes(), 0);
        assert_eq!(p.stall, 0);
    }

    #[test]
    fn transfer_blocks_compute() {
        let mut p = GpuPipeline::new();
        let o = p.schedule_expert(0, 100, 8, 50);
        assert_eq!(o.copy_end, 100);
        assert_eq!(o.compute_end, 150);
        assert_eq!(p.stall, 100);
        assert_eq!(p.bytes_demand, 8);
    }

    #[test]
    fn pipeline_overlaps_copy_and_compute() {
        // expert A: trans 100 compute 100; expert B same. B's copy overlaps
        // A's compute → makespan 300, not 400 (the Eq. 5 max() behaviour).
        let mut p = GpuPipeline::new();
        p.schedule_expert(0, 100, 1, 100);
        let o = p.schedule_expert(0, 100, 1, 100);
        assert_eq!(o.compute_end, 300);
    }

    #[test]
    fn copy_stream_is_fifo() {
        let mut p = GpuPipeline::new();
        let e1 = p.schedule_transfer(0, 100, 1, TransferKind::Prefetch);
        let e2 = p.schedule_transfer(0, 50, 1, TransferKind::CacheUpdate);
        assert_eq!(e1, 100);
        assert_eq!(e2, 150);
        assert_eq!(p.bytes_prefetch, 1);
        assert_eq!(p.bytes_cache, 1);
    }

    #[test]
    fn demand_preempts_speculative_traffic() {
        let mut p = GpuPipeline::new();
        // an in-flight prefetch must NOT delay a demand fetch (priority
        // lanes), but speculative traffic queues behind demand.
        p.schedule_transfer(0, 1000, 1, TransferKind::Prefetch);
        let o = p.schedule_expert(100, 200, 1, 50);
        assert_eq!(o.copy_end, 300, "demand lane ignores speculative backlog");
        assert_eq!(o.compute_end, 350);
        let spec = p.schedule_transfer(0, 100, 1, TransferKind::CacheUpdate);
        assert_eq!(spec, 1100, "spec queues behind earlier spec");
        let spec2 = p.schedule_transfer(0, 100, 1, TransferKind::Prefetch);
        assert!(spec2 >= 1200);
    }

    #[test]
    fn barrier_advances_streams() {
        let mut p = GpuPipeline::new();
        p.barrier(500);
        let o = p.schedule_expert(0, 0, 0, 10);
        assert_eq!(o.compute_end, 510);
    }

    #[test]
    fn stall_only_counts_copy_wait() {
        let mut p = GpuPipeline::new();
        p.schedule_expert(0, 0, 0, 100); // busy till 100
        p.schedule_expert(0, 0, 0, 100); // queued behind, no stall
        assert_eq!(p.stall, 0);
        p.schedule_expert(0, 300, 1, 10); // copy till 300, compute waits 100
        assert_eq!(p.stall, 100);
    }
}
