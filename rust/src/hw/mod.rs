//! Heterogeneous-platform simulator: the stand-in for the paper's
//! RTX 3090 + EPYC 7532 + PCIe 4.0 testbed (DESIGN.md §1).
//!
//! Design: **virtual time, real numerics**. The inference engine computes
//! every activation for real via PJRT, but all reported latencies come from
//! the analytic cost models here, evaluated on the *paper-scale* model
//! dimensions (`config::PaperDims`). Policy code (assignment solve, cache
//! update) additionally has its *measured wall-clock* charged into virtual
//! time 1:1, because on the paper's testbed that code would run on the same
//! CPU it runs on here — that is how the paper's "greedy = 4.5 % vs optimal
//! = 55 % overhead" comparison is reproduced honestly.

pub mod calibrate;
pub mod cost;
pub mod gpu_mem;
pub mod pipeline;

pub use calibrate::LinFit;
pub use cost::{ns, CostModel, Ns};
pub use gpu_mem::GpuMemModel;
pub use pipeline::{GpuPipeline, PipelineOutcome, TransferKind};
