//! Analytic cost model for CPU expert execution, GPU expert execution,
//! and PCIe transfers — the `t_cpu(w)`, `t_gpu(w)`, `trans_time` of the
//! paper's §4.1 (Eqs. 4–6), plus attention/gate/head costs so end-to-end
//! tokens/s are complete.
//!
//! The paper obtains these via warm-up profiling on its testbed; we obtain
//! them from a roofline model parameterised by the paper's Table 1 hardware
//! numbers and Table 3 model dimensions (or, alternatively, by actually
//! warm-up-profiling the PJRT kernels — see [`super::calibrate`]).

use anyhow::Result;

use crate::config::{HwConfig, ModelPreset, PaperDims, Presets};

/// Virtual nanoseconds.
pub type Ns = u64;

/// Convert seconds (f64) to virtual nanoseconds.
pub fn ns(secs: f64) -> Ns {
    (secs * 1e9).round().max(0.0) as Ns
}

/// Roofline cost model for one (model, hardware) pair.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub hw: HwConfig,
    pub paper: PaperDims,
    /// Scaled k (experts activated per token) — same as paper dims.
    pub top_k: usize,
    /// On-disk bytes per fp16 byte for NVMe-resident experts (the tiered
    /// store's quantized on-disk format; 1.0 = fp16 on disk, the format
    /// host RAM and the GPU execute from). Set from the scenario's
    /// `quant_ratio` preset field via [`Self::with_quant_ratio`].
    pub disk_quant_ratio: f64,
}

impl CostModel {
    pub fn new(model: &ModelPreset, hw: &HwConfig) -> Self {
        CostModel {
            hw: hw.clone(),
            paper: model.paper.clone(),
            top_k: model.paper.top_k,
            disk_quant_ratio: 1.0,
        }
    }

    /// The one-stop constructor for scenario consumers: resolve a
    /// scenario (or plain model preset) name and apply its on-disk
    /// quantization ratio. Prefer this over hand-pairing
    /// `CostModel::new` with [`Presets::quant_ratio`] — a `-q4` scenario
    /// built through here can never silently run with fp16-on-disk costs.
    pub fn for_scenario(presets: &Presets, name: &str) -> Result<Self> {
        let (model, hw) = presets.scenario(name)?;
        Ok(Self::new(model, hw).with_quant_ratio(presets.quant_ratio(name)))
    }

    /// Apply a scenario's on-disk quantization ratio (see
    /// [`crate::config::Scenario::quant_ratio`]). Experts on NVMe then
    /// occupy `ratio × fp16` bytes: reads/writes move fewer bytes, but a
    /// promoted expert must pass the CPU [`Self::transcode_time`] stage
    /// before host RAM holds usable fp16 weights.
    pub fn with_quant_ratio(mut self, ratio: f64) -> Self {
        // hard assert, not debug: a silently-clamped ratio would distort
        // every NVMe timing downstream (config parsing validates its own
        // inputs with a proper error; reaching here out of range is a
        // caller bug, and this is a cold construction path)
        assert!(ratio > 0.0 && ratio <= 1.0, "quant ratio must be in (0, 1], got {ratio}");
        self.disk_quant_ratio = ratio;
        self
    }

    /// Bytes of one expert's parameters.
    pub fn expert_bytes(&self) -> f64 {
        self.paper.expert_bytes()
    }

    /// Bytes of one expert as stored on NVMe (quantized when the scenario
    /// keeps offloaded experts in a compressed on-disk format).
    pub fn disk_expert_bytes(&self) -> f64 {
        self.expert_bytes() * self.disk_quant_ratio
    }

    /// CPU execution time for one expert with workload `w` tokens (Eq. 4's
    /// `t_cpu(w)`): roofline over the 16-core CPU — compute-bound at large
    /// `w`, DRAM-bound (streaming the expert weights once) at small `w`.
    pub fn t_cpu(&self, w: usize) -> Ns {
        if w == 0 {
            return 0;
        }
        let compute = self.paper.expert_flops_per_token() * w as f64 / self.hw.cpu_flops;
        let memory = self.expert_bytes() / self.hw.cpu_mem_bw;
        ns(compute.max(memory) + self.hw.cpu_dispatch_s)
    }

    /// GPU compute time for one expert with workload `w` (the
    /// `compute_expert(w_i)` term of Eq. 5).
    pub fn t_gpu_compute(&self, w: usize) -> Ns {
        if w == 0 {
            return 0;
        }
        let compute = self.paper.expert_flops_per_token() * w as f64 / self.hw.gpu_flops;
        let memory = self.expert_bytes() / self.hw.gpu_mem_bw;
        ns(compute.max(memory) + self.hw.gpu_kernel_launch_s)
    }

    /// PCIe transfer time for one expert's weights (Eq. 6's `trans_time`).
    pub fn trans_time(&self) -> Ns {
        ns(self.expert_bytes() / self.hw.pcie_bw + self.hw.pcie_latency_s)
    }

    /// Inter-GPU P2P/NVLink copy time for one expert's fp16 weights
    /// (device `a` → device `b` on the shared fabric lane). Only multi-GPU
    /// runs ever charge this: at `num_gpus = 1` no P2P copy is issued.
    pub fn p2p_time(&self) -> Ns {
        ns(self.expert_bytes() / self.hw.p2p_bw + self.hw.p2p_latency_s)
    }

    /// NVMe read time for one expert (disk → host promotion in the tiered
    /// store), computed from the *on-disk* bytes — a quantized format
    /// makes the read proportionally cheaper. This is the third-tier
    /// analogue of [`Self::trans_time`].
    pub fn nvme_read_time(&self) -> Ns {
        ns(self.disk_expert_bytes() / self.hw.nvme_read_bw + self.hw.nvme_latency_s)
    }

    /// NVMe write time for one expert (host → disk spill, when the store
    /// runs with write-back enabled). Write-back persists the on-disk
    /// format, so it too moves the (possibly quantized) disk bytes.
    pub fn nvme_write_time(&self) -> Ns {
        ns(self.disk_expert_bytes() / self.hw.nvme_write_bw + self.hw.nvme_latency_s)
    }

    /// CPU transcode (dequantize) time for one expert promoted from a
    /// quantized on-disk format: memory-bound — stream the quantized
    /// bytes in and write the fp16 weights out through host DRAM — plus
    /// one CPU dispatch. Zero when the on-disk format is already fp16
    /// (ratio 1.0): the read lands directly usable.
    pub fn transcode_time(&self) -> Ns {
        if self.disk_quant_ratio >= 1.0 {
            return 0;
        }
        ns((self.disk_expert_bytes() + self.expert_bytes()) / self.hw.cpu_mem_bw
            + self.hw.cpu_dispatch_s)
    }

    /// End-to-end disk → usable-in-host-RAM latency estimate for one
    /// expert: NVMe read of the on-disk bytes chained into the CPU
    /// transcode stage. What assignment cost estimates and the store's
    /// host-wait snapshots charge for a disk-resident expert.
    pub fn nvme_fetch_time(&self) -> Ns {
        self.nvme_read_time() + self.transcode_time()
    }

    /// Total paper-scale bytes of all routed experts (all layers) — the
    /// quantity host RAM must hold in the paper's two-tier deployment.
    pub fn total_expert_bytes(&self) -> f64 {
        self.paper.total_expert_bytes()
    }

    /// GPU execution time for one expert (Eq. 5): transfer overlapped with
    /// compute via the copy/compute stream pipeline, so the cost is the max;
    /// zero transfer when the expert is already resident (cache hit or
    /// correct prefetch — §4.3 cooperation rule).
    pub fn t_gpu(&self, w: usize, resident: bool) -> Ns {
        if w == 0 {
            return 0;
        }
        if resident {
            self.t_gpu_compute(w)
        } else {
            self.t_gpu_compute(w).max(self.trans_time())
        }
    }

    /// Attention time for a batch step (`tokens` query tokens, average KV
    /// length `kv_len`). Attention weights are GPU-resident in all compared
    /// frameworks; decode attention is memory-bound (weights + KV read).
    pub fn attn_time(&self, tokens: usize, kv_len: usize) -> Ns {
        let d = self.paper.hidden as f64;
        let b = self.paper.dtype_bytes as f64;
        let flops = self.paper.attn_flops_per_token(kv_len) * tokens as f64;
        let bytes = 4.0 * d * d * b + (tokens * kv_len) as f64 * 2.0 * d * b;
        ns((flops / self.hw.gpu_flops).max(bytes / self.hw.gpu_mem_bw)
            + self.hw.gpu_kernel_launch_s)
    }

    /// Gate (router) time for a batch step of `tokens` tokens. Also the cost
    /// of one *extra* prediction gating pass for prefetching (§6.3-4).
    pub fn gate_time(&self, tokens: usize) -> Ns {
        let flops = self.paper.gate_flops_per_token() * tokens as f64;
        ns(flops / self.hw.gpu_flops + self.hw.gpu_kernel_launch_s)
    }

    /// Embedding + LM head for a batch step (lumped, minor).
    pub fn head_time(&self, tokens: usize) -> Ns {
        // vocab ~ 32k two-byte rows: memory-bound read of the head matrix.
        let d = self.paper.hidden as f64;
        let bytes = 32_000.0 * d * self.paper.dtype_bytes as f64;
        let flops = 2.0 * 32_000.0 * d * tokens as f64;
        ns((flops / self.hw.gpu_flops).max(bytes / self.hw.gpu_mem_bw)
            + self.hw.gpu_kernel_launch_s)
    }

    /// Per-layer non-MoE overhead for a decode step (norms, stream sync).
    pub fn layer_fixed(&self) -> Ns {
        ns(2.0 * self.hw.gpu_kernel_launch_s)
    }

    /// Fault-degraded view of this model: GPU compute slowed by `gpu_mult`
    /// (thermal throttle — core and memory clocks both drop) and PCIe
    /// transfers slowed by `pcie_mult` (link renegotiation). Identity
    /// multipliers change nothing. CPU and NVMe costs are untouched — the
    /// NVMe perturbations live in the store's read-fault ledger, and a
    /// throttled GPU is exactly when the CPU becomes the better device,
    /// which every assignment solver sees for free through a context built
    /// on this view. Allocates (the hw preset owns a display name), so the
    /// simulator builds its views once per fault plan, never per step.
    pub fn degraded(&self, gpu_mult: f64, pcie_mult: f64) -> CostModel {
        let mut d = self.clone();
        if gpu_mult > 1.0 {
            d.hw.gpu_flops /= gpu_mult;
            d.hw.gpu_mem_bw /= gpu_mult;
        }
        if pcie_mult > 1.0 {
            d.hw.pcie_bw /= pcie_mult;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Presets;

    fn cm(model: &str) -> CostModel {
        let p = Presets::load_default().unwrap();
        CostModel::new(p.model(model).unwrap(), p.hw("local-pc").unwrap())
    }

    #[test]
    fn zero_workload_is_free() {
        let c = cm("mixtral-sim");
        assert_eq!(c.t_cpu(0), 0);
        assert_eq!(c.t_gpu(0, false), 0);
        assert_eq!(c.t_gpu(0, true), 0);
    }

    #[test]
    fn mixtral_transfer_dominates_small_workloads() {
        // Paper §3.2: PCIe transfer is the bottleneck for uncached GPU
        // experts — a Mixtral expert (352 MB) at ~25 GB/s is ~14 ms, far
        // above its GPU compute time at w=1.
        let c = cm("mixtral-sim");
        let tr = c.trans_time();
        assert!(tr > 10_000_000 && tr < 20_000_000, "trans = {tr}ns");
        assert!(c.t_gpu_compute(1) < tr / 10);
        assert_eq!(c.t_gpu(1, false), tr);
        assert!(c.t_gpu(1, true) < tr / 10);
    }

    #[test]
    fn cpu_beats_uncached_gpu_at_small_w_and_loses_at_large_w() {
        // The crossover that motivates dynamic assignment (paper Fig. 4).
        let c = cm("mixtral-sim");
        assert!(c.t_cpu(1) < c.t_gpu(1, false));
        assert!(c.t_cpu(64) > c.t_gpu(64, false));
    }

    #[test]
    fn t_cpu_monotone_nondecreasing() {
        let c = cm("deepseek-sim");
        let mut prev = 0;
        for w in 0..200 {
            let t = c.t_cpu(w);
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn cached_gpu_never_slower_than_uncached() {
        for m in ["mixtral-sim", "deepseek-sim", "qwen-sim"] {
            let c = cm(m);
            for w in [1, 4, 16, 64, 256] {
                assert!(c.t_gpu(w, true) <= c.t_gpu(w, false));
            }
        }
    }

    #[test]
    fn attn_scales_with_kv_len() {
        let c = cm("mixtral-sim");
        assert!(c.attn_time(16, 1024) > c.attn_time(16, 64));
    }

    #[test]
    fn nvme_tier_is_slower_than_pcie() {
        for m in ["mixtral-sim", "deepseek-sim", "qwen-sim"] {
            let c = cm(m);
            assert!(c.nvme_read_time() > c.trans_time(), "{m}: NVMe read must cost more");
            assert!(c.nvme_write_time() >= c.nvme_read_time(), "{m}: writes are slower");
        }
    }

    #[test]
    fn p2p_fabric_beats_host_pcie() {
        // The economics of the multi-GPU exec path: pulling a cached
        // expert from a peer device over NVLink-class fabric must cost
        // less than re-staging it from host RAM over PCIe, or the P2P
        // branch in simrun would never win.
        for m in ["mixtral-sim", "deepseek-sim", "qwen-sim"] {
            let c = cm(m);
            assert!(c.p2p_time() > 0, "{m}: a P2P copy is never free");
            assert!(c.p2p_time() < c.trans_time(), "{m}: P2P must beat PCIe");
        }
        // quantization doesn't touch P2P: both ends hold fp16
        let q4 = cm("mixtral-sim").with_quant_ratio(0.28);
        assert_eq!(q4.p2p_time(), cm("mixtral-sim").p2p_time());
    }

    #[test]
    fn quantized_disk_tier_is_asymmetric() {
        // A q4 on-disk format trades the big fp16 NVMe read for a small
        // quantized read plus a CPU transcode stage — and wins.
        let fp16 = cm("mixtral-sim");
        let q4 = cm("mixtral-sim").with_quant_ratio(0.28);
        assert_eq!(fp16.disk_quant_ratio, 1.0, "fp16 on disk is the default");
        assert_eq!(fp16.transcode_time(), 0, "fp16 on disk needs no transcode");
        assert_eq!(fp16.nvme_fetch_time(), fp16.nvme_read_time());
        assert_eq!(fp16.disk_expert_bytes(), fp16.expert_bytes());
        // on-disk bytes and read/write times shrink with the ratio
        assert!(q4.disk_expert_bytes() < 0.3 * fp16.disk_expert_bytes());
        assert!(q4.nvme_read_time() < fp16.nvme_read_time() / 3);
        assert!(q4.nvme_write_time() < fp16.nvme_write_time() / 3);
        // the transcode stage is real and separately priced
        assert!(q4.transcode_time() > 0);
        assert_eq!(q4.nvme_fetch_time(), q4.nvme_read_time() + q4.transcode_time());
        // the asymmetry pays: small read + CPU transcode beats the big read
        assert!(q4.nvme_fetch_time() < fp16.nvme_fetch_time());
        // host RAM and PCIe still see fp16 (the transcode's output format)
        assert_eq!(q4.expert_bytes(), fp16.expert_bytes());
        assert_eq!(q4.trans_time(), fp16.trans_time());
    }

    #[test]
    fn for_scenario_applies_the_preset_quant_ratio() {
        let p = Presets::load_default().unwrap();
        let q4 = CostModel::for_scenario(&p, "mixtral-sim-ram16-q4").unwrap();
        let fp16 = CostModel::for_scenario(&p, "mixtral-sim-ram16").unwrap();
        assert!(q4.disk_quant_ratio < 1.0, "q4 scenario must carry its ratio");
        assert_eq!(fp16.disk_quant_ratio, 1.0);
        assert!(q4.nvme_read_time() < fp16.nvme_read_time());
        // plain model presets resolve too (default hardware, fp16 disk)
        let plain = CostModel::for_scenario(&p, "mixtral-sim").unwrap();
        assert_eq!(plain.disk_quant_ratio, 1.0);
        assert!(CostModel::for_scenario(&p, "no-such-scenario").is_err());
    }

    #[test]
    fn quant_ratio_applies_across_models() {
        for m in ["mixtral-sim", "deepseek-sim", "qwen-sim"] {
            let fp16 = cm(m);
            let q4 = cm(m).with_quant_ratio(0.28);
            assert!(q4.nvme_read_time() < fp16.nvme_read_time(), "{m}");
            assert!(q4.transcode_time() > 0, "{m}");
            assert!(q4.nvme_fetch_time() < fp16.nvme_fetch_time(), "{m}");
        }
    }

    #[test]
    fn total_expert_bytes_exceeds_small_ram_budgets() {
        // The motivation for the third tier: Mixtral's 256 experts at
        // ~352 MB each (~90 GB) cannot fit a 16 GB host-RAM budget.
        let c = cm("mixtral-sim");
        assert!(c.total_expert_bytes() > 80e9);
        assert!(c.total_expert_bytes() > 16e9);
    }

    #[test]
    fn degraded_view_slows_gpu_and_pcie_only() {
        let c = cm("mixtral-sim");
        let d = c.degraded(2.0, 1.5);
        assert!(d.t_gpu_compute(64) > c.t_gpu_compute(64));
        assert!(d.attn_time(16, 256) > c.attn_time(16, 256));
        assert!(d.trans_time() > c.trans_time());
        assert_eq!(d.t_cpu(64), c.t_cpu(64), "the CPU is unaffected");
        assert_eq!(d.nvme_read_time(), c.nvme_read_time(), "NVMe faults live in the store ledger");
        assert_eq!(d.transcode_time(), c.transcode_time());
        // identity multipliers reproduce the clean view exactly
        let same = c.degraded(1.0, 1.0);
        assert_eq!(same.t_gpu_compute(64), c.t_gpu_compute(64));
        assert_eq!(same.trans_time(), c.trans_time());
        assert_eq!(same.attn_time(16, 256), c.attn_time(16, 256));
        // a throttled GPU shifts the CPU/GPU crossover toward the CPU
        let heavy = c.degraded(8.0, 1.0);
        assert!(heavy.t_gpu(64, true) > c.t_gpu(64, true));
        assert_eq!(heavy.t_cpu(64), c.t_cpu(64));
    }

    #[test]
    fn deepseek_expert_cheaper_than_mixtral() {
        // DeepSeek-V2-Lite experts (17 MB) vs Mixtral (352 MB).
        assert!(cm("deepseek-sim").expert_bytes() * 10.0 < cm("mixtral-sim").expert_bytes());
        assert!(cm("deepseek-sim").trans_time() < cm("mixtral-sim").trans_time() / 10);
    }
}
