//! Warm-up profiling: fit `t(w) = a + b·w` from measured (workload, time)
//! points, the way the paper builds its `t_cpu` / `t_gpu` tables before
//! inference ("All hardware-specific timing values can be obtained through
//! warm-up profiling before execution", §4.1).
//!
//! Used by `InferenceEngine::calibrate_local` (and the `calibrate` CLI
//! subcommand) to derive a machine-local [`super::CostModel`] from real PJRT
//! kernel timings — demonstrating the full warm-up-profiling path even
//! though the paper-preset analytic model drives the headline experiments.

/// Least-squares linear fit `y = a + b·x`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinFit {
    pub a: f64,
    pub b: f64,
}

impl LinFit {
    /// Fit from points; requires ≥ 2 distinct x values (else b = 0).
    pub fn fit(points: &[(f64, f64)]) -> LinFit {
        let n = points.len() as f64;
        if points.is_empty() {
            return LinFit { a: 0.0, b: 0.0 };
        }
        let sx: f64 = points.iter().map(|p| p.0).sum();
        let sy: f64 = points.iter().map(|p| p.1).sum();
        let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
        let denom = n * sxx - sx * sx;
        if denom.abs() < 1e-12 {
            return LinFit { a: sy / n, b: 0.0 };
        }
        let b = (n * sxy - sx * sy) / denom;
        let a = (sy - b * sx) / n;
        LinFit { a, b }
    }

    pub fn eval(&self, x: f64) -> f64 {
        self.a + self.b * x
    }

    /// Coefficient of determination on the fitting data.
    pub fn r2(&self, points: &[(f64, f64)]) -> f64 {
        let n = points.len() as f64;
        if points.is_empty() {
            return 1.0;
        }
        let mean = points.iter().map(|p| p.1).sum::<f64>() / n;
        let ss_tot: f64 = points.iter().map(|p| (p.1 - mean).powi(2)).sum();
        let ss_res: f64 = points.iter().map(|p| (p.1 - self.eval(p.0)).powi(2)).sum();
        if ss_tot < 1e-12 {
            1.0
        } else {
            1.0 - ss_res / ss_tot
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 3.0 + 2.0 * i as f64)).collect();
        let f = LinFit::fit(&pts);
        assert!((f.a - 3.0).abs() < 1e-9);
        assert!((f.b - 2.0).abs() < 1e-9);
        assert!(f.r2(&pts) > 0.999999);
    }

    #[test]
    fn noisy_line_close() {
        let pts: Vec<(f64, f64)> = (0..50)
            .map(|i| {
                let x = i as f64;
                (x, 10.0 + 0.5 * x + if i % 2 == 0 { 0.3 } else { -0.3 })
            })
            .collect();
        let f = LinFit::fit(&pts);
        assert!((f.b - 0.5).abs() < 0.01);
        assert!((f.a - 10.0).abs() < 0.5);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(LinFit::fit(&[]), LinFit { a: 0.0, b: 0.0 });
        let f = LinFit::fit(&[(2.0, 5.0), (2.0, 7.0)]);
        assert_eq!(f.b, 0.0);
        assert!((f.a - 6.0).abs() < 1e-9);
    }
}
