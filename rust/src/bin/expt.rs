//! `expt` — regenerate the paper's tables and figures.
//!
//!     cargo run --release --bin expt -- list
//!     cargo run --release --bin expt -- fig12 [table2 ...]
//!     cargo run --release --bin expt -- all --jobs 8
//!
//! Each experiment prints a markdown section and writes it to
//! `results/<id>.md`. Trace pools are generated on demand (cached under
//! `artifacts/traces/`); run `dali prepare` first to prebuild them.
//!
//! `--jobs N` runs sweep cells on N scoped worker threads (`--jobs 0` /
//! default = one per core). Replays are deterministic, so the parallelism
//! never changes a reported number — only the wall time.
//!
//! A failing experiment (error or panic) no longer aborts the sweep: the
//! remaining experiments still run and write their results, then the
//! driver reports every failure by id and exits non-zero.

use anyhow::{bail, Result};

use dali::expt::{registry, run_one, ExptCtx};
use dali::util::{pool, results_dir, Args};

fn main() -> Result<()> {
    let args = Args::from_env();
    let which: Vec<String> = args.positional.clone();
    if which.is_empty() || which[0] == "list" {
        println!("available experiments:");
        for (id, desc, _) in registry() {
            println!("  {id:-8} {desc}");
        }
        println!("  all      run everything");
        println!("flags: --jobs N   parallel sweep workers (0 = one per core, default)");
        return Ok(());
    }
    let jobs = pool::resolve_jobs(args.usize_or("jobs", 0));
    eprintln!("[expt] sweeps run with {jobs} parallel jobs (--jobs N to override)");
    let ctx = ExptCtx::new()?.with_jobs(jobs);
    let ids: Vec<&str> = if which[0] == "all" {
        registry().iter().map(|(id, _, _)| *id).collect()
    } else {
        which.iter().map(|s| s.as_str()).collect()
    };
    let t0 = std::time::Instant::now();
    let mut failed: Vec<(String, String)> = Vec::new();
    for id in ids {
        let started = std::time::Instant::now();
        eprintln!("[expt] running {id}...");
        // Catch panics (a bad sweep cell, an assertion in a replay) as well
        // as plain errors, so one broken experiment never discards the
        // results of the ones that already completed.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_one(&ctx, id)));
        let text = match outcome {
            Ok(Ok(text)) => text,
            Ok(Err(e)) => {
                eprintln!("[expt] {id} FAILED: {e:#}");
                failed.push((id.to_string(), format!("{e:#}")));
                continue;
            }
            Err(payload) => {
                let msg = payload
                    .downcast::<String>()
                    .map(|s| *s)
                    .or_else(|p| p.downcast::<&'static str>().map(|s| (*s).to_string()))
                    .unwrap_or_else(|_| "non-string panic payload".to_string());
                eprintln!("[expt] {id} PANICKED: {msg}");
                failed.push((id.to_string(), msg));
                continue;
            }
        };
        println!("{text}");
        let path = results_dir().join(format!("{id}.md"));
        std::fs::write(&path, &text)?;
        eprintln!("[expt] {id} done in {:.1}s → {}", started.elapsed().as_secs_f64(), path.display());
    }
    eprintln!("[expt] total {:.1}s", t0.elapsed().as_secs_f64());
    if !failed.is_empty() {
        eprintln!("[expt] {} experiment(s) failed:", failed.len());
        for (id, msg) in &failed {
            eprintln!("[expt]   {id}: {}", msg.lines().next().unwrap_or(""));
        }
        bail!(
            "{} of the requested experiments failed: {}",
            failed.len(),
            failed.iter().map(|(id, _)| id.as_str()).collect::<Vec<_>>().join(", ")
        );
    }
    Ok(())
}
