//! `expt` — regenerate the paper's tables and figures.
//!
//!     cargo run --release --bin expt -- list
//!     cargo run --release --bin expt -- fig12 [table2 ...]
//!     cargo run --release --bin expt -- all --jobs 8
//!
//! Each experiment prints a markdown section and writes it to
//! `results/<id>.md`. Trace pools are generated on demand (cached under
//! `artifacts/traces/`); run `dali prepare` first to prebuild them.
//!
//! `--jobs N` runs sweep cells on N scoped worker threads (`--jobs 0` /
//! default = one per core). Replays are deterministic, so the parallelism
//! never changes a reported number — only the wall time.

use anyhow::Result;

use dali::expt::{registry, run_one, ExptCtx};
use dali::util::{pool, results_dir, Args};

fn main() -> Result<()> {
    let args = Args::from_env();
    let which: Vec<String> = args.positional.clone();
    if which.is_empty() || which[0] == "list" {
        println!("available experiments:");
        for (id, desc, _) in registry() {
            println!("  {id:-8} {desc}");
        }
        println!("  all      run everything");
        println!("flags: --jobs N   parallel sweep workers (0 = one per core, default)");
        return Ok(());
    }
    let jobs = pool::resolve_jobs(args.usize_or("jobs", 0));
    eprintln!("[expt] sweeps run with {jobs} parallel jobs (--jobs N to override)");
    let ctx = ExptCtx::new()?.with_jobs(jobs);
    let ids: Vec<&str> = if which[0] == "all" {
        registry().iter().map(|(id, _, _)| *id).collect()
    } else {
        which.iter().map(|s| s.as_str()).collect()
    };
    let t0 = std::time::Instant::now();
    for id in ids {
        let started = std::time::Instant::now();
        eprintln!("[expt] running {id}...");
        let text = run_one(&ctx, id)?;
        println!("{text}");
        let path = results_dir().join(format!("{id}.md"));
        std::fs::write(&path, &text)?;
        eprintln!("[expt] {id} done in {:.1}s → {}", started.elapsed().as_secs_f64(), path.display());
    }
    eprintln!("[expt] total {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
