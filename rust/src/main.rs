//! `dali` — leader entrypoint + CLI.
//!
//! Subcommands:
//!   info                          show presets + artifact status
//!   calibrate --preset P          compute residual vectors + activation stats
//!   prepare [--preset P]          calibrate + generate all standard trace pools
//!   run --preset P [--framework dali] [--batch 8] [--steps 32]
//!       [--gpus N] [--solve-cost modeled|measured] [--placement auto|on|off]
//!       [--trace out.jsonl] [--trace-digest] [--synthetic]
//!       [--faults profile|spec] [--fault-seed N]
//!                                 replay a decode benchmark and print metrics;
//!                                 every run also prints a whole-run trace
//!                                 digest (`trace_digest=0x…`). `--trace`
//!                                 streams typed scheduling events to a JSONL
//!                                 file, `--trace-digest` prints only the
//!                                 audit line, `--synthetic` replays a
//!                                 generated locality workload (no artifacts
//!                                 needed — what CI uses), `--faults` installs
//!                                 a deterministic fault plan (named profile
//!                                 from presets.json / built-ins, or an inline
//!                                 `key=value,...` spec — see README), and
//!                                 `--gpus N` overrides the hardware preset's
//!                                 device count (expert-parallel sharding
//!                                 across N GPU tiers joined by a P2P fabric)
//!   trace summarize FILE [--top 10]
//!                                 aggregate a `--trace` capture: per-lane
//!                                 utilization, prefetch/promote-ahead
//!                                 accounting, top-N wasted prefetches
//!   bench [--steps 256] [--batch 8] [--out BENCH_simrun.json] [--strict]
//!                                 simulator hot-path throughput + allocation
//!                                 audit (incl. the memory-limited
//!                                 store-attached scenario) + per-scenario
//!                                 replay digest (--strict fails on drift);
//!                                 writes machine-readable JSON
//!   serve --preset P [--port 8743] [--framework dali]
//!                                 start the HTTP serving front-end
//!   serve --sim [--scenario mixtral-sim-ram16] [--framework dali]
//!         [--arrival steady-poisson|bursty|diurnal|spec] [--load R]
//!         [--requests 32] [--max-batch 8] [--max-tokens 16] [--seed N]
//!         [--slo unlimited|tight|lenient|observe|spec]
//!         [--faults profile|spec] [--fault-seed N] [--trace-digest]
//!                                 multi-tenant continuous-batching serving
//!                                 simulation in virtual time: seeded arrivals
//!                                 share one pipeline (GPU cache, tiered
//!                                 store, NVMe/PCIe/transcode lanes); `--slo`
//!                                 arms deadline admission control, load
//!                                 shedding, and the adaptive degradation
//!                                 ladder; prints per-request TTFT/TPOT/queue
//!                                 p50/p99 plus SLO attainment/goodput and the
//!                                 same greppable `trace_digest=0x…` audit
//!                                 line as `run` (`--trace-digest` prints only
//!                                 that line — what CI's serve determinism
//!                                 and overload checks compare)
//!
//! Experiments (paper tables/figures) live in the separate `expt` binary.

use anyhow::{bail, Result};

use dali::config::Presets;
use dali::coordinator::assignment::SolveCost;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::{replay_decode_gpus, Phase, StepSimulator};
use dali::fault::FaultPlan;
use dali::hw::CostModel;
use dali::serve::{simulate_serve, ServeSim, ServeSimCfg};
use dali::store::{PlacementCfg, TieredStore, MAX_DEVICES};
use dali::trace::{DigestSink, JsonSink, TraceSummary};
use dali::util::alloc_counter::{alloc_calls, dealloc_calls, CountingAlloc};
use dali::util::{fmt_ns, repo_root, Args};
use dali::workload::prep;
use dali::workload::trace::{synthetic_locality_trace, BatchStep};

// `dali bench` reads the counters to prove the simulator's `run_step`
// performs no steady-state heap allocation (see util::alloc_counter for
// the overhead rationale).
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn parse_framework(name: &str) -> Result<Framework> {
    Ok(match name {
        "naive" => Framework::Naive,
        "llama.cpp" | "llamacpp" => Framework::LlamaCpp,
        "ktransformers" | "kt" => Framework::KTransformers,
        "fiddler" => Framework::Fiddler,
        "moe-lightning" | "lightning" => Framework::MoELightning,
        "hybrimoe" => Framework::HybriMoE,
        "dali" => Framework::Dali,
        "dali-opt" => Framework::DaliOpt,
        "dali-beam" => Framework::DaliBeam,
        other => bail!("unknown framework '{other}'"),
    })
}

fn cmd_info() -> Result<()> {
    let p = Presets::load_default()?;
    println!("model presets:");
    for (name, m) in &p.models {
        let have = dali::moe::Manifest::load_preset(name).is_ok();
        println!(
            "  {name:-14} {} — sim {}L/{}E/top{}, paper expert {:.0} MB, artifacts: {}",
            m.display,
            m.sim.layers,
            m.sim.n_routed,
            m.sim.top_k,
            m.paper.expert_bytes() / 1e6,
            if have { "ok" } else { "MISSING (make artifacts)" }
        );
    }
    println!("hardware presets:");
    for (name, h) in &p.hardware {
        println!("  {name:-14} {}", h.display);
    }
    println!("scenarios (memory-limited tiered-store presets):");
    for (name, sc) in &p.scenarios {
        println!("  {name:-20} {} on {}", sc.model, sc.hardware);
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "mixtral-sim");
    let c = prep::ensure_calib(&preset)?;
    println!("calibrated {preset}: {} tokens, {} residual vectors", c.tokens, c.res_vec.len());
    Ok(())
}

fn cmd_prepare(args: &Args) -> Result<()> {
    let presets: Vec<String> = match args.get("preset") {
        Some(p) => vec![p.to_string()],
        None => Presets::load_default()?.model_names().iter().map(|s| s.to_string()).collect(),
    };
    prep::prepare_all(&presets)?;
    println!("prepared: {presets:?}");
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "mixtral-sim");
    let fw = parse_framework(&args.str_or("framework", "dali"))?;
    let batch = args.usize_or("batch", 8);
    let steps = args.usize_or("steps", 32);
    let presets = Presets::load_default()?;
    // `--preset` accepts a model name or a scenario (e.g. mixtral-sim-ram16,
    // which pairs the model with a memory-limited hardware preset).
    let (model_name, hw_name) = match presets.scenarios.get(&preset) {
        Some(sc) => (sc.model.clone(), args.str_or("hw", &sc.hardware)),
        None => (preset.clone(), args.str_or("hw", "local-pc")),
    };
    let model = presets.model(&model_name)?;
    let hw = presets.hw(&hw_name)?;
    // Scenarios may keep offloaded experts quantized on NVMe (`*-q4`):
    // smaller reads, plus a CPU transcode stage per promotion. The hand
    // pairing (instead of `CostModel::for_scenario`) exists only because
    // `--hw` may override the scenario's hardware; `quant` always follows
    // the scenario itself.
    let quant = presets.quant_ratio(&preset);
    let cost = CostModel::new(model, hw).with_quant_ratio(quant);
    // Device count: the hardware preset's `num_gpus` is the source of
    // truth; `--gpus N` overrides it (e.g. to replay a 2-GPU scenario on
    // one device for an ablation). Same validation as HwConfig::validate.
    let n_gpus = match args.get("gpus") {
        Some(v) => {
            let n: usize = v.parse().map_err(|_| anyhow::anyhow!("bad --gpus '{v}'"))?;
            if !(1..=MAX_DEVICES).contains(&n) {
                bail!("--gpus must be in 1..={MAX_DEVICES}, got {n}");
            }
            n
        }
        None => hw.num_gpus,
    };
    // `--synthetic` replays a generated locality workload with a cold
    // frequency prior instead of the calibrated trace pools — no artifacts
    // required, so a clean checkout (read: CI) can exercise the full
    // store + trace path. Same generator and seed as `dali bench`.
    let (trace, freq) = if args.bool("synthetic") {
        let dims = &model.sim;
        let t = synthetic_locality_trace(
            dims.layers,
            dims.n_routed,
            dims.top_k,
            16,
            steps.max(32),
            0xbe7c,
        );
        (t, vec![vec![0.0; dims.n_routed]; dims.layers])
    } else {
        let calib = prep::ensure_calib(&model_name)?;
        (prep::ensure_trace(&model_name, "c4-sim", 32, 16, 64)?, calib.freq)
    };
    let cfg = FrameworkCfg::paper_default(&model.sim);
    let mut bundle = fw.bundle(&model.sim, &cost, &freq, &cfg);
    // `--solve-cost measured` restores the seed's wall-clock charging
    // (nondeterministic; for calibrating the modeled constants).
    bundle.solve_cost = match args.str_or("solve-cost", "modeled").as_str() {
        "measured" => SolveCost::Measured,
        "modeled" => SolveCost::Modeled,
        other => bail!("unknown --solve-cost '{other}' (modeled|measured)"),
    };
    // `--placement on|off` overrides the framework's default placement
    // policy (predictive for the DALI bundles, reactive for baselines).
    match args.str_or("placement", "auto").as_str() {
        "on" => bundle.placement = PlacementCfg::predictive(cfg.prefetch_size),
        "off" => bundle.placement = PlacementCfg::default(),
        "auto" => {}
        other => bail!("unknown --placement '{other}' (auto|on|off)"),
    }
    let seq_ids: Vec<usize> = (0..batch).collect();
    let store = TieredStore::for_model(hw, &cost, model.sim.layers, model.sim.n_routed);
    let tiered = !store.is_unlimited();
    // `--faults profile|spec` installs a deterministic fault plan: a named
    // profile from presets.json's `fault_profiles` (falling back to the
    // built-ins), or an inline `key=value,...` spec. Same `(--fault-seed,
    // profile)` ⇒ same trace digest; `--faults clean` is bit-identical to
    // running without the flag.
    let faults = match args.get("faults") {
        Some(spec) => {
            let profile = presets.fault_profile(spec)?;
            Some(FaultPlan::new(profile, args.u64_or("fault-seed", 0xfa17)))
        }
        None => None,
    };
    // Every run goes through a digest sink (allocation-free; the whole-run
    // audit line below is what CI's digest-stability check compares).
    // `--trace PATH` tees the same event stream into a JSONL file.
    let m = match args.get("trace") {
        Some(path) => {
            let file = std::fs::File::create(path)?;
            let (m, (_digest, json)) = replay_decode_gpus(
                &trace,
                &seq_ids,
                steps,
                &cost,
                bundle,
                &freq,
                model.sim.n_shared,
                7,
                n_gpus,
                faults,
                Some(store),
                (DigestSink::new(), JsonSink::new(file)),
            );
            let events = json.events;
            json.finish()?;
            println!("trace: {events} events -> {path}");
            m
        }
        None => {
            replay_decode_gpus(
                &trace,
                &seq_ids,
                steps,
                &cost,
                bundle,
                &freq,
                model.sim.n_shared,
                7,
                n_gpus,
                faults,
                Some(store),
                DigestSink::new(),
            )
            .0
        }
    };
    if args.bool("trace-digest") {
        // audit-only mode: just the machine-greppable line below
        if let Some(d) = m.trace_digest {
            println!("trace_digest=0x{d:016x}");
        }
        return Ok(());
    }
    println!(
        "preset={preset} framework={} batch={batch} steps={steps} gpus={n_gpus}",
        fw.name()
    );
    println!("  decode speed      : {:.2} tokens/s (simulated)", m.tokens_per_s());
    println!("  virtual time      : {}", fmt_ns(m.total_ns));
    println!("  MoE time          : {}", fmt_ns(m.moe_ns));
    println!(
        "  PCIe busy         : {} ({:.1}% of total)",
        fmt_ns(m.pcie_busy_ns),
        100.0 * m.pcie_time_share()
    );
    println!(
        "  PCIe traffic      : {:.2} GB demand / {:.2} GB prefetch / {:.2} GB cache",
        m.pcie_demand_bytes as f64 / 1e9,
        m.pcie_prefetch_bytes as f64 / 1e9,
        m.pcie_cache_bytes as f64 / 1e9
    );
    println!("  cache hit rate    : {:.1}%", 100.0 * m.cache_hit_rate());
    println!("  prefetch accuracy : {:.1}%", 100.0 * m.prefetch_accuracy());
    println!("  sched overhead    : {:.2}%", 100.0 * m.sched_share());
    if n_gpus > 1 {
        for d in 0..n_gpus {
            println!(
                "  gpu[{d}]            : compute {} / copy {} / {} cache hits",
                fmt_ns(m.dev_compute_busy_ns[d]),
                fmt_ns(m.dev_copy_busy_ns[d]),
                m.dev_cache_hits[d]
            );
        }
        println!(
            "  P2P fabric        : {} copies ({} re-homes), {:.2} GB, busy {}",
            m.p2p_copies,
            m.p2p_migrations,
            m.p2p_bytes as f64 / 1e9,
            fmt_ns(m.p2p_busy_ns)
        );
    }
    if tiered {
        println!(
            "  tier hits         : {} gpu / {} host / {} disk (miss rate {:.1}%)",
            m.tier_gpu_hits,
            m.tier_host_hits,
            m.tier_disk_misses,
            100.0 * m.disk_miss_rate()
        );
        println!(
            "  NVMe              : {} read ({:.1}% of total), {:.2} GB in, {} promotions",
            fmt_ns(m.nvme_read_ns),
            100.0 * m.nvme_time_share(),
            m.nvme_read_bytes as f64 / 1e9,
            m.store_promotions
        );
        println!(
            "  placement         : {} ahead promotions ({:.1}% consumed), demand NVMe {}, \
             {} hidden behind compute",
            m.store_promote_ahead,
            100.0 * m.promote_ahead_hit_rate(),
            fmt_ns(m.nvme_demand_ns),
            fmt_ns(m.nvme_overlap_hidden_ns)
        );
        println!(
            "  on-disk format    : {} — transcode {}, {:.2} GB NVMe saved",
            if quant < 1.0 { format!("quantized ({quant:.2}x fp16)") } else { "fp16".into() },
            fmt_ns(m.transcode_ns),
            m.disk_bytes_saved as f64 / 1e9
        );
    }
    if faults.is_some() {
        println!(
            "  faults            : {} retries (stall {}), {} aborts, ram pressure \
             {} events / {} spills",
            m.fault_retries,
            fmt_ns(m.fault_stall_ns),
            m.fault_aborts,
            m.ram_pressure_events,
            m.ram_pressure_spills
        );
        println!(
            "  degraded windows  : gpu {} / pcie {}",
            fmt_ns(m.degraded_gpu_ns),
            fmt_ns(m.degraded_pcie_ns)
        );
    }
    if let Some(d) = m.trace_digest {
        println!("trace_digest=0x{d:016x}");
    }
    Ok(())
}

/// `dali trace summarize FILE [--top N]` — aggregate a `--trace` JSONL
/// capture offline: per-lane busy time/utilization, overlap-hidden time,
/// prefetch + promote-ahead accounting, and the top-N most-wasted
/// prefetch targets.
fn cmd_trace(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("summarize") => {
            let path = match args.positional.get(2) {
                Some(p) => p.clone(),
                None => args.require("in")?.to_string(),
            };
            let text = std::fs::read_to_string(&path)?;
            let summary = TraceSummary::from_json_lines(&text)?;
            print!("{}", summary.render(args.usize_or("top", 10)));
            Ok(())
        }
        other => bail!("unknown trace subcommand {other:?} (expected: summarize FILE [--top N])"),
    }
}

/// One preset's hot-path benchmark record.
struct BenchEntry {
    preset: String,
    steps_per_s: f64,
    layer_steps_per_s: f64,
    replays: u64,
    allocs_per_step: f64,
    deallocs_per_step: f64,
    sim_tokens_per_s: f64,
    /// Whole-run trace digest of the scenario's replay (every replay in the
    /// throughput loop runs the same (trace, bundle, seed), so they must all
    /// produce this digest).
    trace_digest: u64,
    /// True if any replay in the loop disagreed — nondeterminism in the
    /// scheduling path. `--strict` turns this into a failure.
    digest_drift: bool,
}

/// `dali bench` — simulator hot-path throughput + allocation audit.
///
/// Replays a synthetic locality workload (no PJRT / artifacts needed) with
/// the DALI policy bundle per scenario, measuring (a) wall-clock replay
/// steps/sec — the perf-trajectory metric — and (b) heap allocations per
/// steady-state decode step via the counting global allocator, which must
/// be zero after the scratch buffers warm up. The `mixtral-sim-ram16`
/// scenario attaches the memory-limited tiered store, so the predictive
/// placement path (promote-ahead, score demotion, NVMe arrival tracking)
/// is on both the perf trajectory and the `--strict` allocation gate;
/// `mixtral-sim-ram16-q4` repeats it with the quantized on-disk format,
/// putting the asymmetric read/transcode lanes under the same gate, and
/// the `+flaky-nvme` tier repeats *that* under a deterministic fault plan
/// so the retry/backoff ledger is held to the same zero-alloc,
/// digest-stable standard. Results go to stdout and to a machine-readable
/// `BENCH_simrun.json`.
fn cmd_bench(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 256).max(32);
    let batch = args.usize_or("batch", 8);
    let strict = args.bool("strict");
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => repo_root().join("BENCH_simrun.json"),
    };
    let presets = Presets::load_default()?;
    let mut entries: Vec<BenchEntry> = Vec::new();
    for (scenario, fault_name) in [
        ("deepseek-sim", None),
        ("qwen-sim", None),
        ("mixtral-sim", None),
        ("mixtral-sim-ram16", None),
        ("mixtral-sim-ram16-q4", None),
        ("mixtral-sim-ram16-q4", Some("flaky-nvme")),
        ("deepseek-v3-sim-2gpu", None),
    ] {
        let label = match fault_name {
            Some(f) => format!("{scenario}+{f}"),
            None => scenario.to_string(),
        };
        let faults = match fault_name {
            Some(f) => Some(FaultPlan::new(presets.fault_profile(f)?, 0xfa17)),
            None => None,
        };
        let (model, hw) = presets.scenario(scenario)?;
        let dims = &model.sim;
        // Multi-GPU scenarios (hw num_gpus > 1) run the expert-parallel
        // sharded pipeline — the P2P fabric and per-device lanes sit under
        // the same zero-alloc + digest gates as the single-device tiers.
        let n_gpus = hw.num_gpus;
        let cost = CostModel::for_scenario(&presets, scenario)?;
        let trace =
            synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, steps, 0xbe7c);
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        let ids: Vec<usize> = (0..batch).collect();
        let mk_store = || -> Option<TieredStore> {
            let st = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
            (!st.is_unlimited()).then_some(st)
        };

        // --- (b) steady-state allocation audit ------------------------------
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
        let mut sim =
            StepSimulator::new(&cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7)
                .with_gpus(n_gpus);
        if let Some(plan) = faults {
            sim = sim.with_faults(plan);
        }
        if let Some(st) = mk_store() {
            sim = sim.with_store(st);
        }
        let mut stepbuf = BatchStep::default();
        trace.compose_prefill_into(&ids, &mut stepbuf);
        sim.run_step(&stepbuf, 8, Phase::Prefill);
        sim.reset_metrics();
        let warmup = 16usize;
        for s in 0..warmup {
            trace.compose_decode_into(&ids, s, &mut stepbuf);
            sim.run_step(&stepbuf, 16 + s, Phase::Decode);
        }
        let a0 = alloc_calls();
        let d0 = dealloc_calls();
        let audit_steps = (trace.min_steps() - warmup) as f64;
        for s in warmup..trace.min_steps() {
            trace.compose_decode_into(&ids, s, &mut stepbuf);
            sim.run_step(&stepbuf, 16 + s, Phase::Decode);
        }
        let allocs_per_step = (alloc_calls() - a0) as f64 / audit_steps;
        let deallocs_per_step = (dealloc_calls() - d0) as f64 / audit_steps;
        let m = sim.finish();

        // --- (a) replay throughput (wall clock) -----------------------------
        let t0 = std::time::Instant::now();
        let budget = std::time::Duration::from_millis(600);
        let mut replays = 0u64;
        let mut decode_steps = 0u64;
        // Every replay runs under the digest sink, so the throughput number
        // includes the (allocation-free) audit cost and each scenario pins
        // one digest for the whole loop — drift means the scheduling path
        // went nondeterministic.
        let mut run_digest: Option<u64> = None;
        let mut digest_drift = false;
        while t0.elapsed() < budget {
            let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
            let (mm, _sink) = replay_decode_gpus(
                &trace,
                &ids,
                steps,
                &cost,
                bundle,
                &freq,
                dims.n_shared,
                7,
                n_gpus,
                faults,
                mk_store(),
                DigestSink::new(),
            );
            match (run_digest, mm.trace_digest) {
                (None, d) => run_digest = d,
                (Some(a), Some(b)) => digest_drift |= a != b,
                (Some(_), None) => digest_drift = true,
            }
            decode_steps += mm.layer_steps / dims.layers as u64;
            replays += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        let steps_per_s = decode_steps as f64 / wall;
        let entry = BenchEntry {
            preset: label.clone(),
            steps_per_s,
            layer_steps_per_s: steps_per_s * dims.layers as f64,
            replays,
            allocs_per_step,
            deallocs_per_step,
            sim_tokens_per_s: m.tokens_per_s(),
            trace_digest: run_digest.unwrap_or(0),
            digest_drift,
        };
        println!(
            "bench simrun/{label:<31} {:>10.0} steps/s  ({} replays, {} layers)  \
             allocs/step {:.2}  frees/step {:.2}  digest 0x{:016x}{}",
            entry.steps_per_s,
            entry.replays,
            dims.layers,
            allocs_per_step,
            deallocs_per_step,
            entry.trace_digest,
            if entry.digest_drift { "  DRIFT" } else { "" }
        );
        entries.push(entry);
    }

    // --- serve tier: the continuous-batching serving simulation under the
    // same zero-alloc + digest-stability gates. The audit instance is built
    // exactly like `simulate_serve` builds its cells, warmed until every
    // request has been admitted (prefill steps done), then measured over
    // the remaining pure-decode ticks; throughput replays the whole cell.
    {
        let scenario = "mixtral-sim-ram16";
        let label = format!("serve/{scenario}");
        let serve_cfg =
            ServeSimCfg { n_requests: 32, max_batch: 8, max_tokens: 16, ..Default::default() };
        let (model, hw) = presets.scenario(scenario)?;
        let dims = &model.sim;
        let cost = CostModel::for_scenario(&presets, scenario)?;
        let trace = synthetic_locality_trace(
            dims.layers,
            dims.n_routed,
            dims.top_k,
            16,
            serve_cfg.max_tokens.max(16),
            serve_cfg.seed ^ 0x7ace,
        );
        let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
        let cfg = FrameworkCfg::paper_default(dims);
        let bundle = Framework::Dali.bundle(dims, &cost, &freq, &cfg);
        let mut sim = StepSimulator::new(
            &cost, bundle, &freq, dims.layers, dims.n_routed, dims.n_shared, 7,
        )
        .with_sink(DigestSink::new());
        let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
        if !store.is_unlimited() {
            sim = sim.with_store(store);
        }
        let mut serve = ServeSim::new(sim, &trace, serve_cfg.clone())?;
        while serve.admitted() < serve_cfg.n_requests && serve.tick() {}
        let a0 = alloc_calls();
        let d0 = dealloc_calls();
        let mut audit_ticks = 0u64;
        while serve.tick() {
            audit_ticks += 1;
        }
        let allocs_per_step = (alloc_calls() - a0) as f64 / audit_ticks.max(1) as f64;
        let deallocs_per_step = (dealloc_calls() - d0) as f64 / audit_ticks.max(1) as f64;
        let audit_report = serve.finish();

        let t0 = std::time::Instant::now();
        let budget = std::time::Duration::from_millis(300);
        let mut replays = 0u64;
        let mut decode_steps = 0u64;
        let mut run_digest = audit_report.run.trace_digest;
        let mut digest_drift = false;
        while t0.elapsed() < budget {
            let r = simulate_serve(&presets, scenario, Framework::Dali, &serve_cfg, None)?;
            match (run_digest, r.run.trace_digest) {
                (None, d) => run_digest = d,
                (Some(a), Some(b)) => digest_drift |= a != b,
                (Some(_), None) => digest_drift = true,
            }
            decode_steps += r.run.layer_steps / dims.layers as u64;
            replays += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        let steps_per_s = decode_steps as f64 / wall;
        let entry = BenchEntry {
            preset: label.clone(),
            steps_per_s,
            layer_steps_per_s: steps_per_s * dims.layers as f64,
            replays,
            allocs_per_step,
            deallocs_per_step,
            sim_tokens_per_s: audit_report.tokens_per_s(),
            trace_digest: run_digest.unwrap_or(0),
            digest_drift,
        };
        println!(
            "bench simrun/{label:<31} {:>10.0} steps/s  ({} replays, {} layers)  \
             allocs/step {:.2}  frees/step {:.2}  digest 0x{:016x}{}",
            entry.steps_per_s,
            entry.replays,
            dims.layers,
            allocs_per_step,
            deallocs_per_step,
            entry.trace_digest,
            if entry.digest_drift { "  DRIFT" } else { "" }
        );
        entries.push(entry);
    }

    // machine-readable trajectory record (schema kept flat on purpose)
    let mut json = String::from("{\n  \"bench\": \"simrun_replay\",\n  \"schema\": 1,\n");
    json.push_str(&format!("  \"batch\": {batch},\n  \"decode_steps\": {steps},\n"));
    json.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"preset\": \"{}\", \"steps_per_s\": {:.1}, \"layer_steps_per_s\": {:.1}, \
             \"replays\": {}, \"hot_loop_allocs_per_step\": {:.3}, \
             \"hot_loop_frees_per_step\": {:.3}, \"sim_tokens_per_s\": {:.3}, \
             \"trace_digest\": \"0x{:016x}\", \"digest_drift\": {}}}{}\n",
            e.preset,
            e.steps_per_s,
            e.layer_steps_per_s,
            e.replays,
            e.allocs_per_step,
            e.deallocs_per_step,
            e.sim_tokens_per_s,
            e.trace_digest,
            e.digest_drift,
            if i + 1 == entries.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json)?;
    println!("wrote {}", out_path.display());
    let worst = entries.iter().map(|e| e.allocs_per_step).fold(0.0f64, f64::max);
    if worst > 0.0 {
        println!("WARNING: hot loop allocated {worst:.2} times/step (expected 0)");
        if strict {
            bail!("--strict: steady-state allocation detected in run_step");
        }
    }
    let drifted: Vec<&str> =
        entries.iter().filter(|e| e.digest_drift).map(|e| e.preset.as_str()).collect();
    if !drifted.is_empty() {
        println!("WARNING: replay digest drift in {drifted:?} (expected bit-identical replays)");
        if strict {
            bail!("--strict: trace digest drift across identical replays");
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.bool("sim") {
        return cmd_serve_sim(args);
    }
    let preset = args.str_or("preset", "mixtral-sim");
    let port = args.usize_or("port", 8743) as u16;
    let fw = parse_framework(&args.str_or("framework", "dali"))?;
    dali::serve::server::serve_blocking(&preset, port, fw)
}

/// `dali serve --sim` — one multi-tenant continuous-batching serving
/// cell in virtual time (no engine, no sockets): seeded arrivals, shared
/// pipeline, per-request SLO percentiles, digest-locked.
fn cmd_serve_sim(args: &Args) -> Result<()> {
    let presets = Presets::load_default()?;
    let scenario = args.str_or("scenario", "mixtral-sim-ram16");
    let fw = parse_framework(&args.str_or("framework", "dali"))?;
    // `--arrival` names a presets.json / built-in process or gives an
    // inline `key=value,...` spec; `--load` overrides just its rate
    let mut arrival = presets.arrival(&args.str_or("arrival", "steady-poisson"))?;
    if let Some(load) = args.get("load") {
        let rate: f64 = load.parse().map_err(|_| anyhow::anyhow!("bad --load '{load}'"))?;
        arrival = arrival.with_rate(rate);
    }
    // `--slo` names a presets.json / built-in policy or gives an inline
    // `key=value,...` spec; the default is digest-transparent
    let slo_name = args.str_or("slo", "unlimited");
    let slo = presets.slo(&slo_name)?;
    let cfg = ServeSimCfg {
        arrival,
        n_requests: args.usize_or("requests", 32),
        max_batch: args.usize_or("max-batch", 8),
        max_tokens: args.usize_or("max-tokens", 16),
        slo,
        seed: args.u64_or("seed", 0x5e11),
    };
    let faults = match args.get("faults") {
        Some(spec) => Some(FaultPlan::new(
            presets.fault_profile(spec)?,
            args.u64_or("fault-seed", 0xfa17),
        )),
        None => None,
    };
    let r = simulate_serve(&presets, &scenario, fw, &cfg, faults)?;
    if args.bool("trace-digest") {
        if let Some(d) = r.run.trace_digest {
            println!("trace_digest=0x{d:016x}");
        }
        return Ok(());
    }
    println!(
        "serve-sim scenario={scenario} framework={} arrival={} rate={} requests={} \
         slots={} max_tokens={} slo={slo_name}",
        fw.name(),
        cfg.arrival.kind.name(),
        cfg.arrival.rate,
        cfg.n_requests,
        cfg.max_batch,
        cfg.max_tokens
    );
    println!(
        "  resolved          : {} finished / {} rejected / {} evicted of {} requests, \
         {} tokens",
        r.finished, r.rejected, r.evicted, r.requests, r.tokens_out
    );
    println!("  makespan          : {}", fmt_ns(r.makespan_ns));
    println!("  throughput        : {:.2} tokens/s (virtual)", r.tokens_per_s());
    println!(
        "  TTFT p50 / p99    : {} / {}",
        fmt_ns(r.ttft_p50_ns),
        fmt_ns(r.ttft_p99_ns)
    );
    println!(
        "  TPOT p50 / p99    : {} / {}",
        fmt_ns(r.tpot_p50_ns),
        fmt_ns(r.tpot_p99_ns)
    );
    println!(
        "  queue p50 / p99   : {} / {}",
        fmt_ns(r.queue_p50_ns),
        fmt_ns(r.queue_p99_ns)
    );
    if !cfg.slo.is_unlimited() {
        println!(
            "  SLO attainment    : {:.1}% ({} of {} requests within deadlines)",
            100.0 * r.slo_attainment(),
            r.slo_attained,
            r.requests
        );
        println!(
            "  goodput           : {} tokens ({:.2} tokens/s within-SLO)",
            r.goodput_tokens,
            r.goodput_per_s()
        );
        if r.degraded_ns > 0 {
            println!(
                "  degraded mode     : {} ({:.1}% of makespan)",
                fmt_ns(r.degraded_ns),
                100.0 * r.degraded_ns as f64 / r.makespan_ns.max(1) as f64
            );
        }
    }
    println!("  cache hit rate    : {:.1}%", 100.0 * r.run.cache_hit_rate());
    if r.run.tier_host_hits + r.run.tier_disk_misses > 0 {
        println!(
            "  tier hits         : {} gpu / {} host / {} disk",
            r.run.tier_gpu_hits, r.run.tier_host_hits, r.run.tier_disk_misses
        );
    }
    if faults.is_some() {
        println!(
            "  faults            : {} retries (stall {}), {} aborts",
            r.run.fault_retries,
            fmt_ns(r.run.fault_stall_ns),
            r.run.fault_aborts
        );
    }
    if let Some(d) = r.run.trace_digest {
        println!("trace_digest=0x{d:016x}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") | None => cmd_info(),
        Some("calibrate") => cmd_calibrate(&args),
        Some("prepare") => cmd_prepare(&args),
        Some("run") => cmd_run(&args),
        Some("trace") => cmd_trace(&args),
        Some("bench") => cmd_bench(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => {
            bail!("unknown subcommand '{other}' (info|calibrate|prepare|run|trace|bench|serve)")
        }
    }
}
