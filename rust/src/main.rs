//! `dali` — leader entrypoint + CLI.
//!
//! Subcommands:
//!   info                          show presets + artifact status
//!   calibrate --preset P          compute residual vectors + activation stats
//!   prepare [--preset P]          calibrate + generate all standard trace pools
//!   run --preset P [--framework dali] [--batch 8] [--steps 32]
//!                                 replay a decode benchmark and print metrics
//!   serve --preset P [--port 8743] [--framework dali]
//!                                 start the HTTP serving front-end
//!
//! Experiments (paper tables/figures) live in the separate `expt` binary.

use anyhow::{bail, Result};

use dali::config::Presets;
use dali::coordinator::frameworks::{Framework, FrameworkCfg};
use dali::coordinator::simrun::replay_decode_store;
use dali::hw::CostModel;
use dali::store::TieredStore;
use dali::util::{fmt_ns, Args};
use dali::workload::prep;

fn parse_framework(name: &str) -> Result<Framework> {
    Ok(match name {
        "naive" => Framework::Naive,
        "llama.cpp" | "llamacpp" => Framework::LlamaCpp,
        "ktransformers" | "kt" => Framework::KTransformers,
        "fiddler" => Framework::Fiddler,
        "moe-lightning" | "lightning" => Framework::MoELightning,
        "hybrimoe" => Framework::HybriMoE,
        "dali" => Framework::Dali,
        "dali-opt" => Framework::DaliOpt,
        "dali-beam" => Framework::DaliBeam,
        other => bail!("unknown framework '{other}'"),
    })
}

fn cmd_info() -> Result<()> {
    let p = Presets::load_default()?;
    println!("model presets:");
    for (name, m) in &p.models {
        let have = dali::moe::Manifest::load_preset(name).is_ok();
        println!(
            "  {name:-14} {} — sim {}L/{}E/top{}, paper expert {:.0} MB, artifacts: {}",
            m.display,
            m.sim.layers,
            m.sim.n_routed,
            m.sim.top_k,
            m.paper.expert_bytes() / 1e6,
            if have { "ok" } else { "MISSING (make artifacts)" }
        );
    }
    println!("hardware presets:");
    for (name, h) in &p.hardware {
        println!("  {name:-14} {}", h.display);
    }
    println!("scenarios (memory-limited tiered-store presets):");
    for (name, sc) in &p.scenarios {
        println!("  {name:-20} {} on {}", sc.model, sc.hardware);
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "mixtral-sim");
    let c = prep::ensure_calib(&preset)?;
    println!("calibrated {preset}: {} tokens, {} residual vectors", c.tokens, c.res_vec.len());
    Ok(())
}

fn cmd_prepare(args: &Args) -> Result<()> {
    let presets: Vec<String> = match args.get("preset") {
        Some(p) => vec![p.to_string()],
        None => Presets::load_default()?.model_names().iter().map(|s| s.to_string()).collect(),
    };
    prep::prepare_all(&presets)?;
    println!("prepared: {presets:?}");
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "mixtral-sim");
    let fw = parse_framework(&args.str_or("framework", "dali"))?;
    let batch = args.usize_or("batch", 8);
    let steps = args.usize_or("steps", 32);
    let presets = Presets::load_default()?;
    // `--preset` accepts a model name or a scenario (e.g. mixtral-sim-ram16,
    // which pairs the model with a memory-limited hardware preset).
    let (model_name, hw_name) = match presets.scenarios.get(&preset) {
        Some(sc) => (sc.model.clone(), args.str_or("hw", &sc.hardware)),
        None => (preset.clone(), args.str_or("hw", "local-pc")),
    };
    let model = presets.model(&model_name)?;
    let hw = presets.hw(&hw_name)?;
    let cost = CostModel::new(model, hw);
    let calib = prep::ensure_calib(&model_name)?;
    let trace = prep::ensure_trace(&model_name, "c4-sim", 32, 16, 64)?;
    let cfg = FrameworkCfg::paper_default(&model.sim);
    let bundle = fw.bundle(&model.sim, &cost, &calib.freq, &cfg);
    let seq_ids: Vec<usize> = (0..batch).collect();
    let store = TieredStore::for_model(hw, &cost, model.sim.layers, model.sim.n_routed);
    let tiered = !store.is_unlimited();
    let m = replay_decode_store(
        &trace,
        &seq_ids,
        steps,
        &cost,
        bundle,
        calib.freq.clone(),
        model.sim.n_shared,
        7,
        Some(store),
    );
    println!("preset={preset} framework={} batch={batch} steps={steps}", fw.name());
    println!("  decode speed      : {:.2} tokens/s (simulated)", m.tokens_per_s());
    println!("  virtual time      : {}", fmt_ns(m.total_ns));
    println!("  MoE time          : {}", fmt_ns(m.moe_ns));
    println!(
        "  PCIe busy         : {} ({:.1}% of total)",
        fmt_ns(m.pcie_busy_ns),
        100.0 * m.pcie_time_share()
    );
    println!(
        "  PCIe traffic      : {:.2} GB demand / {:.2} GB prefetch / {:.2} GB cache",
        m.pcie_demand_bytes as f64 / 1e9,
        m.pcie_prefetch_bytes as f64 / 1e9,
        m.pcie_cache_bytes as f64 / 1e9
    );
    println!("  cache hit rate    : {:.1}%", 100.0 * m.cache_hit_rate());
    println!("  prefetch accuracy : {:.1}%", 100.0 * m.prefetch_accuracy());
    println!("  sched overhead    : {:.2}%", 100.0 * m.sched_share());
    if tiered {
        println!(
            "  tier hits         : {} gpu / {} host / {} disk (miss rate {:.1}%)",
            m.tier_gpu_hits,
            m.tier_host_hits,
            m.tier_disk_misses,
            100.0 * m.disk_miss_rate()
        );
        println!(
            "  NVMe              : {} read ({:.1}% of total), {:.2} GB in, {} promotions",
            fmt_ns(m.nvme_read_ns),
            100.0 * m.nvme_time_share(),
            m.nvme_read_bytes as f64 / 1e9,
            m.store_promotions
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "mixtral-sim");
    let port = args.usize_or("port", 8743) as u16;
    let fw = parse_framework(&args.str_or("framework", "dali"))?;
    dali::serve::server::serve_blocking(&preset, port, fw)
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") | None => cmd_info(),
        Some("calibrate") => cmd_calibrate(&args),
        Some("prepare") => cmd_prepare(&args),
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => bail!("unknown subcommand '{other}' (info|calibrate|prepare|run|serve)"),
    }
}
