//! Configuration: model presets, hardware presets, shape buckets.
//!
//! `configs/presets.json` is the single source of truth shared with the
//! python AOT pipeline. A preset carries two sets of dimensions:
//!
//! * `sim` — the scaled-down model that is actually computed via PJRT;
//! * `paper` — the real model of the paper's Table 3, consumed only by the
//!   [`crate::hw::CostModel`] so simulated-time ratios (PCIe vs compute)
//!   match the paper's testbed.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::fault::FaultProfile;
use crate::serve::arrival::ArrivalSpec;
use crate::serve::slo::SloSpec;
use crate::util::json::Value;

/// Scaled model dimensions — what PJRT actually computes.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub n_routed: usize,
    pub top_k: usize,
    pub n_shared: usize,
    pub moe_inter: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl ModelDims {
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(ModelDims {
            layers: v.get("layers")?.as_usize()?,
            hidden: v.get("hidden")?.as_usize()?,
            heads: v.get("heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            n_routed: v.get("n_routed")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            n_shared: v.get("n_shared")?.as_usize()?,
            moe_inter: v.get("moe_inter")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            max_seq: v.get("max_seq")?.as_usize()?,
        })
    }
}

/// The paper's real model dimensions (Table 3) — drives the cost model only.
#[derive(Debug, Clone, PartialEq)]
pub struct PaperDims {
    pub layers: usize,
    pub hidden: usize,
    pub n_routed: usize,
    pub top_k: usize,
    pub n_shared: usize,
    pub moe_inter: usize,
    /// Bytes per weight element (2 = fp16, what local-PC deployments use).
    pub dtype_bytes: usize,
}

impl PaperDims {
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(PaperDims {
            layers: v.get("layers")?.as_usize()?,
            hidden: v.get("hidden")?.as_usize()?,
            n_routed: v.get("n_routed")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            n_shared: v.get("n_shared")?.as_usize()?,
            moe_inter: v.get("moe_inter")?.as_usize()?,
            dtype_bytes: v.get("dtype_bytes")?.as_usize()?,
        })
    }

    /// Bytes of one expert's parameters (w1 + w2 + w3).
    pub fn expert_bytes(&self) -> f64 {
        (3 * self.hidden * self.moe_inter * self.dtype_bytes) as f64
    }

    /// Total bytes of all routed experts across all layers — what host RAM
    /// must hold in the paper's two-tier deployment. Single source of truth
    /// for both [`HwConfig::is_memory_limited`] and the tiered store's
    /// slot conversion (via `CostModel::total_expert_bytes`).
    pub fn total_expert_bytes(&self) -> f64 {
        self.expert_bytes() * (self.n_routed * self.layers) as f64
    }

    /// FLOPs to run one token through one expert (3 GEMMs, 2 FLOPs/MAC).
    pub fn expert_flops_per_token(&self) -> f64 {
        (6 * self.hidden * self.moe_inter) as f64
    }

    /// FLOPs for one token of attention at KV length `kv_len`.
    pub fn attn_flops_per_token(&self, kv_len: usize) -> f64 {
        (8 * self.hidden * self.hidden + 4 * kv_len * self.hidden) as f64
    }

    /// FLOPs for the gate GEMM for one token.
    pub fn gate_flops_per_token(&self) -> f64 {
        (2 * self.hidden * self.n_routed) as f64
    }
}

/// One model preset: scaled sim dims + paper dims.
#[derive(Debug, Clone)]
pub struct ModelPreset {
    pub display: String,
    pub sim: ModelDims,
    pub paper: PaperDims,
}

/// Hardware platform parameters (paper Table 1 numbers for the default
/// `local-pc` preset). All rates are per-second; times are seconds.
///
/// The NVMe fields parameterise the third storage tier of the
/// [`crate::store`] subsystem; `host_ram_bytes == 0` means "host RAM holds
/// every expert" (the paper's original two-tier assumption).
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    pub display: String,
    pub gpu_flops: f64,
    pub gpu_mem_bw: f64,
    pub gpu_mem_bytes: f64,
    pub gpu_kernel_launch_s: f64,
    pub cpu_flops: f64,
    pub cpu_mem_bw: f64,
    pub cpu_dispatch_s: f64,
    pub cpu_cores: usize,
    pub pcie_bw: f64,
    pub pcie_latency_s: f64,
    /// Number of GPU device tiers (1..= [`crate::store::MAX_DEVICES`]).
    /// The device-count source of truth for the whole stack: per-device
    /// caches, PCIe lanes, fault windows and metrics all size from it.
    pub num_gpus: usize,
    /// Optional per-device VRAM budgets (heterogeneous boxes). Empty =
    /// every device gets `gpu_mem_bytes`; when present the length must
    /// equal `num_gpus` and every entry must be positive.
    pub gpu_mem_bytes_dev: Vec<f64>,
    /// Inter-GPU P2P/NVLink bandwidth (multi-GPU boxes; unused at 1 GPU).
    pub p2p_bw: f64,
    /// Per-copy P2P latency (fabric command overhead).
    pub p2p_latency_s: f64,
    /// Host RAM budget for expert weights; 0 = unlimited (two-tier mode).
    pub host_ram_bytes: f64,
    /// NVMe sequential read bandwidth (disk → host promotions).
    pub nvme_read_bw: f64,
    /// NVMe sequential write bandwidth (host → disk spills, when enabled).
    pub nvme_write_bw: f64,
    /// Per-transfer NVMe latency (queue + command overhead).
    pub nvme_latency_s: f64,
}

impl HwConfig {
    pub fn from_json(v: &Value) -> Result<Self> {
        let opt_f64 = |key: &str, default: f64| -> Result<f64> {
            v.opt(key).map(|x| x.as_f64()).transpose().map(|x| x.unwrap_or(default))
        };
        Ok(HwConfig {
            display: v.get("display")?.as_str()?.to_string(),
            gpu_flops: v.get("gpu_flops")?.as_f64()?,
            gpu_mem_bw: v.get("gpu_mem_bw")?.as_f64()?,
            gpu_mem_bytes: v.get("gpu_mem_bytes")?.as_f64()?,
            gpu_kernel_launch_s: v.get("gpu_kernel_launch_s")?.as_f64()?,
            cpu_flops: v.get("cpu_flops")?.as_f64()?,
            cpu_mem_bw: v.get("cpu_mem_bw")?.as_f64()?,
            cpu_dispatch_s: v.get("cpu_dispatch_s")?.as_f64()?,
            cpu_cores: v.get("cpu_cores")?.as_usize()?,
            pcie_bw: v.get("pcie_bw")?.as_f64()?,
            pcie_latency_s: v.get("pcie_latency_s")?.as_f64()?,
            num_gpus: v.opt("num_gpus").map(|x| x.as_usize()).transpose()?.unwrap_or(1),
            gpu_mem_bytes_dev: v
                .opt("gpu_mem_bytes_dev")
                .map(|x| x.as_f64_vec())
                .transpose()?
                .unwrap_or_default(),
            // NVLink-class fabric default; a PCIe-P2P box should override
            // this down to its measured peer-to-peer rate.
            p2p_bw: opt_f64("p2p_bw", 50e9)?,
            p2p_latency_s: opt_f64("p2p_latency_s", 5e-6)?,
            host_ram_bytes: opt_f64("host_ram_bytes", 0.0)?,
            nvme_read_bw: opt_f64("nvme_read_bw", 6e9)?,
            nvme_write_bw: opt_f64("nvme_write_bw", 3e9)?,
            nvme_latency_s: opt_f64("nvme_latency_s", 100e-6)?,
        })
    }

    /// Whether host RAM cannot hold every expert of `paper` — i.e. the
    /// tiered store must spill cold experts to NVMe.
    pub fn is_memory_limited(&self, paper: &PaperDims) -> bool {
        self.host_ram_bytes > 0.0 && self.host_ram_bytes < paper.total_expert_bytes()
    }

    /// VRAM budget of device `d`: the per-device override when present,
    /// else the uniform `gpu_mem_bytes`.
    pub fn gpu_mem_bytes_for(&self, d: usize) -> f64 {
        self.gpu_mem_bytes_dev.get(d).copied().unwrap_or(self.gpu_mem_bytes)
    }

    /// Total VRAM across all device tiers.
    pub fn total_gpu_mem_bytes(&self) -> f64 {
        (0..self.num_gpus).map(|d| self.gpu_mem_bytes_for(d)).sum()
    }

    /// Reject degenerate platform parameters at load time instead of
    /// letting them divide their way into NaN/infinite virtual times deep
    /// inside a run. Every rate and the GPU cache budget must be strictly
    /// positive; `host_ram_bytes` may be 0 (the documented "unlimited"
    /// two-tier sentinel) but not negative or non-finite.
    pub fn validate(&self, name: &str) -> Result<()> {
        for (field, v) in [
            ("gpu_flops", self.gpu_flops),
            ("gpu_mem_bw", self.gpu_mem_bw),
            ("gpu_mem_bytes", self.gpu_mem_bytes),
            ("cpu_flops", self.cpu_flops),
            ("cpu_mem_bw", self.cpu_mem_bw),
            ("pcie_bw", self.pcie_bw),
            ("nvme_read_bw", self.nvme_read_bw),
            ("nvme_write_bw", self.nvme_write_bw),
            ("p2p_bw", self.p2p_bw),
        ] {
            if !(v > 0.0 && v.is_finite()) {
                bail!("hardware preset '{name}': {field} must be positive, got {v}");
            }
        }
        if !(self.host_ram_bytes >= 0.0 && self.host_ram_bytes.is_finite()) {
            bail!(
                "hardware preset '{name}': host_ram_bytes must be >= 0 (0 = unlimited), got {}",
                self.host_ram_bytes
            );
        }
        // The device count was dead weight for nine PRs (nothing read it,
        // so 0-GPU presets loaded fine); now the whole stack sizes from it.
        if self.num_gpus == 0 || self.num_gpus > crate::store::MAX_DEVICES {
            bail!(
                "hardware preset '{name}': num_gpus must be in 1..={}, got {}",
                crate::store::MAX_DEVICES,
                self.num_gpus
            );
        }
        if !self.gpu_mem_bytes_dev.is_empty() {
            if self.gpu_mem_bytes_dev.len() != self.num_gpus {
                bail!(
                    "hardware preset '{name}': gpu_mem_bytes_dev has {} entries for {} GPUs",
                    self.gpu_mem_bytes_dev.len(),
                    self.num_gpus
                );
            }
            for (d, &b) in self.gpu_mem_bytes_dev.iter().enumerate() {
                if !(b > 0.0 && b.is_finite()) {
                    bail!(
                        "hardware preset '{name}': gpu_mem_bytes_dev[{d}] must be positive, got {b}"
                    );
                }
            }
        }
        Ok(())
    }
}

/// A named (model, hardware) pairing — the memory-limited presets such as
/// `mixtral-sim-ram16` that open the latency-vs-host-RAM sensitivity axis.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub model: String,
    pub hardware: String,
    /// On-disk bytes per fp16 byte for NVMe-resident experts — the tiered
    /// store's quantized on-disk format (the `*-q4` scenarios). 1.0 (the
    /// default) keeps fp16 on disk: no transcode stage, the PR 1
    /// behaviour. Consumed by `CostModel::with_quant_ratio`.
    pub quant_ratio: f64,
}

/// Static shape buckets for the AOT artifacts.
#[derive(Debug, Clone)]
pub struct Buckets {
    pub tokens: Vec<usize>,
    pub prefill_seq: Vec<usize>,
    pub decode_batch: Vec<usize>,
}

impl Buckets {
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Buckets {
            tokens: v.get("tokens")?.as_usize_vec()?,
            prefill_seq: v.get("prefill_seq")?.as_usize_vec()?,
            decode_batch: v.get("decode_batch")?.as_usize_vec()?,
        })
    }

    /// Smallest bucket >= n, or the largest bucket if n exceeds all
    /// (callers then split the batch).
    pub fn pick(buckets: &[usize], n: usize) -> usize {
        *buckets
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or(buckets.last().expect("bucket list must be non-empty"))
    }
}

/// The whole presets.json.
#[derive(Debug, Clone)]
pub struct Presets {
    pub models: BTreeMap<String, ModelPreset>,
    pub buckets: Buckets,
    pub hardware: BTreeMap<String, HwConfig>,
    pub scenarios: BTreeMap<String, Scenario>,
    /// Named fault-injection profiles (`fault_profiles` section), stored
    /// as the same `key=value` spec strings `dali run --faults` accepts.
    pub fault_profiles: BTreeMap<String, FaultProfile>,
    /// Named request-arrival processes (`arrival` section) for the
    /// serving simulation, stored as the same `key=value` spec strings
    /// `dali serve --sim --arrival` accepts.
    pub arrivals: BTreeMap<String, ArrivalSpec>,
    /// Named SLO policies (`slo` section) for the serving simulation,
    /// stored as the same `key=value` spec strings
    /// `dali serve --sim --slo` accepts.
    pub slos: BTreeMap<String, SloSpec>,
}

impl Presets {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading presets from {}", path.display()))?;
        let v = Value::parse(&text).context("parsing presets.json")?;
        let mut models = BTreeMap::new();
        for (name, m) in v.get("models")?.as_obj()? {
            models.insert(
                name.clone(),
                ModelPreset {
                    display: m.get("display")?.as_str()?.to_string(),
                    sim: ModelDims::from_json(m.get("sim")?)?,
                    paper: PaperDims::from_json(m.get("paper")?)?,
                },
            );
        }
        let mut hardware = BTreeMap::new();
        for (name, h) in v.get("hardware")?.as_obj()? {
            let hw = HwConfig::from_json(h)
                .with_context(|| format!("hardware preset '{name}'"))?;
            hw.validate(name)?;
            hardware.insert(name.clone(), hw);
        }
        let mut scenarios = BTreeMap::new();
        if let Some(s) = v.opt("scenarios") {
            for (name, sc) in s.as_obj()? {
                let quant_ratio =
                    sc.opt("quant_ratio").map(|x| x.as_f64()).transpose()?.unwrap_or(1.0);
                if !(quant_ratio > 0.0 && quant_ratio <= 1.0) {
                    bail!("scenario '{name}': quant_ratio must be in (0, 1], got {quant_ratio}");
                }
                let model = sc.get("model")?.as_str()?.to_string();
                let hw_name = sc.get("hardware")?.as_str()?.to_string();
                let mp = match models.get(&model) {
                    Some(mp) => mp,
                    None => bail!("scenario '{name}': unknown model preset '{model}'"),
                };
                let hw = match hardware.get(&hw_name) {
                    Some(hw) => hw,
                    None => bail!("scenario '{name}': unknown hardware preset '{hw_name}'"),
                };
                // A RAM budget too small for even one expert is a zero-slot
                // host tier: every access would thrash the same slot and
                // virtual times go nonsensical without an explicit error.
                if hw.host_ram_bytes > 0.0 && hw.host_ram_bytes < mp.paper.expert_bytes() {
                    bail!(
                        "scenario '{name}': host RAM budget {:.0} B holds zero experts \
                         ({:.0} B each) — raise host_ram_bytes or omit it for the \
                         unlimited two-tier mode",
                        hw.host_ram_bytes,
                        mp.paper.expert_bytes()
                    );
                }
                scenarios
                    .insert(name.clone(), Scenario { model, hardware: hw_name, quant_ratio });
            }
        }
        let mut fault_profiles = BTreeMap::new();
        if let Some(fp) = v.opt("fault_profiles") {
            for (name, spec) in fp.as_obj()? {
                let prof = FaultProfile::parse_spec(spec.as_str()?)
                    .with_context(|| format!("fault profile '{name}'"))?;
                fault_profiles.insert(name.clone(), prof);
            }
        }
        let mut arrivals = BTreeMap::new();
        if let Some(ar) = v.opt("arrival") {
            for (name, spec) in ar.as_obj()? {
                let s = ArrivalSpec::parse_spec(spec.as_str()?)
                    .with_context(|| format!("arrival preset '{name}'"))?;
                arrivals.insert(name.clone(), s);
            }
        }
        let mut slos = BTreeMap::new();
        if let Some(sl) = v.opt("slo") {
            for (name, spec) in sl.as_obj()? {
                let s = SloSpec::parse_spec(spec.as_str()?)
                    .with_context(|| format!("slo preset '{name}'"))?;
                slos.insert(name.clone(), s);
            }
        }
        Ok(Presets {
            models,
            buckets: Buckets::from_json(v.get("buckets")?)?,
            hardware,
            scenarios,
            fault_profiles,
            arrivals,
            slos,
        })
    }

    /// Load `<repo>/configs/presets.json`.
    pub fn load_default() -> Result<Self> {
        Self::load(&crate::util::repo_root().join("configs").join("presets.json"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelPreset> {
        self.models.get(name).with_context(|| format!("unknown model preset '{name}'"))
    }

    pub fn hw(&self, name: &str) -> Result<&HwConfig> {
        self.hardware.get(name).with_context(|| format!("unknown hardware preset '{name}'"))
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Resolve a scenario name to its (model, hardware) pair. A plain model
    /// name is accepted too (paired with `local-pc`), so every CLI that
    /// takes `--preset` transparently accepts `mixtral-sim-ram16`.
    pub fn scenario(&self, name: &str) -> Result<(&ModelPreset, &HwConfig)> {
        if let Some(sc) = self.scenarios.get(name) {
            return Ok((self.model(&sc.model)?, self.hw(&sc.hardware)?));
        }
        Ok((self.model(name)?, self.hw("local-pc")?))
    }

    pub fn scenario_names(&self) -> Vec<&str> {
        self.scenarios.keys().map(|s| s.as_str()).collect()
    }

    /// On-disk quantization ratio of a scenario: the scenario's
    /// `quant_ratio` field, or 1.0 (fp16 on disk) for plain model presets
    /// and scenarios without the field. Prefer building scenario cost
    /// models through `CostModel::for_scenario`, which pairs this lookup
    /// with the constructor so it can't be forgotten.
    pub fn quant_ratio(&self, name: &str) -> f64 {
        self.scenarios.get(name).map(|s| s.quant_ratio).unwrap_or(1.0)
    }

    /// Resolve `dali run --faults <arg>`: the presets file's
    /// `fault_profiles` section first, then the built-in named profiles
    /// (so `clean`/`flaky-nvme`/`thermal`/`ram-pressure` work without a
    /// presets file), then an inline `key=value,...` spec.
    pub fn fault_profile(&self, name: &str) -> Result<FaultProfile> {
        if let Some(p) = self.fault_profiles.get(name) {
            return Ok(*p);
        }
        if let Some(p) = FaultProfile::named(name) {
            return Ok(p);
        }
        FaultProfile::parse_spec(name).with_context(|| {
            format!(
                "'{name}' is not a named fault profile (presets: [{}], built-ins: \
                 clean, flaky-nvme, thermal, ram-pressure) and failed to parse as a \
                 key=value spec",
                self.fault_profiles.keys().map(|s| s.as_str()).collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Resolve `dali serve --sim --arrival <arg>` / `expt serve` arrival
    /// names: the presets file's `arrival` section first, then the
    /// built-in named processes (`steady`/`bursty`/`diurnal` work without
    /// a presets file), then an inline `key=value,...` spec.
    pub fn arrival(&self, name: &str) -> Result<ArrivalSpec> {
        if let Some(s) = self.arrivals.get(name) {
            return Ok(*s);
        }
        if let Some(s) = ArrivalSpec::named(name) {
            return Ok(s);
        }
        ArrivalSpec::parse_spec(name).with_context(|| {
            format!(
                "'{name}' is not a named arrival preset (presets: [{}], built-ins: \
                 steady, bursty, diurnal) and failed to parse as a key=value spec",
                self.arrivals.keys().map(|s| s.as_str()).collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Resolve `dali serve --sim --slo <arg>` / `expt serve` SLO-policy
    /// names: the presets file's `slo` section first, then the built-in
    /// named policies (`unlimited`/`tight`/`lenient`/`observe` work
    /// without a presets file), then an inline `key=value,...` spec.
    pub fn slo(&self, name: &str) -> Result<SloSpec> {
        if let Some(s) = self.slos.get(name) {
            return Ok(*s);
        }
        if let Some(s) = SloSpec::named(name) {
            return Ok(s);
        }
        SloSpec::parse_spec(name).with_context(|| {
            format!(
                "'{name}' is not a named SLO preset (presets: [{}], built-ins: \
                 unlimited, tight, lenient, observe) and failed to parse as a \
                 key=value spec",
                self.slos.keys().map(|s| s.as_str()).collect::<Vec<_>>().join(", ")
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_default_presets() {
        let p = Presets::load_default().unwrap();
        assert!(p.models.contains_key("mixtral-sim"));
        assert!(p.models.contains_key("deepseek-sim"));
        assert!(p.models.contains_key("qwen-sim"));
        assert!(p.hardware.contains_key("local-pc"));
    }

    #[test]
    fn paper_dims_mixtral_expert_size() {
        let p = Presets::load_default().unwrap();
        let m = p.model("mixtral-sim").unwrap();
        // Mixtral-8x7B fp16 expert: 3 * 4096 * 14336 * 2 bytes ≈ 352 MB
        let mb = m.paper.expert_bytes() / 1e6;
        assert!((330.0..380.0).contains(&mb), "expert MB = {mb}");
        // 8 experts/layer * 32 layers ≈ 45B params of experts
        let total_params =
            m.paper.expert_bytes() / 2.0 * (m.paper.n_routed * m.paper.layers) as f64;
        assert!((40e9..50e9).contains(&total_params));
    }

    #[test]
    fn sim_dims_consistent_with_heads() {
        let p = Presets::load_default().unwrap();
        for (_, m) in &p.models {
            assert_eq!(m.sim.heads * m.sim.head_dim, m.sim.hidden);
            assert!(m.sim.top_k <= m.sim.n_routed);
            assert_eq!(m.sim.vocab % 16, 0, "vocab must split into 16 clusters");
        }
    }

    #[test]
    fn bucket_pick() {
        let b = vec![1, 2, 4, 8];
        assert_eq!(Buckets::pick(&b, 1), 1);
        assert_eq!(Buckets::pick(&b, 3), 4);
        assert_eq!(Buckets::pick(&b, 8), 8);
        assert_eq!(Buckets::pick(&b, 9), 8); // caller splits
    }

    #[test]
    fn memory_limited_scenarios_resolve() {
        let p = Presets::load_default().unwrap();
        let (m, hw) = p.scenario("mixtral-sim-ram16").unwrap();
        assert_eq!(m.paper.n_routed, 8);
        assert!(hw.host_ram_bytes > 0.0);
        // 16 GB cannot hold 256 experts x 352 MB
        assert!(hw.is_memory_limited(&m.paper));
        // unlimited default is not memory-limited
        let (m2, hw2) = p.scenario("mixtral-sim").unwrap();
        assert!(!hw2.is_memory_limited(&m2.paper));
        assert!(p.scenario("no-such-model").is_err());
        assert!(!p.scenario_names().is_empty());
    }

    #[test]
    fn quantized_scenarios_carry_their_disk_ratio() {
        let p = Presets::load_default().unwrap();
        // fp16-on-disk scenarios (and plain models) default to 1.0
        assert_eq!(p.quant_ratio("mixtral-sim-ram16"), 1.0);
        assert_eq!(p.quant_ratio("mixtral-sim"), 1.0);
        assert_eq!(p.quant_ratio("no-such-scenario"), 1.0);
        // the q4 scenarios keep offloaded experts quantized on NVMe
        let q4 = p.quant_ratio("mixtral-sim-ram16-q4");
        assert!(q4 > 0.0 && q4 < 0.5, "q4 ratio = {q4}");
        assert_eq!(p.quant_ratio("mixtral-sim-ram8-q4"), q4);
        // q4 scenarios resolve to the same (model, hardware) as their
        // fp16 twins — only the on-disk format differs
        let (m, hw) = p.scenario("mixtral-sim-ram16-q4").unwrap();
        let (m2, hw2) = p.scenario("mixtral-sim-ram16").unwrap();
        assert_eq!(m.display, m2.display);
        assert_eq!(hw, hw2);
    }

    #[test]
    fn nvme_fields_parse_with_defaults() {
        let p = Presets::load_default().unwrap();
        let hw = p.hw("local-pc").unwrap();
        assert!(hw.nvme_read_bw > 0.0 && hw.nvme_write_bw > 0.0);
        assert!(hw.nvme_read_bw < hw.pcie_bw, "NVMe is the slower tier");
        assert_eq!(hw.host_ram_bytes, 0.0, "default host RAM is unlimited");
        let ram16 = p.hw("local-pc-ram16").unwrap();
        assert!((ram16.host_ram_bytes - 16e9).abs() < 1e6);
    }

    #[test]
    fn degenerate_hw_budgets_are_rejected_by_name() {
        let p = Presets::load_default().unwrap();
        let hw = p.hw("local-pc").unwrap();
        // explicit zero host RAM is the documented unlimited sentinel
        assert!(hw.validate("local-pc").is_ok());
        let mut bad = hw.clone();
        bad.gpu_mem_bytes = 0.0;
        let err = bad.validate("zero-cache").unwrap_err().to_string();
        assert!(err.contains("zero-cache") && err.contains("gpu_mem_bytes"), "{err}");
        let mut bad = hw.clone();
        bad.nvme_read_bw = 0.0;
        assert!(bad.validate("dead-nvme").unwrap_err().to_string().contains("nvme_read_bw"));
        let mut bad = hw.clone();
        bad.host_ram_bytes = -1.0;
        assert!(bad.validate("neg-ram").unwrap_err().to_string().contains("host_ram_bytes"));
    }

    #[test]
    fn degenerate_device_counts_are_rejected_by_name() {
        let p = Presets::load_default().unwrap();
        let hw = p.hw("local-pc").unwrap();
        // the PR 10 bugfix: num_gpus = 0 used to load silently (nothing
        // read the field); now it is the device-count source of truth
        let mut bad = hw.clone();
        bad.num_gpus = 0;
        let err = bad.validate("no-gpus").unwrap_err().to_string();
        assert!(err.contains("no-gpus") && err.contains("num_gpus"), "{err}");
        let mut bad = hw.clone();
        bad.num_gpus = crate::store::MAX_DEVICES + 1;
        assert!(bad.validate("too-many").unwrap_err().to_string().contains("num_gpus"));
        let mut bad = hw.clone();
        bad.p2p_bw = 0.0;
        assert!(bad.validate("dead-fabric").unwrap_err().to_string().contains("p2p_bw"));
        // per-device budgets must match the device count and be positive
        let mut bad = hw.clone();
        bad.num_gpus = 2;
        bad.gpu_mem_bytes_dev = vec![24e9];
        let err = bad.validate("short-dev").unwrap_err().to_string();
        assert!(err.contains("gpu_mem_bytes_dev") && err.contains("2 GPUs"), "{err}");
        let mut bad = hw.clone();
        bad.num_gpus = 2;
        bad.gpu_mem_bytes_dev = vec![24e9, 0.0];
        assert!(bad
            .validate("zero-dev")
            .unwrap_err()
            .to_string()
            .contains("gpu_mem_bytes_dev[1]"));
        // a heterogeneous pair validates and resolves per device
        let mut good = hw.clone();
        good.num_gpus = 2;
        good.gpu_mem_bytes_dev = vec![24e9, 16e9];
        good.validate("hetero").unwrap();
        assert_eq!(good.gpu_mem_bytes_for(0), 24e9);
        assert_eq!(good.gpu_mem_bytes_for(1), 16e9);
        assert_eq!(good.total_gpu_mem_bytes(), 40e9);
        // uniform fallback when no override is present
        assert_eq!(hw.gpu_mem_bytes_for(0), hw.gpu_mem_bytes);
        assert_eq!(hw.total_gpu_mem_bytes(), hw.gpu_mem_bytes);
    }

    #[test]
    fn zero_slot_scenarios_fail_to_load() {
        // a RAM budget smaller than one expert is a zero-slot host tier
        let text = r#"{
            "models": {"m": {"display": "m", "sim": {
                "layers": 2, "hidden": 64, "heads": 4, "head_dim": 16,
                "n_routed": 4, "top_k": 2, "n_shared": 0, "moe_inter": 64,
                "vocab": 256, "max_seq": 64},
              "paper": {"layers": 2, "hidden": 4096, "n_routed": 4,
                "top_k": 2, "n_shared": 0, "moe_inter": 14336,
                "dtype_bytes": 2}}},
            "buckets": {"tokens": [1], "prefill_seq": [8], "decode_batch": [1]},
            "hardware": {"h": {"display": "h", "gpu_flops": 1e12,
                "gpu_mem_bw": 1e11, "gpu_mem_bytes": 1e9,
                "gpu_kernel_launch_s": 1e-6, "cpu_flops": 1e11,
                "cpu_mem_bw": 1e10, "cpu_dispatch_s": 1e-6, "cpu_cores": 8,
                "pcie_bw": 1e10, "pcie_latency_s": 1e-6,
                "host_ram_bytes": 1e6}},
            "scenarios": {"tiny-ram": {"model": "m", "hardware": "h"}}
        }"#;
        let dir = std::env::temp_dir().join("dali_cfg_test_zero_slot");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("presets.json");
        std::fs::write(&path, text).unwrap();
        let err = Presets::load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("tiny-ram") && msg.contains("zero experts"), "{msg}");
    }

    #[test]
    fn fault_profiles_resolve_from_presets_builtins_and_specs() {
        let p = Presets::load_default().unwrap();
        // presets.json mirrors the built-ins — same knobs either way
        let from_file = p.fault_profile("flaky-nvme").unwrap();
        assert_eq!(Some(from_file), crate::fault::FaultProfile::named("flaky-nvme"));
        assert!(p.fault_profile("clean").unwrap().is_clean());
        assert!(!p.fault_profile("thermal").unwrap().is_clean());
        assert!(!p.fault_profile("ram-pressure").unwrap().is_clean());
        // inline spec fallback
        let spec = p.fault_profile("nvme_fail_prob=0.5,max_retries=1").unwrap();
        assert_eq!(spec.nvme_fail_prob, 0.5);
        assert_eq!(spec.max_retries, 1);
        // garbage is a named error
        let err = p.fault_profile("no-such-profile").unwrap_err();
        assert!(format!("{err:#}").contains("no-such-profile"));
    }

    #[test]
    fn arrival_presets_resolve_from_presets_builtins_and_specs() {
        let p = Presets::load_default().unwrap();
        // presets.json names the three paper-style processes
        let steady = p.arrival("steady-poisson").unwrap();
        assert_eq!(steady.kind, crate::serve::arrival::ArrivalKind::Poisson);
        assert_eq!(p.arrival("bursty").unwrap().kind, crate::serve::arrival::ArrivalKind::Bursty);
        assert_eq!(
            p.arrival("diurnal").unwrap().kind,
            crate::serve::arrival::ArrivalKind::Diurnal
        );
        // built-in fallback + inline spec fallback
        assert!(p.arrival("steady").is_ok());
        let inline = p.arrival("kind=poisson,rate=12").unwrap();
        assert_eq!(inline.rate, 12.0);
        // garbage is a named error listing the presets
        let err = format!("{:#}", p.arrival("no-such-arrival").unwrap_err());
        assert!(err.contains("no-such-arrival") && err.contains("steady-poisson"), "{err}");
        // the overload-sweep mixed-length process ships in presets.json
        let mixed = p.arrival("bursty-mixed").unwrap();
        assert!(mixed.has_lengths() && mixed.len_min >= 1 && mixed.len_max > mixed.len_min);
    }

    #[test]
    fn slo_presets_resolve_from_presets_builtins_and_specs() {
        let p = Presets::load_default().unwrap();
        // presets.json names the four shipped policies, and every named
        // entry parses into a valid spec (the CI preset-sanity invariant)
        for name in ["unlimited", "tight", "lenient", "observe"] {
            let s = p.slo(name).unwrap();
            s.validate().unwrap();
        }
        let tight = p.slo("tight").unwrap();
        assert!(tight.is_guarded() && tight.ttft_ms > 0.0);
        assert!(!p.slo("unlimited").unwrap().is_guarded());
        let observe = p.slo("observe").unwrap();
        assert!(!observe.enforce && observe.ttft_ms > 0.0, "observe scores but never acts");
        // inline spec fallback
        let inline = p.slo("ttft_ms=100,queue_cap=8").unwrap();
        assert_eq!((inline.ttft_ms, inline.queue_cap), (100.0, 8));
        // garbage is a named error listing the built-ins
        let err = format!("{:#}", p.slo("no-such-slo").unwrap_err());
        assert!(err.contains("no-such-slo") && err.contains("tight"), "{err}");
    }

    #[test]
    fn hw_preset_matches_table1() {
        let p = Presets::load_default().unwrap();
        let hw = p.hw("local-pc").unwrap();
        assert_eq!(hw.num_gpus, 1);
        // PCIe 4.0 x16 ≈ 32 GB/s theoretical; effective ~25
        assert!((20e9..32e9).contains(&hw.pcie_bw));
        assert!(hw.gpu_mem_bytes <= 24e9 * 1.01);
        let two = p.hw("local-pc-2gpu").unwrap();
        assert_eq!(two.num_gpus, 2);
        assert!(two.p2p_bw > two.pcie_bw, "P2P fabric beats host PCIe");
        let four = p.hw("local-pc-4gpu").unwrap();
        assert_eq!(four.num_gpus, 4);
    }

    #[test]
    fn deepseek_v3_scenarios_stay_memory_limited_even_multi_gpu() {
        // the whole point of the -2gpu/-4gpu cells: DeepSeek-V3's 256
        // routed experts × 61 layers at q4 still dwarf 2–4 × 24 GB VRAM +
        // host RAM, so every tier of the hierarchy stays active
        let p = Presets::load_default().unwrap();
        for name in ["deepseek-v3-sim-1gpu", "deepseek-v3-sim-2gpu", "deepseek-v3-sim-4gpu"] {
            let (m, hw) = p.scenario(name).unwrap();
            assert_eq!(m.paper.n_routed, 256, "{name}");
            assert!(hw.is_memory_limited(&m.paper), "{name} must need the NVMe tier");
            let q4 = p.quant_ratio(name);
            assert!(q4 > 0.0 && q4 < 0.5, "{name} ships a q4 on-disk format");
            // even the on-disk q4 footprint exceeds all VRAM + host RAM
            let footprint = m.paper.total_expert_bytes() * q4;
            assert!(
                footprint > hw.total_gpu_mem_bytes() + hw.host_ram_bytes,
                "{name}: q4 footprint must exceed VRAM + RAM"
            );
        }
        let (_, hw2) = p.scenario("deepseek-v3-sim-2gpu").unwrap();
        assert_eq!(hw2.num_gpus, 2);
        let (_, hw4) = p.scenario("deepseek-v3-sim-4gpu").unwrap();
        assert_eq!(hw4.num_gpus, 4);
    }
}
