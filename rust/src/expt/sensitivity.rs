//! Sensitivity analyses (paper §6.4): Fig. 18 and Table 9, plus the
//! tiered-store extension — decode latency vs host-RAM budget (a scenario
//! axis the paper's two-tier model cannot express).

use anyhow::{ensure, Result};

use super::common::*;
use crate::coordinator::assignment::GreedyAssigner;
use crate::coordinator::cache::WorkloadAwareCache;
use crate::coordinator::prefetch::{NoPrefetcher, ResidualPrefetcher};
use crate::coordinator::simrun::Phase;
use crate::hw::CostModel;
use crate::metrics::RunMetrics;
use crate::store::{PlacementCfg, TieredStore};
use crate::util::Table;
use crate::workload::trace::{synthetic_locality_trace, Trace};

/// Fig. 18 (a-d): prefetch size, cache size, (w,u) hit grid, adaptation.
/// Sub-sweeps (a)-(c) run one parallel cell per setting on the shared
/// trace; (d) is a single stateful simulation and stays serial.
pub fn fig18(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 18 — sensitivity analyses\n\n");
    ctx.prewarm(&["mixtral-sim", "deepseek-sim"])?;

    // --- (a) prefetch size on Mixtral ---------------------------------------
    {
        let preset = "mixtral-sim";
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let cfg = ctx.fwcfg(preset)?;
        let mut t = Table::new(vec!["prefetch size", "tokens/s (BS8)"]);
        let cells: Vec<usize> = vec![0, 1, 2, 4];
        for (ps, m) in ctx.parallel_cells(cells, |ps| -> Result<f64> {
            let bundle = ctx.bundle_parts(
                &dims,
                Box::new(GreedyAssigner::new()),
                Box::new(ResidualPrefetcher),
                Box::new(WorkloadAwareCache::new(
                    dims.layers, dims.n_routed, cfg.cache_size, cfg.w_size, cfg.u_size, 3,
                )),
                ps,
            );
            Ok(ctx.decode_with(preset, bundle, &trace, 8, 32)?.tokens_per_s())
        }) {
            t.row(vec![format!("PS{ps}"), format!("{:.2}", m?)]);
        }
        out.push_str(&format!("### (a) prefetch size (mixtral-sim)\n\n{}\nPaper: PS=1 is optimal on Mixtral — larger PS cannot be overlapped.\n\n", t.render()));
    }

    // --- (b) cached expert count on Mixtral ----------------------------------
    {
        let preset = "mixtral-sim";
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let mut t = Table::new(vec!["cache size", "tokens/s (BS8)", "hit rate"]);
        let cells: Vec<usize> = vec![1, 2, 4, 6];
        for (cs, m) in ctx.parallel_cells(cells, |cs| -> Result<RunMetrics> {
            let bundle = ctx.bundle_parts(
                &dims,
                Box::new(GreedyAssigner::new()),
                Box::new(NoPrefetcher),
                Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, cs, 4, 1, 3)),
                0,
            );
            ctx.decode_with(preset, bundle, &trace, 8, 32)
        }) {
            let m = m?;
            t.row(vec![
                cs.to_string(),
                format!("{:.2}", m.tokens_per_s()),
                pct(m.cache_hit_rate()),
            ]);
        }
        out.push_str(&format!("### (b) cached experts per layer (mixtral-sim)\n\n{}\nSpeed should rise with cache size.\n\n", t.render()));
    }

    // --- (c) w_size × u_size hit-rate grid on DeepSeek ------------------------
    {
        let preset = "deepseek-sim";
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let cs = (dims.n_routed / 2).max(1);
        let ws = [2usize, 4, 8, 16];
        let us = [1usize, 2, 4, 8];
        let mut cells = Vec::new();
        for &w in &ws {
            for &u in &us {
                cells.push((w, u));
            }
        }
        let mut grid = ctx.parallel_cells(cells, |(w, u)| -> Result<f64> {
            let bundle = ctx.bundle_parts(
                &dims,
                Box::new(GreedyAssigner::new()),
                Box::new(NoPrefetcher),
                Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, cs, w, u, 3)),
                0,
            );
            Ok(ctx.decode_with(preset, bundle, &trace, 4, STEPS)?.cache_hit_rate())
        });
        let mut t = Table::new(vec!["w\\u", "u=1", "u=2", "u=4", "u=8"]);
        for &w in &ws {
            let mut row = vec![format!("w={w}")];
            for &u in &us {
                let ((cw, cu), rate) = grid.next().expect("one result per (w,u) cell");
                assert_eq!((cw, cu), (w, u), "cell order diverged");
                row.push(pct(rate?));
            }
            t.row(row);
        }
        out.push_str(&format!("### (c) (w_size, u_size) hit-rate grid (deepseek-sim, batch 4)\n\n{}\nPaper: smaller w and larger u raise hit rate (at more replacement traffic).\n\n", t.render()));
    }

    // --- (d) hit rate vs token position on Mixtral ----------------------------
    {
        let preset = "mixtral-sim";
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_wikitext(preset)?;
        let calib = ctx.calib(preset)?;
        let cost = ctx.cost(preset)?;
        let bundle = ctx.bundle_parts(
            &dims,
            Box::new(GreedyAssigner::new()),
            Box::new(NoPrefetcher),
            Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, 4, 8, 1, 3)),
            0,
        );
        let mut sim = crate::coordinator::simrun::StepSimulator::new(
            &cost, bundle, &calib.freq, dims.layers, dims.n_routed, dims.n_shared, 5,
        );
        let ids: Vec<usize> = (0..4).collect();
        sim.run_step(&trace.compose_prefill(&ids), 8, Phase::Prefill);
        sim.reset_metrics();
        let mut t = Table::new(vec!["token group", "hit rate"]);
        let group = 8;
        let mut last = (0u64, 0u64);
        for s in 0..trace.min_steps() {
            sim.run_step(&trace.compose_decode(&ids, s), 16 + s, Phase::Decode);
            if (s + 1) % group == 0 {
                let hits = sim.metrics.cache_hits - last.0;
                let looks = sim.metrics.cache_lookups - last.1;
                last = (sim.metrics.cache_hits, sim.metrics.cache_lookups);
                let rate = if looks > 0 { hits as f64 / looks as f64 } else { 0.0 };
                t.row(vec![format!("{}-{}", s + 1 - group + 1, s + 1), pct(rate)]);
            }
        }
        out.push_str(&format!("### (d) hit rate as generation progresses (mixtral-sim, cache 4, w=8, u=1)\n\n{}\nPaper: rate climbs as the cache adapts to the sequence's domain.\n", t.render()));
    }
    Ok(out)
}

/// Latency vs host-RAM budget (tiered expert store): the paper-style
/// figure the two-tier model cannot express. For every hardware budget ×
/// workload × on-disk-format cell, DALI's bundle is replayed twice —
/// predictive placement (promote-ahead + score demotion) vs the reactive
/// LRU-spill baseline — so the figure tracks the RAM cliff, what placement
/// buys back, and what the quantized on-disk format (small NVMe reads +
/// CPU transcode) buys on top. Workloads: the synthetic locality trace
/// (always available) and the C4 traced pool when artifacts exist
/// (`dali prepare`).
pub fn ram_budget(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from(
        "## RAM-budget sensitivity — decode speed vs host RAM (tiered GPU/host/NVMe store)\n\n\
         DALI bundle (greedy + residual prefetch + workload-aware cache), batch 8. `local-pc` \
         holds every expert in RAM (two-tier baseline); the `ram*` presets spill cold experts \
         to NVMe. \"predictive\" = workload-predictive placement (promote-ahead on the NVMe \
         read stream + predicted-workload demotion); \"lru-spill\" = reactive PR 1 baseline. \
         \"disk fmt\" = on-disk expert format: fp16, or q4 (quantized on NVMe — reads move \
         ~0.28x the bytes, then a CPU transcode lane dequantizes, overlapping later reads).\n\n",
    );
    let preset = "mixtral-sim";
    let model = ctx.model(preset)?;
    let dims = model.sim.clone();
    let cfg = ctx.fwcfg(preset)?;
    let presets = &ctx.presets;
    let synthetic =
        synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 48, 0x7157);
    // the paper-style traced workload, artifact-gated so the sweep stays
    // runnable (synthetic-only) in a fresh checkout
    let traced: Option<Trace> = ctx.trace_c4(preset).ok();
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let mut workloads: Vec<(&str, &Trace)> = vec![("synthetic-locality", &synthetic)];
    if let Some(t) = traced.as_ref() {
        workloads.push(("c4-traced", t));
    }
    let hw_names = ["local-pc", "local-pc-ram16", "local-pc-ram8"];
    // Hardware × on-disk-format rows. q4 is swept only where a disk tier
    // exists (with unlimited RAM nothing is ever read back from NVMe),
    // and each q4 row takes its ratio from its own matching `-q4`
    // scenario, so the sweep and the scenario replays stay on the same
    // number per budget. Guard each lookup: quant_ratio() falls back to
    // 1.0 for unknown names, which would silently turn a q4 row into a
    // duplicate fp16 one.
    let mut hw_rows: Vec<(&str, &str, f64)> = Vec::new();
    for hw_name in hw_names {
        hw_rows.push((hw_name, "fp16", 1.0));
        let q4_scenario = match hw_name {
            "local-pc-ram16" => Some("mixtral-sim-ram16-q4"),
            "local-pc-ram8" => Some("mixtral-sim-ram8-q4"),
            _ => None,
        };
        if let Some(sc) = q4_scenario {
            let ratio = presets.quant_ratio(sc);
            ensure!(ratio < 1.0, "scenario '{sc}' is missing or not quantized (ratio {ratio})");
            hw_rows.push((hw_name, "q4", ratio));
        }
    }
    // (workload index, hardware/format row index, predictive)
    let mut cells: Vec<(usize, usize, bool)> = Vec::new();
    for wi in 0..workloads.len() {
        for ri in 0..hw_rows.len() {
            for predictive in [true, false] {
                cells.push((wi, ri, predictive));
            }
        }
    }
    let workloads_ref = &workloads;
    let hw_rows_ref = &hw_rows;
    let mut results = ctx.parallel_cells(cells, move |(wi, ri, predictive)| {
        || -> Result<(String, String, RunMetrics)> {
            let (hw_name, _, ratio) = hw_rows_ref[ri];
            let hw = presets.hw(hw_name)?;
            let cost = CostModel::new(model, hw).with_quant_ratio(ratio);
            let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
            let slots = if store.is_unlimited() {
                "all".to_string()
            } else {
                store.host_slots().to_string()
            };
            let fw = crate::coordinator::frameworks::Framework::Dali;
            let mut bundle = fw.bundle(&dims, &cost, &freq, &cfg);
            if !predictive {
                bundle.placement = PlacementCfg::default();
            }
            let seq_ids: Vec<usize> = (0..8).collect();
            let m = crate::coordinator::simrun::replay_decode_store(
                workloads_ref[wi].1,
                &seq_ids,
                32,
                &cost,
                bundle,
                &freq,
                dims.n_shared,
                7,
                Some(store),
            );
            let ram = if hw.host_ram_bytes <= 0.0 {
                "unlimited".to_string()
            } else {
                format!("{:.0} GB", hw.host_ram_bytes / 1e9)
            };
            Ok((ram, slots, m))
        }()
    });
    for (wi, (wname, _)) in workloads.iter().enumerate() {
        let mut t = Table::new(vec![
            "hardware",
            "host RAM",
            "disk fmt",
            "host slots",
            "tok/s predictive",
            "tok/s lru-spill",
            "placement gain",
            "disk miss (pred)",
            "ahead hit rate",
            "demand NVMe",
            "transcode",
            "NVMe hidden",
        ]);
        for (ri, &(hw_name, fmt_name, _)) in hw_rows.iter().enumerate() {
            let (cell, pred) = results.next().expect("predictive cell");
            assert_eq!(cell, (wi, ri, true), "cell order diverged");
            let (cell, lru) = results.next().expect("lru cell");
            assert_eq!(cell, (wi, ri, false), "cell order diverged");
            let (ram, slots, pred) = pred?;
            let (_, _, lru) = lru?;
            let unlimited = slots == "all";
            let dash = |s: String| if unlimited { "-".to_string() } else { s };
            t.row(vec![
                hw_name.to_string(),
                ram,
                fmt_name.to_string(),
                slots,
                format!("{:.2}", pred.tokens_per_s()),
                format!("{:.2}", lru.tokens_per_s()),
                dash(times(pred.tokens_per_s() / lru.tokens_per_s().max(1e-9))),
                pct(pred.disk_miss_rate()),
                dash(pct(pred.promote_ahead_hit_rate())),
                dash(format!("{:.1} ms", pred.nvme_demand_ns as f64 / 1e6)),
                dash(format!("{:.1} ms", pred.transcode_ns as f64 / 1e6)),
                dash(format!("{:.1} ms", pred.nvme_overlap_hidden_ns as f64 / 1e6)),
            ]);
        }
        out.push_str(&format!("**{wname}**\n\n{}\n", t.render()));
    }
    if traced.is_none() {
        out.push_str(
            "\n(c4-traced workload skipped: no trace artifacts on disk — run `dali prepare`.)\n",
        );
    }
    out.push_str(
        "\nExpected shape: tokens/s degrades as the host budget shrinks; predictive placement \
         claws part of the cliff back by hiding NVMe reads behind the previous layer's compute \
         and spilling by predicted workload instead of recency; the q4 on-disk format cuts \
         demand NVMe time further (smaller reads, transcode overlapped on its own CPU lane) at \
         the price of the reported transcode column.\n",
    );
    Ok(out)
}

/// Table 9: decode speed under (w_size, u_size) settings.
pub fn table9(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Table 9 — tokens/s under (w_size, u_size) settings (batch 32)\n\n");
    let settings_for = |n_routed: usize| -> Vec<(usize, usize)> {
        if n_routed <= 8 {
            vec![(2, 1), (2, 2), (4, 1), (4, 2), (8, 1)]
        } else {
            vec![(2, 8), (2, 16), (4, 8), (4, 16), (8, 8)]
        }
    };
    // one cell per (model, setting) plus the HybriMoE anchor per model;
    // each preset's trace is loaded once and shared across its cells
    ctx.prewarm(&MODELS)?;
    let traces = MODELS.iter().map(|p| ctx.trace_c4(p)).collect::<Result<Vec<_>>>()?;
    let mut cells: Vec<(usize, &str, Option<(usize, usize)>)> = Vec::new();
    for (pi, preset) in MODELS.iter().enumerate() {
        cells.push((pi, preset, None));
        for wu in settings_for(ctx.model(preset)?.sim.n_routed) {
            cells.push((pi, preset, Some(wu)));
        }
    }
    let mut metrics = ctx.parallel_cells(cells, |(pi, preset, setting)| -> Result<f64> {
        let tps = match setting {
            None => ctx
                .decode_traced(
                    preset,
                    crate::coordinator::frameworks::Framework::HybriMoE,
                    &traces[pi],
                    32,
                    32,
                )?
                .tokens_per_s(),
            Some((w, u)) => {
                let dims = ctx.model(preset)?.sim.clone();
                let cfg = ctx.fwcfg(preset)?;
                let bundle = ctx.bundle_parts(
                    &dims,
                    Box::new(GreedyAssigner::new()),
                    Box::new(ResidualPrefetcher),
                    Box::new(WorkloadAwareCache::new(
                        dims.layers, dims.n_routed, cfg.cache_size, w, u.min(dims.n_routed), 3,
                    )),
                    cfg.prefetch_size,
                );
                ctx.decode_with(preset, bundle, &traces[pi], 32, 32)?.tokens_per_s()
            }
        };
        Ok(tps)
    });
    for preset in MODELS {
        let settings = settings_for(ctx.model(preset)?.sim.n_routed);
        let mut header = vec!["model".to_string(), "HybriMoE".to_string()];
        header.extend(settings.iter().map(|(w, u)| format!("({w},{u})")));
        let mut t = Table::new(header);
        let ((_, p, s), hybri) = metrics.next().expect("hybrimoe cell");
        assert_eq!((p, s), (preset, None), "cell order diverged");
        let mut row = vec![preset.to_string(), format!("{:.2}", hybri?)];
        for &wu in &settings {
            let ((_, p, s), tps) = metrics.next().expect("setting cell");
            assert_eq!((p, s), (preset, Some(wu)), "cell order diverged");
            row.push(format!("{:.2}", tps?));
        }
        t.row(row);
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str("Paper selects (4,8) for DeepSeek/Qwen and (4,1) for Mixtral; even the slowest DALI setting beats HybriMoE.\n");
    Ok(out)
}
