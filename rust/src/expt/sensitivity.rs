//! Sensitivity analyses (paper §6.4): Fig. 18 and Table 9, plus the
//! tiered-store extension — decode latency vs host-RAM budget (a scenario
//! axis the paper's two-tier model cannot express).

use anyhow::Result;

use super::common::*;
use crate::coordinator::assignment::GreedyAssigner;
use crate::coordinator::cache::WorkloadAwareCache;
use crate::coordinator::prefetch::{NoPrefetcher, ResidualPrefetcher};
use crate::coordinator::simrun::Phase;
use crate::hw::CostModel;
use crate::store::TieredStore;
use crate::util::Table;
use crate::workload::trace::synthetic_locality_trace;

/// Fig. 18 (a-d): prefetch size, cache size, (w,u) hit grid, adaptation.
pub fn fig18(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 18 — sensitivity analyses\n\n");

    // --- (a) prefetch size on Mixtral ---------------------------------------
    {
        let preset = "mixtral-sim";
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let cfg = ctx.fwcfg(preset)?;
        let mut t = Table::new(vec!["prefetch size", "tokens/s (BS8)"]);
        for ps in [0usize, 1, 2, 4] {
            let bundle = ctx.bundle_parts(
                &dims,
                Box::new(GreedyAssigner::new()),
                Box::new(ResidualPrefetcher),
                Box::new(WorkloadAwareCache::new(
                    dims.layers, dims.n_routed, cfg.cache_size, cfg.w_size, cfg.u_size, 3,
                )),
                ps,
            );
            let m = ctx.decode_with(preset, bundle, &trace, 8, 32)?;
            t.row(vec![format!("PS{ps}"), format!("{:.2}", m.tokens_per_s())]);
        }
        out.push_str(&format!("### (a) prefetch size (mixtral-sim)\n\n{}\nPaper: PS=1 is optimal on Mixtral — larger PS cannot be overlapped.\n\n", t.render()));
    }

    // --- (b) cached expert count on Mixtral ----------------------------------
    {
        let preset = "mixtral-sim";
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let mut t = Table::new(vec!["cache size", "tokens/s (BS8)", "hit rate"]);
        for cs in [1usize, 2, 4, 6] {
            let bundle = ctx.bundle_parts(
                &dims,
                Box::new(GreedyAssigner::new()),
                Box::new(NoPrefetcher),
                Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, cs, 4, 1, 3)),
                0,
            );
            let m = ctx.decode_with(preset, bundle, &trace, 8, 32)?;
            t.row(vec![
                cs.to_string(),
                format!("{:.2}", m.tokens_per_s()),
                pct(m.cache_hit_rate()),
            ]);
        }
        out.push_str(&format!("### (b) cached experts per layer (mixtral-sim)\n\n{}\nSpeed should rise with cache size.\n\n", t.render()));
    }

    // --- (c) w_size × u_size hit-rate grid on DeepSeek ------------------------
    {
        let preset = "deepseek-sim";
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let cs = (dims.n_routed / 2).max(1);
        let mut t = Table::new(vec!["w\\u", "u=1", "u=2", "u=4", "u=8"]);
        for w in [2usize, 4, 8, 16] {
            let mut row = vec![format!("w={w}")];
            for u in [1usize, 2, 4, 8] {
                let bundle = ctx.bundle_parts(
                    &dims,
                    Box::new(GreedyAssigner::new()),
                    Box::new(NoPrefetcher),
                    Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, cs, w, u, 3)),
                    0,
                );
                let m = ctx.decode_with(preset, bundle, &trace, 4, STEPS)?;
                row.push(pct(m.cache_hit_rate()));
            }
            t.row(row);
        }
        out.push_str(&format!("### (c) (w_size, u_size) hit-rate grid (deepseek-sim, batch 4)\n\n{}\nPaper: smaller w and larger u raise hit rate (at more replacement traffic).\n\n", t.render()));
    }

    // --- (d) hit rate vs token position on Mixtral ----------------------------
    {
        let preset = "mixtral-sim";
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_wikitext(preset)?;
        let calib = ctx.calib(preset)?;
        let cost = ctx.cost(preset)?;
        let bundle = ctx.bundle_parts(
            &dims,
            Box::new(GreedyAssigner::new()),
            Box::new(NoPrefetcher),
            Box::new(WorkloadAwareCache::new(dims.layers, dims.n_routed, 4, 8, 1, 3)),
            0,
        );
        let mut sim = crate::coordinator::simrun::StepSimulator::new(
            &cost, bundle, &calib.freq, dims.layers, dims.n_routed, dims.n_shared, 5,
        );
        let ids: Vec<usize> = (0..4).collect();
        sim.run_step(&trace.compose_prefill(&ids), 8, Phase::Prefill);
        sim.reset_metrics();
        let mut t = Table::new(vec!["token group", "hit rate"]);
        let group = 8;
        let mut last = (0u64, 0u64);
        for s in 0..trace.min_steps() {
            sim.run_step(&trace.compose_decode(&ids, s), 16 + s, Phase::Decode);
            if (s + 1) % group == 0 {
                let hits = sim.metrics.cache_hits - last.0;
                let looks = sim.metrics.cache_lookups - last.1;
                last = (sim.metrics.cache_hits, sim.metrics.cache_lookups);
                let rate = if looks > 0 { hits as f64 / looks as f64 } else { 0.0 };
                t.row(vec![format!("{}-{}", s + 1 - group + 1, s + 1), pct(rate)]);
            }
        }
        out.push_str(&format!("### (d) hit rate as generation progresses (mixtral-sim, cache 4, w=8, u=1)\n\n{}\nPaper: rate climbs as the cache adapts to the sequence's domain.\n", t.render()));
    }
    Ok(out)
}

/// Latency vs host-RAM budget (tiered expert store): the new scenario axis.
/// DALI's policy bundle replayed over the same synthetic workload while the
/// host tier shrinks from "holds everything" down to 8 GB — one parallel
/// cell per hardware preset.
pub fn ram_budget(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from(
        "## RAM-budget sensitivity — decode speed vs host RAM (tiered GPU/host/NVMe store)\n\n\
         Synthetic locality workload; DALI bundle (greedy + residual prefetch + workload-aware \
         cache). `local-pc` holds every expert in RAM (two-tier baseline); the `ram*` presets \
         spill cold experts to NVMe.\n\n",
    );
    let preset = "mixtral-sim";
    let model = ctx.model(preset)?;
    let dims = model.sim.clone();
    let cfg = ctx.fwcfg(preset)?;
    let presets = &ctx.presets;
    let trace = synthetic_locality_trace(dims.layers, dims.n_routed, dims.top_k, 16, 48, 0x7157);
    let freq = vec![vec![0.0; dims.n_routed]; dims.layers];
    let mut t = Table::new(vec![
        "hardware",
        "host RAM",
        "host slots",
        "tokens/s (BS8)",
        "disk miss rate",
        "NVMe busy share",
        "promotions",
    ]);
    let hw_names = vec!["local-pc", "local-pc-ram16", "local-pc-ram8"];
    let rows = ctx.parallel(hw_names, |hw_name| -> Result<Vec<String>> {
        let hw = presets.hw(hw_name)?;
        let cost = CostModel::new(model, hw);
        let store = TieredStore::for_model(hw, &cost, dims.layers, dims.n_routed);
        let slots = if store.is_unlimited() {
            "all".to_string()
        } else {
            store.host_slots().to_string()
        };
        let fw = crate::coordinator::frameworks::Framework::Dali;
        let bundle = fw.bundle(&dims, &cost, &freq, &cfg);
        let seq_ids: Vec<usize> = (0..8).collect();
        let m = crate::coordinator::simrun::replay_decode_store(
            &trace,
            &seq_ids,
            32,
            &cost,
            bundle,
            &freq,
            dims.n_shared,
            7,
            Some(store),
        );
        let ram = if hw.host_ram_bytes <= 0.0 {
            "unlimited".to_string()
        } else {
            format!("{:.0} GB", hw.host_ram_bytes / 1e9)
        };
        Ok(vec![
            hw_name.to_string(),
            ram,
            slots,
            format!("{:.2}", m.tokens_per_s()),
            pct(m.disk_miss_rate()),
            pct(m.nvme_time_share()),
            m.store_promotions.to_string(),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    out.push_str(&t.render());
    out.push_str(
        "\nExpected shape: tokens/s degrades monotonically as the host budget shrinks; the \
         NVMe read stream saturates once the hot set no longer fits host RAM.\n",
    );
    Ok(out)
}

/// Table 9: decode speed under (w_size, u_size) settings.
pub fn table9(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Table 9 — tokens/s under (w_size, u_size) settings (batch 32)\n\n");
    let settings_for = |n_routed: usize| -> Vec<(usize, usize)> {
        if n_routed <= 8 {
            vec![(2, 1), (2, 2), (4, 1), (4, 2), (8, 1)]
        } else {
            vec![(2, 8), (2, 16), (4, 8), (4, 16), (8, 8)]
        }
    };
    // one cell per (model, setting) plus the HybriMoE anchor per model;
    // each preset's trace is loaded once and shared across its cells
    ctx.prewarm(&MODELS)?;
    let traces = MODELS.iter().map(|p| ctx.trace_c4(p)).collect::<Result<Vec<_>>>()?;
    let mut cells: Vec<(usize, &str, Option<(usize, usize)>)> = Vec::new();
    for (pi, preset) in MODELS.iter().enumerate() {
        cells.push((pi, preset, None));
        for wu in settings_for(ctx.model(preset)?.sim.n_routed) {
            cells.push((pi, preset, Some(wu)));
        }
    }
    let mut metrics = ctx.parallel_cells(cells, |(pi, preset, setting)| -> Result<f64> {
        let tps = match setting {
            None => ctx
                .decode_traced(
                    preset,
                    crate::coordinator::frameworks::Framework::HybriMoE,
                    &traces[pi],
                    32,
                    32,
                )?
                .tokens_per_s(),
            Some((w, u)) => {
                let dims = ctx.model(preset)?.sim.clone();
                let cfg = ctx.fwcfg(preset)?;
                let bundle = ctx.bundle_parts(
                    &dims,
                    Box::new(GreedyAssigner::new()),
                    Box::new(ResidualPrefetcher),
                    Box::new(WorkloadAwareCache::new(
                        dims.layers, dims.n_routed, cfg.cache_size, w, u.min(dims.n_routed), 3,
                    )),
                    cfg.prefetch_size,
                );
                ctx.decode_with(preset, bundle, &traces[pi], 32, 32)?.tokens_per_s()
            }
        };
        Ok(tps)
    });
    for preset in MODELS {
        let settings = settings_for(ctx.model(preset)?.sim.n_routed);
        let mut header = vec!["model".to_string(), "HybriMoE".to_string()];
        header.extend(settings.iter().map(|(w, u)| format!("({w},{u})")));
        let mut t = Table::new(header);
        let ((_, p, s), hybri) = metrics.next().expect("hybrimoe cell");
        assert_eq!((p, s), (preset, None), "cell order diverged");
        let mut row = vec![preset.to_string(), format!("{:.2}", hybri?)];
        for &wu in &settings {
            let ((_, p, s), tps) = metrics.next().expect("setting cell");
            assert_eq!((p, s), (preset, Some(wu)), "cell order diverged");
            row.push(format!("{:.2}", tps?));
        }
        t.row(row);
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str("Paper selects (4,8) for DeepSeek/Qwen and (4,1) for Mixtral; even the slowest DALI setting beats HybriMoE.\n");
    Ok(out)
}
