//! Breakdown analyses (paper §6.3): Figs. 14-17, 19 and Table 4.

use anyhow::Result;

use super::common::*;
use crate::coordinator::assignment::{
    AllCpuAssigner, EnumerateAssigner, GreedyAssigner, StaticThresholdAssigner,
};
use crate::coordinator::cache::{LruCache, NoCache, ScoreCache, WorkloadAwareCache};
use crate::coordinator::prefetch::{
    FeaturePrefetcher, NoPrefetcher, RandomPrefetcher, ResidualPrefetcher,
};
use crate::util::Table;

/// Fig. 14: assignment strategies in isolation (no prefetch, no cache).
pub fn fig14(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from(
        "## Fig. 14 — assignment-only comparison (no prefetch / no cache)\n\n",
    );
    let mut hybri_speedups = vec![];
    let mut dali_speedups = vec![];
    let presets = ["deepseek-sim", "mixtral-sim"];
    ctx.prewarm(&presets)?;
    // load each preset's trace once and share it across all of its cells
    let traces = presets
        .iter()
        .map(|p| ctx.trace_c4(p))
        .collect::<Result<Vec<_>>>()?;
    let mut cells = Vec::new();
    for (pi, preset) in presets.iter().enumerate() {
        for &b in &BATCHES {
            for which in ["naive", "static", "greedy"] {
                cells.push((pi, *preset, b, which));
            }
        }
    }
    // results come back paired with their cells (see parallel_cells)
    let mut metrics = ctx.parallel_cells(cells, |(pi, preset, b, which)| -> Result<f64> {
        let dims = ctx.model(preset)?.sim.clone();
        let assigner: Box<dyn crate::coordinator::assignment::Assigner> = match which {
            "naive" => Box::new(AllCpuAssigner::new()),
            "static" => Box::new(StaticThresholdAssigner::new()),
            _ => Box::new(GreedyAssigner::new()),
        };
        let bundle = ctx.bundle_parts(
            &dims,
            assigner,
            Box::new(NoPrefetcher),
            Box::new(NoCache::new(dims.layers, dims.n_routed)),
            0,
        );
        Ok(ctx.decode_with(preset, bundle, &traces[pi], b, 32)?.tokens_per_s())
    });
    let mut next_cell = |preset: &str, b: usize, which: &str| -> Result<f64> {
        let ((pi, p, bb, w), r) = metrics.next().expect("one result per cell");
        assert_eq!((presets[pi], p, bb, w), (preset, preset, b, which), "cell order diverged");
        r
    };
    for preset in presets {
        let mut t = Table::new(vec!["batch", "naive (all-CPU)", "HybriMoE static", "DALI greedy"]);
        for &b in &BATCHES {
            let naive = next_cell(preset, b, "naive")?;
            let stat = next_cell(preset, b, "static")?;
            let greedy = next_cell(preset, b, "greedy")?;
            hybri_speedups.push(stat / naive.max(1e-9));
            dali_speedups.push(greedy / naive.max(1e-9));
            t.row(vec![
                format!("BS{b}"),
                format!("{naive:.2}"),
                format!("{stat:.2} ({})", times(stat / naive)),
                format!("{greedy:.2} ({})", times(greedy / naive)),
            ]);
        }
        out.push_str(&format!("**{preset}**\n\n{}\n", t.render()));
    }
    let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
    out.push_str(&format!(
        "average speedup vs naive: HybriMoE static {} (paper 3.58x), DALI greedy {} (paper 4.42x); greedy vs static = {}\n",
        times(avg(&hybri_speedups)),
        times(avg(&dali_speedups)),
        times(avg(&dali_speedups) / avg(&hybri_speedups)),
    ));
    Ok(out)
}

fn dali_like_bundle(
    ctx: &ExptCtx,
    preset: &str,
    assigner: Box<dyn crate::coordinator::assignment::Assigner>,
) -> Result<crate::coordinator::simrun::PolicyBundle> {
    let dims = ctx.model(preset)?.sim.clone();
    let cfg = ctx.fwcfg(preset)?;
    Ok(ctx.bundle_parts(
        &dims,
        assigner,
        Box::new(ResidualPrefetcher),
        Box::new(WorkloadAwareCache::new(
            dims.layers,
            dims.n_routed,
            cfg.cache_size,
            cfg.w_size,
            cfg.u_size,
            cfg.seed,
        )),
        cfg.prefetch_size,
    ))
}

/// Fig. 15: end-to-end decode speed, greedy vs exact solver (solve cost
/// charged into virtual time, as at runtime). One parallel cell per
/// (model, batch, solver), sharing each preset's trace.
pub fn fig15(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 15 — greedy vs Opt_plan decode speed (incl. solving)\n\n");
    let mut t = Table::new(vec!["model", "batch", "Opt_plan tok/s", "greedy tok/s", "speedup", "opt sched%", "greedy sched%"]);
    let mut ratios = vec![];
    let presets = ["deepseek-sim", "mixtral-sim"];
    ctx.prewarm(&presets)?;
    let traces = presets.iter().map(|p| ctx.trace_c4(p)).collect::<Result<Vec<_>>>()?;
    let mut cells = Vec::new();
    for (pi, preset) in presets.iter().enumerate() {
        for &b in &[16usize, 32] {
            for which in ["greedy", "opt"] {
                cells.push((pi, *preset, b, which));
            }
        }
    }
    let mut metrics = ctx.parallel_cells(cells, |(pi, preset, b, which)| {
        let assigner: Box<dyn crate::coordinator::assignment::Assigner> = match which {
            "opt" => Box::new(EnumerateAssigner::new()),
            _ => Box::new(GreedyAssigner::new()),
        };
        ctx.decode_with(preset, dali_like_bundle(ctx, preset, assigner)?, &traces[pi], b, 32)
    });
    for (pi, preset) in presets.iter().enumerate() {
        for &b in &[16usize, 32] {
            let (cell, g) = metrics.next().expect("greedy cell");
            assert_eq!(cell, (pi, *preset, b, "greedy"), "cell order diverged");
            let (cell, o) = metrics.next().expect("opt cell");
            assert_eq!(cell, (pi, *preset, b, "opt"), "cell order diverged");
            let (g, o) = (g?, o?);
            let speed = g.tokens_per_s() / o.tokens_per_s().max(1e-9);
            ratios.push(speed);
            t.row(vec![
                preset.to_string(),
                format!("BS{b}"),
                format!("{:.2}", o.tokens_per_s()),
                format!("{:.2}", g.tokens_per_s()),
                times(speed),
                pct(o.sched_share()),
                pct(g.sched_share()),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\naverage greedy speedup over Opt_plan: {} (paper: 1.70x; solve overhead 4.5% vs 55%)\n",
        times(ratios.iter().sum::<f64>() / ratios.len() as f64)
    ));
    Ok(out)
}

/// Table 4: MoE execution time only (solve cost excluded).
///
/// Cache and prefetch are disabled so the executed-schedule gap reflects
/// only the assignment decision (with them on, divergent cache evolution
/// dominates the comparison).
pub fn table4(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Table 4 — MoE time (s), greedy vs optimal schedule (excl. solve)\n\n");
    let mut t = Table::new(vec!["model", "batch", "Opt_plan", "greedy", "gap"]);
    for preset in ["deepseek-sim", "mixtral-sim"] {
        let dims = ctx.model(preset)?.sim.clone();
        for &b in &[16usize, 32] {
            let trace = ctx.trace_c4(preset)?;
            let mk = |assigner: Box<dyn crate::coordinator::assignment::Assigner>| {
                ctx.bundle_parts(
                    &dims,
                    assigner,
                    Box::new(NoPrefetcher),
                    Box::new(NoCache::new(dims.layers, dims.n_routed)),
                    0,
                )
            };
            let g = ctx.decode_with(preset, mk(Box::new(GreedyAssigner::new())), &trace, b, 32)?;
            let o =
                ctx.decode_with(preset, mk(Box::new(EnumerateAssigner::new())), &trace, b, 32)?;
            // exclude scheduling by comparing the MoE makespans only
            let gm = g.moe_ns as f64 / 1e9;
            let om = o.moe_ns as f64 / 1e9;
            t.row(vec![
                preset.to_string(),
                format!("BS{b}"),
                format!("{om:.3}"),
                format!("{gm:.3}"),
                format!("{:+.1}%", 100.0 * (gm - om) / om.max(1e-9)),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push_str("\nPaper Table 4 gaps: 7.8-15% (greedy attains ≥ ~85-92% of optimal).\n");
    Ok(out)
}

/// Fig. 16: (a) speedup of prefetch strategies on Mixtral; (b) accuracy.
/// Both sub-figures run one parallel cell per (strategy, batch) /
/// (method, top-j) on the shared trace.
pub fn fig16(ctx: &ExptCtx) -> Result<String> {
    let preset = "mixtral-sim";
    let dims = ctx.model(preset)?.sim.clone();
    ctx.prewarm(&[preset])?;
    let trace = ctx.trace_c4(preset)?;
    let calib = ctx.calib(preset)?;
    let mut out = String::from("## Fig. 16 — prefetch strategies on Mixtral\n\n### (a) decode speedup vs no prefetching (each prefetches 2 experts)\n\n");
    let mut t = Table::new(vec!["strategy", "BS8 tok/s", "BS32 tok/s", "avg speedup"]);
    let strategies = ["naive", "random", "hybrimoe", "dali"];
    let mut cells = Vec::new();
    for which in strategies {
        for b in [8usize, 32] {
            cells.push((which, b));
        }
    }
    let mut metrics = ctx.parallel_cells(cells, |(which, batch)| -> Result<f64> {
        let prefetcher: Box<dyn crate::coordinator::prefetch::Prefetcher> = match which {
            "random" => Box::new(RandomPrefetcher),
            "hybrimoe" => Box::new(FeaturePrefetcher),
            "dali" => Box::new(ResidualPrefetcher),
            _ => Box::new(NoPrefetcher),
        };
        let ps = if which == "naive" { 0 } else { 2 };
        let bundle = ctx.bundle_parts(
            &dims,
            Box::new(GreedyAssigner::new()),
            prefetcher,
            Box::new(NoCache::new(dims.layers, dims.n_routed)),
            ps,
        );
        Ok(ctx.decode_with(preset, bundle, &trace, batch, 32)?.tokens_per_s())
    });
    let mut base = (0.0, 0.0);
    for which in strategies {
        let (cell, a) = metrics.next().expect("BS8 cell");
        assert_eq!(cell, (which, 8), "cell order diverged");
        let (cell, b) = metrics.next().expect("BS32 cell");
        assert_eq!(cell, (which, 32), "cell order diverged");
        let (a, b) = (a?, b?);
        if which == "naive" {
            base = (a, b);
        }
        let avg = (a / base.0 + b / base.1) / 2.0;
        t.row(vec![which.to_string(), format!("{a:.2}"), format!("{b:.2}"), times(avg)]);
    }
    out.push_str(&t.render());

    out.push_str("\n### (b) prefetch accuracy (top-k highest-workload experts, batch 8)\n\n");
    let mut t2 = Table::new(vec!["method", "Top-1", "Top-2", "Top-3"]);
    let ids: Vec<usize> = (0..8).collect();
    let methods = [
        ("EdgeMoE", PredKind::Statistical),
        ("HybriMoE", PredKind::Feature),
        ("DALI", PredKind::Residual),
    ];
    let mut acc_cells = Vec::new();
    for &(name, kind) in &methods {
        for j in [1usize, 2, 3] {
            acc_cells.push((name, kind, j));
        }
    }
    let mut accs = ctx
        .parallel_cells(acc_cells, |(_, kind, j)| {
            prefetch_accuracy(&trace, &calib, &ids, 48, kind, j)
        });
    for &(name, kind) in &methods {
        let mut row = vec![name.to_string()];
        for j in [1usize, 2, 3] {
            let (cell, acc) = accs.next().expect("one accuracy per cell");
            assert_eq!(cell, (name, kind, j), "cell order diverged");
            row.push(pct(acc));
        }
        t2.row(row);
    }
    out.push_str(&t2.render());
    Ok(out)
}

/// Fig. 17: cache replacement strategies — decode speed + hit rate. One
/// parallel cell per (cache ratio, policy) on the shared trace.
pub fn fig17(ctx: &ExptCtx) -> Result<String> {
    let preset = "mixtral-sim";
    let dims = ctx.model(preset)?.sim.clone();
    ctx.prewarm(&[preset])?;
    let trace = ctx.trace_c4(preset)?;
    let cfg = ctx.fwcfg(preset)?;
    let mut out = String::from("## Fig. 17 — cache replacement strategies (mixtral-sim, batch 4)\n\n");
    let mut t = Table::new(vec!["cache ratio", "LRU hit", "HybriMoE hit", "DALI hit", "HybriMoE tok/s", "DALI tok/s", "speedup"]);
    let fracs = [8usize, 4, 2];
    let policies = ["lru", "score", "wa"];
    let mut cells = Vec::new();
    for &frac in &fracs {
        for which in policies {
            cells.push((frac, which));
        }
    }
    let mut metrics = ctx.parallel_cells(cells, |(frac, which)| {
        let cs = (dims.n_routed / frac).max(1);
        let cache: Box<dyn crate::coordinator::cache::ExpertCache> = match which {
            "lru" => Box::new(LruCache::new(dims.layers, dims.n_routed, cs, 13)),
            "score" => Box::new(ScoreCache::new(dims.layers, dims.n_routed, cs, 13)),
            _ => Box::new(WorkloadAwareCache::new(
                dims.layers, dims.n_routed, cs, cfg.w_size, cfg.u_size, 13,
            )),
        };
        let bundle = ctx.bundle_parts(
            &dims,
            Box::new(GreedyAssigner::new()),
            Box::new(NoPrefetcher),
            cache,
            0,
        );
        ctx.decode_with(preset, bundle, &trace, 4, STEPS)
    });
    for &frac in &fracs {
        let cs = (dims.n_routed / frac).max(1);
        let mut next_cell = |which: &str| {
            let (cell, m) = metrics.next().expect("one result per cell");
            assert_eq!(cell, (frac, which), "cell order diverged");
            m
        };
        let lru = next_cell("lru")?;
        let sc = next_cell("score")?;
        let wa = next_cell("wa")?;
        t.row(vec![
            format!("{}/{}", cs, dims.n_routed),
            pct(lru.cache_hit_rate()),
            pct(sc.cache_hit_rate()),
            pct(wa.cache_hit_rate()),
            format!("{:.2}", sc.tokens_per_s()),
            format!("{:.2}", wa.tokens_per_s()),
            times(wa.tokens_per_s() / sc.tokens_per_s().max(1e-9)),
        ]);
    }
    out.push_str(&t.render());
    out.push_str("\nPaper: workload-aware replacement beats score-based by ~1.23x with consistently higher hit rates.\n");
    Ok(out)
}

/// Fig. 19: cumulative contribution of each technique. One parallel cell
/// per (model, stage); bundles are built inside the cell workers (boxed
/// policies are not clonable across cells).
pub fn fig19(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 19 — breakdown waterfall (cache ratio 25%)\n\n");
    const STAGES: [&str; 4] = [
        "naive (all CPU)",
        "+ greedy assignment",
        "+ residual prefetch",
        "+ workload-aware cache",
    ];
    let presets = ["mixtral-sim", "qwen-sim"];
    ctx.prewarm(&presets)?;
    let traces = presets.iter().map(|p| ctx.trace_c4(p)).collect::<Result<Vec<_>>>()?;
    let mut cells = Vec::new();
    for (pi, preset) in presets.iter().enumerate() {
        for stage in 0..STAGES.len() {
            cells.push((pi, *preset, stage));
        }
    }
    let mut metrics = ctx.parallel_cells(cells, |(pi, preset, stage)| -> Result<f64> {
        let dims = ctx.model(preset)?.sim.clone();
        let cfg = ctx.fwcfg(preset)?;
        let cs = (dims.n_routed / 4).max(1); // 25% cache ratio
        let ps = if dims.n_routed <= 8 { 1 } else { 8 };
        let assigner: Box<dyn crate::coordinator::assignment::Assigner> = if stage == 0 {
            Box::new(AllCpuAssigner::new())
        } else {
            Box::new(GreedyAssigner::new())
        };
        let prefetcher: Box<dyn crate::coordinator::prefetch::Prefetcher> = if stage >= 2 {
            Box::new(ResidualPrefetcher)
        } else {
            Box::new(NoPrefetcher)
        };
        let cache: Box<dyn crate::coordinator::cache::ExpertCache> = if stage >= 3 {
            Box::new(WorkloadAwareCache::new(
                dims.layers, dims.n_routed, cs, cfg.w_size, cfg.u_size, cfg.seed,
            ))
        } else {
            Box::new(NoCache::new(dims.layers, dims.n_routed))
        };
        let bundle = ctx.bundle_parts(
            &dims,
            assigner,
            prefetcher,
            cache,
            if stage >= 2 { ps } else { 0 },
        );
        Ok(ctx.decode_with(preset, bundle, &traces[pi], 8, 32)?.tokens_per_s())
    });
    for (pi, preset) in presets.iter().enumerate() {
        let mut t = Table::new(vec!["configuration", "tokens/s", "vs naive", "vs previous"]);
        let mut naive = 0.0;
        let mut prev = 0.0;
        for (stage, name) in STAGES.iter().enumerate() {
            let (cell, tps) = metrics.next().expect("one result per stage cell");
            assert_eq!(cell, (pi, *preset, stage), "cell order diverged");
            let tps = tps?;
            if naive == 0.0 {
                naive = tps;
                prev = tps;
            }
            t.row(vec![
                name.to_string(),
                format!("{tps:.2}"),
                times(tps / naive),
                times(tps / prev),
            ]);
            prev = tps;
        }
        out.push_str(&format!("**{preset}** (batch 8)\n\n{}\n", t.render()));
    }
    out.push_str("Paper: greedy 4.1x (largest), prefetch ~+9%, cache ~+38%.\n");
    Ok(out)
}
