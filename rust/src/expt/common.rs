//! Shared experiment plumbing: preset/cost/trace access, policy builders,
//! prefetch-accuracy computation, and the standard replay configurations.

use anyhow::Result;

use crate::config::{ModelDims, ModelPreset, Presets};
use crate::coordinator::assignment::*;
use crate::coordinator::cache::*;
use crate::coordinator::frameworks::{Framework, FrameworkCfg};
use crate::coordinator::prefetch::*;
use crate::coordinator::simrun::PolicyBundle;
use crate::hw::CostModel;
use crate::metrics::RunMetrics;
use crate::workload::{prep, CalibData, Trace};

/// The three evaluated models, in the paper's order.
pub const MODELS: [&str; 3] = ["deepseek-sim", "qwen-sim", "mixtral-sim"];

/// Batch sizes used by the sweeps (paper Figs. 4-7, 12-13).
pub const BATCHES: [usize; 4] = [8, 16, 32, 64];

/// Default decode steps for speed benchmarks.
pub const STEPS: usize = 48;

pub struct ExptCtx {
    pub presets: Presets,
    /// Worker threads for sweep cells (see [`Self::parallel`]); 1 = serial.
    pub jobs: usize,
}

impl ExptCtx {
    pub fn new() -> Result<Self> {
        Ok(ExptCtx { presets: Presets::load_default()?, jobs: 1 })
    }

    /// Set the sweep parallelism (the `expt` binary's `--jobs N`).
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// Run independent sweep cells on the scoped-thread pool, preserving
    /// input order. Every replay is deterministic (fixed seeds, modeled
    /// solve cost), so `--jobs N` never changes any reported number.
    pub fn parallel<T: Send, R: Send>(
        &self,
        items: Vec<T>,
        f: impl Fn(T) -> R + Sync,
    ) -> Vec<R> {
        crate::util::pool::parallel_map(self.jobs, items, f)
    }

    /// [`Self::parallel`] that hands each result back *paired with its
    /// cell*: sweeps consume the pairs in generation order and assert the
    /// cell matches the table slot, so a drifted loop nest panics instead
    /// of silently misattributing replays.
    pub fn parallel_cells<T, R>(
        &self,
        cells: Vec<T>,
        f: impl Fn(T) -> R + Sync,
    ) -> impl Iterator<Item = (T, R)>
    where
        T: Send + Clone,
        R: Send,
    {
        let results = self.parallel(cells.clone(), f);
        cells.into_iter().zip(results)
    }

    /// Ensure calibration + the C4 trace pool exist on disk for `presets`
    /// before a parallel sweep starts — cell workers then only ever *read*
    /// the artifact cache, so there is no generation race.
    pub fn prewarm(&self, presets: &[&str]) -> Result<()> {
        for p in presets {
            self.calib(p)?;
            self.trace_c4(p)?;
        }
        Ok(())
    }

    pub fn model(&self, preset: &str) -> Result<&ModelPreset> {
        self.presets.model(preset)
    }

    pub fn cost(&self, preset: &str) -> Result<CostModel> {
        Ok(CostModel::new(self.presets.model(preset)?, self.presets.hw("local-pc")?))
    }

    pub fn calib(&self, preset: &str) -> Result<CalibData> {
        prep::ensure_calib(preset)
    }

    /// The standard C4 speed-benchmark trace pool.
    pub fn trace_c4(&self, preset: &str) -> Result<Trace> {
        prep::ensure_trace(preset, "c4-sim", 32, 16, 64)
    }

    /// The Wikitext locality pool.
    pub fn trace_wikitext(&self, preset: &str) -> Result<Trace> {
        prep::ensure_trace(preset, "wikitext-sim", 16, 16, 48)
    }

    pub fn fwcfg(&self, preset: &str) -> Result<FrameworkCfg> {
        Ok(FrameworkCfg::paper_default(&self.presets.model(preset)?.sim))
    }

    /// Replay decode for a framework with the paper-default config.
    pub fn decode(
        &self,
        preset: &str,
        fw: Framework,
        batch: usize,
        steps: usize,
    ) -> Result<RunMetrics> {
        let trace = self.trace_c4(preset)?;
        self.decode_traced(preset, fw, &trace, batch, steps)
    }

    /// [`Self::decode`] against a pre-loaded trace: parallel sweeps load
    /// each preset's pool from disk once and share it across cells instead
    /// of re-deserializing it per cell.
    pub fn decode_traced(
        &self,
        preset: &str,
        fw: Framework,
        trace: &Trace,
        batch: usize,
        steps: usize,
    ) -> Result<RunMetrics> {
        let model = self.model(preset)?;
        let cost = self.cost(preset)?;
        let calib = self.calib(preset)?;
        let cfg = self.fwcfg(preset)?;
        let bundle = fw.bundle(&model.sim, &cost, &calib.freq, &cfg);
        let seq_ids: Vec<usize> = (0..batch).collect();
        Ok(crate::coordinator::simrun::replay_decode(
            trace,
            &seq_ids,
            steps,
            &cost,
            bundle,
            &calib.freq,
            model.sim.n_shared,
            7,
        ))
    }

    /// [`Self::prefill`] against a pre-loaded trace (see
    /// [`Self::decode_traced`]).
    pub fn prefill_traced(
        &self,
        preset: &str,
        fw: Framework,
        trace: &Trace,
        batch: usize,
    ) -> Result<RunMetrics> {
        let model = self.model(preset)?;
        let cost = self.cost(preset)?;
        let calib = self.calib(preset)?;
        let cfg = self.fwcfg(preset)?;
        let bundle = fw.bundle(&model.sim, &cost, &calib.freq, &cfg);
        let seq_ids: Vec<usize> = (0..batch).collect();
        Ok(crate::coordinator::simrun::replay_prefill(
            trace,
            &seq_ids,
            &cost,
            bundle,
            &calib.freq,
            model.sim.n_shared,
            7,
        ))
    }

    /// Replay decode with an explicit policy bundle.
    pub fn decode_with(
        &self,
        preset: &str,
        bundle: PolicyBundle,
        trace: &Trace,
        batch: usize,
        steps: usize,
    ) -> Result<RunMetrics> {
        let model = self.model(preset)?;
        let calib = self.calib(preset)?;
        let cost = self.cost(preset)?;
        let seq_ids: Vec<usize> = (0..batch).collect();
        Ok(crate::coordinator::simrun::replay_decode(
            trace,
            &seq_ids,
            steps,
            &cost,
            bundle,
            &calib.freq,
            model.sim.n_shared,
            7,
        ))
    }

    /// Replay prefill with an explicit framework.
    pub fn prefill(&self, preset: &str, fw: Framework, batch: usize) -> Result<RunMetrics> {
        let trace = self.trace_c4(preset)?;
        self.prefill_traced(preset, fw, &trace, batch)
    }

    /// A custom-component bundle for ablations (greedy base).
    pub fn bundle_parts(
        &self,
        dims: &ModelDims,
        assigner: Box<dyn Assigner>,
        prefetcher: Box<dyn Prefetcher>,
        cache: Box<dyn ExpertCache>,
        prefetch_size: usize,
    ) -> PolicyBundle {
        PolicyBundle {
            assigner,
            prefetcher,
            cache,
            prefetch_size,
            cpu_eff: 1.0,
            layer_overhead_ns: 0,
            gpu_free_slots: dims.n_routed,
            solve_cost: Default::default(),
            placement: Default::default(),
        }
    }
}

/// Which prediction signal to score for accuracy experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredKind {
    /// EdgeMoE: calibration activation frequency (static ranking).
    Statistical,
    /// HybriMoE: raw-feature gate of the next layer.
    Feature,
    /// DALI: residual-corrected features.
    Residual,
}

/// Top-`j` prefetch accuracy over a composed batch replay (paper Table 2 /
/// Fig. 16b metric): at every (step, layer<L-1), compare the predictor's
/// top-j experts against the true top-j *highest-workload* experts of the
/// next layer; accuracy = |intersection| / j, averaged.
pub fn prefetch_accuracy(
    trace: &Trace,
    calib: &CalibData,
    seq_ids: &[usize],
    steps: usize,
    kind: PredKind,
    top_j: usize,
) -> f64 {
    let n = trace.n_routed;
    let mut total = 0.0;
    let mut count = 0usize;
    let max_steps = steps.min(trace.min_steps());
    // All buffers are hoisted out of the (step, layer) loop and reused —
    // this routine scores thousands of cells per table.
    let mut step = crate::workload::trace::BatchStep::default();
    let mut pred_scores = vec![0.0f64; n];
    let mut truth_scores = vec![0.0f64; n];
    let mut pred = Vec::with_capacity(n);
    let mut want = Vec::with_capacity(n);
    for s in 0..max_steps {
        trace.compose_decode_into(seq_ids, s, &mut step);
        if step.tokens == 0 {
            continue;
        }
        for l in 0..trace.layers.saturating_sub(1) {
            let truth = &step.layers[l + 1].workloads;
            if truth.iter().all(|&w| w == 0) {
                continue;
            }
            pred_scores.iter_mut().for_each(|d| *d = 0.0);
            match kind {
                PredKind::Statistical => {
                    for (d, &f) in pred_scores.iter_mut().zip(&calib.freq[l + 1]) {
                        *d = f;
                    }
                }
                PredKind::Feature => {
                    for (d, &c) in pred_scores.iter_mut().zip(&step.layers[l].pred_raw) {
                        *d = c as f64;
                    }
                }
                PredKind::Residual => {
                    for (d, &c) in pred_scores.iter_mut().zip(&step.layers[l].pred_res) {
                        *d = c as f64;
                    }
                }
            }
            top_n_into(&pred_scores, top_j, &mut pred);
            for (d, &w) in truth_scores.iter_mut().zip(truth) {
                *d = w as f64;
            }
            top_n_into(&truth_scores, top_j, &mut want);
            let hit = pred.iter().filter(|e| want.contains(e)).count();
            total += hit as f64 / top_j as f64;
            count += 1;
        }
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

/// Format a ratio as `x.xx×`.
pub fn times(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format a fraction as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

/// Geometric-mean speedup of `a` over `b` element-wise.
pub fn avg_speedup(dali: &[f64], base: &[f64]) -> f64 {
    let ratios: Vec<f64> =
        dali.iter().zip(base).filter(|(_, &b)| b > 0.0).map(|(&d, &b)| d / b).collect();
    if ratios.is_empty() {
        return 0.0;
    }
    ratios.iter().sum::<f64>() / ratios.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{LayerStepRecord, PrefillLayerRecord, SeqTrace};

    fn mk_trace() -> Trace {
        // layer 0 predicts layer 1; truth at layer 1 = expert 2 heavy.
        let rec_l0 = LayerStepRecord {
            topk: vec![0],
            topk_scores: vec![1.0],
            pred_raw: vec![1], // wrong prediction
            pred_res: vec![2], // right prediction
            cos_raw: 0.5,
            cos_res: 0.9,
        };
        let rec_l1 = LayerStepRecord {
            topk: vec![2],
            topk_scores: vec![1.0],
            pred_raw: vec![],
            pred_res: vec![],
            cos_raw: 0.0,
            cos_res: 0.0,
        };
        let pre = PrefillLayerRecord {
            counts: vec![0; 4],
            gate_scores: vec![0.0; 4],
            pred_raw: vec![0; 4],
            pred_res: vec![0; 4],
        };
        Trace {
            preset: "t".into(),
            task: "t".into(),
            n_routed: 4,
            top_k: 1,
            layers: 2,
            seqs: vec![SeqTrace {
                prompt_len: 1,
                prefill: vec![pre.clone(), pre],
                steps: vec![vec![rec_l0, rec_l1]],
            }],
        }
    }

    #[test]
    fn accuracy_distinguishes_predictors() {
        let t = mk_trace();
        let calib = CalibData {
            preset: "t".into(),
            tokens: 1,
            res_vec: vec![],
            freq: vec![vec![0.0; 4], vec![0.9, 0.0, 0.0, 0.0]],
        };
        let res = prefetch_accuracy(&t, &calib, &[0], 1, PredKind::Residual, 1);
        let raw = prefetch_accuracy(&t, &calib, &[0], 1, PredKind::Feature, 1);
        let stat = prefetch_accuracy(&t, &calib, &[0], 1, PredKind::Statistical, 1);
        assert!((res - 1.0).abs() < 1e-9);
        assert!(raw.abs() < 1e-9);
        assert!(stat.abs() < 1e-9, "freq ranks expert 0, truth is 2");
    }

    #[test]
    fn helpers_format() {
        assert_eq!(times(1.5), "1.50x");
        assert_eq!(pct(0.253), "25.3%");
        assert!((avg_speedup(&[2.0, 4.0], &[1.0, 2.0]) - 2.0).abs() < 1e-9);
    }
}
