//! Overall results (paper §6.2): Fig. 12 (decode) and Fig. 13 (prefill).

use anyhow::Result;

use super::common::*;
use crate::coordinator::frameworks::Framework;
use crate::util::Table;

/// Fig. 12: decoding speed across frameworks, models, batch sizes.
pub fn fig12(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 12 — decoding speed (simulated tokens/s)\n\n");
    let frameworks = Framework::comparison_set();
    let mut speedups: Vec<(Framework, Vec<f64>)> =
        frameworks.iter().map(|&f| (f, vec![])).collect();
    // every (model, batch, framework) cell replays independently; each
    // preset's trace is loaded from disk once and shared across its cells
    ctx.prewarm(&MODELS)?;
    let traces = MODELS.iter().map(|p| ctx.trace_c4(p)).collect::<Result<Vec<_>>>()?;
    let mut cells = Vec::new();
    for (pi, preset) in MODELS.iter().enumerate() {
        for &b in &BATCHES {
            for &fw in &frameworks {
                cells.push((pi, *preset, b, fw));
            }
        }
    }
    // results come back paired with their cells so the two loop nests can
    // never silently misattribute a replay to the wrong table cell
    let mut metrics = ctx.parallel_cells(cells, |(pi, preset, b, fw)| {
        ctx.decode_traced(preset, fw, &traces[pi], b, STEPS)
    });
    for (pi, preset) in MODELS.iter().enumerate() {
        let mut t = Table::new(vec!["batch", "llama.cpp", "ktransformers", "moe-lightning", "hybrimoe", "dali"]);
        for &b in &BATCHES {
            let mut row = vec![format!("BS{b}")];
            let mut tps = vec![];
            for &fw in &frameworks {
                let (cell, m) = metrics.next().expect("one result per cell");
                assert_eq!(cell, (pi, *preset, b, fw), "cell order diverged");
                let m = m?;
                tps.push(m.tokens_per_s());
                row.push(format!("{:.2}", m.tokens_per_s()));
            }
            let dali = *tps.last().unwrap();
            for (i, (_, v)) in speedups.iter_mut().enumerate() {
                v.push(dali / tps[i].max(1e-9));
            }
            t.row(row);
        }
        out.push_str(&format!("**{preset}**\n\n{}\n", t.render()));
    }
    let mut t = Table::new(vec!["DALI speedup over", "average", "paper"]);
    let paper = [("llama.cpp", "3.97x"), ("ktransformers", "2.16x"), ("moe-lightning", "1.48x"), ("hybrimoe", "1.32x")];
    for (i, (fw, v)) in speedups.iter().enumerate() {
        if *fw == Framework::Dali {
            continue;
        }
        let avg = v.iter().sum::<f64>() / v.len() as f64;
        t.row(vec![fw.name().to_string(), times(avg), paper[i].1.to_string()]);
    }
    out.push_str(&format!("**average DALI speedups**\n\n{}\n", t.render()));
    Ok(out)
}

/// Fig. 13: prefill speed on DeepSeek across batch sizes.
pub fn fig13(ctx: &ExptCtx) -> Result<String> {
    let preset = "deepseek-sim";
    let mut out = String::from("## Fig. 13 — prefill speed on DeepSeek (simulated tokens/s)\n\n");
    let frameworks = Framework::comparison_set();
    let mut t = Table::new(vec!["batch", "llama.cpp", "ktransformers", "moe-lightning", "hybrimoe", "dali"]);
    let mut speedups: Vec<Vec<f64>> = vec![vec![]; frameworks.len()];
    ctx.prewarm(&[preset])?;
    let trace = ctx.trace_c4(preset)?;
    let batches = [1usize, 8, 16, 32, 64];
    let mut cells = Vec::new();
    for &b in &batches {
        for &fw in &frameworks {
            cells.push((b, fw));
        }
    }
    let mut metrics =
        ctx.parallel_cells(cells, |(b, fw)| ctx.prefill_traced(preset, fw, &trace, b));
    for &b in &batches {
        let mut row = vec![format!("BS{b}")];
        let mut tps = vec![];
        for &fw in &frameworks {
            let (cell, m) = metrics.next().expect("one result per cell");
            assert_eq!(cell, (b, fw), "cell order diverged");
            let m = m?;
            tps.push(m.tokens_per_s());
            row.push(format!("{:.1}", m.tokens_per_s()));
        }
        let dali = *tps.last().unwrap();
        for (i, v) in speedups.iter_mut().enumerate() {
            v.push(dali / tps[i].max(1e-9));
        }
        t.row(row);
    }
    out.push_str(&t.render());
    let mut s = Table::new(vec!["DALI speedup over", "average", "paper"]);
    let paper = [("llama.cpp", "7.62x"), ("ktransformers", "3.80x"), ("moe-lightning", "2.45x"), ("hybrimoe", "2.00x")];
    for (i, &fw) in frameworks.iter().enumerate() {
        if fw == Framework::Dali {
            continue;
        }
        let avg = speedups[i].iter().sum::<f64>() / speedups[i].len() as f64;
        s.row(vec![fw.name().to_string(), times(avg), paper[i].1.to_string()]);
    }
    out.push_str(&format!("\n**average DALI speedups (prefill)**\n\n{}\n", s.render()));
    Ok(out)
}
