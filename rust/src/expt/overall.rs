//! Overall results (paper §6.2): Fig. 12 (decode) and Fig. 13 (prefill).

use anyhow::Result;

use super::common::*;
use crate::coordinator::frameworks::Framework;
use crate::util::Table;

/// Fig. 12: decoding speed across frameworks, models, batch sizes.
pub fn fig12(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 12 — decoding speed (simulated tokens/s)\n\n");
    let frameworks = Framework::comparison_set();
    let mut speedups: Vec<(Framework, Vec<f64>)> =
        frameworks.iter().map(|&f| (f, vec![])).collect();
    for preset in MODELS {
        let mut t = Table::new(vec!["batch", "llama.cpp", "ktransformers", "moe-lightning", "hybrimoe", "dali"]);
        for &b in &BATCHES {
            let mut row = vec![format!("BS{b}")];
            let mut tps = vec![];
            for &fw in &frameworks {
                let m = ctx.decode(preset, fw, b, STEPS)?;
                tps.push(m.tokens_per_s());
                row.push(format!("{:.2}", m.tokens_per_s()));
            }
            let dali = *tps.last().unwrap();
            for (i, (_, v)) in speedups.iter_mut().enumerate() {
                v.push(dali / tps[i].max(1e-9));
            }
            t.row(row);
        }
        out.push_str(&format!("**{preset}**\n\n{}\n", t.render()));
    }
    let mut t = Table::new(vec!["DALI speedup over", "average", "paper"]);
    let paper = [("llama.cpp", "3.97x"), ("ktransformers", "2.16x"), ("moe-lightning", "1.48x"), ("hybrimoe", "1.32x")];
    for (i, (fw, v)) in speedups.iter().enumerate() {
        if *fw == Framework::Dali {
            continue;
        }
        let avg = v.iter().sum::<f64>() / v.len() as f64;
        t.row(vec![fw.name().to_string(), times(avg), paper[i].1.to_string()]);
    }
    out.push_str(&format!("**average DALI speedups**\n\n{}\n", t.render()));
    Ok(out)
}

/// Fig. 13: prefill speed on DeepSeek across batch sizes.
pub fn fig13(ctx: &ExptCtx) -> Result<String> {
    let preset = "deepseek-sim";
    let mut out = String::from("## Fig. 13 — prefill speed on DeepSeek (simulated tokens/s)\n\n");
    let frameworks = Framework::comparison_set();
    let mut t = Table::new(vec!["batch", "llama.cpp", "ktransformers", "moe-lightning", "hybrimoe", "dali"]);
    let mut speedups: Vec<Vec<f64>> = vec![vec![]; frameworks.len()];
    for &b in &[1usize, 8, 16, 32, 64] {
        let mut row = vec![format!("BS{b}")];
        let mut tps = vec![];
        for &fw in &frameworks {
            let m = ctx.prefill(preset, fw, b)?;
            tps.push(m.tokens_per_s());
            row.push(format!("{:.1}", m.tokens_per_s()));
        }
        let dali = *tps.last().unwrap();
        for (i, v) in speedups.iter_mut().enumerate() {
            v.push(dali / tps[i].max(1e-9));
        }
        t.row(row);
    }
    out.push_str(&t.render());
    let mut s = Table::new(vec!["DALI speedup over", "average", "paper"]);
    let paper = [("llama.cpp", "7.62x"), ("ktransformers", "3.80x"), ("moe-lightning", "2.45x"), ("hybrimoe", "2.00x")];
    for (i, &fw) in frameworks.iter().enumerate() {
        if fw == Framework::Dali {
            continue;
        }
        let avg = speedups[i].iter().sum::<f64>() / speedups[i].len() as f64;
        s.row(vec![fw.name().to_string(), times(avg), paper[i].1.to_string()]);
    }
    out.push_str(&format!("\n**average DALI speedups (prefill)**\n\n{}\n", s.render()));
    Ok(out)
}
