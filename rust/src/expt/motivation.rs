//! Motivation experiments (paper §3): Figs. 4-8 and Table 2.

use anyhow::Result;

use super::common::*;
use crate::coordinator::assignment::StaticThresholdAssigner;
use crate::coordinator::cache::{LruCache, NoCache, ScoreCache};
use crate::coordinator::frameworks::Framework;
use crate::coordinator::prefetch::{FeaturePrefetcher, NoPrefetcher};
use crate::metrics::RunMetrics;
use crate::util::Table;

/// Fig. 4: execution time of CPU- vs GPU-assigned experts under the static
/// expert-wise policy (Fiddler) — the load-imbalance motivation.
pub fn fig4(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 4 — CPU vs GPU execution time, static assignment\n\n");
    out.push_str("Static per-expert placement (Fiddler policy, no cache/prefetch); decode, 32 steps.\nImbalance = max(CPU,GPU) / min(CPU,GPU) busy time — the paper's motivation for dynamic assignment.\n\n");
    for preset in ["deepseek-sim", "qwen-sim"] {
        let model = ctx.model(preset)?;
        let dims = model.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let mut t = Table::new(vec!["batch", "CPU busy (s)", "GPU busy (s)", "imbalance"]);
        for &b in &BATCHES {
            let bundle = ctx.bundle_parts(
                &dims,
                Box::new(StaticThresholdAssigner::new()),
                Box::new(NoPrefetcher),
                Box::new(NoCache::new(dims.layers, dims.n_routed)),
                0,
            );
            let m = ctx.decode_with(preset, bundle, &trace, b, 32)?;
            let cpu = m.moe_cpu_busy_ns as f64 / 1e9;
            let gpu = m.moe_gpu_busy_ns as f64 / 1e9;
            let imb = cpu.max(gpu) / cpu.min(gpu).max(1e-9);
            let imb_s = if imb > 1000.0 { ">1000x".to_string() } else { format!("{imb:.1}x") };
            t.row(vec![b.to_string(), format!("{cpu:.3}"), format!("{gpu:.3}"), imb_s]);
        }
        out.push_str(&format!("**{preset}**\n\n{}\n", t.render()));
    }
    Ok(out)
}

/// Fig. 5: PCIe transfer time as a share of total inference time,
/// HybriMoE vs DALI, across batch sizes.
pub fn fig5(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 5 — PCIe share of inference time\n\n");
    let mut t = Table::new(vec!["model", "batch", "HybriMoE", "DALI"]);
    let (mut h_sum, mut d_sum, mut n) = (0.0, 0.0, 0);
    ctx.prewarm(&MODELS)?;
    let traces = MODELS.iter().map(|p| ctx.trace_c4(p)).collect::<Result<Vec<_>>>()?;
    let mut cells = Vec::new();
    for (pi, preset) in MODELS.iter().enumerate() {
        for &b in &BATCHES {
            for fw in [Framework::HybriMoE, Framework::Dali] {
                cells.push((pi, *preset, b, fw));
            }
        }
    }
    let mut metrics = ctx.parallel_cells(cells, |(pi, preset, b, fw)| {
        ctx.decode_traced(preset, fw, &traces[pi], b, 32)
    });
    let mut next_cell = |preset: &str, b: usize, fw: Framework| -> Result<RunMetrics> {
        let ((_, p, bb, f), m) = metrics.next().expect("one result per cell");
        assert_eq!((p, bb, f), (preset, b, fw), "cell order diverged");
        m
    };
    for preset in MODELS {
        for &b in &BATCHES {
            let h = next_cell(preset, b, Framework::HybriMoE)?;
            let d = next_cell(preset, b, Framework::Dali)?;
            h_sum += h.pcie_time_share();
            d_sum += d.pcie_time_share();
            n += 1;
            t.row(vec![
                preset.to_string(),
                format!("BS{b}"),
                pct(h.pcie_time_share()),
                pct(d.pcie_time_share()),
            ]);
        }
    }
    t.row(vec![
        "**average**".into(),
        "".into(),
        pct(h_sum / n as f64),
        pct(d_sum / n as f64),
    ]);
    out.push_str(&t.render());
    out.push_str(
        "\nPaper reports PCIe up to 78.1% of hybrid execution (HybriMoE), reduced by DALI.\n\
         Deviation note: in our calibrated regime (t_cpu ≈ trans_time on Mixtral), DALI's\n\
         greedy assignment deliberately *spends* PCIe bandwidth to offload the CPU —\n\
         transfers overlap compute per Eq. 5 — so its demand-transfer share of (much\n\
         shorter) total time is higher even though end-to-end latency is lower (Fig. 12).\n\
         The paper's direction holds for the motivation case: hybrid execution without\n\
         DALI's cache/prefetch is transfer-bound at large batch.\n",
    );
    Ok(out)
}

/// Table 2: accuracy of predicting the top-k *highest-workload* experts.
pub fn table2(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Table 2 — prefetch accuracy for high-workload experts\n\n");
    for preset in ["deepseek-sim", "mixtral-sim"] {
        let trace = ctx.trace_c4(preset)?;
        let calib = ctx.calib(preset)?;
        let mut t = Table::new(vec!["topk", "method", "BS8", "BS16", "BS32", "BS64"]);
        for top_j in [1usize, 2] {
            for (name, kind) in [
                ("EdgeMoE", PredKind::Statistical),
                ("HybriMoE", PredKind::Feature),
                ("DALI", PredKind::Residual),
            ] {
                let mut row = vec![format!("Topk={top_j}"), name.to_string()];
                for &b in &BATCHES {
                    let ids: Vec<usize> = (0..b).collect();
                    let acc = prefetch_accuracy(&trace, &calib, &ids, 48, kind, top_j);
                    row.push(pct(acc));
                }
                t.row(row);
            }
        }
        out.push_str(&format!("**{preset}**\n\n{}\n", t.render()));
    }
    out.push_str("Expected shape: statistical < raw-feature < residual-corrected (paper adds DALI in Fig. 16b).\n");
    Ok(out)
}

/// Fig. 6: speedup delivered by HybriMoE's own (feature-based) prefetching
/// over no prefetching, inside the HybriMoE framework.
pub fn fig6(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 6 — HybriMoE prefetching speedup vs no prefetching\n\n");
    let mut t = Table::new(vec!["model", "BS8", "BS16", "BS32", "BS64"]);
    for preset in ["deepseek-sim", "mixtral-sim"] {
        let model = ctx.model(preset)?;
        let dims = model.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let cfg = ctx.fwcfg(preset)?;
        let mut row = vec![preset.to_string()];
        for &b in &BATCHES {
            let mk = |prefetch: bool| {
                ctx.bundle_parts(
                    &dims,
                    Box::new(StaticThresholdAssigner::new()),
                    if prefetch { Box::new(FeaturePrefetcher) } else { Box::new(NoPrefetcher) },
                    Box::new(ScoreCache::new(dims.layers, dims.n_routed, cfg.cache_size, cfg.seed)),
                    if prefetch { cfg.prefetch_size } else { 0 },
                )
            };
            let with = ctx.decode_with(preset, mk(true), &trace, b, 32)?.tokens_per_s();
            let without = ctx.decode_with(preset, mk(false), &trace, b, 32)?.tokens_per_s();
            row.push(times(with / without.max(1e-9)));
        }
        t.row(row);
    }
    out.push_str(&t.render());
    out.push_str("\nPaper finds these gains marginal (low accuracy + prediction overhead) — expect ~1.0-1.1x.\n");
    Ok(out)
}

/// Fig. 7: cache hit rates of LRU vs score-based replacement vs cache size.
pub fn fig7(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 7 — LRU / score-cache hit rates vs cache size\n\n");
    for preset in ["deepseek-sim", "mixtral-sim"] {
        let model = ctx.model(preset)?;
        let dims = model.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let sizes: Vec<usize> = [8usize, 4, 2]
            .iter()
            .map(|f| (dims.n_routed / f).max(1))
            .collect();
        let mut t = Table::new(vec!["cache size", "LRU", "HybriMoE (score)"]);
        for &cs in &sizes {
            let lru = ctx.bundle_parts(
                &dims,
                Box::new(StaticThresholdAssigner::new()),
                Box::new(NoPrefetcher),
                Box::new(LruCache::new(dims.layers, dims.n_routed, cs, 11)),
                0,
            );
            let score = ctx.bundle_parts(
                &dims,
                Box::new(StaticThresholdAssigner::new()),
                Box::new(NoPrefetcher),
                Box::new(ScoreCache::new(dims.layers, dims.n_routed, cs, 11)),
                0,
            );
            let ml = ctx.decode_with(preset, lru, &trace, 4, STEPS)?;
            let ms = ctx.decode_with(preset, score, &trace, 4, STEPS)?;
            t.row(vec![
                format!("{cs}/{}", dims.n_routed),
                pct(ml.cache_hit_rate()),
                pct(ms.cache_hit_rate()),
            ]);
        }
        out.push_str(&format!("**{preset}** (batch 4)\n\n{}\n", t.render()));
    }
    out.push_str("Both ignore workload; paper reports e.g. 25.3% for HybriMoE on Mixtral.\n");
    Ok(out)
}

/// Fig. 8: correlation of high-workload experts between adjacent tokens.
pub fn fig8(ctx: &ExptCtx) -> Result<String> {
    let preset = "mixtral-sim";
    let trace = ctx.trace_wikitext(preset)?;
    let n = trace.n_routed;
    let high = 3usize; // top-3 by workload, as in the paper
    let ids: Vec<usize> = (0..8).collect();
    let mut out = String::from(
        "## Fig. 8 — adjacent-token high-workload correlation (mixtral-sim)\n\nCell (m, n) = count of (expert m high-workload at token i) ∧ (expert n high at i+1).\nA strong diagonal = temporal locality, the basis of Workload-Aware caching.\n\n",
    );
    for layer in 0..trace.layers {
        let mut mat = vec![vec![0u32; n]; n];
        let steps = trace.min_steps();
        let mut prev_high: Option<Vec<usize>> = None;
        for s in 0..steps {
            let step = trace.compose_decode(&ids, s);
            let w: Vec<f64> = step.layers[layer].workloads.iter().map(|&x| x as f64).collect();
            let cur = crate::coordinator::prefetch::top_n(&w, high);
            if let Some(prev) = prev_high {
                for &m in &prev {
                    for &nn in &cur {
                        mat[m][nn] += 1;
                    }
                }
            }
            prev_high = Some(cur);
        }
        let total: u32 = mat.iter().flatten().sum();
        let diag: u32 = (0..n).map(|i| mat[i][i]).sum();
        out.push_str(&format!(
            "layer {layer}: diagonal mass = {} (uniform baseline would be {})\n\n```\n",
            pct(diag as f64 / total.max(1) as f64),
            pct(1.0 / n as f64)
        ));
        for m in 0..n {
            let row: Vec<String> = (0..n).map(|c| format!("{:3}", mat[m][c])).collect();
            out.push_str(&format!("  {}\n", row.join(" ")));
        }
        out.push_str("```\n\n");
    }
    Ok(out)
}
