//! Serving SLO curves: the serving-level view of the paper's claim.
//! Per-request TTFT/TPOT percentiles vs offered load, swept across host
//! RAM budgets and policy bundles, with a fault-profile composition cell
//! — all on the multi-tenant continuous-batching simulation
//! ([`crate::serve::sim`]), where every request stream contends for one
//! shared virtual-time pipeline.

use anyhow::{ensure, Result};

use super::common::*;
use crate::coordinator::frameworks::Framework;
use crate::fault::FaultPlan;
use crate::hw::Ns;
use crate::metrics::ServeReport;
use crate::serve::{simulate_serve, ServeSimCfg};
use crate::util::Table;

const N_REQUESTS: usize = 48;
const MAX_BATCH: usize = 8;
const MAX_TOKENS: usize = 16;

fn ms(ns: Ns) -> String {
    format!("{:.3}", ns as f64 / 1e6)
}

fn digest(r: &ServeReport) -> String {
    format!("0x{:016x}", r.run.trace_digest.unwrap_or(0))
}

/// The `expt serve` sweep: load (req/s) × RAM budget × policy SLO grid,
/// plus a fault-profile composition row and an in-run determinism check.
pub fn slo_curves(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from(
        "## Serving SLO curves — TTFT/TPOT vs offered load × RAM budget × policy\n\n\
         Multi-tenant continuous-batching simulation: seeded Poisson arrivals share one \
         virtual-time pipeline (GPU cache, tiered expert store, NVMe/PCIe/transcode lanes); \
         48 requests, 8 batch slots, 16 decode tokens per request. Latencies are virtual \
         milliseconds, percentiles nearest-rank over per-request samples; every cell is \
         digest-locked (same seed \u{21d2} bit-identical report).\n\n",
    );
    let scenarios = ["mixtral-sim", "mixtral-sim-ram16", "mixtral-sim-ram8"];
    let loads = [2.0, 8.0, 32.0];
    let policies = [Framework::Dali, Framework::HybriMoE];
    let arrival = ctx.presets.arrival("steady-poisson")?;
    let cell_cfg = |load: f64| ServeSimCfg {
        arrival: arrival.with_rate(load),
        n_requests: N_REQUESTS,
        max_batch: MAX_BATCH,
        max_tokens: MAX_TOKENS,
        ..Default::default()
    };
    let mut cells: Vec<(usize, usize, usize)> = Vec::new();
    for si in 0..scenarios.len() {
        for li in 0..loads.len() {
            for fi in 0..policies.len() {
                cells.push((si, li, fi));
            }
        }
    }
    let presets = &ctx.presets;
    let mut results = ctx.parallel_cells(cells, |(si, li, fi)| -> Result<ServeReport> {
        simulate_serve(presets, scenarios[si], policies[fi], &cell_cfg(loads[li]), None)
    });
    let mut first: Option<ServeReport> = None;
    for scenario in scenarios {
        let mut t = Table::new(vec![
            "load req/s",
            "policy",
            "TTFT p50 ms",
            "TTFT p99 ms",
            "TPOT p50 ms",
            "TPOT p99 ms",
            "queue p99 ms",
            "tok/s",
            "digest",
        ]);
        for &load in &loads {
            for fw in policies {
                let (_, r) = results.next().expect("one report per cell");
                let r = r?;
                ensure!(
                    r.requests == N_REQUESTS as u64,
                    "cell lost requests: {}/{N_REQUESTS}",
                    r.requests
                );
                t.row(vec![
                    format!("{load:.0}"),
                    fw.name().to_string(),
                    ms(r.ttft_p50_ns),
                    ms(r.ttft_p99_ns),
                    ms(r.tpot_p50_ns),
                    ms(r.tpot_p99_ns),
                    ms(r.queue_p99_ns),
                    format!("{:.2}", r.tokens_per_s()),
                    digest(&r),
                ]);
                if first.is_none() {
                    first = Some(r);
                }
            }
        }
        out.push_str(&format!("**{scenario}**\n\n{}\n", t.render()));
    }
    // determinism self-check: replay the grid's first cell and require a
    // bit-identical report
    let again = simulate_serve(
        presets,
        scenarios[0],
        policies[0],
        &cell_cfg(loads[0]),
        None,
    )?;
    let first = first.expect("grid produced at least one cell");
    ensure!(
        again == first,
        "same-seed serve cell was not bit-identical: {} vs {}",
        digest(&again),
        digest(&first)
    );
    out.push_str("Same-seed determinism check: first cell replayed bit-identical.\n\n");
    // fault composition: the serving view of a flaky NVMe under the
    // tightest RAM budget
    let faulted_scenario = "mixtral-sim-ram8";
    let plan = FaultPlan::new(presets.fault_profile("flaky-nvme")?, 0xfa17);
    let clean = simulate_serve(presets, faulted_scenario, Framework::Dali, &cell_cfg(8.0), None)?;
    let faulted = simulate_serve(
        presets,
        faulted_scenario,
        Framework::Dali,
        &cell_cfg(8.0),
        Some(plan),
    )?;
    let mut t = Table::new(vec![
        "faults",
        "TTFT p99 ms",
        "TPOT p99 ms",
        "tok/s",
        "digest",
    ]);
    for (name, r) in [("clean", &clean), ("flaky-nvme", &faulted)] {
        t.row(vec![
            name.to_string(),
            ms(r.ttft_p99_ns),
            ms(r.tpot_p99_ns),
            format!("{:.2}", r.tokens_per_s()),
            digest(r),
        ]);
    }
    out.push_str(&format!(
        "**fault composition — {faulted_scenario}, DALI, load 8 req/s**\n\n{}\n\
         Expected shape: TTFT/TPOT tails grow with load (slot contention) and with shrinking \
         host RAM (shared-store thrash across tenants); DALI's bundle holds the tail down vs \
         the baseline policy; NVMe faults surface as a TPOT-tail tax, not a crash.\n\n",
        t.render()
    ));
    out.push_str(&overload_sweep(ctx)?);
    Ok(out)
}

/// The overload grid: offered load × SLO policy × fault profile on the
/// memory-limited scenario. `observe` scores the same deadlines as
/// `tight` without acting (digest-identical to unguarded), so each row
/// pair reads directly as guarded-vs-unguarded at equal traffic.
fn overload_sweep(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from(
        "## Overload protection — SLO policy \u{d7} load \u{d7} faults\n\n\
         Bursty arrivals on mixtral-sim-ram16, 32 requests into 4 slots. `observe` stamps \
         the tight deadlines but never intervenes; `tight` arms admission control, deadline \
         load-shedding, and the degradation ladder. Attainment counts requests finishing \
         within both TTFT and completion budgets; goodput counts only their tokens.\n\n",
    );
    let scenario = "mixtral-sim-ram16";
    let loads = [8.0, 256.0];
    let slos = ["observe", "tight"];
    let fault_names = ["clean", "flaky-nvme"];
    let arrival = ctx.presets.arrival("bursty-mixed")?;
    let presets = &ctx.presets;
    let cell_cfg = |load: f64, slo: &str| -> Result<ServeSimCfg> {
        Ok(ServeSimCfg {
            arrival: arrival.with_rate(load),
            n_requests: 32,
            max_batch: 4,
            max_tokens: MAX_TOKENS,
            slo: presets.slo(slo)?,
            ..Default::default()
        })
    };
    let mut cells: Vec<(usize, usize, usize)> = Vec::new();
    for li in 0..loads.len() {
        for si in 0..slos.len() {
            for fi in 0..fault_names.len() {
                cells.push((li, si, fi));
            }
        }
    }
    let mut results = ctx.parallel_cells(cells, |(li, si, fi)| -> Result<ServeReport> {
        let plan = match fault_names[fi] {
            "clean" => None,
            name => Some(FaultPlan::new(presets.fault_profile(name)?, 0xfa17)),
        };
        simulate_serve(presets, scenario, Framework::Dali, &cell_cfg(loads[li], slos[si])?, plan)
    });
    let mut t = Table::new(vec![
        "load req/s",
        "slo",
        "faults",
        "fin/rej/evt",
        "attain %",
        "goodput tok/s",
        "TTFT p99 ms",
        "degraded ms",
        "digest",
    ]);
    for &load in &loads {
        for slo in slos {
            for fault in fault_names {
                let (_, r) = results.next().expect("one report per overload cell");
                let r = r?;
                ensure!(
                    r.finished + r.rejected + r.evicted == r.requests,
                    "overload cell leaked requests: {}+{}+{} != {} \
                     (load {load}, slo {slo}, faults {fault})",
                    r.finished,
                    r.rejected,
                    r.evicted,
                    r.requests
                );
                t.row(vec![
                    format!("{load:.0}"),
                    slo.to_string(),
                    fault.to_string(),
                    format!("{}/{}/{}", r.finished, r.rejected, r.evicted),
                    format!("{:.1}", 100.0 * r.slo_attainment()),
                    format!("{:.2}", r.goodput_per_s()),
                    ms(r.ttft_p99_ns),
                    ms(r.degraded_ns),
                    digest(&r),
                ]);
            }
        }
    }
    out.push_str(&t.render());
    out.push_str(
        "\nExpected shape: under light load the two policies agree (nothing to shed); under \
         burst overload `tight` trades a few rejections/evictions for higher attainment and \
         a lower accepted-TTFT tail than `observe`, and time-in-degraded-mode appears only \
         where the ladder actually engaged.\n",
    );
    Ok(out)
}
