//! Appendix experiments: Figs. 20-22 and Tables 5-8.

use anyhow::Result;

use super::common::*;
use crate::coordinator::assignment::{BeamAssigner, EnumerateAssigner, GreedyAssigner};
use crate::coordinator::frameworks::Framework;
use crate::hw::GpuMemModel;
use crate::util::Table;
use crate::workload::prep;

/// Fig. 20 (A.1): CPU vs GPU MoE execution time, HybriMoE vs DALI.
pub fn fig20(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 20 (A.1) — MoE execution balance, HybriMoE vs DALI\n\n");
    let mut t = Table::new(vec![
        "model", "batch", "HybriMoE CPU(s)", "HybriMoE GPU(s)", "DALI CPU(s)", "DALI GPU(s)", "moe time ratio",
    ]);
    for preset in ["deepseek-sim", "mixtral-sim"] {
        for &b in &[16usize, 64] {
            let h = ctx.decode(preset, Framework::HybriMoE, b, 32)?;
            let d = ctx.decode(preset, Framework::Dali, b, 32)?;
            t.row(vec![
                preset.to_string(),
                format!("BS{b}"),
                format!("{:.3}", h.moe_cpu_busy_ns as f64 / 1e9),
                format!("{:.3}", h.moe_gpu_busy_ns as f64 / 1e9),
                format!("{:.3}", d.moe_cpu_busy_ns as f64 / 1e9),
                format!("{:.3}", d.moe_gpu_busy_ns as f64 / 1e9),
                times(h.moe_ns as f64 / d.moe_ns.max(1) as f64),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push_str("\nDALI narrows the CPU/GPU busy-time gap and lowers overall MoE latency.\n");
    Ok(out)
}

/// Fig. 21 (A.2): optimal vs greedy vs beam — MoE time and plan overhead.
pub fn fig21(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Fig. 21 (A.2) — scheduling algorithms: MoE time + plan overhead\n\n");
    let mut t = Table::new(vec!["model", "algorithm", "MoE time (s)", "plan overhead (s)", "tok/s"]);
    for preset in ["deepseek-sim", "mixtral-sim"] {
        let dims = ctx.model(preset)?.sim.clone();
        let trace = ctx.trace_c4(preset)?;
        let algos: Vec<(&str, Box<dyn crate::coordinator::assignment::Assigner>)> = vec![
            ("opt_plan", Box::new(EnumerateAssigner::new())),
            ("greedy", Box::new(GreedyAssigner::new())),
            ("beam(2)", Box::new(BeamAssigner::new(2))),
        ];
        for (name, assigner) in algos {
            let bundle = ctx.bundle_parts(
                &dims,
                assigner,
                Box::new(crate::coordinator::prefetch::NoPrefetcher),
                Box::new(crate::coordinator::cache::NoCache::new(dims.layers, dims.n_routed)),
                0,
            );
            let m = ctx.decode_with(preset, bundle, &trace, 32, 32)?;
            t.row(vec![
                preset.to_string(),
                name.to_string(),
                format!("{:.3}", m.moe_ns as f64 / 1e9),
                format!("{:.4}", m.sched_ns as f64 / 1e9),
                format!("{:.2}", m.tokens_per_s()),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push_str("\nBeam can edge out greedy on MoE time but pays multi-beam solve overhead (paper A.2).\n");
    Ok(out)
}

/// Fig. 22 (A.7): decoding speed across decode lengths (mixtral, batch 16).
/// Paper lengths 128-1024 are scaled to 32-256 to match the sim max_seq.
pub fn fig22(ctx: &ExptCtx) -> Result<String> {
    let preset = "mixtral-sim";
    let trace = prep::ensure_trace(preset, "c4-sim", 8, 16, 256)?;
    let model = ctx.model(preset)?;
    let cost = ctx.cost(preset)?;
    let calib = ctx.calib(preset)?;
    let cfg = ctx.fwcfg(preset)?;
    let mut out = String::from(
        "## Fig. 22 (A.7) — decode-length sweep (mixtral-sim, batch 16; paper lengths scaled /4)\n\n",
    );
    let frameworks = [
        Framework::LlamaCpp,
        Framework::KTransformers,
        Framework::HybriMoE,
        Framework::Dali,
    ];
    let mut t = Table::new(vec!["decode len", "llama.cpp", "ktransformers", "hybrimoe", "dali"]);
    let mut speedups = vec![vec![]; 3];
    for &len in &[32usize, 64, 128, 256] {
        let mut row = vec![len.to_string()];
        let mut tps = vec![];
        for &fw in &frameworks {
            let bundle = fw.bundle(&model.sim, &cost, &calib.freq, &cfg);
            let ids: Vec<usize> = (0..16).collect();
            let m = crate::coordinator::simrun::replay_decode(
                &trace, &ids, len, &cost, bundle, &calib.freq, model.sim.n_shared, 7,
            );
            tps.push(m.tokens_per_s());
            row.push(format!("{:.2}", m.tokens_per_s()));
        }
        let dali = *tps.last().unwrap();
        for i in 0..3 {
            speedups[i].push(dali / tps[i].max(1e-9));
        }
        t.row(row);
    }
    out.push_str(&t.render());
    let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
    out.push_str(&format!(
        "\naverage DALI speedups: vs llama.cpp {} (paper 2.78x), vs ktransformers {} (paper 1.96x), vs hybrimoe {} (paper 1.47x)\n",
        times(avg(&speedups[0])),
        times(avg(&speedups[1])),
        times(avg(&speedups[2])),
    ));
    Ok(out)
}

/// Table 5 (A.3): residual-vector generality — prefetch accuracy on
/// downstream tasks, calibrated only on the Wikitext-like set.
pub fn table5(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Table 5 (A.3) — prefetch accuracy on downstream tasks\n\n");
    for preset in ["deepseek-sim", "qwen-sim"] {
        let calib = ctx.calib(preset)?;
        let mut t = Table::new(vec!["method", "arc-e", "arc-c", "obqa", "rte", "average"]);
        for (name, kind) in [("HybriMoE", PredKind::Feature), ("DALI", PredKind::Residual)] {
            let mut row = vec![name.to_string()];
            let mut sum = 0.0;
            for task in ["arc-e-sim", "arc-c-sim", "obqa-sim", "rte-sim"] {
                let trace = prep::ensure_trace(preset, task, 8, 16, 32)?;
                let ids: Vec<usize> = (0..8).collect();
                let k = trace.top_k;
                let acc = prefetch_accuracy(&trace, &calib, &ids, 32, kind, k);
                sum += acc;
                row.push(pct(acc));
            }
            row.push(pct(sum / 4.0));
            t.row(row);
        }
        out.push_str(&format!("**{preset}** (top-k activated-expert prediction)\n\n{}\n", t.render()));
    }
    out.push_str("Residual vectors transfer across domains without re-calibration (paper: +6.9% / +15.7%).\n");
    Ok(out)
}

/// Table 6 (A.4): scheduling overhead share vs sequence length.
pub fn table6(ctx: &ExptCtx) -> Result<String> {
    let preset = "deepseek-sim";
    let mut out = String::from("## Table 6 (A.4) — scheduling overhead vs decode length (deepseek-sim, batch 8)\n\n");
    let trace = ctx.trace_c4(preset)?;
    let mut t = Table::new(vec!["decode len", "HybriMoE", "DALI"]);
    for &len in &[16usize, 32, 64] {
        let h = ctx.decode(preset, Framework::HybriMoE, 8, len)?;
        let d = ctx.decode(preset, Framework::Dali, 8, len)?;
        t.row(vec![len.to_string(), format!("{:.3}%", 100.0 * h.sched_share()), format!("{:.3}%", 100.0 * d.sched_share())]);
    }
    let _ = trace;
    out.push_str(&t.render());
    out.push_str("\nPaper: HybriMoE ~3.0%, DALI ~4.5%, flat in sequence length (fixed decisions per token).\n");
    Ok(out)
}

/// Table 7 (A.4): paper-scale GPU memory usage, HybriMoE vs DALI.
pub fn table7(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Table 7 (A.4) — modeled GPU memory usage (GB), seq len 64\n\n");
    for preset in ["mixtral-sim", "qwen-sim"] {
        let model = ctx.model(preset)?;
        let mem = GpuMemModel::new(&model.paper);
        let cfg = ctx.fwcfg(preset)?;
        // HybriMoE keeps prefetch staging buffers alive across the layer;
        // DALI disposes transient expert buffers as soon as kernels retire.
        let cache = if preset == "mixtral-sim" { 1 } else { cfg.cache_size.min(8) };
        let mut t = Table::new(vec!["batch", "HybriMoE", "DALI"]);
        for &b in &[8usize, 16, 32, 64, 128] {
            let h = mem.total(cache, b, 64, 2 + cfg.prefetch_size);
            let d = mem.total(cache, b, 64, 1);
            t.row(vec![
                b.to_string(),
                format!("{:.2}", h / 1e9),
                format!("{:.2}", d / 1e9),
            ]);
        }
        out.push_str(&format!("**{preset}**\n\n{}\n", t.render()));
    }
    out.push_str("DALI ≤ HybriMoE at every batch (timely disposal of transient expert buffers).\n");
    Ok(out)
}

/// Table 8 (A.5): cosine similarity between prediction inputs and the true
/// next-layer gate input, per layer.
pub fn table8(ctx: &ExptCtx) -> Result<String> {
    let mut out = String::from("## Table 8 (A.5) — cosine similarity of prediction inputs vs truth\n\n");
    for preset in ["qwen-sim", "mixtral-sim"] {
        let trace = ctx.trace_wikitext(preset)?;
        let mut t = Table::new(vec!["layer", "HybriMoE (raw h_l)", "DALI (h_l + res_vec)"]);
        let mut raw_avg = 0.0;
        let mut res_avg = 0.0;
        let mut n = 0.0;
        for l in 0..trace.layers - 1 {
            let mut raw = 0.0f64;
            let mut res = 0.0f64;
            let mut c = 0.0f64;
            for seq in &trace.seqs {
                for step in &seq.steps {
                    raw += step[l].cos_raw as f64;
                    res += step[l].cos_res as f64;
                    c += 1.0;
                }
            }
            raw /= c.max(1.0);
            res /= c.max(1.0);
            raw_avg += raw;
            res_avg += res;
            n += 1.0;
            t.row(vec![l.to_string(), format!("{raw:.3}"), format!("{res:.3}")]);
        }
        t.row(vec![
            "**average**".into(),
            format!("{:.3}", raw_avg / n),
            format!("{:.3}", res_avg / n),
        ]);
        out.push_str(&format!("**{preset}**\n\n{}\n", t.render()));
    }
    out.push_str("Residual correction moves the prediction input closer to the true gate input (paper: 0.79 → 0.89).\n");
    Ok(out)
}
