//! Experiment harness: one generator per table/figure of the paper's
//! evaluation (§6 + appendix). See DESIGN.md §4 for the full index.
//!
//! Every experiment renders a markdown section (printed and written to
//! `results/<id>.md` by the `expt` binary); EXPERIMENTS.md embeds these
//! verbatim. All numbers are simulated local-PC virtual time over real
//! routing traces — deterministic run-to-run.

pub mod appendix;
pub mod breakdown;
pub mod common;
pub mod motivation;
pub mod overall;
pub mod sensitivity;
pub mod serve;

use anyhow::{bail, Result};

pub use common::ExptCtx;

/// (id, paper reference, runner).
pub type Runner = fn(&ExptCtx) -> Result<String>;

pub fn registry() -> Vec<(&'static str, &'static str, Runner)> {
    vec![
        ("fig4", "Fig. 4 — CPU/GPU time under static assignment", motivation::fig4),
        ("fig5", "Fig. 5 — PCIe share of inference time", motivation::fig5),
        ("table2", "Table 2 — prefetch accuracy (EdgeMoE/HybriMoE/DALI)", motivation::table2),
        ("fig6", "Fig. 6 — HybriMoE prefetch speedup vs none", motivation::fig6),
        ("fig7", "Fig. 7 — LRU vs score cache hit rates", motivation::fig7),
        ("fig8", "Fig. 8 — adjacent-token high-workload correlation", motivation::fig8),
        ("fig12", "Fig. 12 — decode speed across frameworks", overall::fig12),
        ("fig13", "Fig. 13 — prefill speed on DeepSeek", overall::fig13),
        ("fig14", "Fig. 14 — assignment-only comparison", breakdown::fig14),
        ("fig15", "Fig. 15 — greedy vs optimal incl. solve cost", breakdown::fig15),
        ("table4", "Table 4 — MoE time greedy vs optimal (excl. solve)", breakdown::table4),
        ("fig16", "Fig. 16 — prefetch strategies: speedup + accuracy", breakdown::fig16),
        ("fig17", "Fig. 17 — cache strategies: speed + hit rate", breakdown::fig17),
        ("fig19", "Fig. 19 — cumulative breakdown waterfall", breakdown::fig19),
        (
            "fig18",
            "Fig. 18 — sensitivity: prefetch size, cache size, (w,u), adaptation",
            sensitivity::fig18,
        ),
        ("table9", "Table 9 — (w_size, u_size) sweep", sensitivity::table9),
        (
            "ram",
            "RAM-budget sensitivity — decode speed vs host RAM, predictive vs LRU placement",
            sensitivity::ram_budget,
        ),
        (
            "serve",
            "Serving SLO curves — TTFT/TPOT p50/p99 vs load × RAM budget × policy",
            serve::slo_curves,
        ),
        ("fig20", "Fig. 20 (A.1) — CPU/GPU balance HybriMoE vs DALI", appendix::fig20),
        ("fig21", "Fig. 21 (A.2) — beam search vs greedy vs optimal", appendix::fig21),
        ("fig22", "Fig. 22 (A.7) — decode-length sweep", appendix::fig22),
        ("table5", "Table 5 (A.3) — prefetch accuracy on downstream tasks", appendix::table5),
        ("table6", "Table 6 (A.4) — scheduling overhead vs sequence length", appendix::table6),
        ("table7", "Table 7 (A.4) — GPU memory usage", appendix::table7),
        ("table8", "Table 8 (A.5) — gate-input cosine similarity", appendix::table8),
    ]
}

pub fn run_one(ctx: &ExptCtx, id: &str) -> Result<String> {
    for (name, _, f) in registry() {
        if name == id {
            return f(ctx);
        }
    }
    bail!("unknown experiment '{id}' — see `expt list`")
}
