//! `artifacts/<preset>/manifest.json` loader.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{Buckets, ModelDims};
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub file: String,
    pub shape: Vec<usize>,
}

/// Parsed manifest plus the directory it came from.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub dims: ModelDims,
    pub buckets: Buckets,
    pub artifacts: BTreeMap<String, String>,
    pub weights: BTreeMap<String, WeightEntry>,
    pub golden: String,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(preset_dir: &Path) -> Result<Self> {
        let path = preset_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        let v = Value::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = BTreeMap::new();
        for (k, a) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), a.as_str()?.to_string());
        }
        let mut weights = BTreeMap::new();
        for (k, w) in v.get("weights")?.as_obj()? {
            weights.insert(
                k.clone(),
                WeightEntry {
                    file: w.get("file")?.as_str()?.to_string(),
                    shape: w.get("shape")?.as_usize_vec()?,
                },
            );
        }
        Ok(Manifest {
            preset: v.get("preset")?.as_str()?.to_string(),
            dims: ModelDims::from_json(v.get("dims")?)?,
            buckets: Buckets::from_json(v.get("buckets")?)?,
            artifacts,
            weights,
            golden: v.get("golden")?.as_str()?.to_string(),
            dir: preset_dir.to_path_buf(),
        })
    }

    /// Load `artifacts/<preset>` under the repo root.
    pub fn load_preset(preset: &str) -> Result<Self> {
        Self::load(&crate::util::artifacts_dir().join(preset))
    }

    /// Absolute path of a named HLO artifact (e.g. `expert_t8`).
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest (preset {})", self.preset))?;
        Ok(self.dir.join(f))
    }

    pub fn golden_path(&self) -> PathBuf {
        self.dir.join(&self.golden)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared skip probe — see `crate::util::artifacts_ready`.
    fn artifacts_ready() -> bool {
        crate::util::artifacts_ready("mixtral-sim")
    }

    #[test]
    fn load_all_presets() {
        if !artifacts_ready() {
            return;
        }
        for preset in ["mixtral-sim", "deepseek-sim", "qwen-sim"] {
            let m = Manifest::load_preset(preset).unwrap();
            assert_eq!(m.preset, preset);
            assert!(!m.artifacts.is_empty());
            assert!(!m.weights.is_empty());
            // every token bucket has its four artifacts
            for t in &m.buckets.tokens {
                for kind in ["embed", "gate", "expert", "head"] {
                    let name = format!("{kind}_t{t}");
                    assert!(m.artifact_path(&name).unwrap().exists(), "{name} missing");
                }
            }
        }
    }

    #[test]
    fn expert_weights_complete() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load_preset("mixtral-sim").unwrap();
        for l in 0..m.dims.layers {
            for e in 0..m.dims.n_routed {
                for w in ["w1", "w2", "w3"] {
                    let key = format!("layer.{l}.moe.expert.{e}.{w}");
                    let entry = m.weights.get(&key).expect(&key);
                    assert!(m.dir.join(&entry.file).exists());
                }
            }
        }
    }

    #[test]
    fn unknown_artifact_errors() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load_preset("mixtral-sim").unwrap();
        assert!(m.artifact_path("nope_t1").is_err());
    }
}
