//! Host (CPU DRAM) weight store — "during deployment, all expert weights are
//! stored in CPU DRAM" (paper §4). Loads the flat-f32 binaries once and hands
//! out slices; the runtime wraps them in PJRT literals on demand.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// One named tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// All model weights, keyed by the manifest's flat names.
pub struct WeightStore {
    tensors: BTreeMap<String, Tensor>,
}

impl WeightStore {
    pub fn load(m: &Manifest) -> Result<Self> {
        let mut tensors = BTreeMap::new();
        for (name, entry) in &m.weights {
            let path = m.dir.join(&entry.file);
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading weight {}", path.display()))?;
            let numel: usize = entry.shape.iter().product();
            if bytes.len() != numel * 4 {
                bail!(
                    "weight {name}: file has {} bytes, shape {:?} needs {}",
                    bytes.len(),
                    entry.shape,
                    numel * 4
                );
            }
            let mut data = vec![0f32; numel];
            for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            tensors.insert(name.clone(), Tensor { shape: entry.shape.clone(), data });
        }
        Ok(WeightStore { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("weight '{name}' not loaded"))
    }

    /// (w1, w2, w3) of a routed expert.
    pub fn expert(&self, layer: usize, expert: usize) -> Result<[&Tensor; 3]> {
        Ok([
            self.get(&format!("layer.{layer}.moe.expert.{expert}.w1"))?,
            self.get(&format!("layer.{layer}.moe.expert.{expert}.w2"))?,
            self.get(&format!("layer.{layer}.moe.expert.{expert}.w3"))?,
        ])
    }

    /// (w1, w2, w3) of a shared expert.
    pub fn shared_expert(&self, layer: usize, idx: usize) -> Result<[&Tensor; 3]> {
        Ok([
            self.get(&format!("layer.{layer}.moe.shared.{idx}.w1"))?,
            self.get(&format!("layer.{layer}.moe.shared.{idx}.w2"))?,
            self.get(&format!("layer.{layer}.moe.shared.{idx}.w3"))?,
        ])
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }

    /// Total resident bytes (f32 host copies).
    pub fn host_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.numel() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared skip probe — see `crate::util::artifacts_ready`.
    fn artifacts_ready() -> bool {
        crate::util::artifacts_ready("mixtral-sim")
    }

    #[test]
    fn load_mixtral_weights() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load_preset("mixtral-sim").unwrap();
        let w = WeightStore::load(&m).unwrap();
        let emb = w.get("embed.table").unwrap();
        assert_eq!(emb.shape, vec![m.dims.vocab, m.dims.hidden]);
        assert_eq!(emb.numel(), m.dims.vocab * m.dims.hidden);
        let [w1, w2, w3] = w.expert(0, 0).unwrap();
        assert_eq!(w1.shape, vec![m.dims.hidden, m.dims.moe_inter]);
        assert_eq!(w2.shape, vec![m.dims.moe_inter, m.dims.hidden]);
        assert_eq!(w3.shape, vec![m.dims.hidden, m.dims.moe_inter]);
        assert!(w.host_bytes() > 1_000_000);
    }

    #[test]
    fn clustered_embeddings_have_intra_cluster_similarity() {
        if !artifacts_ready() {
            return;
        }
        // The corpus generator relies on vocab clusters (DESIGN.md §1);
        // verify the python-side structure actually landed in the weights.
        let m = Manifest::load_preset("mixtral-sim").unwrap();
        let w = WeightStore::load(&m).unwrap();
        let emb = w.get("embed.table").unwrap();
        let d = m.dims.hidden;
        let block = m.dims.vocab / 16;
        let cos = |a: &[f32], b: &[f32]| {
            let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            dot / (na * nb)
        };
        // same cluster: tokens 0 and 1; different clusters: 0 and block*8
        let t0 = &emb.data[0..d];
        let t1 = &emb.data[d..2 * d];
        let tf = &emb.data[8 * block * d..8 * block * d + d];
        assert!(cos(t0, t1) > 0.5, "intra-cluster cos = {}", cos(t0, t1));
        assert!(cos(t0, tf) < 0.5, "inter-cluster cos = {}", cos(t0, tf));
    }

    #[test]
    fn missing_weight_errors() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load_preset("mixtral-sim").unwrap();
        let w = WeightStore::load(&m).unwrap();
        assert!(w.get("layer.99.moe.expert.0.w1").is_err());
        assert!(w.expert(0, 999).is_err());
    }
}
