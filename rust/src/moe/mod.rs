//! Model-side substrate: artifact manifests and the host weight store.
//!
//! Mirrors what `python/compile/aot.py` wrote into `artifacts/<preset>/`:
//! the manifest (dims + bucket lists + file index), the flat-f32 weight
//! binaries (all experts live in host DRAM, exactly like the paper's
//! deployment where CPU memory holds every expert), and the golden
//! reference activations used by integration tests.

pub mod manifest;
pub mod weights;

pub use manifest::Manifest;
pub use weights::WeightStore;
