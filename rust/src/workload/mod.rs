//! Synthetic workload substrate: the stand-in for the paper's C4/Wikitext
//! corpora and lm-eval downstream tasks (DESIGN.md §1).
//!
//! The paper's cache/prefetch results rest on two measurable input
//! statistics — adjacent-token routing locality (Fig. 8) and adjacent-layer
//! gate-input similarity (Table 8). The corpus generator reproduces the
//! *cause* (semantic clustering of adjacent tokens) rather than the
//! statistics directly: sequences dwell on a vocab topic-cluster and drift,
//! and the clustered embedding table (python `gen_weights`) turns that into
//! correlated routing through the *real* gate computation.

pub mod calib;
pub mod corpus;
pub mod prep;
pub mod trace;

pub use calib::CalibData;
pub use corpus::{CorpusGen, TaskProfile};
pub use trace::{BatchStep, LayerStepData, SeqTrace, Trace};
