//! Preparation pipeline: calibration + trace-pool generation, cached on
//! disk under `artifacts/` so experiments are instant to re-run.
//!
//! Mirrors the paper's workflow (§6.1): residual vectors and activation
//! statistics come from a Wikitext-like calibration set; speed benchmarks
//! sample from a C4-like corpus.

use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::engine::InferenceEngine;
use crate::workload::corpus::{CorpusGen, TaskProfile};
use crate::workload::{CalibData, Trace};

/// Default calibration set: 24 Wikitext-like sequences of 32 tokens.
pub const CALIB_SEQS: usize = 24;
pub const CALIB_LEN: usize = 32;

pub fn task_by_name(name: &str) -> Result<TaskProfile> {
    if name == "wikitext-sim" {
        return Ok(TaskProfile::wikitext());
    }
    if name == "c4-sim" {
        return Ok(TaskProfile::c4());
    }
    TaskProfile::downstream()
        .into_iter()
        .find(|t| t.name == name)
        .ok_or_else(|| anyhow!("unknown task '{name}'"))
}

/// Load cached calibration data, or compute it with the live engine.
pub fn ensure_calib(preset: &str) -> Result<CalibData> {
    let path = CalibData::path_for(preset);
    if let Ok(c) = CalibData::load(&path) {
        return Ok(c);
    }
    eprintln!("[prep] calibrating {preset} ({CALIB_SEQS} seqs x {CALIB_LEN} tokens)...");
    let mut eng = InferenceEngine::new(preset)?;
    let mut gen = CorpusGen::new(eng.dims.vocab, TaskProfile::wikitext(), 0xca11b);
    let seqs = gen.batch(CALIB_SEQS, CALIB_LEN);
    eng.calibrate(&seqs)
}

/// Canonical on-disk location of a trace pool.
pub fn trace_path(preset: &str, task: &str, pool: usize, prompt: usize, steps: usize) -> PathBuf {
    crate::util::artifacts_dir()
        .join("traces")
        .join(format!("{preset}-{task}-n{pool}p{prompt}s{steps}.bin"))
}

/// Load a cached trace pool, or generate it with the live engine.
///
/// Generation decodes in groups of the largest decode-batch bucket; routing
/// is per-sequence so grouping does not affect the recorded trace.
pub fn ensure_trace(
    preset: &str,
    task_name: &str,
    pool: usize,
    prompt: usize,
    steps: usize,
) -> Result<Trace> {
    let path = trace_path(preset, task_name, pool, prompt, steps);
    if let Ok(t) = Trace::load(&path) {
        return Ok(t);
    }
    ensure_calib(preset)?;
    eprintln!("[prep] tracing {preset}/{task_name}: {pool} seqs, prompt {prompt}, {steps} steps...");
    let eng = InferenceEngine::new(preset)?; // picks up calib from disk
    let task = task_by_name(task_name)?;
    let mut gen = CorpusGen::new(eng.dims.vocab, task, 0x7ace ^ pool as u64);
    let group = *eng.rt.manifest().buckets.decode_batch.iter().max().unwrap_or(&4);
    let mut merged: Option<Trace> = None;
    let mut done = 0;
    while done < pool {
        let n = group.min(pool - done);
        let prompts = gen.batch(n, prompt);
        let out = eng.run_batch(&prompts, steps, true)?;
        let t = out.trace.context("trace missing")?;
        match &mut merged {
            None => merged = Some(t),
            Some(m) => m.seqs.extend(t.seqs),
        }
        done += n;
        eprintln!("[prep]   {done}/{pool} sequences traced");
    }
    let mut trace = merged.context("empty pool")?;
    trace.task = task_name.to_string();
    trace.save(&path)?;
    Ok(trace)
}

/// The standard trace pools used by the experiment suite.
pub fn standard_pools(preset: &str) -> Vec<(String, usize, usize, usize)> {
    let mut pools = vec![
        // (task, pool, prompt, steps) — C4 for speed benchmarks (§6.1-2)
        ("c4-sim".to_string(), 32, 16, 64),
        // Wikitext for locality / cache statistics
        ("wikitext-sim".to_string(), 16, 16, 48),
    ];
    if preset == "mixtral-sim" {
        // long-decode pool for the Fig. 22 decode-length sweep
        pools.push(("c4-sim".to_string(), 8, 16, 256));
    }
    if preset != "mixtral-sim" {
        // downstream tasks for Table 5 (DeepSeek + Qwen in the paper)
        for t in crate::workload::corpus::TaskProfile::downstream() {
            pools.push((t.name.to_string(), 8, 16, 32));
        }
    }
    pools
}

/// Prepare calibration + all standard pools for the given presets.
pub fn prepare_all(presets: &[String]) -> Result<()> {
    for p in presets {
        ensure_calib(p)?;
        for (task, pool, prompt, steps) in standard_pools(p) {
            ensure_trace(p, &task, pool, prompt, steps)?;
        }
    }
    Ok(())
}
