//! Offline calibration data (paper §4.2 Eq. 11 + EdgeMoE's statistics).
//!
//! Produced once per preset by `InferenceEngine::calibrate` running prefill
//! over the Wikitext-like calibration corpus:
//!
//! * `res_vec[l]` — the layer-l residual vector, the *token-averaged*
//!   difference between adjacent layers' gate inputs (Eq. 11). Used by
//!   Residual-Based Prefetching; reused across downstream tasks (Table 5).
//! * `freq[l][e]` — expert activation frequency, the statistical predictor
//!   EdgeMoE uses.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct CalibData {
    pub preset: String,
    /// Calibration tokens observed.
    pub tokens: usize,
    /// `res_vec[l]` for l in 0..layers-1 (last layer needs no prediction).
    pub res_vec: Vec<Vec<f32>>,
    /// Activation frequency per layer per routed expert.
    pub freq: Vec<Vec<f64>>,
}

impl CalibData {
    pub fn path_for(preset: &str) -> std::path::PathBuf {
        crate::util::artifacts_dir().join("calib").join(format!("{preset}.json"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let v = Value::obj(vec![
            ("preset", Value::str(self.preset.clone())),
            ("tokens", Value::num(self.tokens as f64)),
            (
                "res_vec",
                Value::arr(self.res_vec.iter().map(|r| Value::from_f32s(r)).collect()),
            ),
            ("freq", Value::arr(self.freq.iter().map(|f| Value::from_f64s(f)).collect())),
        ]);
        std::fs::write(path, v.to_json()).context("writing calib data")
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("opening calib {} — run `dali calibrate`", path.display()))?;
        let v = Value::parse(&text).context("parsing calib data")?;
        Ok(CalibData {
            preset: v.get("preset")?.as_str()?.to_string(),
            tokens: v.get("tokens")?.as_usize()?,
            res_vec: v
                .get("res_vec")?
                .as_arr()?
                .iter()
                .map(|r| r.as_f32_vec())
                .collect::<Result<_>>()?,
            freq: v.get("freq")?.as_arr()?.iter().map(|f| f.as_f64_vec()).collect::<Result<_>>()?,
        })
    }
}

/// Accumulator used by the engine while streaming calibration tokens.
#[derive(Debug, Clone)]
pub struct CalibAccum {
    layers: usize,
    hidden: usize,
    n_routed: usize,
    pub tokens: usize,
    diff_sum: Vec<Vec<f64>>,
    act_count: Vec<Vec<u64>>,
}

impl CalibAccum {
    pub fn new(layers: usize, hidden: usize, n_routed: usize) -> Self {
        CalibAccum {
            layers,
            hidden,
            n_routed,
            tokens: 0,
            diff_sum: vec![vec![0.0; hidden]; layers.saturating_sub(1)],
            act_count: vec![vec![0; n_routed]; layers],
        }
    }

    /// Observe one token's gate inputs at layers l and l+1.
    pub fn observe_pair(&mut self, layer: usize, h_l: &[f32], h_next: &[f32]) {
        debug_assert_eq!(h_l.len(), self.hidden);
        let dst = &mut self.diff_sum[layer];
        for i in 0..self.hidden {
            dst[i] += (h_next[i] - h_l[i]) as f64;
        }
    }

    /// Observe one token's routed experts at a layer.
    pub fn observe_routing(&mut self, layer: usize, topk: &[usize]) {
        for &e in topk {
            self.act_count[layer][e] += 1;
        }
    }

    pub fn add_tokens(&mut self, n: usize) {
        self.tokens += n;
    }

    pub fn finish(self, preset: &str) -> CalibData {
        let n = self.tokens.max(1) as f64;
        CalibData {
            preset: preset.to_string(),
            tokens: self.tokens,
            res_vec: self
                .diff_sum
                .into_iter()
                .map(|v| v.into_iter().map(|x| (x / n) as f32).collect())
                .collect(),
            freq: self
                .act_count
                .into_iter()
                .map(|v| v.into_iter().map(|c| c as f64 / n).collect())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_averages_residuals() {
        let mut a = CalibAccum::new(2, 3, 4);
        a.observe_pair(0, &[0.0, 0.0, 0.0], &[2.0, 4.0, 6.0]);
        a.observe_pair(0, &[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]);
        a.observe_routing(0, &[1, 2]);
        a.observe_routing(1, &[0, 0]);
        a.add_tokens(2);
        let c = a.finish("t");
        assert_eq!(c.res_vec.len(), 1);
        assert!((c.res_vec[0][0] - 1.0).abs() < 1e-6);
        assert!((c.res_vec[0][2] - 3.0).abs() < 1e-6);
        assert!((c.freq[0][1] - 0.5).abs() < 1e-9);
        assert!((c.freq[1][0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut a = CalibAccum::new(2, 2, 2);
        a.observe_pair(0, &[0.0, 0.0], &[1.0, 1.0]);
        a.add_tokens(1);
        let c = a.finish("t");
        let dir = crate::util::test_temp_dir("calib");
        let p = dir.join("c.json");
        c.save(&p).unwrap();
        let c2 = CalibData::load(&p).unwrap();
        assert_eq!(c2.res_vec, c.res_vec);
        assert_eq!(c2.tokens, 1);
        let _ = std::fs::remove_dir_all(dir);
    }
}
