//! Clustered-topic corpus generator.

use crate::util::DetRng;

/// Number of vocab clusters — must match `python/compile/model.py::N_CLUSTERS`.
pub const N_CLUSTERS: usize = 16;

/// A synthetic "task": which topic clusters it draws from and how strongly
/// sequences dwell within one topic. Mirrors the paper's datasets:
/// calibration (Wikitext) spans all topics; each downstream task (Table 5)
/// concentrates on a disjoint topic subset with its own dwell dynamics.
#[derive(Debug, Clone)]
pub struct TaskProfile {
    pub name: &'static str,
    /// Clusters this task's sequences draw from.
    pub clusters: Vec<usize>,
    /// Probability of staying in the current cluster at each token.
    pub p_stay: f64,
}

impl TaskProfile {
    pub fn wikitext() -> Self {
        TaskProfile { name: "wikitext-sim", clusters: (0..N_CLUSTERS).collect(), p_stay: 0.90 }
    }

    pub fn c4() -> Self {
        TaskProfile { name: "c4-sim", clusters: (0..N_CLUSTERS).collect(), p_stay: 0.85 }
    }

    /// The four downstream tasks of paper Table 5.
    pub fn downstream() -> Vec<Self> {
        vec![
            TaskProfile { name: "arc-e-sim", clusters: (0..4).collect(), p_stay: 0.92 },
            TaskProfile { name: "arc-c-sim", clusters: (4..8).collect(), p_stay: 0.88 },
            TaskProfile { name: "obqa-sim", clusters: (8..12).collect(), p_stay: 0.90 },
            TaskProfile { name: "rte-sim", clusters: (12..16).collect(), p_stay: 0.84 },
        ]
    }
}

/// Sequence generator over a vocab of `vocab` tokens split into
/// [`N_CLUSTERS`] contiguous blocks.
pub struct CorpusGen {
    vocab: usize,
    task: TaskProfile,
    rng: DetRng,
}

impl CorpusGen {
    pub fn new(vocab: usize, task: TaskProfile, seed: u64) -> Self {
        assert!(vocab % N_CLUSTERS == 0, "vocab must split into {N_CLUSTERS} clusters");
        CorpusGen { vocab, task, rng: DetRng::new(seed) }
    }

    fn block(&self) -> usize {
        self.vocab / N_CLUSTERS
    }

    /// Generate one sequence of `len` token ids.
    pub fn sequence(&mut self, len: usize) -> Vec<i32> {
        let block = self.block();
        let mut cluster = self.task.clusters[self.rng.usize_below(self.task.clusters.len())];
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            if !self.rng.chance(self.task.p_stay) {
                cluster = self.task.clusters[self.rng.usize_below(self.task.clusters.len())];
            }
            let tok = cluster * block + self.rng.usize_below(block);
            out.push(tok as i32);
        }
        out
    }

    /// Generate a batch of sequences.
    pub fn batch(&mut self, n: usize, len: usize) -> Vec<Vec<i32>> {
        (0..n).map(|_| self.sequence(len)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_and_task_clusters() {
        let task = TaskProfile::downstream().remove(0); // arc-e: clusters 0..4
        let mut g = CorpusGen::new(512, task, 1);
        let block = 512 / N_CLUSTERS;
        for s in g.batch(8, 64) {
            for t in s {
                assert!((t as usize) < 512);
                assert!((t as usize) / block < 4, "token outside task clusters");
            }
        }
    }

    #[test]
    fn sequences_dwell_in_clusters() {
        let mut g = CorpusGen::new(512, TaskProfile::wikitext(), 2);
        let block = 512 / N_CLUSTERS;
        let s = g.sequence(256);
        let same_adjacent = s
            .windows(2)
            .filter(|w| (w[0] as usize) / block == (w[1] as usize) / block)
            .count();
        // p_stay = 0.9 → ~90% of adjacent pairs share a cluster (plus chance)
        assert!(same_adjacent as f64 / 255.0 > 0.75, "locality too weak: {same_adjacent}/255");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = CorpusGen::new(512, TaskProfile::c4(), 42).sequence(32);
        let b = CorpusGen::new(512, TaskProfile::c4(), 42).sequence(32);
        assert_eq!(a, b);
        let c = CorpusGen::new(512, TaskProfile::c4(), 43).sequence(32);
        assert_ne!(a, c);
    }

    #[test]
    fn downstream_tasks_are_disjoint() {
        let tasks = TaskProfile::downstream();
        for i in 0..tasks.len() {
            for j in i + 1..tasks.len() {
                for c in &tasks[i].clusters {
                    assert!(!tasks[j].clusters.contains(c));
                }
            }
        }
    }
}
