//! Routing traces: the recorded per-sequence routing + prediction stream.
//!
//! The live engine records one [`SeqTrace`] per sequence (real gate
//! computations via PJRT). Policy experiments then *replay* traces: batches
//! are composed by summing per-sequence routing, which is exact because
//! routing depends only on sequence content, never on batch composition.
//! This mirrors how the paper sweeps policies over shared workloads, and
//! makes the large sweeps (Fig. 12/13 grids) tractable.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Routing + prediction data for one (sequence, step, layer).
#[derive(Debug, Clone)]
pub struct LayerStepRecord {
    /// True top-k routed experts for this token.
    pub topk: Vec<u16>,
    /// Gate probabilities of the chosen experts (HybriMoE's score signal).
    pub topk_scores: Vec<f32>,
    /// Predicted top-k experts of the *next* layer from raw features
    /// (HybriMoE-style, gate_{l+1}(h_l)).
    pub pred_raw: Vec<u16>,
    /// Predicted top-k experts of the next layer from residual-corrected
    /// features (DALI §4.2, gate_{l+1}(h_l + res_vec_l)).
    pub pred_res: Vec<u16>,
    /// Cosine similarity of prediction input vs true next-layer gate input
    /// (Table 8): raw and residual-corrected.
    pub cos_raw: f32,
    pub cos_res: f32,
}

/// Per-layer aggregates for a whole prompt (prefill is one batch step).
#[derive(Debug, Clone)]
pub struct PrefillLayerRecord {
    /// True workload per routed expert (token counts).
    pub counts: Vec<u32>,
    /// Sum of routed gate scores per expert.
    pub gate_scores: Vec<f32>,
    /// Predicted next-layer workload counts (raw / residual features).
    pub pred_raw: Vec<u32>,
    pub pred_res: Vec<u32>,
}

/// Trace of one sequence: prefill aggregates + per-decode-step records.
#[derive(Debug, Clone)]
pub struct SeqTrace {
    pub prompt_len: usize,
    /// `prefill[layer]`
    pub prefill: Vec<PrefillLayerRecord>,
    /// `steps[step][layer]`
    pub steps: Vec<Vec<LayerStepRecord>>,
}

/// A pool of sequence traces for one preset + task.
#[derive(Debug, Clone)]
pub struct Trace {
    pub preset: String,
    pub task: String,
    pub n_routed: usize,
    pub top_k: usize,
    pub layers: usize,
    pub seqs: Vec<SeqTrace>,
}

/// Little-endian binary writer/reader for the trace format (no serde in
/// the offline build; a compact binary beats JSON for multi-MB traces).
struct W(Vec<u8>);

impl W {
    fn u32(&mut self, x: u32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn u16s(&mut self, xs: &[u16]) {
        self.u32(xs.len() as u32);
        for x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn u32s(&mut self, xs: &[u32]) {
        self.u32(xs.len() as u32);
        for x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn f32(&mut self, x: f32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
}

struct R<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> R<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated trace file");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u16s(&mut self) -> Result<Vec<u16>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))).collect()
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.u32()).collect()
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.f32()).collect()
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }
}

const TRACE_MAGIC: u32 = 0x4452_5443; // "DRTC"

impl Trace {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = W(Vec::with_capacity(1 << 20));
        w.u32(TRACE_MAGIC);
        w.u32(1); // version
        w.str(&self.preset);
        w.str(&self.task);
        w.u32(self.n_routed as u32);
        w.u32(self.top_k as u32);
        w.u32(self.layers as u32);
        w.u32(self.seqs.len() as u32);
        for s in &self.seqs {
            w.u32(s.prompt_len as u32);
            w.u32(s.prefill.len() as u32);
            for p in &s.prefill {
                w.u32s(&p.counts);
                w.f32s(&p.gate_scores);
                w.u32s(&p.pred_raw);
                w.u32s(&p.pred_res);
            }
            w.u32(s.steps.len() as u32);
            for step in &s.steps {
                w.u32(step.len() as u32);
                for r in step {
                    w.u16s(&r.topk);
                    w.f32s(&r.topk_scores);
                    w.u16s(&r.pred_raw);
                    w.u16s(&r.pred_res);
                    w.f32(r.cos_raw);
                    w.f32(r.cos_res);
                }
            }
        }
        std::fs::write(path, &w.0).with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("opening trace {}", path.display()))?;
        let mut r = R { b: &bytes, i: 0 };
        if r.u32()? != TRACE_MAGIC {
            bail!("not a DALI trace file: {}", path.display());
        }
        if r.u32()? != 1 {
            bail!("unsupported trace version");
        }
        let preset = r.str()?;
        let task = r.str()?;
        let n_routed = r.u32()? as usize;
        let top_k = r.u32()? as usize;
        let layers = r.u32()? as usize;
        let n_seqs = r.u32()? as usize;
        let mut seqs = Vec::with_capacity(n_seqs);
        for _ in 0..n_seqs {
            let prompt_len = r.u32()? as usize;
            let n_pre = r.u32()? as usize;
            let mut prefill = Vec::with_capacity(n_pre);
            for _ in 0..n_pre {
                prefill.push(PrefillLayerRecord {
                    counts: r.u32s()?,
                    gate_scores: r.f32s()?,
                    pred_raw: r.u32s()?,
                    pred_res: r.u32s()?,
                });
            }
            let n_steps = r.u32()? as usize;
            let mut steps = Vec::with_capacity(n_steps);
            for _ in 0..n_steps {
                let nl = r.u32()? as usize;
                let mut recs = Vec::with_capacity(nl);
                for _ in 0..nl {
                    recs.push(LayerStepRecord {
                        topk: r.u16s()?,
                        topk_scores: r.f32s()?,
                        pred_raw: r.u16s()?,
                        pred_res: r.u16s()?,
                        cos_raw: r.f32()?,
                        cos_res: r.f32()?,
                    });
                }
                steps.push(recs);
            }
            seqs.push(SeqTrace { prompt_len, prefill, steps });
        }
        Ok(Trace { preset, task, n_routed, top_k, layers, seqs })
    }

    /// Decode steps guaranteed for *every* sequence: the minimum step
    /// count across the pool (0 for an empty pool). Replays that bill a
    /// fixed step count clamp to this so no sequence runs dry mid-replay.
    pub fn min_steps(&self) -> usize {
        self.seqs.iter().map(|s| s.steps.len()).min().unwrap_or(0)
    }

    /// Decode steps recorded for the stream backing `sid` (pool-wrapped).
    pub fn decode_len(&self, sid: usize) -> usize {
        self.seqs[sid % self.seqs.len()].steps.len()
    }

    /// Prompt length of the stream backing `sid` (pool-wrapped).
    pub fn prompt_len(&self, sid: usize) -> usize {
        self.seqs[sid % self.seqs.len()].prompt_len
    }
}

/// One composed batch step fed to the policy simulator: per-layer data.
#[derive(Debug, Clone, Default)]
pub struct LayerStepData {
    /// True workload per routed expert (tokens routed there this step).
    pub workloads: Vec<u32>,
    /// Sum of routed gate scores per expert.
    pub gate_scores: Vec<f32>,
    /// Predicted *next-layer* workload counts from raw features.
    pub pred_raw: Vec<u32>,
    /// Predicted next-layer workload counts from residual features.
    pub pred_res: Vec<u32>,
}

impl LayerStepData {
    /// Zero all counters at width `n`, reusing capacity.
    fn reset(&mut self, n: usize) {
        self.workloads.clear();
        self.workloads.resize(n, 0);
        self.gate_scores.clear();
        self.gate_scores.resize(n, 0.0);
        self.pred_raw.clear();
        self.pred_raw.resize(n, 0);
        self.pred_res.clear();
        self.pred_res.resize(n, 0);
    }
}

/// One batch step across all layers.
#[derive(Debug, Clone, Default)]
pub struct BatchStep {
    /// Tokens processed this step (batch size during decode).
    pub tokens: usize,
    /// `layers[l]` — data observed at MoE layer l.
    pub layers: Vec<LayerStepData>,
}

impl BatchStep {
    /// Shape as an all-zero step of `layers` × `n_routed`, reusing every
    /// existing allocation — the replay loops call this once per step.
    pub fn reset(&mut self, layers: usize, n_routed: usize) {
        self.tokens = 0;
        self.layers.resize_with(layers, LayerStepData::default);
        for d in &mut self.layers {
            d.reset(n_routed);
        }
    }
}

impl Trace {
    /// Compose decode step `step` for the batch given by `seq_ids`.
    pub fn compose_decode(&self, seq_ids: &[usize], step: usize) -> BatchStep {
        let mut out = BatchStep::default();
        self.compose_decode_into(seq_ids, step, &mut out);
        out
    }

    /// Buffer-reusing form of [`Self::compose_decode`]: overwrite `out`
    /// with the composed step, allocating nothing once `out` has the
    /// trace's shape.
    pub fn compose_decode_into(&self, seq_ids: &[usize], step: usize, out: &mut BatchStep) {
        out.reset(self.layers, self.n_routed);
        for &sid in seq_ids {
            let seq = &self.seqs[sid % self.seqs.len()];
            if step >= seq.steps.len() {
                continue;
            }
            out.tokens += 1;
            for (l, rec) in seq.steps[step].iter().enumerate() {
                let dst = &mut out.layers[l];
                for (i, &e) in rec.topk.iter().enumerate() {
                    dst.workloads[e as usize] += 1;
                    dst.gate_scores[e as usize] += rec.topk_scores[i];
                }
                for &e in &rec.pred_raw {
                    dst.pred_raw[e as usize] += 1;
                }
                for &e in &rec.pred_res {
                    dst.pred_res[e as usize] += 1;
                }
            }
        }
    }

    /// Compose one decode step from many concurrent streams, each at its
    /// own per-stream offset: `active[i] = (seq_id, step)`. The serving
    /// simulator's continuous batcher admits requests at different virtual
    /// times, so a single batch step mixes stream positions — unlike
    /// [`Self::compose_decode_into`], which marches every stream in
    /// lockstep. Streams whose `step` is past their recorded length
    /// contribute nothing (same finished-sequence rule as lockstep
    /// composition). Allocation-free once `out` has the trace's shape.
    pub fn compose_multi_into(&self, active: &[(usize, usize)], out: &mut BatchStep) {
        out.reset(self.layers, self.n_routed);
        for &(sid, step) in active {
            let seq = &self.seqs[sid % self.seqs.len()];
            if step >= seq.steps.len() {
                continue;
            }
            out.tokens += 1;
            for (l, rec) in seq.steps[step].iter().enumerate() {
                let dst = &mut out.layers[l];
                for (i, &e) in rec.topk.iter().enumerate() {
                    dst.workloads[e as usize] += 1;
                    dst.gate_scores[e as usize] += rec.topk_scores[i];
                }
                for &e in &rec.pred_raw {
                    dst.pred_raw[e as usize] += 1;
                }
                for &e in &rec.pred_res {
                    dst.pred_res[e as usize] += 1;
                }
            }
        }
    }

    /// Compose the prefill batch step for `seq_ids`.
    pub fn compose_prefill(&self, seq_ids: &[usize]) -> BatchStep {
        let mut out = BatchStep::default();
        self.compose_prefill_into(seq_ids, &mut out);
        out
    }

    /// Buffer-reusing form of [`Self::compose_prefill`].
    pub fn compose_prefill_into(&self, seq_ids: &[usize], out: &mut BatchStep) {
        out.reset(self.layers, self.n_routed);
        for &sid in seq_ids {
            let seq = &self.seqs[sid % self.seqs.len()];
            out.tokens += seq.prompt_len;
            for (l, rec) in seq.prefill.iter().enumerate() {
                let dst = &mut out.layers[l];
                for e in 0..self.n_routed {
                    dst.workloads[e] += rec.counts[e];
                    dst.gate_scores[e] += rec.gate_scores[e];
                    dst.pred_raw[e] += rec.pred_raw[e];
                    dst.pred_res[e] += rec.pred_res[e];
                }
            }
        }
    }
}

/// Synthetic routing trace with adjacent-step locality (no PJRT needed):
/// each sequence favours a slowly-drifting hot expert plus neighbours —
/// zipf-ish routing with the temporal locality the cache policies exploit.
/// Shared by the `expt ram` sweep, `dali bench`, and the throughput bench.
pub fn synthetic_locality_trace(
    layers: usize,
    n_routed: usize,
    top_k: usize,
    seqs: usize,
    steps: usize,
    seed: u64,
) -> Trace {
    let mut rng = crate::util::DetRng::new(seed);
    let mk_topk = |rng: &mut crate::util::DetRng, hot: usize| -> Vec<u16> {
        let mut picked: Vec<u16> = Vec::with_capacity(top_k);
        while picked.len() < top_k {
            let raw = if rng.chance(0.5) {
                (hot + rng.usize_below(2)) % n_routed
            } else {
                rng.usize_below(n_routed)
            };
            let e = raw as u16;
            if !picked.contains(&e) {
                picked.push(e);
            }
        }
        picked
    };
    let seqs = (0..seqs)
        .map(|s| {
            let mut hot = s % n_routed;
            let mut step_recs = Vec::with_capacity(steps);
            for _ in 0..steps {
                if rng.chance(0.1) {
                    hot = (hot + 1) % n_routed; // topic drift
                }
                let recs: Vec<LayerStepRecord> = (0..layers)
                    .map(|_| {
                        let topk = mk_topk(&mut rng, hot);
                        LayerStepRecord {
                            topk_scores: topk.iter().map(|_| 1.0 / top_k as f32).collect(),
                            pred_raw: topk.clone(),
                            pred_res: topk.clone(),
                            topk,
                            cos_raw: 0.8,
                            cos_res: 0.9,
                        }
                    })
                    .collect();
                step_recs.push(recs);
            }
            let pre = PrefillLayerRecord {
                counts: {
                    let mut c = vec![0u32; n_routed];
                    c[hot] = 4;
                    c
                },
                gate_scores: vec![0.25; n_routed],
                pred_raw: vec![1; n_routed],
                pred_res: vec![1; n_routed],
            };
            SeqTrace { prompt_len: 8, prefill: vec![pre; layers], steps: step_recs }
        })
        .collect();
    Trace {
        preset: "synthetic".into(),
        task: "locality".into(),
        n_routed,
        top_k,
        layers,
        seqs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> Trace {
        // 2 seqs, 1 layer, 4 experts, k=2, 2 decode steps
        let rec = |topk: Vec<u16>, pr: Vec<u16>, ps: Vec<u16>| LayerStepRecord {
            topk: topk.clone(),
            topk_scores: topk.iter().map(|_| 0.5).collect(),
            pred_raw: pr,
            pred_res: ps,
            cos_raw: 0.8,
            cos_res: 0.9,
        };
        let prefill = |counts: Vec<u32>| PrefillLayerRecord {
            gate_scores: counts.iter().map(|&c| c as f32 * 0.5).collect(),
            pred_raw: counts.clone(),
            pred_res: counts.clone(),
            counts,
        };
        Trace {
            preset: "t".into(),
            task: "t".into(),
            n_routed: 4,
            top_k: 2,
            layers: 1,
            seqs: vec![
                SeqTrace {
                    prompt_len: 3,
                    prefill: vec![prefill(vec![2, 1, 0, 0])],
                    steps: vec![
                        vec![rec(vec![0, 1], vec![0, 2], vec![0, 1])],
                        vec![rec(vec![1, 2], vec![1], vec![2])],
                    ],
                },
                SeqTrace {
                    prompt_len: 3,
                    prefill: vec![prefill(vec![0, 0, 2, 1])],
                    steps: vec![vec![rec(vec![0, 3], vec![3], vec![3])]],
                },
            ],
        }
    }

    #[test]
    fn compose_decode_sums_workloads() {
        let t = tiny_trace();
        let step = t.compose_decode(&[0, 1], 0);
        assert_eq!(step.tokens, 2);
        assert_eq!(step.layers[0].workloads, vec![2, 1, 0, 1]);
        assert_eq!(step.layers[0].pred_raw, vec![1, 0, 1, 1]);
        assert!((step.layers[0].gate_scores[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn compose_decode_skips_finished_seqs() {
        let t = tiny_trace();
        let step = t.compose_decode(&[0, 1], 1); // seq 1 has only 1 step
        assert_eq!(step.tokens, 1);
        assert_eq!(step.layers[0].workloads, vec![0, 1, 1, 0]);
    }

    #[test]
    fn compose_multi_matches_lockstep_at_equal_offsets() {
        let t = tiny_trace();
        let mut multi = BatchStep::default();
        t.compose_multi_into(&[(0, 0), (1, 0)], &mut multi);
        let lock = t.compose_decode(&[0, 1], 0);
        assert_eq!(multi.tokens, lock.tokens);
        assert_eq!(multi.layers[0].workloads, lock.layers[0].workloads);
        assert_eq!(multi.layers[0].pred_res, lock.layers[0].pred_res);
    }

    #[test]
    fn compose_multi_mixes_per_stream_offsets() {
        let t = tiny_trace();
        let mut step = BatchStep::default();
        // seq 0 at its step 1 ({1,2}) + seq 1 at its step 0 ({0,3})
        t.compose_multi_into(&[(0, 1), (1, 0)], &mut step);
        assert_eq!(step.tokens, 2);
        assert_eq!(step.layers[0].workloads, vec![1, 1, 1, 1]);
        // an exhausted stream contributes nothing
        t.compose_multi_into(&[(0, 1), (1, 7)], &mut step);
        assert_eq!(step.tokens, 1);
        assert_eq!(step.layers[0].workloads, vec![0, 1, 1, 0]);
    }

    #[test]
    fn compose_prefill_sums_counts() {
        let t = tiny_trace();
        let step = t.compose_prefill(&[0, 1]);
        assert_eq!(step.tokens, 6);
        assert_eq!(step.layers[0].workloads, vec![2, 1, 2, 1]);
    }

    #[test]
    fn seq_ids_wrap_around_pool() {
        let t = tiny_trace();
        let step = t.compose_decode(&[0, 2], 0); // 2 % 2 == 0 → seq 0 twice
        assert_eq!(step.layers[0].workloads, vec![2, 2, 0, 0]);
    }

    #[test]
    fn compose_into_reuse_matches_fresh_compose() {
        // Reusing one BatchStep across steps (the zero-allocation replay
        // path) must be indistinguishable from composing fresh each step.
        let t = tiny_trace();
        let mut reused = BatchStep::default();
        for step in 0..2 {
            t.compose_decode_into(&[0, 1], step, &mut reused);
            let fresh = t.compose_decode(&[0, 1], step);
            assert_eq!(reused.tokens, fresh.tokens);
            for l in 0..t.layers {
                assert_eq!(reused.layers[l].workloads, fresh.layers[l].workloads);
                assert_eq!(reused.layers[l].gate_scores, fresh.layers[l].gate_scores);
                assert_eq!(reused.layers[l].pred_raw, fresh.layers[l].pred_raw);
                assert_eq!(reused.layers[l].pred_res, fresh.layers[l].pred_res);
            }
        }
        // a prefill composed into the same (dirty) buffer is also clean
        t.compose_prefill_into(&[0, 1], &mut reused);
        let fresh = t.compose_prefill(&[0, 1]);
        assert_eq!(reused.tokens, fresh.tokens);
        assert_eq!(reused.layers[0].workloads, fresh.layers[0].workloads);
    }

    #[test]
    fn synthetic_trace_is_deterministic_and_shaped() {
        let a = synthetic_locality_trace(2, 8, 2, 4, 16, 0x7157);
        let b = synthetic_locality_trace(2, 8, 2, 4, 16, 0x7157);
        assert_eq!(a.seqs.len(), 4);
        assert_eq!(a.min_steps(), 16);
        for (sa, sb) in a.seqs.iter().zip(&b.seqs) {
            for (ra, rb) in sa.steps.iter().zip(&sb.steps) {
                for (la, lb) in ra.iter().zip(rb) {
                    assert_eq!(la.topk, lb.topk, "same seed must give same routing");
                    assert_eq!(la.topk.len(), 2);
                }
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let t = tiny_trace();
        let dir = crate::util::test_temp_dir("trace");
        let p = dir.join("trace.bin");
        t.save(&p).unwrap();
        let t2 = Trace::load(&p).unwrap();
        assert_eq!(t2.seqs.len(), 2);
        assert_eq!(t2.preset, t.preset);
        assert_eq!(t2.seqs[0].steps[0][0].topk, vec![0, 1]);
        assert_eq!(t2.seqs[0].prefill[0].counts, t.seqs[0].prefill[0].counts);
        assert!((t2.seqs[0].steps[0][0].cos_res - 0.9).abs() < 1e-6);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = crate::util::test_temp_dir("trace-bad");
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"not a trace").unwrap();
        assert!(Trace::load(&p).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
