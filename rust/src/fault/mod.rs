//! Deterministic fault injection: seeded hardware-perturbation processes
//! plus the bookkeeping the pipeline's graceful-degradation responses key
//! off.
//!
//! Real local PCs — the paper's target platform — are exactly the machines
//! where the simulator's perfect-hardware assumptions break: consumer NVMe
//! drives stall and retry, PCIe links renegotiate under contention, GPUs
//! thermal-throttle in small cases, and the OS reclaims host RAM from under
//! the process. This module makes those perturbations a first-class,
//! replay-locked axis of the reproduction:
//!
//! * [`FaultProfile`] — the perturbation parameters (all plain numbers,
//!   `Copy`). Named presets ship in code ([`FaultProfile::named`]) and in
//!   `configs/presets.json` (`fault_profiles`, parsed by [`crate::config`]):
//!   `clean`, `flaky-nvme`, `thermal`, `ram-pressure`.
//! * [`FaultPlan`] — a profile bound to a seed. Every query is a **pure
//!   function of `(seed, step, lane/expert, attempt)`** — no wall clock, no
//!   mutable RNG state — so the same `(seed, profile)` replays
//!   bit-identically (the `fault_property` chaos suite locks this via
//!   whole-run trace digests) and resuming from any step needs no replayed
//!   history.
//!
//! Perturbation processes and who responds to them:
//!
//! * **NVMe latency spikes + transient read failures** — consulted by
//!   `TieredStore::schedule_promotion` per read attempt. Failed attempts
//!   occupy the read lane for a timeout, back off exponentially in virtual
//!   time, and retry up to [`FaultProfile::max_retries`]; each retry
//!   surfaces as an `Event::FaultRetry`. Speculative promotions whose
//!   retries exhaust are *aborted* (`Event::FaultAbort`, expert stays on
//!   disk); demand promotions fall back to a final raw read that always
//!   succeeds, so execution can never deadlock. The inflated
//!   `read_free_at()` feeds the existing promote-ahead backlog gate, which
//!   throttles speculation on a sick drive for free.
//! * **PCIe bandwidth degradation windows** — step-periodic multiplier on
//!   PCIe transfer durations; priced into `AssignCtx` so Greedy Assignment
//!   reroutes load to the CPU instead of piling onto the degraded link.
//! * **GPU thermal throttle intervals** — step-periodic multiplier on GPU
//!   compute durations, applied to execution and priced into assignment.
//! * **Host-RAM budget shrink/restore** — step-periodic confiscation of a
//!   fraction of the host tier's slots; `TieredStore::apply_fault_step`
//!   demotes under the workload-aware score until the shrunken budget
//!   holds, with the conservation invariants intact throughout.
//!
//! Window phases are jittered per `(seed, process)` so distinct seeds
//! observe distinct schedules, while a fixed seed's schedule is immutable.
//! The clean profile is **transparent**: every query returns the neutral
//! value and the simulator takes today's exact code paths, so a
//! `--faults clean` run is bit-identical to an un-faulted one (locked in
//! `rust/tests/fault_property.rs`).

use anyhow::{bail, Result};

use crate::hw::Ns;

/// Perturbation parameters. All fields are plain numbers with neutral
/// defaults, so the default profile is exactly the clean machine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultProfile {
    /// Probability a given NVMe read *attempt* transiently fails.
    pub nvme_fail_prob: f64,
    /// Probability a successful NVMe read is a latency spike.
    pub nvme_slow_prob: f64,
    /// Duration multiplier for a spiked read (>= 1).
    pub nvme_slow_mult: f64,
    /// Retry cap after the first failed attempt. A speculative transfer
    /// whose `max_retries + 1` attempts all fail is aborted; a demand
    /// transfer falls back to a final raw read that always succeeds.
    pub max_retries: u32,
    /// A failed attempt occupies the read lane for `timeout_mult` x the
    /// clean read duration before it is declared stalled and retried.
    pub timeout_mult: f64,
    /// Backoff before retry `k` (1-based) is
    /// `backoff_mult * 2^(k-1)` x the clean read duration — virtual-time
    /// waiting that leaves the lane idle, not busy.
    pub backoff_mult: f64,
    /// PCIe degradation window: every `pcie_period` steps, `pcie_len`
    /// steps run with transfers slowed by `pcie_mult`. 0 disables.
    pub pcie_period: u64,
    pub pcie_len: u64,
    pub pcie_mult: f64,
    /// GPU thermal-throttle window (same shape as the PCIe window).
    pub gpu_period: u64,
    pub gpu_len: u64,
    pub gpu_mult: f64,
    /// Host-RAM pressure window: every `ram_period` steps, for `ram_len`
    /// steps, `ram_shrink_frac` of the host tier's slots are confiscated.
    pub ram_period: u64,
    pub ram_len: u64,
    pub ram_shrink_frac: f64,
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile {
            nvme_fail_prob: 0.0,
            nvme_slow_prob: 0.0,
            nvme_slow_mult: 1.0,
            max_retries: 3,
            timeout_mult: 3.0,
            backoff_mult: 1.0,
            pcie_period: 0,
            pcie_len: 0,
            pcie_mult: 1.0,
            gpu_period: 0,
            gpu_len: 0,
            gpu_mult: 1.0,
            ram_period: 0,
            ram_len: 0,
            ram_shrink_frac: 0.0,
        }
    }
}

impl FaultProfile {
    /// The perfect machine — every query neutral, pipeline code paths
    /// identical to an un-faulted run.
    pub fn clean() -> Self {
        FaultProfile::default()
    }

    /// Whether every perturbation process is disabled.
    pub fn is_clean(&self) -> bool {
        self.nvme_fail_prob <= 0.0
            && self.nvme_slow_prob <= 0.0
            && (self.pcie_period == 0 || self.pcie_len == 0 || self.pcie_mult <= 1.0)
            && (self.gpu_period == 0 || self.gpu_len == 0 || self.gpu_mult <= 1.0)
            && (self.ram_period == 0 || self.ram_len == 0 || self.ram_shrink_frac <= 0.0)
    }

    /// The built-in named profiles (mirrored in `configs/presets.json`
    /// under `fault_profiles`; the config loader falls back here so the
    /// names work without a presets file).
    pub fn named(name: &str) -> Option<FaultProfile> {
        match name {
            "clean" => Some(FaultProfile::clean()),
            // Consumer NVMe under sustained mixed load: transient command
            // failures plus long-tail latency spikes.
            "flaky-nvme" => Some(FaultProfile {
                nvme_fail_prob: 0.08,
                nvme_slow_prob: 0.20,
                nvme_slow_mult: 4.0,
                max_retries: 3,
                timeout_mult: 3.0,
                backoff_mult: 1.0,
                ..FaultProfile::default()
            }),
            // Small-case thermal cycling: GPU clocks drop and the PCIe
            // link renegotiates while the fans catch up.
            "thermal" => Some(FaultProfile {
                gpu_period: 24,
                gpu_len: 10,
                gpu_mult: 1.7,
                pcie_period: 36,
                pcie_len: 12,
                pcie_mult: 1.8,
                ..FaultProfile::default()
            }),
            // OS-level memory pressure: a third of the expert budget is
            // reclaimed periodically, then handed back.
            "ram-pressure" => Some(FaultProfile {
                ram_period: 32,
                ram_len: 12,
                ram_shrink_frac: 0.35,
                ..FaultProfile::default()
            }),
            _ => None,
        }
    }

    /// Parse an inline `key=value,key=value` spec (keys are the field
    /// names), starting from the clean profile. `dali run --faults` accepts
    /// either a profile name or this form.
    pub fn parse_spec(spec: &str) -> Result<FaultProfile> {
        let mut p = FaultProfile::clean();
        for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let (k, v) = match part.split_once('=') {
                Some(kv) => kv,
                None => bail!("fault spec '{part}': expected key=value"),
            };
            let (k, v) = (k.trim(), v.trim());
            let f = || -> Result<f64> {
                v.parse::<f64>().map_err(|_| anyhow::anyhow!("fault spec {k}: bad number '{v}'"))
            };
            let u = || -> Result<u64> {
                v.parse::<u64>().map_err(|_| anyhow::anyhow!("fault spec {k}: bad integer '{v}'"))
            };
            match k {
                "nvme_fail_prob" => p.nvme_fail_prob = f()?,
                "nvme_slow_prob" => p.nvme_slow_prob = f()?,
                "nvme_slow_mult" => p.nvme_slow_mult = f()?,
                "max_retries" => p.max_retries = u()? as u32,
                "timeout_mult" => p.timeout_mult = f()?,
                "backoff_mult" => p.backoff_mult = f()?,
                "pcie_period" => p.pcie_period = u()?,
                "pcie_len" => p.pcie_len = u()?,
                "pcie_mult" => p.pcie_mult = f()?,
                "gpu_period" => p.gpu_period = u()?,
                "gpu_len" => p.gpu_len = u()?,
                "gpu_mult" => p.gpu_mult = f()?,
                "ram_period" => p.ram_period = u()?,
                "ram_len" => p.ram_len = u()?,
                "ram_shrink_frac" => p.ram_shrink_frac = f()?,
                other => bail!("fault spec: unknown key '{other}'"),
            }
        }
        p.validate()?;
        Ok(p)
    }

    /// Reject degenerate parameterizations that would produce silent
    /// nonsense (negative probabilities, shrink > whole budget, sub-unit
    /// slowdowns posing as faults).
    pub fn validate(&self) -> Result<()> {
        let prob = |name: &str, v: f64| -> Result<()> {
            if !(0.0..=1.0).contains(&v) {
                bail!("fault profile: {name} must be in [0, 1], got {v}");
            }
            Ok(())
        };
        prob("nvme_fail_prob", self.nvme_fail_prob)?;
        prob("nvme_slow_prob", self.nvme_slow_prob)?;
        prob("ram_shrink_frac", self.ram_shrink_frac)?;
        let mult = |name: &str, v: f64| -> Result<()> {
            if !(v >= 1.0 && v.is_finite()) {
                bail!("fault profile: {name} must be >= 1, got {v}");
            }
            Ok(())
        };
        mult("nvme_slow_mult", self.nvme_slow_mult)?;
        mult("timeout_mult", self.timeout_mult)?;
        mult("pcie_mult", self.pcie_mult)?;
        mult("gpu_mult", self.gpu_mult)?;
        if !(self.backoff_mult >= 0.0 && self.backoff_mult.is_finite()) {
            bail!("fault profile: backoff_mult must be >= 0, got {}", self.backoff_mult);
        }
        let window = |name: &str, period: u64, len: u64| -> Result<()> {
            if period > 0 && len > period {
                bail!("fault profile: {name} window len {len} exceeds period {period}");
            }
            Ok(())
        };
        window("pcie", self.pcie_period, self.pcie_len)?;
        window("gpu", self.gpu_period, self.gpu_len)?;
        window("ram", self.ram_period, self.ram_len)?;
        Ok(())
    }
}

/// Outcome of the NVMe fault ledger for one read transfer: how many
/// attempts fail before it either succeeds or (speculative only) aborts.
/// Computed *synchronously at issue time* — the plan is pure, so the whole
/// retry history of a transfer is a deterministic function of its identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadFaults {
    /// Failed attempts charged before the outcome (each occupies the lane
    /// for the timeout and backs off exponentially before the next).
    pub failures: u32,
    /// All `max_retries + 1` attempts failed. Speculative transfers abort;
    /// demand transfers fall back to a final raw read that succeeds.
    pub exhausted: bool,
    /// Duration multiplier of the successful attempt (latency spike).
    pub slow_mult: f64,
}

impl ReadFaults {
    pub const NONE: ReadFaults = ReadFaults { failures: 0, exhausted: false, slow_mult: 1.0 };
}

/// A [`FaultProfile`] bound to a seed: the deterministic perturbation
/// schedule. Cheap to copy; the store and the simulator each hold one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    profile: FaultProfile,
    seed: u64,
}

/// splitmix64 finalizer — the same mixer `DetRng` builds on; full-period,
/// platform-independent.
#[inline]
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Draw a Bernoulli with exact-in-f64 53-bit resolution from one hash word.
#[inline]
fn hit(h: u64, prob: f64) -> bool {
    prob > 0.0 && ((h >> 11) as f64) < prob * (1u64 << 53) as f64
}

impl FaultPlan {
    pub fn new(profile: FaultProfile, seed: u64) -> Self {
        FaultPlan { profile, seed }
    }

    pub fn profile(&self) -> &FaultProfile {
        &self.profile
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Whether this plan perturbs anything at all.
    pub fn is_clean(&self) -> bool {
        self.profile.is_clean()
    }

    #[inline]
    fn hash(&self, domain: u64, a: u64, b: u64, c: u64) -> u64 {
        mix(self.seed ^ mix(domain ^ mix(a ^ mix(b ^ mix(c)))))
    }

    /// Step-periodic window test with a seed-jittered phase per process.
    #[inline]
    fn in_window(&self, domain: u64, step: u64, period: u64, len: u64) -> bool {
        if period == 0 || len == 0 {
            return false;
        }
        let phase = mix(self.seed ^ domain) % period;
        (step.wrapping_add(phase)) % period < len
    }

    /// Salt a window domain with a GPU device index. Device 0 uses the
    /// base domain **unchanged** — its schedule is bit-identical to the
    /// pre-multi-GPU single-device schedule, which the `num_gpus = 1`
    /// digest-backcompat lock depends on. Higher devices shift the salt
    /// into bits the base domains (16-bit ASCII tags) never occupy, so
    /// each device observes an independently-phased window.
    #[inline]
    fn dev_domain(domain: u64, device: u8) -> u64 {
        domain ^ ((device as u64) << 16)
    }

    /// GPU compute-duration multiplier for `step` (1.0 = full clocks).
    /// Single-device view: equals [`Self::gpu_mult_dev`] on device 0.
    #[inline]
    pub fn gpu_mult(&self, step: u64) -> f64 {
        self.gpu_mult_dev(step, 0)
    }

    /// GPU compute-duration multiplier for `step` on GPU `device`. Thermal
    /// throttle is per-card (airflow, silicon lottery), so each device gets
    /// its own seed-jittered window phase; device 0 reproduces the
    /// pre-refactor single-GPU schedule exactly.
    #[inline]
    pub fn gpu_mult_dev(&self, step: u64, device: u8) -> f64 {
        let p = &self.profile;
        if p.gpu_mult > 1.0
            && self.in_window(Self::dev_domain(0x6770, device), step, p.gpu_period, p.gpu_len)
        {
            p.gpu_mult
        } else {
            1.0
        }
    }

    /// PCIe transfer-duration multiplier for `step` (1.0 = full link).
    /// Single-device view: equals [`Self::pcie_mult_dev`] on device 0.
    #[inline]
    pub fn pcie_mult(&self, step: u64) -> f64 {
        self.pcie_mult_dev(step, 0)
    }

    /// PCIe transfer-duration multiplier for `step` on the link feeding GPU
    /// `device` (each card sits on its own root-port link, so degradation
    /// windows are per-device). Device 0 reproduces the pre-refactor
    /// single-link schedule exactly.
    #[inline]
    pub fn pcie_mult_dev(&self, step: u64, device: u8) -> f64 {
        let p = &self.profile;
        if p.pcie_mult > 1.0
            && self.in_window(Self::dev_domain(0x7063, device), step, p.pcie_period, p.pcie_len)
        {
            p.pcie_mult
        } else {
            1.0
        }
    }

    /// Host-tier slots confiscated at `step` out of a `host_slots` budget.
    #[inline]
    pub fn ram_reserved(&self, step: u64, host_slots: usize) -> usize {
        let p = &self.profile;
        if p.ram_shrink_frac <= 0.0 || !self.in_window(0x7261, step, p.ram_period, p.ram_len) {
            return 0;
        }
        ((host_slots as f64) * p.ram_shrink_frac) as usize
    }

    /// The complete NVMe fault ledger for one read transfer identified by
    /// `(step, layer, expert)`: per-attempt failure draws walked until the
    /// first success or until all `max_retries + 1` attempts fail, plus the
    /// latency-spike draw for the successful attempt.
    pub fn read_faults(&self, step: u64, layer: usize, expert: usize) -> ReadFaults {
        let p = &self.profile;
        if p.nvme_fail_prob <= 0.0 && p.nvme_slow_prob <= 0.0 {
            return ReadFaults::NONE;
        }
        let id = ((layer as u64) << 32) | expert as u64;
        let attempts = p.max_retries + 1;
        let mut failures = 0u32;
        while failures < attempts {
            let h = self.hash(0x6661, step, id, failures as u64);
            if !hit(h, p.nvme_fail_prob) {
                break;
            }
            failures += 1;
        }
        let exhausted = failures == attempts;
        let slow = self.hash(0x736c, step, id, failures as u64);
        let slow_mult =
            if p.nvme_slow_mult > 1.0 && hit(slow, p.nvme_slow_prob) { p.nvme_slow_mult } else { 1.0 };
        ReadFaults { failures, exhausted, slow_mult }
    }

    /// Lane time one failed attempt occupies (the per-transfer timeout).
    #[inline]
    pub fn timeout_ns(&self, read_dur: Ns) -> Ns {
        scale_ns(read_dur, self.profile.timeout_mult)
    }

    /// Virtual-time backoff before retry `k` (1-based): exponential, priced
    /// as lane-idle waiting.
    #[inline]
    pub fn backoff_ns(&self, read_dur: Ns, k: u32) -> Ns {
        let base = scale_ns(read_dur, self.profile.backoff_mult);
        base.saturating_mul(1u64 << (k.saturating_sub(1)).min(16))
    }
}

/// Scale a virtual duration by a fault multiplier. Exactly identity at 1.0
/// (the clean path stays bit-identical, not merely close).
#[inline]
pub fn scale_ns(d: Ns, mult: f64) -> Ns {
    if mult == 1.0 {
        d
    } else {
        (d as f64 * mult) as Ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profile_is_clean_and_named_profiles_resolve() {
        assert!(FaultProfile::default().is_clean());
        assert!(FaultProfile::named("clean").unwrap().is_clean());
        for name in ["flaky-nvme", "thermal", "ram-pressure"] {
            let p = FaultProfile::named(name).unwrap();
            assert!(!p.is_clean(), "{name} must perturb something");
            p.validate().unwrap();
        }
        assert!(FaultProfile::named("no-such-profile").is_none());
    }

    #[test]
    fn plan_queries_are_pure_functions_of_identity() {
        let p = FaultProfile::named("flaky-nvme").unwrap();
        let a = FaultPlan::new(p, 17);
        let b = FaultPlan::new(p, 17);
        for step in 0..64u64 {
            for e in 0..8usize {
                assert_eq!(a.read_faults(step, 1, e), b.read_faults(step, 1, e));
            }
            assert_eq!(a.gpu_mult(step), b.gpu_mult(step));
            assert_eq!(a.pcie_mult(step), b.pcie_mult(step));
            assert_eq!(a.ram_reserved(step, 40), b.ram_reserved(step, 40));
        }
        // a different seed sees a different schedule
        let c = FaultPlan::new(p, 18);
        let differs = (0..256u64).any(|s| a.read_faults(s, 0, 0) != c.read_faults(s, 0, 0));
        assert!(differs, "seeds must decorrelate the failure schedule");
    }

    #[test]
    fn clean_plan_is_neutral_everywhere() {
        let plan = FaultPlan::new(FaultProfile::clean(), 99);
        assert!(plan.is_clean());
        for step in 0..32u64 {
            assert_eq!(plan.read_faults(step, 0, 3), ReadFaults::NONE);
            assert_eq!(plan.gpu_mult(step), 1.0);
            assert_eq!(plan.pcie_mult(step), 1.0);
            assert_eq!(plan.ram_reserved(step, 100), 0);
        }
    }

    #[test]
    fn read_faults_respect_the_retry_cap() {
        let mut p = FaultProfile::named("flaky-nvme").unwrap();
        p.nvme_fail_prob = 1.0; // every attempt fails
        let plan = FaultPlan::new(p, 1);
        let r = plan.read_faults(0, 0, 0);
        assert!(r.exhausted);
        assert_eq!(r.failures, p.max_retries + 1);
        p.nvme_fail_prob = 0.0;
        let plan = FaultPlan::new(p, 1);
        assert_eq!(plan.read_faults(0, 0, 0).failures, 0);
    }

    #[test]
    fn failure_rate_tracks_the_configured_probability() {
        let mut p = FaultProfile::clean();
        p.nvme_fail_prob = 0.25;
        p.max_retries = 0; // one attempt: failures is a plain Bernoulli
        let plan = FaultPlan::new(p, 7);
        let n = 4000u64;
        let fails =
            (0..n).filter(|&s| plan.read_faults(s, 0, 0).failures > 0).count() as f64 / n as f64;
        assert!((fails - 0.25).abs() < 0.03, "observed failure rate {fails}");
    }

    #[test]
    fn windows_cover_the_configured_fraction() {
        let p = FaultProfile::named("thermal").unwrap();
        let plan = FaultPlan::new(p, 3);
        let n = p.gpu_period * 100;
        let hot = (0..n).filter(|&s| plan.gpu_mult(s) > 1.0).count() as u64;
        assert_eq!(hot, p.gpu_len * 100, "throttle duty cycle is exact");
        // within one period the window is contiguous (mod wraparound)
        let first: Vec<bool> = (0..p.gpu_period).map(|s| plan.gpu_mult(s) > 1.0).collect();
        let edges = (0..first.len())
            .filter(|&i| first[i] != first[(i + 1) % first.len()])
            .count();
        assert_eq!(edges, 2, "one contiguous window per period");
    }

    #[test]
    fn device_windows_decorrelate_but_device_zero_matches_the_scalar_view() {
        let p = FaultProfile::named("thermal").unwrap();
        let plan = FaultPlan::new(p, 11);
        for step in 0..(p.gpu_period * 4) {
            // the scalar queries are exactly the device-0 views — the
            // num_gpus = 1 digest lock rides on this identity
            assert_eq!(plan.gpu_mult(step), plan.gpu_mult_dev(step, 0));
            assert_eq!(plan.pcie_mult(step), plan.pcie_mult_dev(step, 0));
        }
        // each device keeps the exact duty cycle but on its own phase
        let n = p.gpu_period * 100;
        for d in 0..4u8 {
            let hot = (0..n).filter(|&s| plan.gpu_mult_dev(s, d) > 1.0).count() as u64;
            assert_eq!(hot, p.gpu_len * 100, "device {d} duty cycle is exact");
        }
        let decorrelated = (0..n).any(|s| {
            (plan.gpu_mult_dev(s, 0) > 1.0) != (plan.gpu_mult_dev(s, 1) > 1.0)
                || (plan.pcie_mult_dev(s, 0) > 1.0) != (plan.pcie_mult_dev(s, 2) > 1.0)
        });
        assert!(decorrelated, "devices must not throttle in lockstep");
        // purity holds per device too
        let again = FaultPlan::new(p, 11);
        for s in 0..64u64 {
            for d in 0..4u8 {
                assert_eq!(plan.gpu_mult_dev(s, d), again.gpu_mult_dev(s, d));
                assert_eq!(plan.pcie_mult_dev(s, d), again.pcie_mult_dev(s, d));
            }
        }
    }

    #[test]
    fn backoff_grows_exponentially_and_timeout_scales() {
        let p = FaultProfile::named("flaky-nvme").unwrap();
        let plan = FaultPlan::new(p, 5);
        let d = 1_000_000;
        assert_eq!(plan.timeout_ns(d), 3_000_000);
        assert_eq!(plan.backoff_ns(d, 1), d);
        assert_eq!(plan.backoff_ns(d, 2), 2 * d);
        assert_eq!(plan.backoff_ns(d, 3), 4 * d);
    }

    #[test]
    fn spec_parsing_round_trips_and_rejects_junk() {
        let p = FaultProfile::parse_spec("nvme_fail_prob=0.1,max_retries=2,gpu_period=8,gpu_len=2,gpu_mult=1.5").unwrap();
        assert_eq!(p.nvme_fail_prob, 0.1);
        assert_eq!(p.max_retries, 2);
        assert_eq!(p.gpu_mult, 1.5);
        assert!(!p.is_clean());
        assert!(FaultProfile::parse_spec("").unwrap().is_clean());
        assert!(FaultProfile::parse_spec("bogus_key=1").is_err());
        assert!(FaultProfile::parse_spec("nvme_fail_prob").is_err());
        assert!(FaultProfile::parse_spec("nvme_fail_prob=2.0").is_err(), "prob > 1 rejected");
        assert!(FaultProfile::parse_spec("gpu_mult=0.5").is_err(), "sub-unit mult rejected");
        assert!(FaultProfile::parse_spec("ram_period=4,ram_len=9").is_err(), "len > period");
    }

    #[test]
    fn scale_ns_is_identity_at_one() {
        assert_eq!(scale_ns(12345, 1.0), 12345);
        assert_eq!(scale_ns(1000, 2.5), 2500);
        assert_eq!(scale_ns(0, 7.0), 0);
    }
}
